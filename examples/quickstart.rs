//! Quickstart: build an Ising grid, run relaxed residual BP on several
//! threads, and read out marginals.
//!
//!     cargo run --release --example quickstart

use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::run::run_config;

fn main() -> anyhow::Result<()> {
    // A 100×100 Ising model with random couplings (seeded, reproducible).
    let cfg = RunConfig::new(ModelSpec::Ising { n: 100 }, AlgorithmSpec::RelaxedResidual)
        .with_threads(4)
        .with_seed(42);

    let report = run_config(&cfg)?;
    let m = &report.stats.metrics.total;
    println!("converged      : {}", report.stats.converged);
    println!("wall time      : {:.3} s", report.stats.wall_secs);
    println!("updates        : {} ({} useful)", m.updates, m.useful_updates);
    println!("wasted pops    : {}", m.wasted_pops);
    println!(
        "throughput     : {:.0} updates/s",
        m.updates as f64 / report.stats.wall_secs
    );

    // Beliefs for a few variables.
    let marginals = report.marginals();
    for (i, p) in marginals.iter().enumerate().take(5) {
        println!("P(X_{i} = +1) = {:.4}", p[1]);
    }
    Ok(())
}
