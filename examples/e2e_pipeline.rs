//! END-TO-END SYSTEM DRIVER — proves all three layers compose on a real
//! workload (the paper's headline experiment in miniature):
//!
//!   1. L1/L2 artifacts (Pallas kernel + JAX sweep, AOT-compiled by
//!      `make artifacts`) are loaded through the PJRT CPU client;
//!   2. the L3 Rust coordinator runs the full §5.1 roster on an Ising
//!      grid and an LDPC decode, multithreaded, to convergence;
//!   3. relaxed vs exact update overhead (Table 3's metric) and the
//!      relaxed-vs-best-non-relaxed speedup (Table 4's metric) are
//!      computed and printed;
//!   4. results are appended to results/e2e_pipeline.csv.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline

use relaxed_bp::bp::{decode_bits, Messages};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::builders::{self, ldpc};
use relaxed_bp::runtime::artifacts_dir;

struct Cell {
    alg: String,
    time: f64,
    updates: u64,
    converged: bool,
}

fn run_cell(
    mrf: &relaxed_bp::model::Mrf,
    spec: &ModelSpec,
    alg: AlgorithmSpec,
    threads: usize,
    use_pjrt: bool,
) -> anyhow::Result<(Cell, Messages)> {
    let msgs = Messages::uniform(mrf);
    let mut cfg = RunConfig::new(spec.clone(), alg.clone())
        .with_threads(threads)
        .with_seed(42);
    cfg.use_pjrt = use_pjrt;
    let stats = build_engine(&alg).run(mrf, &msgs, &cfg)?;
    Ok((
        Cell {
            alg: alg.name(),
            time: stats.wall_secs,
            updates: stats.metrics.total.updates,
            converged: stats.converged,
        },
        msgs,
    ))
}

fn main() -> anyhow::Result<()> {
    let have_artifacts = artifacts_dir().join("grid_step_64.hlo.txt").exists();
    println!("=== relaxed-bp end-to-end pipeline ===");
    println!("artifacts present: {have_artifacts} (dir: {})\n", artifacts_dir().display());

    // ---------- Stage 1: Ising grid, full roster ----------
    let spec = ModelSpec::Ising { n: 64 };
    let mrf = builders::build(&spec, 42);
    println!(
        "[1] Ising 64×64 ({} messages), ε = 1e-5",
        mrf.num_messages()
    );
    let mut cells: Vec<Cell> = Vec::new();
    let (seq, _) = run_cell(&mrf, &spec, AlgorithmSpec::SequentialResidual, 1, false)?;
    let baseline_time = seq.time;
    let baseline_updates = seq.updates;
    cells.push(seq);
    for (alg, threads, pjrt) in [
        (AlgorithmSpec::Synchronous, 4, false),
        (AlgorithmSpec::Synchronous, 1, have_artifacts), // PJRT sweep path
        (AlgorithmSpec::CoarseGrained, 4, false),
        (AlgorithmSpec::RelaxedResidual, 4, false),
        (AlgorithmSpec::WeightDecay, 4, false),
        (AlgorithmSpec::Priority, 4, false),
        (AlgorithmSpec::Splash { h: 2 }, 4, false),
        (AlgorithmSpec::RelaxedSmartSplash { h: 2 }, 4, false),
        (AlgorithmSpec::RandomSplash { h: 2 }, 4, false),
        (AlgorithmSpec::RelaxedResidualBatched { batch: 64 }, 2, have_artifacts),
    ] {
        let (cell, _) = run_cell(&mrf, &spec, alg, threads, pjrt)?;
        cells.push(cell);
    }
    println!(
        "{:32} {:>9} {:>10} {:>9} {:>9}",
        "algorithm", "time(s)", "updates", "speedup", "upd.ratio"
    );
    for c in &cells {
        println!(
            "{:32} {:>9.3} {:>10} {:>8.2}x {:>8.3}x{}",
            c.alg,
            c.time,
            c.updates,
            baseline_time / c.time,
            c.updates as f64 / baseline_updates as f64,
            if c.converged { "" } else { "  (DNF)" }
        );
    }

    // ---------- Stage 2: LDPC decode ----------
    println!("\n[2] (3,6)-LDPC decode, 3000 vars, ε_channel = 0.07");
    let inst = ldpc::build(3000, 0.07, 42);
    let lspec = ModelSpec::Ldpc { n: 3000, flip_prob: 0.07 };
    let channel_errs: usize = inst.received.iter().map(|&b| b as usize).sum();
    for alg in [
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::Synchronous,
    ] {
        let threads = if alg == AlgorithmSpec::SequentialResidual { 1 } else { 4 };
        let (cell, msgs) = run_cell(&inst.mrf, &lspec, alg, threads, false)?;
        let errs = decode_bits(&inst.mrf, &msgs, inst.num_vars)
            .iter()
            .zip(&inst.sent)
            .filter(|(a, b)| a != b)
            .count();
        println!(
            "{:32} {:>9.3}s {:>10} updates, {} → {} bit errors {}",
            cell.alg,
            cell.time,
            cell.updates,
            channel_errs,
            errs,
            if errs == 0 { "✓ decoded" } else { "✗" }
        );
        assert_eq!(errs, 0, "decode must succeed below threshold");
    }

    // ---------- Stage 3: relaxation overhead (Table 3 metric) ----------
    println!("\n[3] relaxation overhead: relaxed residual vs exact baseline");
    for p in [1usize, 2, 4, 8] {
        let (cell, _) = run_cell(&mrf, &spec, AlgorithmSpec::RelaxedResidual, p, false)?;
        println!(
            "  p={p}: {:+.2}% extra updates",
            100.0 * (cell.updates as f64 / baseline_updates as f64 - 1.0)
        );
    }

    // ---------- CSV ----------
    std::fs::create_dir_all("results")?;
    let mut csv = String::from("algorithm,time_secs,updates,converged\n");
    for c in &cells {
        csv.push_str(&format!("{},{},{},{}\n", c.alg, c.time, c.updates, c.converged));
    }
    std::fs::write("results/e2e_pipeline.csv", csv)?;
    println!("\nwrote results/e2e_pipeline.csv — all stages passed ✓");
    Ok(())
}
