//! Exactness on trees: BP marginals must match brute-force enumeration,
//! and the Appendix-A optimal schedule must do the minimum number of
//! updates (2·(n−1)) while the relaxed version wastes only O(q²·H).
//!
//!     cargo run --release --example tree_marginals

use relaxed_bp::bp::{all_marginals, exact_marginals, max_marginal_diff, Messages};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::builders;

fn main() -> anyhow::Result<()> {
    let spec = ModelSpec::Tree { n: 15 };
    let mrf = builders::build(&spec, 1);

    // Reference: exhaustive enumeration of all 2^15 assignments.
    let exact = exact_marginals(&mrf, 1 << 20).expect("tree small enough to enumerate");

    for alg in [
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::OptimalTree,
        AlgorithmSpec::RelaxedOptimalTree,
        AlgorithmSpec::RelaxedResidual,
    ] {
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), alg.clone()).with_threads(2);
        let stats = build_engine(&alg).run(&mrf, &msgs, &cfg)?;
        let bp = all_marginals(&mrf, &msgs);
        let diff = max_marginal_diff(&bp, &exact);
        println!(
            "{:24} converged={} updates={:4} useful={:4} max|BP-exact|={:.2e}",
            alg.name(),
            stats.converged,
            stats.metrics.total.updates,
            stats.metrics.total.useful_updates,
            diff
        );
        assert!(diff < 1e-6, "BP must be exact on trees");
    }
    println!("all schedules exact on the tree ✓");
    Ok(())
}
