//! LDPC decoding — the paper's flagship application (§5.2): decode a
//! (3,6)-LDPC codeword sent through a binary symmetric channel, comparing
//! schedulers on wall-clock, update count, and bit-error rate.
//!
//!     cargo run --release --example ldpc_decoding [n_vars] [flip_prob]

use relaxed_bp::bp::{decode_bits, Messages};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::builders::ldpc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3000);
    let eps_ch: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0.07);

    println!("(3,6)-LDPC: {n} variables, BSC flip probability {eps_ch}");
    let inst = ldpc::build(n, eps_ch, 42);
    let channel_errors: usize = inst.received.iter().map(|&b| b as usize).sum();
    println!("channel introduced {channel_errors} bit errors\n");
    println!(
        "{:28} {:>9} {:>12} {:>10} {:>8}",
        "algorithm", "time (s)", "updates", "bit errors", "ok"
    );

    for alg in [
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::Synchronous,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::WeightDecay,
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
    ] {
        let msgs = Messages::uniform(&inst.mrf);
        let threads = if alg == AlgorithmSpec::SequentialResidual { 1 } else { 4 };
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n, flip_prob: eps_ch },
            alg.clone(),
        )
        .with_threads(threads)
        .with_seed(42);
        let stats = build_engine(&alg).run(&inst.mrf, &msgs, &cfg)?;
        let decoded = decode_bits(&inst.mrf, &msgs, inst.num_vars);
        let errors = decoded
            .iter()
            .zip(&inst.sent)
            .filter(|(a, b)| a != b)
            .count();
        println!(
            "{:28} {:>9.3} {:>12} {:>10} {:>8}",
            alg.name(),
            stats.wall_secs,
            stats.metrics.total.updates,
            errors,
            if errors == 0 && stats.converged { "✓" } else { "✗" }
        );
    }
    Ok(())
}
