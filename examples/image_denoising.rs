//! Binary image denoising with a hand-built grid MRF — the classic loopy
//! BP application, exercising the *public model-construction API* rather
//! than the canned generators: node evidence from noisy pixels, smoothness
//! edge factors, inference with relaxed residual BP.
//!
//!     cargo run --release --example image_denoising [side] [noise]

use relaxed_bp::bp::{decode_bits, Messages};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::{FactorPool, GraphBuilder, Mrf, NodeFactors};
use relaxed_bp::util::Xoshiro256;

/// Ground truth: a filled disc on an n×n canvas.
fn disc_image(n: usize) -> Vec<u8> {
    let c = n as f64 / 2.0;
    let r2 = (n as f64 * 0.3).powi(2);
    (0..n * n)
        .map(|i| {
            let (y, x) = ((i / n) as f64, (i % n) as f64);
            (((x - c).powi(2) + (y - c).powi(2)) < r2) as u8
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let noise: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0.15);

    let truth = disc_image(n);
    let mut rng = Xoshiro256::seed_from_u64(7);
    let noisy: Vec<u8> = truth
        .iter()
        .map(|&b| if rng.bernoulli(noise) { 1 - b } else { b })
        .collect();
    let noisy_errors = noisy.iter().zip(&truth).filter(|(a, b)| a != b).count();

    // ---- Build the MRF through the public API ----
    let mut gb = GraphBuilder::new(n * n);
    let mut edge_count = 0;
    for r in 0..n {
        for c in 0..n {
            if c + 1 < n {
                gb.add_edge(r * n + c, r * n + c + 1);
                edge_count += 1;
            }
            if r + 1 < n {
                gb.add_edge(r * n + c, (r + 1) * n + c);
                edge_count += 1;
            }
        }
    }
    let mut pool = FactorPool::new();
    // Smoothness prior: neighboring pixels agree with odds 2:1.
    let smooth = pool.add(2, 2, &[2.0, 1.0, 1.0, 2.0]);
    // Evidence: observed pixel is correct with probability 1-noise.
    let factors: Vec<Vec<f64>> = noisy
        .iter()
        .map(|&b| {
            if b == 0 {
                vec![1.0 - noise, noise]
            } else {
                vec![noise, 1.0 - noise]
            }
        })
        .collect();
    let mrf = Mrf::assemble(
        "denoise",
        gb.build(),
        vec![2; n * n],
        NodeFactors::from_vecs(&factors),
        vec![smooth; edge_count],
        pool,
    );

    // ---- Inference ----
    let msgs = Messages::uniform(&mrf);
    let alg = AlgorithmSpec::RelaxedResidual;
    let cfg = RunConfig::new(ModelSpec::Ising { n }, alg.clone())
        .with_threads(4)
        .with_epsilon(1e-4);
    let stats = build_engine(&alg).run(&mrf, &msgs, &cfg)?;

    let denoised = decode_bits(&mrf, &msgs, n * n);
    let remaining = denoised.iter().zip(&truth).filter(|(a, b)| a != b).count();

    println!("{n}×{n} image, noise {noise}");
    println!("noisy pixels wrong    : {noisy_errors}");
    println!("after BP denoising    : {remaining}");
    println!(
        "inference             : {:.3} s, {} updates, converged={}",
        stats.wall_secs,
        stats.metrics.total.updates,
        stats.converged
    );
    assert!(
        remaining < noisy_errors / 2,
        "denoising should fix most noise"
    );
    // ASCII peek at the center rows.
    for r in (n / 2 - 2)..(n / 2 + 2) {
        let row: String = (0..n)
            .map(|c| if denoised[r * n + c] == 1 { '#' } else { '.' })
            .collect();
        println!("{row}");
    }
    Ok(())
}
