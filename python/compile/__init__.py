"""Build-time compile path: L2 JAX models + L1 Pallas kernels -> HLO text.

Never imported at runtime; the Rust binary consumes only the emitted
artifacts/*.hlo.txt files through PJRT.
"""
