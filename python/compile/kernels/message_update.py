"""L1: the batched binary message-update Pallas kernel.

The compute hot-spot of belief propagation on binary models is the dense
per-message "apply edge factor + normalize + residual" step:

    new[b, j] = normalize_j( sum_i prod[b, i] * psi[b, i, j] )
    res[b]    = || new[b, :] - cur[b, :] ||_2

This kernel processes the batch in VMEM-sized tiles of `block` messages.
On TPU the [block, 2] x [block, 2, 2] batched matvec maps onto the VPU
(too narrow for the MXU; see DESIGN.md section Hardware-Adaptation for the
roofline discussion) with the HBM->VMEM schedule expressed by the
BlockSpecs below. On CPU the kernel MUST run with interpret=True: real
TPU lowering emits a Mosaic custom-call that the CPU PJRT plugin cannot
execute.

Correctness oracle: kernels.ref.ref_batched_update (pure jnp), enforced by
python/tests/test_kernel.py across hypothesis-driven shape/value sweeps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: 64 messages x 2 states = one f32 VMEM tile lane-pair on
# TPU-like 8x128 vector registers; also divides every artifact batch size.
DEFAULT_BLOCK = 64


def _update_kernel(prod_ref, psi_ref, cur_ref, new_ref, res_ref):
    """Kernel body over one [block] tile of messages."""
    prod = prod_ref[...]          # [block, 2]
    psi = psi_ref[...]            # [block, 2, 2]
    cur = cur_ref[...]            # [block, 2]

    # Batched 1x2 @ 2x2 matvec, unrolled over the tiny state dimension so
    # the compiler sees pure [block]-wide vector ops (VPU-friendly).
    un0 = prod[:, 0] * psi[:, 0, 0] + prod[:, 1] * psi[:, 1, 0]
    un1 = prod[:, 0] * psi[:, 0, 1] + prod[:, 1] * psi[:, 1, 1]
    z = un0 + un1
    safe = z > 0.0
    zinv = jnp.where(safe, 1.0 / jnp.where(safe, z, 1.0), 0.0)
    n0 = jnp.where(safe, un0 * zinv, 0.5)
    n1 = jnp.where(safe, un1 * zinv, 0.5)

    d0 = n0 - cur[:, 0]
    d1 = n1 - cur[:, 1]
    res_ref[...] = jnp.sqrt(d0 * d0 + d1 * d1)
    new_ref[...] = jnp.stack([n0, n1], axis=-1)


@functools.partial(jax.jit, static_argnames=("block",))
def batched_update(prod, psi, cur, block=DEFAULT_BLOCK):
    """Pallas-backed batched update; pads the batch to a tile multiple.

    Args/returns as kernels.ref.ref_batched_update.
    """
    b = prod.shape[0]
    bt = min(block, b) if b > 0 else block
    pad = (-b) % bt
    if pad:
        # Identity lanes: psi = I, prod = cur = uniform -> res 0.
        prod = jnp.concatenate([prod, jnp.full((pad, 2), 0.5, prod.dtype)])
        eye = jnp.broadcast_to(jnp.eye(2, dtype=psi.dtype), (pad, 2, 2))
        psi = jnp.concatenate([psi, eye])
        cur = jnp.concatenate([cur, jnp.full((pad, 2), 0.5, cur.dtype)])
    total = prod.shape[0]
    grid = (total // bt,)

    new, res = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, 2), lambda i: (i, 0)),
            pl.BlockSpec((bt, 2, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, 2), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total, 2), prod.dtype),
            jax.ShapeDtypeStruct((total,), prod.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(prod, psi, cur)
    return new[:b], res[:b]


def vmem_bytes(block=DEFAULT_BLOCK, dtype_bytes=4):
    """Estimated VMEM working set per tile (for DESIGN.md's roofline
    accounting): prod + psi + cur + new + res."""
    per_msg = (2 + 4 + 2 + 2 + 1) * dtype_bytes
    return block * per_msg
