"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `ref_*` counterpart to float32 tolerance across randomized
shape/value sweeps (see python/tests/). They are also used directly by the
L2 model as the fallback implementation when a kernel is disabled.
"""

import jax.numpy as jnp


def ref_batched_update(prod, psi, cur):
    """Batched binary message update.

    Args:
      prod: [B, 2] gather products psi_i(x_i) * prod mu_{k->i}(x_i)
        (precomputed by the Rust coordinator).
      psi:  [B, 2, 2] edge factor matrices psi(x_i, x_j).
      cur:  [B, 2] current message values.

    Returns:
      (new, res): normalized updated messages [B, 2] and L2 residuals [B].
    """
    un = jnp.einsum("bi,bij->bj", prod, psi)
    z = jnp.sum(un, axis=-1, keepdims=True)
    new = jnp.where(z > 0, un / jnp.where(z > 0, z, 1.0), 0.5)
    res = jnp.sqrt(jnp.sum((new - cur) ** 2, axis=-1))
    return new, res


def ref_grid_step(pot, h, v, msgs):
    """One synchronous BP round over an n x n binary grid.

    Message layout (matches rust/src/runtime/grid.rs):
      msgs[d, r, c, :] = message INTO node (r, c) from direction d, where
      d = 0: from the left neighbor  (r, c-1)
      d = 1: from the right neighbor (r, c+1)
      d = 2: from above (r-1, c)
      d = 3: from below (r+1, c)
    Boundary slots (e.g. d=0 at c=0) hold the uniform message (0.5, 0.5)
    and are preserved.

    Args:
      pot:  [n, n, 2] node potentials.
      h:    [n, n-1, 2, 2] horizontal factors psi(x_{r,c}, x_{r,c+1}).
      v:    [n-1, n, 2, 2] vertical factors psi(x_{r,c}, x_{r+1,c}).
      msgs: [4, n, n, 2].

    Returns:
      (new_msgs [4, n, n, 2], max_res scalar) with max_res the max L2
      residual over all message slots (boundary slots never change).
    """
    n = pot.shape[0]

    # Product of potential and all incoming messages at each node.
    belief = pot * msgs[0] * msgs[1] * msgs[2] * msgs[3]

    def normalize(un):
        z = jnp.sum(un, axis=-1, keepdims=True)
        return jnp.where(z > 0, un / jnp.where(z > 0, z, 1.0), 0.5)

    # Cavity product at each node excluding direction d.
    def cavity(d):
        m = msgs[d]
        return belief / jnp.where(m > 0, m, 1.0)

    new = msgs

    # d=0 slot at (r, c>=1): message (r,c-1)->(r,c). The source node
    # (r,c-1) must exclude what it received FROM (r,c): its d=1 slot.
    src = cavity(1)[:, : n - 1, :]
    out0 = normalize(jnp.einsum("rci,rcij->rcj", src, h))
    new = new.at[0, :, 1:, :].set(out0)

    # d=1 slot at (r, c<n-1): message (r,c+1)->(r,c); factor transposed.
    src = cavity(0)[:, 1:, :]
    out1 = normalize(jnp.einsum("rcj,rcij->rci", src, h))
    new = new.at[1, :, : n - 1, :].set(out1)

    # d=2 slot at (r>=1, c): message (r-1,c)->(r,c).
    src = cavity(3)[: n - 1, :, :]
    out2 = normalize(jnp.einsum("rci,rcij->rcj", src, v))
    new = new.at[2, 1:, :, :].set(out2)

    # d=3 slot at (r<n-1, c): message (r+1,c)->(r,c); factor transposed.
    src = cavity(2)[1:, :, :]
    out3 = normalize(jnp.einsum("rcj,rcij->rci", src, v))
    new = new.at[3, : n - 1, :, :].set(out3)

    res = jnp.sqrt(jnp.sum((new - msgs) ** 2, axis=-1))
    return new, jnp.max(res)
