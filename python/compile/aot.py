"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Usage (normally via `make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--batch-sizes 64,256,1024] [--grid-sizes 16,64,128]

Emits, per size:
    artifacts/batched_update_{B}.hlo.txt   (prod[B,2], psi[B,2,2], cur[B,2])
    artifacts/grid_step_{n}.hlo.txt        (pot, h, v, msgs tensors)

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. Lowered with return_tuple=True; the
Rust loader unwraps the tuple.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_batched_update(batch: int, impl: str = "ref") -> str:
    """impl="ref": fused jnp graph (fast on XLA CPU, the default artifact).
    impl="pallas": the L1 kernel in interpret mode (TPU-shaped; emitted as
    *_pallas.hlo.txt for cross-validation)."""
    fn = model.batched_update_model_ref if impl == "ref" else model.batched_update_model
    lowered = jax.jit(fn).lower(
        spec((batch, 2)), spec((batch, 2, 2)), spec((batch, 2))
    )
    return to_hlo_text(lowered)


def lower_grid_step(n: int, impl: str = "ref") -> str:
    from compile.kernels.ref import ref_grid_step

    fn = ref_grid_step if impl == "ref" else model.grid_step_model
    lowered = jax.jit(fn).lower(
        spec((n, n, 2)), spec((n, n - 1, 2, 2)), spec((n - 1, n, 2, 2)),
        spec((4, n, n, 2)),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch-sizes", default="64,256,1024")
    ap.add_argument("--grid-sizes", default="16,64,128")
    # Back-compat shim for the scaffold's `--out` single-file form.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir if args.out is None else os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    def emit(path, text):
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    batches = [int(x) for x in args.batch_sizes.split(",") if x]
    grids = [int(x) for x in args.grid_sizes.split(",") if x]
    for b in batches:
        emit(os.path.join(out_dir, f"batched_update_{b}.hlo.txt"),
             lower_batched_update(b, impl="ref"))
    for n in grids:
        emit(os.path.join(out_dir, f"grid_step_{n}.hlo.txt"),
             lower_grid_step(n, impl="ref"))
    # Pallas-kernel flavors (smallest sizes) for runtime cross-validation.
    if batches:
        b = min(batches)
        emit(os.path.join(out_dir, f"batched_update_{b}_pallas.hlo.txt"),
             lower_batched_update(b, impl="pallas"))
    if grids:
        n = min(grids)
        emit(os.path.join(out_dir, f"grid_step_{n}_pallas.hlo.txt"),
             lower_grid_step(n, impl="pallas"))

    # Marker consumed by the Makefile's up-to-date check.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
