"""L2: JAX compute graphs lowered to the AOT artifacts.

Two entry points, both built on the L1 Pallas kernel
(kernels.message_update.batched_update):

- `batched_update_model(prod, psi, cur)` — the generic batched binary
  message update used by the Rust coordinator's `relaxed_residual_batched`
  engine (the Multiqueue pops a batch, Rust gathers the cavity products,
  the kernel does the dense matvec + normalize + residual).

- `grid_step_model(pot, h, v, msgs)` — one full synchronous BP round over
  an n x n binary grid (Ising/Potts), used by the `synch` engine's PJRT
  path. The elementwise belief/cavity algebra stays in jnp (XLA fuses it);
  the four per-direction dense update batches are routed through the same
  Pallas kernel.

Tensor layouts match rust/src/runtime/{batch,grid}.rs exactly; the pure-jnp
oracles in kernels.ref define the semantics.
"""

import jax.numpy as jnp

from compile.kernels.message_update import batched_update
from compile.kernels.ref import ref_batched_update


def batched_update_model(prod, psi, cur):
    """[B,2],[B,2,2],[B,2] -> ([B,2] new, [B] res). Pallas-kernel flavor."""
    return batched_update(prod, psi, cur)


def batched_update_model_ref(prod, psi, cur):
    """Same computation from the pure-jnp oracle.

    This is what the default CPU artifacts are lowered from: Pallas with
    interpret=True lowers its tile grid to while/dynamic-slice HLO that the
    XLA *CPU* backend executes ~34x slower than the equivalent fused jnp
    graph (measured; EXPERIMENTS.md section Perf). The two flavors are
    asserted numerically identical in pytest and in the Rust
    pjrt_integration tests; the Pallas flavor is the TPU-targeted
    implementation and is still emitted as `*_pallas.hlo.txt` for
    cross-validation.
    """
    return ref_batched_update(prod, psi, cur)


def grid_step_model(pot, h, v, msgs):
    """One synchronous round; see kernels.ref.ref_grid_step for layout."""
    n = pot.shape[0]

    belief = pot * msgs[0] * msgs[1] * msgs[2] * msgs[3]

    def cavity(d):
        m = msgs[d]
        return belief / jnp.where(m > 0, m, 1.0)

    def run(src, psi_mats, old):
        """Flatten a [.., 2] direction batch through the Pallas kernel."""
        shape = src.shape[:-1]
        new_flat, res_flat = batched_update(
            src.reshape(-1, 2), psi_mats.reshape(-1, 2, 2), old.reshape(-1, 2)
        )
        return new_flat.reshape(*shape, 2), res_flat.reshape(shape)

    new = msgs
    max_res = jnp.zeros((), dtype=msgs.dtype)

    # d=0: (r,c-1)->(r,c); source cavity excludes its d=1 slot.
    out0, r0 = run(cavity(1)[:, : n - 1, :], h, msgs[0, :, 1:, :])
    new = new.at[0, :, 1:, :].set(out0)
    max_res = jnp.maximum(max_res, jnp.max(r0))

    # d=1: (r,c+1)->(r,c); transposed factor.
    ht = jnp.swapaxes(h, -1, -2)
    out1, r1 = run(cavity(0)[:, 1:, :], ht, msgs[1, :, : n - 1, :])
    new = new.at[1, :, : n - 1, :].set(out1)
    max_res = jnp.maximum(max_res, jnp.max(r1))

    # d=2: (r-1,c)->(r,c).
    out2, r2 = run(cavity(3)[: n - 1, :, :], v, msgs[2, 1:, :, :])
    new = new.at[2, 1:, :, :].set(out2)
    max_res = jnp.maximum(max_res, jnp.max(r2))

    # d=3: (r+1,c)->(r,c); transposed factor.
    vt = jnp.swapaxes(v, -1, -2)
    out3, r3 = run(cavity(2)[1:, :, :], vt, msgs[3, : n - 1, :, :])
    new = new.at[3, : n - 1, :, :].set(out3)
    max_res = jnp.maximum(max_res, jnp.max(r3))

    return new, max_res
