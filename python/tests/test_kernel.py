"""L1 kernel correctness: Pallas batched_update vs the pure-jnp oracle,
including hypothesis-driven sweeps over batch sizes, block sizes, and value
regimes (degenerate factors, zero normalizers, denormal-ish inputs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.message_update import batched_update, vmem_bytes, DEFAULT_BLOCK
from compile.kernels.ref import ref_batched_update


def rand(key, shape, lo=0.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape,
                              dtype=jnp.float32, minval=lo, maxval=hi)


def assert_matches_ref(prod, psi, cur, **kw):
    new_k, res_k = batched_update(prod, psi, cur, **kw)
    new_r, res_r = ref_batched_update(prod, psi, cur)
    np.testing.assert_allclose(new_k, new_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(res_k, res_r, rtol=1e-5, atol=1e-6)


class TestKernelVsRef:
    def test_aligned_batch(self):
        assert_matches_ref(rand(0, (128, 2), 0.01, 1), rand(1, (128, 2, 2)),
                           rand(2, (128, 2)))

    def test_unaligned_batch_padding(self):
        assert_matches_ref(rand(3, (100, 2), 0.01, 1), rand(4, (100, 2, 2)),
                           rand(5, (100, 2)))

    def test_batch_smaller_than_block(self):
        assert_matches_ref(rand(6, (3, 2), 0.01, 1), rand(7, (3, 2, 2)),
                           rand(8, (3, 2)))

    def test_single_message(self):
        assert_matches_ref(rand(9, (1, 2), 0.01, 1), rand(10, (1, 2, 2)),
                           rand(11, (1, 2)))

    @pytest.mark.parametrize("block", [8, 32, 64, 128])
    def test_block_sizes(self, block):
        assert_matches_ref(rand(12, (256, 2), 0.01, 1), rand(13, (256, 2, 2)),
                           rand(14, (256, 2)), block=block)

    def test_zero_normalizer_uniform_fallback(self):
        prod = jnp.array([[0.4, 0.6]], dtype=jnp.float32)
        psi = jnp.zeros((1, 2, 2), dtype=jnp.float32)
        cur = jnp.array([[0.5, 0.5]], dtype=jnp.float32)
        new, res = batched_update(prod, psi, cur)
        np.testing.assert_allclose(new, [[0.5, 0.5]], atol=1e-7)
        np.testing.assert_allclose(res, [0.0], atol=1e-7)

    def test_deterministic_factor(self):
        # Equality factor propagates prod exactly.
        prod = jnp.array([[0.1, 0.9]], dtype=jnp.float32)
        psi = jnp.broadcast_to(jnp.eye(2, dtype=jnp.float32), (1, 2, 2))
        cur = jnp.array([[0.5, 0.5]], dtype=jnp.float32)
        new, res = batched_update(prod, psi, cur)
        np.testing.assert_allclose(new, [[0.1, 0.9]], rtol=1e-6)
        np.testing.assert_allclose(res, [np.sqrt(0.4**2 * 2)], rtol=1e-5)

    def test_outputs_are_normalized(self):
        new, _ = batched_update(rand(15, (500, 2), 0.01, 1),
                                rand(16, (500, 2, 2), 0.0, 5.0),
                                rand(17, (500, 2)))
        np.testing.assert_allclose(jnp.sum(new, axis=-1), 1.0, rtol=1e-5)

    def test_residual_zero_at_fixed_point(self):
        prod = rand(18, (64, 2), 0.01, 1)
        psi = rand(19, (64, 2, 2), 0.01, 1)
        new, _ = batched_update(prod, psi, rand(20, (64, 2)))
        _, res2 = batched_update(prod, psi, new)
        np.testing.assert_allclose(res2, 0.0, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=300),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_hypothesis_sweep(b, seed, scale):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    prod = jax.random.uniform(k1, (b, 2), dtype=jnp.float32) * scale + 1e-6
    psi = jax.random.uniform(k2, (b, 2, 2), dtype=jnp.float32) * scale
    cur = jax.random.uniform(k3, (b, 2), dtype=jnp.float32)
    cur = cur / jnp.sum(cur, axis=-1, keepdims=True)
    new_k, res_k = batched_update(prod, psi, cur)
    new_r, res_r = ref_batched_update(prod, psi, cur)
    np.testing.assert_allclose(new_k, new_r, rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(res_k, res_r, rtol=2e-5, atol=1e-6)


def test_vmem_estimate_sane():
    # One tile must fit comfortably in a 16 MiB TPU VMEM.
    assert vmem_bytes(DEFAULT_BLOCK) < 1 << 20
    assert vmem_bytes(1024) == 1024 * 11 * 4
