"""AOT pipeline: lowering emits parseable HLO text with the expected
parameter shapes, and the emitted program computes the same numbers as the
jitted model when run through the local XLA client."""

import re

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import lower_batched_update, lower_grid_step, to_hlo_text
from compile import model


def test_batched_update_hlo_structure():
    text = lower_batched_update(64)
    assert "HloModule" in text
    assert "f32[64,2]" in text
    assert "f32[64,2,2]" in text
    # Tuple-rooted (return_tuple=True): new + res.
    assert re.search(r"ROOT.*tuple", text) or "(f32[64,2]" in text


def test_grid_step_hlo_structure():
    text = lower_grid_step(16)
    assert "HloModule" in text
    assert "f32[4,16,16,2]" in text
    assert "f32[16,15,2,2]" in text


def test_hlo_has_no_custom_calls():
    # interpret=True Pallas must lower to plain HLO ops a CPU client can
    # run — a Mosaic custom-call here would break the Rust runtime.
    for text in (lower_batched_update(64), lower_grid_step(16)):
        assert "custom-call" not in text, "unexpected custom-call in artifact"


def test_lowered_matches_jit_numerics():
    b = 64
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    prod = jax.random.uniform(k[0], (b, 2), dtype=jnp.float32) + 0.01
    psi = jax.random.uniform(k[1], (b, 2, 2), dtype=jnp.float32)
    cur = jax.random.uniform(k[2], (b, 2), dtype=jnp.float32)
    expect_new, expect_res = model.batched_update_model(prod, psi, cur)

    # Compile the lowered module and execute it via jax's own runtime.
    compiled = jax.jit(model.batched_update_model).lower(prod, psi, cur).compile()
    got_new, got_res = compiled(prod, psi, cur)
    np.testing.assert_allclose(got_new, expect_new, rtol=1e-6)
    np.testing.assert_allclose(got_res, expect_res, rtol=1e-6)


def test_hlo_text_is_stable():
    a = lower_batched_update(64)
    b = lower_batched_update(64)
    assert a == b, "lowering must be deterministic for artifact caching"
