"""L2 model correctness: the grid sweep vs its oracle, convergence of
repeated sweeps, and boundary-slot preservation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import ref_grid_step
from compile.model import grid_step_model


def make_grid(n, seed, coupling=1.0):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    pot = jax.random.uniform(k[0], (n, n, 2), dtype=jnp.float32) + 0.1
    h = jnp.exp(jax.random.uniform(k[1], (n, n - 1, 2, 2), dtype=jnp.float32,
                                   minval=-coupling, maxval=coupling))
    v = jnp.exp(jax.random.uniform(k[2], (n - 1, n, 2, 2), dtype=jnp.float32,
                                   minval=-coupling, maxval=coupling))
    msgs = jnp.full((4, n, n, 2), 0.5, dtype=jnp.float32)
    return pot, h, v, msgs


@pytest.mark.parametrize("n", [2, 3, 5, 8])
def test_matches_ref(n):
    pot, h, v, msgs = make_grid(n, n)
    a_m, a_r = grid_step_model(pot, h, v, msgs)
    b_m, b_r = ref_grid_step(pot, h, v, msgs)
    np.testing.assert_allclose(a_m, b_m, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a_r, b_r, rtol=1e-4, atol=1e-5)


def test_boundary_slots_preserved():
    n = 4
    pot, h, v, msgs = make_grid(n, 1)
    new, _ = grid_step_model(pot, h, v, msgs)
    # d=0 at c=0, d=1 at c=n-1, d=2 at r=0, d=3 at r=n-1 stay uniform.
    np.testing.assert_allclose(new[0, :, 0, :], 0.5, atol=1e-7)
    np.testing.assert_allclose(new[1, :, n - 1, :], 0.5, atol=1e-7)
    np.testing.assert_allclose(new[2, 0, :, :], 0.5, atol=1e-7)
    np.testing.assert_allclose(new[3, n - 1, :, :], 0.5, atol=1e-7)


def test_messages_normalized():
    pot, h, v, msgs = make_grid(6, 2)
    new, _ = grid_step_model(pot, h, v, msgs)
    np.testing.assert_allclose(jnp.sum(new, axis=-1), 1.0, rtol=1e-5)


def test_repeated_sweeps_converge():
    pot, h, v, msgs = make_grid(5, 3, coupling=0.5)
    res = None
    for _ in range(200):
        msgs, res = grid_step_model(pot, h, v, msgs)
        if float(res) < 1e-5:
            break
    assert float(res) < 1e-5, f"did not converge: {float(res)}"


def test_fixed_point_residual_zero():
    pot, h, v, msgs = make_grid(4, 5, coupling=0.3)
    for _ in range(300):
        msgs, res = grid_step_model(pot, h, v, msgs)
        if float(res) < 1e-7:
            break
    new, res2 = grid_step_model(pot, h, v, msgs)
    assert float(res2) < 1e-5
    np.testing.assert_allclose(new, msgs, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(min_value=2, max_value=7),
       seed=st.integers(min_value=0, max_value=10**6))
def test_hypothesis_grid_vs_ref(n, seed):
    pot, h, v, msgs = make_grid(n, seed)
    # One random pre-step so messages are non-uniform.
    msgs, _ = ref_grid_step(pot, h, v, msgs)
    a_m, a_r = grid_step_model(pot, h, v, msgs)
    b_m, b_r = ref_grid_step(pot, h, v, msgs)
    np.testing.assert_allclose(a_m, b_m, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(a_r, b_r, rtol=1e-4, atol=1e-5)
