//! Property tests for incremental re-convergence on evidence deltas
//! (`EvidenceDelta` + `Engine::resume`):
//!
//! - warm-start parity: re-converging from a resident state across a prior
//!   perturbation reaches the same fixed point as a scratch solve of the
//!   perturbed instance — marginal L∞ ≤ 1e-9 under f64 on every model
//!   family and across the engine roster (≤ 1e-5 under f32, where two
//!   stored fixed points may legitimately sit one rounding plateau apart);
//! - an empty delta is a no-op on every delta-aware engine: zero tasks
//!   seeded (`tasks_touched == 0`), zero updates committed, and the
//!   message state bitwise unchanged;
//! - delta-then-delta composes: two sequential resumes land on the same
//!   fixed point as one resume over the merged delta;
//! - resume keeps the pool's pop-accounting identity and quiesces across
//!   shard counts, including shard counts that don't divide the thread
//!   count.
//!
//! Parity runs use a tiny epsilon (far below both arms' discretization)
//! so the two trajectories are forced onto the same fixed point rather
//! than merely into the same ε-ball.

use relaxed_bp::bp::{max_marginal_diff, Kernel, Precision};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, PartitionSpec, RunConfig};
use relaxed_bp::model::{builders, EvidenceDelta};
use relaxed_bp::run::{run_config, run_on_model};

/// Every family in the roster at property-test sizes.
fn family_specs() -> Vec<ModelSpec> {
    vec![
        ModelSpec::Tree { n: 31 },
        ModelSpec::Path { n: 8 },
        ModelSpec::AdversarialTree { n: 36 },
        ModelSpec::UniformTree { n: 40, arity: 3 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Potts { n: 4, q: 32 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
        ModelSpec::PowerLaw { n: 80, m: 3 },
    ]
}

/// Engines with a delta-aware seeder (an `Engine::resume` override that
/// seeds only the perturbed frontier and reports it as `tasks_touched`).
/// Round-based engines and the analytic optimal-tree schedule keep the
/// default warm-correct resume, which seeds nothing incremental.
fn delta_aware(alg: &AlgorithmSpec) -> bool {
    use AlgorithmSpec::*;
    matches!(
        alg,
        SequentialResidual
            | CoarseGrained
            | RelaxedResidual
            | WeightDecay
            | Priority
            | Splash { .. }
            | SmartSplash { .. }
            | RelaxedSmartSplash { .. }
            | RandomSplash { .. }
            | RelaxedResidualBatched { .. }
    )
}

/// Converge `cfg` from uniform, perturb `fraction` of the priors, then
/// re-converge both warm (resume from the resident state) and scratch
/// (uniform restart on the perturbed instance); return the marginal L∞
/// between the two fixed points and the warm run's seeded-frontier count.
fn warm_vs_scratch(cfg: &RunConfig, fraction: f64, delta_seed: u64) -> (f64, u64) {
    let mut warm = run_config(cfg).unwrap();
    assert!(warm.stats.converged, "{:?}: base run did not converge", cfg.algorithm);
    let delta = EvidenceDelta::random_perturbation(&warm.mrf, fraction, delta_seed);
    assert!(!delta.is_empty());

    let mut scratch_mrf = builders::build(&cfg.model, cfg.seed);
    delta.apply(&mut scratch_mrf);
    let scratch = run_on_model(cfg, scratch_mrf).unwrap();
    assert!(scratch.stats.converged, "{:?}: scratch run did not converge", cfg.algorithm);

    warm.resume_delta(&delta, None).unwrap();
    assert!(warm.stats.converged, "{:?}: warm resume did not converge", cfg.algorithm);

    let diff = max_marginal_diff(&warm.marginals(), &scratch.marginals());
    (diff, warm.stats.metrics.total.tasks_touched)
}

/// Warm-start parity on every model family, across the kernel-axis
/// corners (all-new: fused+simd; all-historical: edgewise+scalar) and
/// both storage precisions, with the relaxed Multiqueue contender.
#[test]
fn warm_matches_scratch_on_every_family() {
    for spec in family_specs() {
        for (fused, kernel) in [(true, Kernel::Simd), (false, Kernel::Scalar)] {
            for precision in [Precision::F64, Precision::F32] {
                let mut cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
                    .with_threads(2)
                    .with_seed(17)
                    .with_fused(fused)
                    .with_kernel(kernel)
                    .with_precision(precision);
                // Far below both discretizations: forces each arm onto an
                // exactly-stored fixed point (f32 residuals snap to 0.0
                // once the candidate rounds to the stored bits).
                cfg.epsilon = 1e-12;
                cfg.time_limit_secs = 120.0;
                let (diff, touched) = warm_vs_scratch(&cfg, 0.05, 99);
                assert!(touched > 0, "{spec:?}: warm resume seeded no frontier");
                // Two f32 stored fixed points may differ by a rounding
                // plateau (~1 ulp of the message scale); f64 fixed points
                // at ε = 1e-12 must agree to 1e-9.
                let bound = if precision == Precision::F64 { 1e-9 } else { 1e-5 };
                assert!(
                    diff <= bound,
                    "{spec:?} fused={fused} {kernel:?} {precision:?}: warm vs scratch L∞ = {diff}"
                );
            }
        }
    }
}

/// Warm-start parity across the full engine roster (delta-aware engines
/// seed the frontier; the others fall back to the warm-correct default
/// resume), at both kernel-axis corners under f64.
#[test]
fn warm_matches_scratch_across_engine_roster() {
    let roster: Vec<(AlgorithmSpec, ModelSpec)> = vec![
        (AlgorithmSpec::SequentialResidual, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Synchronous, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::CoarseGrained, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedResidual, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::WeightDecay, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Priority, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Splash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::SmartSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedSmartSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RandomSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Bucket, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RandomSynchronous { low_p: 0.4 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedResidualBatched { batch: 4 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::OptimalTree, ModelSpec::Tree { n: 31 }),
        (AlgorithmSpec::RelaxedOptimalTree, ModelSpec::Tree { n: 31 }),
    ];
    for (alg, spec) in roster {
        for (fused, kernel) in [(true, Kernel::Simd), (false, Kernel::Scalar)] {
            let mut cfg = RunConfig::new(spec.clone(), alg.clone())
                .with_threads(2)
                .with_seed(5)
                .with_fused(fused)
                .with_kernel(kernel);
            cfg.epsilon = 1e-12;
            cfg.time_limit_secs = 120.0;
            let (diff, touched) = warm_vs_scratch(&cfg, 0.1, 7);
            if delta_aware(&alg) {
                assert!(touched > 0, "{alg:?}: delta-aware engine seeded no frontier");
            } else {
                assert_eq!(touched, 0, "{alg:?}: default resume must not report a frontier");
            }
            assert!(
                diff <= 1e-9,
                "{alg:?} fused={fused} {kernel:?}: warm vs scratch L∞ = {diff}"
            );
        }
    }
}

/// An empty delta is a no-op on every delta-aware engine: the seeder
/// injects nothing (the run starts quiescent and the elected verifier
/// confirms convergence), no update is committed, and the resident
/// message state survives bitwise.
#[test]
fn empty_delta_is_a_noop() {
    let roster: Vec<(AlgorithmSpec, ModelSpec)> = vec![
        (AlgorithmSpec::SequentialResidual, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::CoarseGrained, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedResidual, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::WeightDecay, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Priority, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Splash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::SmartSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedSmartSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RandomSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedResidualBatched { batch: 4 }, ModelSpec::Ising { n: 4 }),
    ];
    for (alg, spec) in roster {
        assert!(delta_aware(&alg));
        let cfg = RunConfig::new(spec.clone(), alg.clone()).with_threads(2).with_seed(5);
        let mut rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged, "{alg:?}: base run did not converge");
        let before = rep.msgs.snapshot();

        let delta = EvidenceDelta::new();
        assert!(delta.is_empty());
        rep.resume_delta(&delta, None).unwrap();

        assert!(rep.stats.converged, "{alg:?}: empty-delta resume did not converge");
        let m = &rep.stats.metrics.total;
        assert_eq!(m.tasks_touched, 0, "{alg:?}: empty delta seeded tasks");
        assert_eq!(m.updates, 0, "{alg:?}: empty delta committed updates");
        let after = rep.msgs.snapshot();
        assert_eq!(before.len(), after.len());
        for (i, (a, b)) in before.iter().zip(after.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{alg:?} cell {i}: empty delta changed the message state ({a} vs {b})"
            );
        }
    }
}

/// Two sequential deltas compose: resume(d1) then resume(d2) lands on the
/// same fixed point as one scratch solve under merged(d1, d2) (later
/// entries win on overlap, matching `EvidenceDelta::merged`).
#[test]
fn sequential_deltas_compose_to_the_merged_fixed_point() {
    for spec in [ModelSpec::PowerLaw { n: 80, m: 3 }, ModelSpec::Ising { n: 5 }] {
        let mut cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(21);
        cfg.epsilon = 1e-12;
        cfg.time_limit_secs = 120.0;
        let mut rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged);

        // Both deltas are computed against the BASE priors, so applying d1
        // then d2 is exactly the later-wins merge.
        let d1 = EvidenceDelta::random_perturbation(&rep.mrf, 0.05, 1);
        let d2 = EvidenceDelta::random_perturbation(&rep.mrf, 0.05, 2);
        rep.resume_delta(&d1, None).unwrap();
        assert!(rep.stats.converged, "{spec:?}: first resume did not converge");
        rep.resume_delta(&d2, None).unwrap();
        assert!(rep.stats.converged, "{spec:?}: second resume did not converge");

        let merged = d1.merged(&d2);
        let mut scratch_mrf = builders::build(&spec, cfg.seed);
        merged.apply(&mut scratch_mrf);
        let scratch = run_on_model(&cfg, scratch_mrf).unwrap();
        assert!(scratch.stats.converged);

        let diff = max_marginal_diff(&rep.marginals(), &scratch.marginals());
        assert!(diff <= 1e-9, "{spec:?}: delta-then-delta vs merged L∞ = {diff}");
    }
}

/// Resume keeps the pool's exactly-once pop accounting and quiesces
/// across shard counts — including 7, which divides neither the thread
/// count nor the frontier — and reports the exact frontier size.
#[test]
fn resume_pop_accounting_and_quiescence_across_shard_counts() {
    let spec = ModelSpec::PowerLaw { n: 80, m: 3 };
    let threads = 4usize;
    for shards in [1usize, 2, 7, threads] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(threads)
            .with_seed(33)
            .with_partition(PartitionSpec::Affine { shards, spill: 0.1, bfs: false });
        let mut rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged, "shards={shards}: base run did not converge");

        let delta = EvidenceDelta::random_perturbation(&rep.mrf, 0.05, 44);
        let frontier: u64 =
            delta.nodes().map(|i| rep.mrf.graph.slots(i as usize).len() as u64).sum();
        assert!(frontier > 0);
        rep.resume_delta(&delta, None).unwrap();

        assert!(rep.stats.converged, "shards={shards}: warm resume did not converge");
        let m = &rep.stats.metrics.total;
        assert_eq!(
            m.tasks_touched, frontier,
            "shards={shards}: tasks_touched must equal the perturbed out-edge count"
        );
        // One update per successful claim: every pop is accounted as
        // stale, claim-failed, or an executed update.
        assert_eq!(
            m.pops,
            m.stale_pops + m.claim_failures + m.updates,
            "shards={shards}: pop accounting identity broken on resume"
        );
    }
}
