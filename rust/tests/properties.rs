//! Property-based tests. `proptest` is unavailable in the offline build,
//! so these use a seed-sweep harness over the library's own deterministic
//! PRNG: each property runs against many independently generated random
//! cases, and a failure message always contains the seed for replay.

use relaxed_bp::bp::{
    all_marginals, compute_message, exact_marginals, max_marginal_diff, msg_buf, residual_l2,
    Lookahead, Messages, MsgSource,
};
use relaxed_bp::configio::{parse, AlgorithmSpec, Json, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::{builders, io as model_io, FactorPool, GraphBuilder, Mrf, NodeFactors};
use relaxed_bp::sched::{Entry, Multiqueue, RandomQueues, Scheduler, TaskStates};
use relaxed_bp::util::Xoshiro256;

const CASES: u64 = 30;

/// Random tree MRF with random positive factors (binary domains).
fn random_tree_mrf(rng: &mut Xoshiro256) -> Mrf {
    let n = 2 + rng.index(14); // 2..=15 nodes: oracle-enumerable
    let mut gb = GraphBuilder::new(n);
    let mut pool = FactorPool::new();
    let mut edge_idx = Vec::new();
    for i in 1..n {
        let parent = rng.index(i);
        gb.add_edge(parent, i);
        let m = [
            rng.uniform(0.05, 1.0),
            rng.uniform(0.05, 1.0),
            rng.uniform(0.05, 1.0),
            rng.uniform(0.05, 1.0),
        ];
        edge_idx.push(pool.add(2, 2, &m));
    }
    let factors: Vec<Vec<f64>> = (0..n)
        .map(|_| vec![rng.uniform(0.05, 1.0), rng.uniform(0.05, 1.0)])
        .collect();
    Mrf::assemble(
        "random_tree",
        gb.build(),
        vec![2; n],
        NodeFactors::from_vecs(&factors),
        edge_idx,
        pool,
    )
}

#[test]
fn prop_bp_exact_on_random_trees() {
    // BP at convergence computes exact marginals on any tree.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mrf = random_tree_mrf(&mut rng);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(
            ModelSpec::Tree { n: mrf.num_nodes() },
            AlgorithmSpec::SequentialResidual,
        )
        .with_epsilon(1e-10);
        let stats = build_engine(&cfg.algorithm).run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "seed {seed}");
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 22).unwrap();
        let diff = max_marginal_diff(&bp, &exact);
        assert!(diff < 1e-7, "seed {seed}: diff {diff}");
    }
}

#[test]
fn prop_relaxed_matches_exact_on_random_trees() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(1000 + seed);
        let mrf = random_tree_mrf(&mut rng);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(
            ModelSpec::Tree { n: mrf.num_nodes() },
            AlgorithmSpec::RelaxedResidual,
        )
        .with_threads(2)
        .with_seed(seed)
        .with_epsilon(1e-10);
        let stats = build_engine(&cfg.algorithm).run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "seed {seed}");
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 22).unwrap();
        let diff = max_marginal_diff(&bp, &exact);
        assert!(diff < 1e-7, "seed {seed}: diff {diff}");
    }
}

#[test]
fn prop_update_rule_invariants() {
    // For any model and any reachable message state: outputs normalized,
    // non-negative, and recomputation is deterministic.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(2000 + seed);
        let mrf = random_tree_mrf(&mut rng);
        let msgs = Messages::uniform(&mrf);
        // Randomize the state.
        for e in 0..mrf.num_messages() as u32 {
            let a = rng.uniform(0.01, 0.99);
            msgs.write_msg(&mrf, e, &[a, 1.0 - a]);
        }
        let mut out1 = msg_buf();
        let mut out2 = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            let len = compute_message(&mrf, &msgs, e, &mut out1);
            let sum: f64 = out1[..len].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "seed {seed} edge {e}: sum {sum}");
            assert!(out1[..len].iter().all(|&v| v >= 0.0), "seed {seed} edge {e}");
            compute_message(&mrf, &msgs, e, &mut out2);
            assert_eq!(&out1[..len], &out2[..len], "seed {seed} edge {e}");
        }
    }
}

#[test]
fn prop_lookahead_residual_consistency() {
    // After init, the stored residual equals the L2 distance between
    // pending and live; after commit it is zero and live == old pending.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(3000 + seed);
        let mrf = random_tree_mrf(&mut rng);
        let msgs = Messages::uniform(&mrf);
        let la = Lookahead::init(&mrf, &msgs, relaxed_bp::bp::Kernel::Simd);
        for e in 0..mrf.num_messages() as u32 {
            let mut pend = msg_buf();
            let mut live = msg_buf();
            let len = la.read_pending(&mrf, e, &mut pend);
            msgs.read_msg(&mrf, e, &mut live);
            let expect = residual_l2(&pend[..len], &live[..len]);
            assert!(
                (la.residual(e) - expect).abs() < 1e-12,
                "seed {seed} edge {e}"
            );
            la.commit(&mrf, &msgs, e);
            assert_eq!(la.residual(e), 0.0);
            msgs.read_msg(&mrf, e, &mut live);
            assert_eq!(&pend[..len], &live[..len], "seed {seed} edge {e}");
        }
    }
}

#[test]
fn prop_multiqueue_preserves_multiset() {
    // Any interleaving of inserts/pops loses nothing and duplicates nothing.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(4000 + seed);
        let q = Multiqueue::new(1 + rng.index(8));
        let n = 50 + rng.index(500);
        let mut inserted = Vec::new();
        let mut popped = Vec::new();
        for t in 0..n as u32 {
            if rng.bernoulli(0.7) || inserted.len() == popped.len() {
                q.insert(Entry { prio: rng.next_f64(), task: t, epoch: 0 }, &mut rng);
                inserted.push(t);
            } else if let Some(e) = q.pop(&mut rng) {
                popped.push(e.task);
            }
        }
        while let Some(e) = q.pop(&mut rng) {
            popped.push(e.task);
        }
        inserted.sort_unstable();
        popped.sort_unstable();
        assert_eq!(inserted, popped, "seed {seed}");
    }
}

#[test]
fn prop_multiqueue_rank_beats_random_queues() {
    // Structural property behind Theorem 1: two-choice rank error is
    // consistently below single-random-queue rank error.
    let mut mq_wins = 0;
    for seed in 0..10u64 {
        let n = 1000u32;
        let mq = Multiqueue::new(8);
        let rq = RandomQueues::new(8);
        let mut rng = Xoshiro256::seed_from_u64(5000 + seed);
        for t in 0..n {
            mq.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut rng);
            rq.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut rng);
        }
        let rank_err = |pop: &mut dyn FnMut() -> Option<Entry>| {
            let mut live: std::collections::BTreeSet<u32> = (0..n).collect();
            let mut total = 0usize;
            while let Some(e) = pop() {
                total += live.range(e.task + 1..).count();
                live.remove(&e.task);
            }
            total
        };
        let mut r1 = Xoshiro256::seed_from_u64(seed);
        let mq_err = rank_err(&mut || mq.pop(&mut r1));
        let mut r2 = Xoshiro256::seed_from_u64(seed);
        let rq_err = rank_err(&mut || rq.pop(&mut r2));
        if mq_err < rq_err {
            mq_wins += 1;
        }
    }
    assert!(mq_wins >= 9, "multiqueue should ~always have lower rank error: {mq_wins}/10");
}

#[test]
fn prop_task_states_claim_exclusive_under_contention() {
    for seed in 0..10u64 {
        let ts = std::sync::Arc::new(TaskStates::new(64));
        let claims: Vec<usize> = std::thread::scope(|s| {
            (0..4)
                .map(|t| {
                    let ts = std::sync::Arc::clone(&ts);
                    s.spawn(move || {
                        let mut rng = Xoshiro256::stream(seed, t);
                        let mut won = 0;
                        for _ in 0..256 {
                            let task = rng.index(64) as u32;
                            if ts.try_claim(task, 0) {
                                won += 1;
                            }
                        }
                        won
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let total: usize = claims.iter().sum();
        assert!(total <= 64, "seed {seed}: {total} claims on 64 tasks");
    }
}

#[test]
fn prop_graph_builder_csr_consistency() {
    // Random simple graphs: CSR invariants hold.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(6000 + seed);
        let n = 3 + rng.index(40);
        let mut gb = GraphBuilder::new(n);
        let mut present = std::collections::HashSet::new();
        let mut m = 0;
        for _ in 0..n * 2 {
            let a = rng.index(n);
            let b = rng.index(n);
            if a != b && present.insert((a.min(b), a.max(b))) {
                gb.add_edge(a, b);
                m += 1;
            }
        }
        let g = gb.build();
        g.validate();
        assert_eq!(g.num_directed_edges(), 2 * m, "seed {seed}");
        let deg_sum: usize = (0..n).map(|i| g.degree(i)).sum();
        assert_eq!(deg_sum, 2 * m, "seed {seed}");
    }
}

#[test]
fn prop_mrf_io_roundtrip_random_models() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(7000 + seed);
        let mrf = random_tree_mrf(&mut rng);
        let mut buf = Vec::new();
        model_io::write_mrf(&mrf, &mut buf).unwrap();
        let back = model_io::read_mrf(&buf[..]).unwrap();
        assert_eq!(back.num_nodes(), mrf.num_nodes(), "seed {seed}");
        assert_eq!(back.msg_offset, mrf.msg_offset, "seed {seed}");
        for i in 0..mrf.num_nodes() {
            assert_eq!(back.node_factors.of(i), mrf.node_factors.of(i), "seed {seed}");
        }
    }
}

/// Random JSON value generator for parser round-trip fuzzing.
fn random_json(rng: &mut Xoshiro256, depth: usize) -> Json {
    match if depth == 0 { rng.index(4) } else { rng.index(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.bernoulli(0.5)),
        2 => Json::Num((rng.uniform(-1e6, 1e6) * 100.0).round() / 100.0),
        3 => {
            let len = rng.index(12);
            Json::Str(
                (0..len)
                    .map(|_| {
                        let c = rng.index(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' { c as char } else { 'x' }
                    })
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.index(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.index(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    for seed in 0..200u64 {
        let mut rng = Xoshiro256::seed_from_u64(8000 + seed);
        let v = random_json(&mut rng, 3);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let back = parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
            assert_eq!(back, v, "seed {seed}");
        }
    }
}

#[test]
fn prop_ldpc_decodes_across_seeds() {
    // BSC(0.05) is well below the (3,6) threshold: decode must succeed for
    // essentially every instance at this size.
    let mut ok = 0;
    let total = 10;
    for seed in 0..total {
        let inst = builders::ldpc::build(120, 0.05, 9000 + seed);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 120, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedResidual,
        )
        .with_threads(2)
        .with_seed(seed);
        let stats = build_engine(&cfg.algorithm).run(&inst.mrf, &msgs, &cfg).unwrap();
        if stats.converged {
            let bits = relaxed_bp::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
            if bits == inst.sent {
                ok += 1;
            }
        }
    }
    assert!(ok >= total - 1, "decoded {ok}/{total}");
}

#[test]
fn prop_marginal_agreement_random_seeds_multithreaded() {
    // Relaxed residual at p=4 agrees with the sequential fixed point on
    // random Ising instances.
    for seed in 0..8u64 {
        let spec = ModelSpec::Ising { n: 6 };
        let mrf = builders::build(&spec, 10_000 + seed);
        let msgs_a = Messages::uniform(&mrf);
        let cfg_a = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual)
            .with_seed(10_000 + seed);
        let sa = build_engine(&cfg_a.algorithm).run(&mrf, &msgs_a, &cfg_a).unwrap();
        let msgs_b = Messages::uniform(&mrf);
        let cfg_b = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(4)
            .with_seed(10_000 + seed);
        let sb = build_engine(&cfg_b.algorithm).run(&mrf, &msgs_b, &cfg_b).unwrap();
        assert!(sa.converged && sb.converged, "seed {seed}");
        let diff = max_marginal_diff(&all_marginals(&mrf, &msgs_a), &all_marginals(&mrf, &msgs_b));
        assert!(diff < 1e-2, "seed {seed}: diff {diff}");
    }
}
