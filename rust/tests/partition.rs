//! Property tests for the locality layer: the partitioner's structural
//! invariants, sharded message arenas vs the flat layout, and the
//! shard-affine execution path end to end.
//!
//! `proptest` is unavailable offline, so these follow the repo's
//! seed-sweep idiom: each property runs against many deterministic random
//! cases and failure messages carry the seed for replay.

use relaxed_bp::bp::{all_marginals, max_marginal_diff, msg_buf, Messages, MsgSource};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, PartitionSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::{builders, Partition};
use relaxed_bp::run::run_config;
use relaxed_bp::util::Xoshiro256;

const CASES: u64 = 30;

/// Shard counts the acceptance criteria call out explicitly.
const SHARD_COUNTS: &[usize] = &[1, 2, 7];

#[test]
fn prop_every_task_in_exactly_one_shard() {
    // validate() itself asserts the exactly-once property; this sweep
    // exercises it over random universe sizes and shard counts for both
    // construction modes.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let n = 1 + rng.index(500);
        let k = 1 + rng.index(16);
        let p = Partition::contiguous(n, k);
        p.validate();
        assert_eq!(p.num_tasks(), n, "seed {seed}");
        let total: usize = (0..p.num_shards()).map(|s| p.tasks_of(s).len()).sum();
        assert_eq!(total, n, "seed {seed}: shard ranges tile 0..num_tasks");
        for s in 0..p.num_shards() {
            for &t in p.tasks_of(s) {
                assert_eq!(p.shard_of(t) as usize, s, "seed {seed} task {t}");
            }
        }
    }
}

#[test]
fn prop_bfs_partitions_tile_on_random_models() {
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(1000 + seed);
        let side = 2 + rng.index(5);
        let mrf = builders::build(&ModelSpec::Ising { n: side }, seed);
        for &k in SHARD_COUNTS {
            let pe = Partition::bfs_edges(&mrf.graph, k);
            pe.validate();
            pe.validate_against(&mrf.graph);
            assert_eq!(pe.num_tasks(), mrf.num_messages(), "seed {seed}");
            let pn = Partition::bfs_nodes(&mrf.graph, k);
            pn.validate();
            pn.validate_against(&mrf.graph);
            assert_eq!(pn.num_tasks(), mrf.num_nodes(), "seed {seed}");
        }
    }
}

#[test]
fn prop_partitioner_is_deterministic() {
    let mrf = builders::build(&ModelSpec::Ising { n: 5 }, 3);
    for &k in SHARD_COUNTS {
        let a = Partition::bfs_edges(&mrf.graph, k);
        let b = Partition::bfs_edges(&mrf.graph, k);
        for t in 0..mrf.num_messages() as u32 {
            assert_eq!(a.shard_of(t), b.shard_of(t), "k={k} task {t}");
        }
    }
}

#[test]
fn prop_sharded_messages_equal_flat_under_random_writes() {
    // Any write/read sequence through the public API produces identical
    // state in flat and sharded arenas.
    for seed in 0..CASES {
        let mut rng = Xoshiro256::seed_from_u64(2000 + seed);
        let mrf = builders::build(&ModelSpec::Ising { n: 4 }, seed);
        let k = 1 + rng.index(7);
        let part = if rng.bernoulli(0.5) {
            Partition::bfs_edges(&mrf.graph, k)
        } else {
            Partition::contiguous(mrf.num_messages(), k)
        };
        let flat = Messages::uniform(&mrf);
        let sharded = Messages::uniform_partitioned(&mrf, &part);
        for _ in 0..200 {
            let e = rng.index(mrf.num_messages()) as u32;
            let a = rng.uniform(0.01, 0.99);
            flat.write_msg(&mrf, e, &[a, 1.0 - a]);
            sharded.write_msg(&mrf, e, &[a, 1.0 - a]);
        }
        assert_eq!(flat.snapshot(), sharded.snapshot(), "seed {seed}");
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            flat.read_msg(&mrf, e, &mut a);
            sharded.read_msg(&mrf, e, &mut b);
            assert_eq!(&a[..2], &b[..2], "seed {seed} edge {e}");
        }
    }
}

/// Queue-driven engines applicable to arbitrary (possibly loopy) models —
/// the parity roster, re-run here under the locality axis.
fn pool_roster() -> Vec<AlgorithmSpec> {
    vec![
        AlgorithmSpec::CoarseGrained,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::WeightDecay,
        AlgorithmSpec::Priority,
        AlgorithmSpec::Splash { h: 2 },
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        AlgorithmSpec::RandomSplash { h: 2 },
        AlgorithmSpec::RelaxedResidualBatched { batch: 8 },
    ]
}

#[test]
fn engines_reach_the_reference_fixed_point_with_partitioning_on() {
    // With partitioning off, the parity suite (tests/exec_parity.rs)
    // anchors every engine to the oracle. Here: the same fixed point must
    // be reached with the axis on, for contiguous and BFS shards across
    // the called-out shard counts (including num_threads via shards: 0).
    let spec = ModelSpec::Ising { n: 5 };
    let mrf = builders::build(&spec, 11);
    let msgs_ref = Messages::uniform(&mrf);
    let cfg_ref = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(11);
    let s = build_engine(&cfg_ref.algorithm).run(&mrf, &msgs_ref, &cfg_ref).unwrap();
    assert!(s.converged);
    let reference = all_marginals(&mrf, &msgs_ref);

    for shards in [1usize, 2, 7, 0] {
        for bfs in [false, true] {
            let axis = PartitionSpec::Affine { shards, spill: 0.1, bfs };
            for alg in pool_roster() {
                let cfg = RunConfig::new(spec.clone(), alg.clone())
                    .with_threads(4)
                    .with_seed(11)
                    .with_partition(axis);
                let msgs = relaxed_bp::run::build_messages(&cfg, &mrf).unwrap();
                let stats = build_engine(&alg).run(&mrf, &msgs, &cfg).unwrap();
                assert!(
                    stats.converged,
                    "{} shards={shards} bfs={bfs} did not converge",
                    alg.name()
                );
                let diff = max_marginal_diff(&all_marginals(&mrf, &msgs), &reference);
                assert!(
                    diff < 2e-2,
                    "{} shards={shards} bfs={bfs}: marginal diff {diff}",
                    alg.name()
                );
            }
        }
    }
}

#[test]
fn pop_accounting_identity_holds_with_partitioning() {
    // The shard-affine Multiqueue must not bend the epoch/claim/quiescence
    // protocol: every successful pop is still exactly one of {stale, lost
    // claim race, processed task}.
    let spec = ModelSpec::Ising { n: 5 };
    for shards in [1usize, 2, 7, 0] {
        for alg in [
            AlgorithmSpec::RelaxedResidual,
            AlgorithmSpec::Priority,
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
            AlgorithmSpec::RelaxedResidualBatched { batch: 8 },
        ] {
            let cfg = RunConfig::new(spec.clone(), alg.clone())
                .with_threads(4)
                .with_seed(7)
                .with_partition(PartitionSpec::Affine { shards, spill: 0.1, bfs: false });
            let rep = run_config(&cfg).unwrap();
            assert!(rep.stats.converged, "{} shards={shards}", alg.name());
            let m = &rep.stats.metrics.total;
            let processed = match alg {
                AlgorithmSpec::RelaxedSmartSplash { .. } => m.splashes + m.wasted_pops,
                _ => m.updates,
            };
            assert_eq!(
                m.pops,
                m.stale_pops + m.claim_failures + processed,
                "{} shards={shards}: pop accounting",
                alg.name()
            );
        }
    }
}

#[test]
fn converged_partitioned_runs_end_below_epsilon() {
    let spec = ModelSpec::Ising { n: 5 };
    for spill in [0.0, 0.1, 1.0] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(5)
            .with_partition(PartitionSpec::Affine { shards: 2, spill, bfs: false });
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged, "spill={spill}");
        assert!(
            rep.stats.final_max_priority < cfg.epsilon,
            "spill={spill}: final priority {}",
            rep.stats.final_max_priority
        );
    }
}

#[test]
fn partitioned_tree_run_is_exact() {
    let cfg = RunConfig::new(ModelSpec::Tree { n: 63 }, AlgorithmSpec::RelaxedResidual)
        .with_threads(2)
        .with_partition(PartitionSpec::Affine { shards: 0, spill: 0.1, bfs: true });
    let rep = run_config(&cfg).unwrap();
    assert!(rep.stats.converged);
    for (i, m) in rep.marginals().iter().enumerate() {
        assert!((m[0] - 0.1).abs() < 1e-3, "node {i}: {m:?}");
    }
}

#[test]
fn powerlaw_workload_converges_with_and_without_partitioning() {
    // The locality workload itself: both axes must reach the same fixed
    // point (this is the bench sweep's powerlaw/affine cell in miniature).
    let spec = ModelSpec::PowerLaw { n: 300, m: 2 };
    let run = |axis: PartitionSpec| {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(5)
            .with_partition(axis);
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged, "axis {:?}", axis.label());
        rep.marginals()
    };
    let off = run(PartitionSpec::Off);
    let affine = run(PartitionSpec::affine());
    let diff = max_marginal_diff(&off, &affine);
    assert!(diff < 2e-2, "off vs affine marginal diff {diff}");
}
