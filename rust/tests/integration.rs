//! Cross-module integration tests: every engine against every model
//! family, fixed-point agreement across schedules, serialization flows,
//! and the harness end to end.

use relaxed_bp::bp::{all_marginals, decode_bits, max_marginal_diff, Messages};
use relaxed_bp::configio::{parse, AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::harness::Harness;
use relaxed_bp::model::{builders, io as model_io};
use relaxed_bp::run::{run_config, run_on_model};

/// The full engine roster applicable to general (possibly loopy) models.
fn general_roster() -> Vec<AlgorithmSpec> {
    vec![
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::Synchronous,
        AlgorithmSpec::CoarseGrained,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::WeightDecay,
        AlgorithmSpec::Priority,
        AlgorithmSpec::Splash { h: 2 },
        AlgorithmSpec::SmartSplash { h: 2 },
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        AlgorithmSpec::RandomSplash { h: 2 },
        AlgorithmSpec::Bucket,
        AlgorithmSpec::RandomSynchronous { low_p: 0.4 },
        AlgorithmSpec::RelaxedResidualBatched { batch: 16 },
    ]
}

#[test]
fn every_engine_reaches_the_same_fixed_point_on_ising() {
    let spec = ModelSpec::Ising { n: 6 };
    let mrf = builders::build(&spec, 11);

    // Reference fixed point from the sequential baseline.
    let msgs_ref = Messages::uniform(&mrf);
    let cfg_ref = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(11);
    let s = build_engine(&cfg_ref.algorithm).run(&mrf, &msgs_ref, &cfg_ref).unwrap();
    assert!(s.converged);
    let reference = all_marginals(&mrf, &msgs_ref);

    for alg in general_roster() {
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), alg.clone()).with_threads(3).with_seed(11);
        let stats = build_engine(&alg).run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "{} did not converge", alg.name());
        let got = all_marginals(&mrf, &msgs);
        let diff = max_marginal_diff(&got, &reference);
        assert!(diff < 2e-2, "{}: marginal diff {diff}", alg.name());
    }
}

#[test]
fn every_engine_is_exact_on_the_tree_model() {
    let spec = ModelSpec::Tree { n: 63 };
    let mrf = builders::build(&spec, 1);
    for alg in general_roster() {
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), alg.clone()).with_threads(2).with_seed(5);
        let stats = build_engine(&alg).run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "{}", alg.name());
        // Equality factors: every node's belief equals the root prior.
        for (i, m) in all_marginals(&mrf, &msgs).iter().enumerate() {
            assert!(
                (m[0] - 0.1).abs() < 1e-3,
                "{} node {i}: {m:?}",
                alg.name()
            );
        }
    }
}

#[test]
fn ldpc_decode_agreement_across_main_engines() {
    let inst = builders::ldpc::build(120, 0.05, 3);
    let spec = ModelSpec::Ldpc { n: 120, flip_prob: 0.05 };
    for alg in [
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::Synchronous,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        AlgorithmSpec::WeightDecay,
    ] {
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(spec.clone(), alg.clone()).with_threads(2).with_seed(3);
        let stats = build_engine(&alg).run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "{}", alg.name());
        let bits = decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent, "{} decode", alg.name());
    }
}

#[test]
fn model_io_roundtrip_preserves_inference_results() {
    let spec = ModelSpec::Potts { n: 5, q: 3 };
    let mrf = builders::build(&spec, 9);
    let path = "/tmp/rbp_integration_model.rbpm";
    model_io::save(&mrf, path).unwrap();
    let loaded = model_io::load(path).unwrap();
    std::fs::remove_file(path).ok();

    let cfg = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(9);
    let a = run_on_model(&cfg, mrf).unwrap();
    let b = run_on_model(&cfg, loaded).unwrap();
    assert!(a.stats.converged && b.stats.converged);
    assert_eq!(a.stats.metrics.total.updates, b.stats.metrics.total.updates);
    assert!(max_marginal_diff(&a.marginals(), &b.marginals()) < 1e-12);
}

#[test]
fn run_config_json_flow() {
    let text = r#"{
        "model": {"kind": "ising", "n": 5},
        "algorithm": "rss:2",
        "threads": 2,
        "seed": 4
    }"#;
    let cfg = RunConfig::from_json(&parse(text).unwrap()).unwrap();
    assert_eq!(cfg.algorithm, AlgorithmSpec::RelaxedSmartSplash { h: 2 });
    let report = run_config(&cfg).unwrap();
    assert!(report.stats.converged);
    // The JSON report round-trips through our own parser.
    let back = parse(&report.to_json().to_string_pretty()).unwrap();
    assert_eq!(back.get("converged").unwrap().as_bool(), Some(true));
}

#[test]
fn harness_tiny_full_suite_produces_reports() {
    let out = std::path::PathBuf::from("/tmp/rbp_integration_results");
    std::fs::remove_dir_all(&out).ok();
    let h = Harness {
        scale: 0.0004,
        threads: vec![1, 2],
        max_threads: 2,
        out_dir: out.clone(),
        seed: 3,
        time_limit: 60.0,
        ..Harness::default()
    };
    h.table3().unwrap();
    h.table7().unwrap();
    h.fig2().unwrap();
    for f in ["table3", "table7", "fig2"] {
        assert!(out.join(format!("{f}.md")).exists(), "{f}.md");
        assert!(out.join(format!("{f}.csv")).exists(), "{f}.csv");
        assert!(out.join(format!("{f}.traces.json")).exists(), "{f}.traces.json");
    }
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn sequential_residual_is_bit_deterministic() {
    let cfg = RunConfig::new(ModelSpec::Ising { n: 7 }, AlgorithmSpec::SequentialResidual)
        .with_seed(13);
    let a = run_config(&cfg).unwrap();
    let b = run_config(&cfg).unwrap();
    assert_eq!(a.stats.metrics.total.updates, b.stats.metrics.total.updates);
    assert_eq!(a.msgs.snapshot(), b.msgs.snapshot());
}

#[test]
fn relaxed_overhead_stays_bounded_on_threads() {
    // Table 3's qualitative claim at test scale: the relaxed update
    // overhead at several threads stays within a small factor.
    let spec = ModelSpec::Ising { n: 10 };
    let mrf = builders::build(&spec, 17);
    let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(17);
    let base = run_on_model(&cfg, mrf.clone()).unwrap();
    assert!(base.stats.converged);
    for p in [1, 2, 4] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(p)
            .with_seed(17);
        let r = run_on_model(&cfg, mrf.clone()).unwrap();
        assert!(r.stats.converged);
        let ratio =
            r.stats.metrics.total.updates as f64 / base.stats.metrics.total.updates as f64;
        assert!(ratio < 1.5, "p={p}: ratio {ratio}");
    }
}

#[test]
fn adversarial_tree_wastes_more_than_uniform_tree() {
    // Lemma 2's direction: at equal relaxation, the adversarial instance
    // forces (weakly) more wasted work than the uniform-expansion tree.
    let n = 900;
    let run = |spec: ModelSpec| {
        let mrf = builders::build(&spec, 5);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(4)
            .with_seed(5);
        let stats = build_engine(&cfg.algorithm).run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let m = stats.metrics.total.clone();
        (m.updates, m.useful_updates, mrf.num_messages() as u64)
    };
    let (u_upd, u_useful, u_edges) = run(ModelSpec::UniformTree { n, arity: 2 });
    let (a_upd, a_useful, a_edges) = run(ModelSpec::AdversarialTree { n });
    // Useful updates ≈ one per away-from-root edge in both cases.
    assert!(u_useful <= u_edges && a_useful <= a_edges);
    let u_waste = u_upd as f64 / u_useful.max(1) as f64;
    let a_waste = a_upd as f64 / a_useful.max(1) as f64;
    assert!(
        a_waste >= u_waste * 0.9,
        "adversarial waste {a_waste:.3} vs uniform {u_waste:.3}"
    );
}

#[test]
fn optimal_tree_engines_on_path_and_tree() {
    for spec in [ModelSpec::Path { n: 200 }, ModelSpec::Tree { n: 255 }] {
        let mrf = builders::build(&spec, 1);
        for relaxed in [false, true] {
            let alg = if relaxed {
                AlgorithmSpec::RelaxedOptimalTree
            } else {
                AlgorithmSpec::OptimalTree
            };
            let msgs = Messages::uniform(&mrf);
            let cfg = RunConfig::new(spec.clone(), alg.clone()).with_threads(2);
            let stats = build_engine(&alg).run(&mrf, &msgs, &cfg).unwrap();
            assert!(stats.converged, "{:?} relaxed={relaxed}", spec.name());
            assert_eq!(
                stats.metrics.total.useful_updates,
                mrf.num_messages() as u64,
                "each message exactly one useful update"
            );
        }
    }
}
