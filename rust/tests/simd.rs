//! Property tests for the vectorized message data path (the
//! `RunConfig::kernel` axis):
//!
//! - simd-vs-scalar agreement ≤ 1e-12 for the edge-wise and fused kernels
//!   on every model family — including transposed edge factors, the exact
//!   zeros produced by deterministic LDPC parity factors, the
//!   zero-normalizer uniform fallback, and wide (q = 32) Potts domains;
//! - the scalar kernel is *bit-for-bit* the historical path (exact
//!   equality against the reference wrapper composition, not an epsilon);
//! - fused-residual (in-kernel / fused-write) parity against the
//!   recomputed read-then-`residual_l2` reference;
//! - bulk and borrowed-slice message I/O return exactly what per-cell
//!   reads return;
//! - end-to-end: scalar and simd engine runs share the fixed point, and
//!   the simd run still decodes LDPC.

use relaxed_bp::bp::{
    compute_message, compute_message_with, fused_node_refresh, max_marginal_diff, msg_buf,
    residual_l2, Kernel, Lookahead, Messages, MsgScratch, MsgSource, NodeScratch,
};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::model::builders;
use relaxed_bp::run::run_config;

/// Every family in the roster at property-test sizes, including the
/// wide-domain Potts grid the SIMD axis is aimed at.
fn family_specs() -> Vec<ModelSpec> {
    vec![
        ModelSpec::Tree { n: 31 },
        ModelSpec::Path { n: 8 },
        ModelSpec::AdversarialTree { n: 36 },
        ModelSpec::UniformTree { n: 40, arity: 3 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Potts { n: 4, q: 32 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
        ModelSpec::PowerLaw { n: 80, m: 3 },
    ]
}

/// Drive the message state away from uniform so products are non-trivial.
fn churn(mrf: &relaxed_bp::model::Mrf, msgs: &Messages, rounds: usize) {
    let mut out = msg_buf();
    for _ in 0..rounds {
        for e in 0..mrf.num_messages() as u32 {
            let len = compute_message(mrf, msgs, e, &mut out);
            msgs.write_msg(mrf, e, &out[..len]);
        }
    }
}

#[test]
fn simd_matches_scalar_edgewise_on_every_family() {
    for spec in family_specs() {
        let mrf = builders::build(&spec, 17);
        let msgs = Messages::uniform(&mrf);
        churn(&mrf, &msgs, 2);
        let mut sc_s = MsgScratch::new();
        let mut sc_v = MsgScratch::new();
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            let la = compute_message_with(&mrf, &msgs, e, &mut a, &mut sc_s, Kernel::Scalar);
            let lb = compute_message_with(&mrf, &msgs, e, &mut b, &mut sc_v, Kernel::Simd);
            assert_eq!(la, lb, "{spec:?} edge {e}");
            for x in 0..la {
                assert!(
                    (a[x] - b[x]).abs() <= 1e-12,
                    "{spec:?} edge {e} x={x}: scalar {} vs simd {}",
                    a[x],
                    b[x]
                );
                // Exact zeros (deterministic factors) must survive the
                // tiled products exactly.
                if a[x] == 0.0 {
                    assert_eq!(b[x], 0.0, "{spec:?} edge {e} x={x}: zero not exact");
                }
            }
        }
    }
}

#[test]
fn simd_matches_scalar_fused_on_every_family() {
    for spec in family_specs() {
        let mrf = builders::build(&spec, 29);
        let msgs = Messages::uniform(&mrf);
        churn(&mrf, &msgs, 1);
        let mut sc_s = NodeScratch::new();
        let mut sc_v = NodeScratch::new();
        for j in 0..mrf.num_nodes() as u32 {
            let mut scalar_out: Vec<(u32, Vec<f64>, f64)> = Vec::new();
            fused_node_refresh(&mrf, &msgs, j, None, &mut sc_s, Kernel::Scalar, |e, vals, res| {
                scalar_out.push((e, vals.to_vec(), res));
            });
            let mut k = 0usize;
            fused_node_refresh(&mrf, &msgs, j, None, &mut sc_v, Kernel::Simd, |e, vals, res| {
                let (se, svals, sres) = &scalar_out[k];
                assert_eq!(*se, e, "{spec:?} node {j} emit order");
                assert_eq!(svals.len(), vals.len());
                for x in 0..vals.len() {
                    assert!(
                        (svals[x] - vals[x]).abs() <= 1e-12,
                        "{spec:?} node {j} edge {e} x={x}"
                    );
                }
                assert!(
                    (sres - res).abs() <= 1e-12,
                    "{spec:?} node {j} edge {e} residual {sres} vs {res}"
                );
                k += 1;
            });
            assert_eq!(k, scalar_out.len(), "{spec:?} node {j} emit count");
        }
    }
}

#[test]
fn scalar_kernel_is_bitwise_the_reference_path() {
    // The scalar kernel must reproduce the pre-SIMD code path bit for
    // bit: exact equality against the reference wrapper (which is that
    // path frozen), for both message values and residual pricing.
    for spec in family_specs() {
        let mrf = builders::build(&spec, 41);
        let msgs = Messages::uniform(&mrf);
        churn(&mrf, &msgs, 1);
        let mut gather = MsgScratch::new();
        let mut a = msg_buf();
        let mut b = msg_buf();
        let mut cur = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            let la = compute_message_with(&mrf, &msgs, e, &mut a, &mut gather, Kernel::Scalar);
            let lb = compute_message(&mrf, &msgs, e, &mut b);
            assert_eq!(la, lb);
            assert_eq!(&a[..la], &b[..lb], "{spec:?} edge {e}: scalar not bitwise");
            // In-kernel residual == read-then-residual_l2, bitwise.
            let cl = msgs.read_msg(&mrf, e, &mut cur);
            let want = residual_l2(&a[..la], &cur[..cl]);
            let got = msgs.residual_l2_against(&mrf, e, &a[..la], Kernel::Scalar);
            assert_eq!(got.to_bits(), want.to_bits(), "{spec:?} edge {e} residual");
        }
    }
}

#[test]
fn fused_write_residual_matches_recomputed_residual() {
    for spec in [ModelSpec::Ldpc { n: 24, flip_prob: 0.07 }, ModelSpec::Potts { n: 4, q: 32 }] {
        let mrf = builders::build(&spec, 7);
        let msgs = Messages::uniform(&mrf);
        churn(&mrf, &msgs, 1);
        let mut out = msg_buf();
        let mut cur = msg_buf();
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            for e in 0..mrf.num_messages() as u32 {
                let len = compute_message(&mrf, &msgs, e, &mut out);
                // Reference: residual against the value before the write.
                let cl = msgs.read_msg(&mrf, e, &mut cur);
                let want = residual_l2(&out[..len], &cur[..cl]);
                let got = msgs.write_msg_residual(&mrf, e, &out[..len], kernel);
                match kernel {
                    Kernel::Scalar => {
                        assert_eq!(got.to_bits(), want.to_bits(), "{spec:?} edge {e}")
                    }
                    Kernel::Simd => assert!(
                        (got - want).abs() <= 1e-12,
                        "{spec:?} edge {e}: fused {got} vs recomputed {want}"
                    ),
                }
                // The write landed: a second fused write of the same
                // value reports zero residual.
                assert_eq!(msgs.write_msg_residual(&mrf, e, &out[..len], kernel), 0.0);
            }
        }
    }
}

#[test]
fn bulk_and_borrowed_reads_match_per_cell_reads() {
    let inst = builders::ldpc::build(24, 0.07, 11);
    let mrf = &inst.mrf;
    let msgs = Messages::uniform(mrf);
    churn(mrf, &msgs, 1);
    let snap = msgs.snapshot();
    let mut a = msg_buf();
    let mut b = msg_buf();
    for e in 0..mrf.num_messages() as u32 {
        let la = msgs.read_msg(mrf, e, &mut a);
        let lb = msgs.read_msg_bulk(mrf, e, &mut b);
        assert_eq!(la, lb);
        assert_eq!(&a[..la], &b[..lb], "edge {e}: bulk read differs");
        // The live atomic state cannot hand out borrows; snapshots must.
        assert!(msgs.borrow_msg(mrf, e).is_none());
        let v = snap.as_slice().borrow_msg(mrf, e).expect("snapshot borrows");
        assert_eq!(v, &a[..la], "edge {e}: borrowed slice differs");
        // Bulk writes land the same values as per-cell writes.
        msgs.write_msg_bulk(mrf, e, &a[..la]);
        let lc = msgs.read_msg(mrf, e, &mut b);
        assert_eq!(&a[..la], &b[..lc], "edge {e}: bulk write differs");
    }
}

#[test]
fn zero_normalizer_fallback_identical_across_kernels() {
    use relaxed_bp::model::{FactorPool, GraphBuilder, Mrf, NodeFactors};
    let mut gb = GraphBuilder::new(2);
    gb.add_edge(0, 1);
    let g = gb.build();
    let mut pool = FactorPool::new();
    let f = pool.add(2, 2, &[0.0, 0.0, 0.0, 0.0]);
    let m = Mrf::assemble(
        "zero",
        g,
        vec![2, 2],
        NodeFactors::from_vecs(&[vec![1.0, 1.0], vec![1.0, 1.0]]),
        vec![f],
        pool,
    );
    let msgs = Messages::uniform(&m);
    let mut out = msg_buf();
    let mut gather = MsgScratch::new();
    for kernel in [Kernel::Scalar, Kernel::Simd] {
        compute_message_with(&m, &msgs, 0, &mut out, &mut gather, kernel);
        assert_eq!(&out[..2], &[0.5, 0.5], "{kernel:?}");
    }
}

#[test]
fn lookahead_kernels_agree_and_price_identically() {
    for spec in [ModelSpec::Ldpc { n: 24, flip_prob: 0.07 }, ModelSpec::PowerLaw { n: 60, m: 3 }] {
        let mrf = builders::build(&spec, 13);
        let live = Messages::uniform(&mrf);
        let a = Lookahead::init_fused(&mrf, &live, Kernel::Scalar);
        let b = Lookahead::init_fused(&mrf, &live, Kernel::Simd);
        let mut pa = msg_buf();
        let mut pb = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            assert!((a.residual(e) - b.residual(e)).abs() <= 1e-12, "{spec:?} edge {e}");
            let la = a.read_pending(&mrf, e, &mut pa);
            let lb = b.read_pending(&mrf, e, &mut pb);
            assert_eq!(la, lb);
            for x in 0..la {
                assert!((pa[x] - pb[x]).abs() <= 1e-12, "{spec:?} edge {e} x={x}");
            }
        }
        assert_eq!(a.kernel(), Kernel::Scalar);
        assert_eq!(b.kernel(), Kernel::Simd);
    }
}

/// Scalar and simd engine runs of the same config land on the same fixed
/// point. Repeated scalar runs are bit-stable (deterministic update
/// count) — pinning the pre-SIMD trajectory as reproducible.
#[test]
fn engine_runs_share_fixed_point_across_kernels() {
    for alg in [
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::Priority,
    ] {
        let mut marginals = Vec::new();
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let mut cfg = RunConfig::new(ModelSpec::Potts { n: 4, q: 32 }, alg.clone())
                .with_threads(2)
                .with_seed(37)
                .with_kernel(kernel);
            cfg.time_limit_secs = 60.0;
            let rep = run_config(&cfg).unwrap();
            assert!(rep.stats.converged, "{alg:?} {kernel:?}");
            marginals.push(rep.marginals());
        }
        let diff = max_marginal_diff(&marginals[0], &marginals[1]);
        assert!(diff < 1e-2, "{alg:?}: scalar vs simd diff {diff}");
    }
    // The scalar trajectory is reproducible run to run (bit-stable
    // sequential engine: identical update counts).
    let mut counts = Vec::new();
    for _ in 0..2 {
        let cfg = RunConfig::new(
            ModelSpec::Potts { n: 4, q: 32 },
            AlgorithmSpec::SequentialResidual,
        )
        .with_seed(37)
        .with_kernel(Kernel::Scalar);
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged);
        counts.push(rep.stats.metrics.total.updates);
    }
    assert_eq!(counts[0], counts[1], "scalar sequential trajectory is deterministic");
}

#[test]
fn ldpc_decodes_under_both_kernels() {
    let inst = builders::ldpc::build(48, 0.05, 19);
    let spec = ModelSpec::Ldpc { n: 48, flip_prob: 0.05 };
    for kernel in [Kernel::Scalar, Kernel::Simd] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(19)
            .with_kernel(kernel);
        let msgs = relaxed_bp::run::build_messages(&cfg, &inst.mrf).unwrap();
        let engine = relaxed_bp::engines::build_engine(&cfg.algorithm);
        let stats = engine.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "{kernel:?}");
        let bits = relaxed_bp::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent, "{kernel:?}");
    }
}
