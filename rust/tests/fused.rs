//! Property tests for the node-centric fused update kernel and the
//! batched scheduler operations (PR 4):
//!
//! - the fused refresh path matches the edge-wise path to ≤ 1e-12 on
//!   every model family (including transposed edge factors and the LDPC
//!   zero-normalizer fallback);
//! - fused engine runs share the edgewise fixed point and keep the
//!   entry/epoch/claim pop-accounting identity across shard counts
//!   {1, 2, 7, num_threads};
//! - `insert_batch` / `pop_batch` preserve pop-accounting parity (every
//!   successful pop is exactly one of stale / lost claim / processed).

use relaxed_bp::bp::{
    compute_message, fused_node_refresh, max_marginal_diff, msg_buf, Kernel, Lookahead, Messages,
    NodeScratch,
};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, PartitionSpec, RunConfig};
use relaxed_bp::engines::Engine;
use relaxed_bp::model::builders;
use relaxed_bp::run::{build_messages, run_config};
use relaxed_bp::util::Xoshiro256;

/// Every family in the roster, at property-test sizes. Covers binary
/// grids (plain + transposed factor orientations), non-binary Potts,
/// wide-domain LDPC (deterministic parity factors → exact zeros and the
/// zero-normalizer fallback), trees, and power-law hubs.
fn family_specs() -> Vec<ModelSpec> {
    vec![
        ModelSpec::Tree { n: 31 },
        ModelSpec::Path { n: 8 },
        ModelSpec::AdversarialTree { n: 36 },
        ModelSpec::UniformTree { n: 40, arity: 3 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
        ModelSpec::PowerLaw { n: 80, m: 3 },
    ]
}

/// Drive the message state away from uniform so excluded products are
/// non-trivial: a few deterministic rounds of committed updates.
fn churn(mrf: &relaxed_bp::model::Mrf, msgs: &Messages, rounds: usize) {
    let mut out = msg_buf();
    for _ in 0..rounds {
        for e in 0..mrf.num_messages() as u32 {
            let len = compute_message(mrf, msgs, e, &mut out);
            msgs.write_msg(mrf, e, &out[..len]);
        }
    }
}

#[test]
fn fused_kernel_matches_edgewise_on_every_family() {
    for spec in family_specs() {
        let mrf = builders::build(&spec, 17);
        let msgs = Messages::uniform(&mrf);
        churn(&mrf, &msgs, 2);
        let mut sc = NodeScratch::new();
        let mut expect = msg_buf();
        for j in 0..mrf.num_nodes() as u32 {
            let mut emitted = 0usize;
            fused_node_refresh(&mrf, &msgs, j, None, &mut sc, Kernel::Scalar, |e, vals, _res| {
                emitted += 1;
                let len = compute_message(&mrf, &msgs, e, &mut expect);
                assert_eq!(len, vals.len(), "{spec:?} edge {e}");
                for x in 0..len {
                    assert!(
                        (vals[x] - expect[x]).abs() <= 1e-12,
                        "{spec:?} node {j} edge {e} x={x}: {} vs {}",
                        vals[x],
                        expect[x]
                    );
                }
            });
            assert_eq!(emitted, mrf.graph.degree(j as usize), "{spec:?} node {j}");
        }
    }
}

#[test]
fn fused_lookahead_init_matches_edgewise_on_every_family() {
    for spec in family_specs() {
        let mrf = builders::build(&spec, 23);
        let msgs = Messages::uniform(&mrf);
        churn(&mrf, &msgs, 1);
        let edgewise = Lookahead::init(&mrf, &msgs, Kernel::Scalar);
        let fused = Lookahead::init_fused(&mrf, &msgs, Kernel::Scalar);
        let mut pa = msg_buf();
        let mut pb = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            assert!(
                (edgewise.residual(e) - fused.residual(e)).abs() <= 1e-12,
                "{spec:?} edge {e}: {} vs {}",
                edgewise.residual(e),
                fused.residual(e)
            );
            let la = edgewise.read_pending(&mrf, e, &mut pa);
            let lb = fused.read_pending(&mrf, e, &mut pb);
            assert_eq!(la, lb);
            for x in 0..la {
                assert!((pa[x] - pb[x]).abs() <= 1e-12, "{spec:?} edge {e} x={x}");
            }
        }
    }
}

#[test]
fn fused_refresh_node_skip_preserves_untouched_pending() {
    let mrf = builders::build(&ModelSpec::Ising { n: 4 }, 5);
    let msgs = Messages::uniform(&mrf);
    let la = Lookahead::init(&mrf, &msgs, Kernel::Simd);
    let e = 2u32;
    let rev = mrf.graph.reverse(e);
    let j = mrf.graph.edge_dst[e as usize];
    let mut before = msg_buf();
    la.read_pending(&mrf, rev, &mut before);
    let res_before = la.residual(rev);
    let mut sc = NodeScratch::new();
    let mut batch = Vec::new();
    la.refresh_node(&mrf, &msgs, j, Some(rev), &mut sc, &mut batch);
    assert!(batch.iter().all(|&(k, _)| k != rev), "skipped edge not refreshed");
    let mut after = msg_buf();
    la.read_pending(&mrf, rev, &mut after);
    assert_eq!(&before[..], &after[..], "skipped edge pending untouched");
    assert_eq!(res_before, la.residual(rev));
}

/// Fused and edgewise runs of the same config land on the same fixed
/// point, converge below ε, and both satisfy the pop-accounting identity
/// `pops = stale_pops + claim_failures + updates` (every successful pop
/// is exactly one of the three), across shard counts {1, 2, 7, threads}.
#[test]
fn fused_engine_parity_and_pop_accounting_across_shard_counts() {
    let threads = 4usize;
    for shards in [1usize, 2, 7, 0] {
        // shards = 0 resolves to one shard per worker thread.
        let partition = PartitionSpec::Affine { shards, spill: 0.1, bfs: false };
        let mut marginals = Vec::new();
        for fused in [false, true] {
            let mut cfg = RunConfig::new(
                ModelSpec::Ising { n: 5 },
                AlgorithmSpec::RelaxedResidual,
            )
            .with_threads(threads)
            .with_seed(31)
            .with_partition(partition)
            .with_fused(fused);
            cfg.time_limit_secs = 60.0;
            let rep = run_config(&cfg).unwrap();
            assert!(rep.stats.converged, "shards={shards} fused={fused}");
            assert!(
                rep.stats.final_max_priority < cfg.epsilon,
                "shards={shards} fused={fused}"
            );
            let m = &rep.stats.metrics.total;
            assert_eq!(
                m.pops,
                m.stale_pops + m.claim_failures + m.updates,
                "pop accounting, shards={shards} fused={fused}"
            );
            marginals.push(rep.marginals());
        }
        let diff = max_marginal_diff(&marginals[0], &marginals[1]);
        assert!(diff < 1e-2, "shards={shards}: fused vs edgewise diff {diff}");
    }
}

/// The batched engine (batch draining + fused node refresh) keeps the
/// accounting identity and decodes LDPC.
#[test]
fn fused_batched_engine_pop_accounting_and_ldpc_decode() {
    let inst = builders::ldpc::build(48, 0.05, 19);
    let spec = ModelSpec::Ldpc { n: 48, flip_prob: 0.05 };
    for fused in [false, true] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidualBatched { batch: 8 })
            .with_threads(2)
            .with_seed(19)
            .with_fused(fused);
        let msgs = build_messages(&cfg, &inst.mrf).unwrap();
        let engine = relaxed_bp::engines::build_engine(&cfg.algorithm);
        let stats = engine.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "fused={fused}");
        let m = &stats.metrics.total;
        assert_eq!(m.pops, m.stale_pops + m.claim_failures + m.updates, "fused={fused}");
        let bits = relaxed_bp::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent, "fused={fused}");
    }
}

/// Splash's fused post-splash refresh preserves convergence and the
/// node-residual fixed point.
#[test]
fn fused_splash_matches_edgewise_splash() {
    let spec = ModelSpec::Ising { n: 4 };
    let mut marginals = Vec::new();
    for fused in [false, true] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedSmartSplash { h: 2 })
            .with_threads(2)
            .with_seed(29)
            .with_fused(fused);
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged, "fused={fused}");
        marginals.push(rep.marginals());
    }
    let diff = max_marginal_diff(&marginals[0], &marginals[1]);
    assert!(diff < 1e-2, "fused vs edgewise splash diff {diff}");
}

/// Multiset preservation of the raw batched scheduler ops under hinted
/// shard routing — the scheduler-level half of the accounting story.
#[test]
fn scheduler_batch_ops_parity_across_shard_counts() {
    use relaxed_bp::sched::{Entry, Multiqueue, Scheduler};
    for shards in [1usize, 2, 7, 4] {
        let q = Multiqueue::shard_affine(4, 4, shards, 0.1);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let n = 500u32;
        let mut batch = Vec::new();
        for t in 0..n {
            batch.push(Entry { prio: rng.next_f64(), task: t, epoch: 0 });
            if batch.len() == 6 || t + 1 == n {
                q.insert_batch(&batch, &mut rng, Some(t % shards as u32));
                batch.clear();
            }
        }
        assert_eq!(q.approx_len(), n as usize);
        let mut seen = std::collections::HashSet::new();
        let mut buf = Vec::new();
        let mut home = 0u32;
        loop {
            buf.clear();
            if q.pop_batch(&mut rng, Some(home), 9, &mut buf) == 0 {
                break;
            }
            for e in &buf {
                assert!(seen.insert(e.task), "shards={shards} dup {}", e.task);
            }
            home = (home + 1) % shards as u32;
        }
        assert_eq!(seen.len(), n as usize, "shards={shards}");
        assert_eq!(q.approx_len(), 0, "shards={shards}");
    }
}
