//! Cross-engine parity for the `exec::WorkerPool` runtime.
//!
//! Every queue-driven engine ported onto the shared runtime must (a)
//! reach marginals within tolerance of `exact_marginals` on a small tree
//! and a small grid, single- and multi-threaded, and (b) report the same
//! `MetricsReport` field semantics: every successful pop is accounted for
//! as exactly one of {stale entry, lost claim race, processed task}, and
//! useful updates never exceed total updates.

use relaxed_bp::bp::{all_marginals, exact_marginals, max_marginal_diff};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, PartitionSpec, RunConfig};
use relaxed_bp::coordinator::MetricsReport;
use relaxed_bp::engines::{build_engine, Engine, EngineStats};
use relaxed_bp::model::builders;

/// Queue-driven engines applicable to arbitrary (possibly loopy) models.
fn pool_roster() -> Vec<AlgorithmSpec> {
    vec![
        AlgorithmSpec::CoarseGrained,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::WeightDecay,
        AlgorithmSpec::Priority,
        AlgorithmSpec::Splash { h: 2 },
        AlgorithmSpec::SmartSplash { h: 2 },
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        AlgorithmSpec::RandomSplash { h: 2 },
        AlgorithmSpec::RelaxedResidualBatched { batch: 8 },
    ]
}

fn run(spec: &ModelSpec, alg: &AlgorithmSpec, threads: usize, seed: u64) -> (Vec<Vec<f64>>, EngineStats) {
    run_partitioned(spec, alg, threads, seed, PartitionSpec::Off)
}

fn run_partitioned(
    spec: &ModelSpec,
    alg: &AlgorithmSpec,
    threads: usize,
    seed: u64,
    partition: PartitionSpec,
) -> (Vec<Vec<f64>>, EngineStats) {
    let mrf = builders::build(spec, seed);
    let cfg = RunConfig::new(spec.clone(), alg.clone())
        .with_threads(threads)
        .with_seed(seed)
        .with_partition(partition);
    let msgs = relaxed_bp::run::build_messages(&cfg, &mrf).unwrap();
    let stats = build_engine(alg).run(&mrf, &msgs, &cfg).unwrap();
    assert!(
        stats.converged,
        "{} (p={threads}, partition={}) did not converge",
        alg.name(),
        partition.label()
    );
    (all_marginals(&mrf, &msgs), stats)
}

/// Processed-task count per engine family, for the pop-accounting
/// identity. Message engines process one committed update per claimed
/// task; splash engines process one splash — or one wasted pop when the
/// node's priority decayed between insert and claim.
fn processed_tasks(alg: &AlgorithmSpec, m: &MetricsReport) -> u64 {
    match alg {
        AlgorithmSpec::Splash { .. }
        | AlgorithmSpec::SmartSplash { .. }
        | AlgorithmSpec::RelaxedSmartSplash { .. }
        | AlgorithmSpec::RandomSplash { .. } => m.total.splashes + m.total.wasted_pops,
        _ => m.total.updates,
    }
}

#[test]
fn all_pool_engines_match_exact_marginals_on_tree() {
    let spec = ModelSpec::Tree { n: 15 };
    let mrf = builders::build(&spec, 2);
    let exact = exact_marginals(&mrf, 1 << 20).unwrap();
    for alg in pool_roster() {
        for threads in [1, 4] {
            let (bp, _) = run(&spec, &alg, threads, 2);
            let diff = max_marginal_diff(&bp, &exact);
            assert!(
                diff < 1e-3,
                "{} (p={threads}) tree marginal diff {diff}",
                alg.name()
            );
        }
    }
}

#[test]
fn all_pool_engines_match_exact_marginals_on_grid() {
    // Loopy BP carries a schedule-independent bias on grids; the oracle
    // tolerance is correspondingly loose (cf. the per-engine unit tests).
    let spec = ModelSpec::Ising { n: 4 };
    let mrf = builders::build(&spec, 3);
    let exact = exact_marginals(&mrf, 1 << 20).unwrap();
    for alg in pool_roster() {
        for threads in [1, 4] {
            let (bp, _) = run(&spec, &alg, threads, 3);
            let diff = max_marginal_diff(&bp, &exact);
            assert!(
                diff < 0.08,
                "{} (p={threads}) grid marginal diff {diff}",
                alg.name()
            );
        }
    }
}

#[test]
fn optimal_tree_engines_match_exact_marginals() {
    // 15 nodes: 2^15 joint states, within the oracle's enumeration limit.
    let spec = ModelSpec::Tree { n: 15 };
    let mrf = builders::build(&spec, 1);
    let exact = exact_marginals(&mrf, 1 << 20).unwrap();
    for alg in [AlgorithmSpec::OptimalTree, AlgorithmSpec::RelaxedOptimalTree] {
        for threads in [1, 4] {
            let (bp, stats) = run(&spec, &alg, threads, 1);
            let diff = max_marginal_diff(&bp, &exact);
            assert!(diff < 1e-6, "{} (p={threads}) diff {diff}", alg.name());
            // Each directed message fires its useful update exactly once.
            assert_eq!(stats.metrics.total.useful_updates, mrf.num_messages() as u64);
        }
    }
}

#[test]
fn pop_accounting_identity_holds_for_every_engine() {
    // The runtime's shared counter semantics: pops = stale_pops +
    // claim_failures + processed tasks, on every engine, at every thread
    // count — the field meanings cannot drift per engine anymore.
    for (spec, algs) in [
        (ModelSpec::Ising { n: 5 }, pool_roster()),
        (
            ModelSpec::Tree { n: 63 },
            vec![AlgorithmSpec::OptimalTree, AlgorithmSpec::RelaxedOptimalTree],
        ),
    ] {
        for alg in algs {
            for threads in [1, 4] {
                let (_, stats) = run(&spec, &alg, threads, 7);
                let m = &stats.metrics;
                assert_eq!(
                    m.total.pops,
                    m.total.stale_pops + m.total.claim_failures + processed_tasks(&alg, m),
                    "{} (p={threads}): pop accounting",
                    alg.name()
                );
                assert!(
                    m.total.useful_updates <= m.total.updates,
                    "{} (p={threads}): useful ≤ total",
                    alg.name()
                );
                assert_eq!(
                    m.per_thread_updates.len(),
                    threads,
                    "{} (p={threads}): one per-thread row per worker",
                    alg.name()
                );
                assert_eq!(
                    m.per_thread_updates.iter().sum::<u64>(),
                    m.total.updates,
                    "{} (p={threads}): per-thread rows sum to total",
                    alg.name()
                );
            }
        }
    }
}

#[test]
fn parity_holds_under_shard_affine_partitioning() {
    // The acceptance shard counts {1, 2, 7, num_threads} (0 = auto =
    // num_threads): sharded arenas + the shard-affine Multiqueue leave
    // every pool engine on the oracle fixed point, and the pop-accounting
    // identity survives the hinted insert/pop paths.
    let threads = 4;
    let spec = ModelSpec::Ising { n: 4 };
    let mrf = builders::build(&spec, 3);
    let exact = exact_marginals(&mrf, 1 << 20).unwrap();
    for shards in [1usize, 2, 7, 0] {
        let axis = PartitionSpec::Affine { shards, spill: 0.1, bfs: false };
        for alg in pool_roster() {
            let (bp, stats) = run_partitioned(&spec, &alg, threads, 3, axis);
            let diff = max_marginal_diff(&bp, &exact);
            assert!(
                diff < 0.08,
                "{} (shards={shards}) grid marginal diff {diff}",
                alg.name()
            );
            let m = &stats.metrics;
            assert_eq!(
                m.total.pops,
                m.total.stale_pops + m.total.claim_failures + processed_tasks(&alg, m),
                "{} (shards={shards}): pop accounting",
                alg.name()
            );
        }
    }
}

#[test]
fn converged_runs_report_sub_epsilon_final_priority() {
    // Engines that verify convergence must exit with every true priority
    // below epsilon (the verifier's guarantee, uniform across policies).
    let spec = ModelSpec::Ising { n: 5 };
    for alg in pool_roster() {
        let (_, stats) = run(&spec, &alg, 2, 5);
        let cfg = RunConfig::new(spec.clone(), alg.clone());
        assert!(
            stats.final_max_priority < cfg.epsilon,
            "{}: final max priority {}",
            alg.name(),
            stats.final_max_priority
        );
    }
}
