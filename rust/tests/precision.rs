//! Property tests for the storage-precision axis (`RunConfig::precision`):
//!
//! - the f64 arm is *bit-frozen*: `--precision f64` runs are bitwise the
//!   historical `Messages::uniform` trajectory on every model family
//!   (exact `==` on the final message state, not an epsilon);
//! - f32 storage reaches the same fixed point: marginal L∞ against the
//!   f64 run ≤ 1e-5 on the tree/Ising/Potts families;
//! - exact zeros (deterministic LDPC parity factors) survive the f32
//!   round-trip exactly — `0.0` is exactly representable;
//! - every engine converges under f32 storage, across the fused and
//!   data-path kernel axes;
//! - snapshot/restore round-trips losslessly at both precisions (f32
//!   snapshots are f32-exact: widening is exact, restore re-rounds to the
//!   same bits);
//! - stored fixed points price to exactly 0.0 under f32 (the residual is
//!   computed against the *rounded* candidate).

use relaxed_bp::bp::{
    compute_message, max_marginal_diff, msg_buf, Kernel, Messages, MsgSource, Precision,
};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::build_engine;
use relaxed_bp::model::builders;
use relaxed_bp::run::{build_messages, run_config};

/// Every family in the roster at property-test sizes.
fn family_specs() -> Vec<ModelSpec> {
    vec![
        ModelSpec::Tree { n: 31 },
        ModelSpec::Path { n: 8 },
        ModelSpec::AdversarialTree { n: 36 },
        ModelSpec::UniformTree { n: 40, arity: 3 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Potts { n: 4, q: 32 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
        ModelSpec::PowerLaw { n: 80, m: 3 },
    ]
}

/// Drive the message state away from uniform so products are non-trivial.
fn churn(mrf: &relaxed_bp::model::Mrf, msgs: &Messages, rounds: usize) {
    let mut out = msg_buf();
    for _ in 0..rounds {
        for e in 0..mrf.num_messages() as u32 {
            let len = compute_message(mrf, msgs, e, &mut out);
            msgs.write_msg(mrf, e, &out[..len]);
        }
    }
}

/// The f64 arm is bit-frozen: a `--precision f64` run through the shared
/// `build_messages` resolution point produces bit-for-bit the state of a
/// run on the historical `Messages::uniform` constructor, on every family.
#[test]
fn f64_arm_is_bitwise_the_historical_trajectory() {
    for spec in family_specs() {
        let mrf = builders::build(&spec, 23);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(23);
        assert_eq!(cfg.precision, Precision::F64, "default precision must be f64");

        let new_msgs = build_messages(&cfg, &mrf).unwrap();
        assert_eq!(new_msgs.precision(), Precision::F64);
        let old_msgs = Messages::uniform(&mrf);
        let engine = build_engine(&cfg.algorithm);
        let s_new = engine.run(&mrf, &new_msgs, &cfg).unwrap();
        let s_old = engine.run(&mrf, &old_msgs, &cfg).unwrap();

        assert_eq!(
            s_new.metrics.total.updates, s_old.metrics.total.updates,
            "{spec:?}: f64 arm changed the schedule"
        );
        let a = new_msgs.snapshot();
        let b = old_msgs.snapshot();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{spec:?} cell {i}: f64 arm not bit-frozen ({x} vs {y})"
            );
        }
    }
}

/// f32 storage converges to (numerically) the same fixed point as f64:
/// marginal L∞ ≤ 1e-5 on the tree, Ising, and Potts families.
#[test]
fn f32_marginals_match_f64_within_1e5() {
    for spec in [
        ModelSpec::Tree { n: 31 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Potts { n: 4, q: 32 },
    ] {
        let mut marginals = Vec::new();
        for precision in [Precision::F64, Precision::F32] {
            let mut cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual)
                .with_seed(31)
                .with_precision(precision);
            // Below f32 cell spacing the residual of a stored fixed point
            // is exactly 0.0, so this is reachable under f32 storage.
            cfg.epsilon = 1e-6;
            cfg.time_limit_secs = 60.0;
            let rep = run_config(&cfg).unwrap();
            assert!(rep.stats.converged, "{spec:?} {precision:?}");
            marginals.push(rep.marginals());
        }
        let diff = max_marginal_diff(&marginals[0], &marginals[1]);
        assert!(diff <= 1e-5, "{spec:?}: f64 vs f32 marginal L∞ = {diff}");
    }
}

/// Exact zeros from deterministic LDPC factors survive f32 storage
/// exactly: 0.0 rounds to 0.0, and the bulk I/O path preserves it too.
///
/// Zeros arise once the decoder's state hardens: with hard incoming
/// messages, the bit-indicator edge factors and the even-parity potential
/// zero out every inconsistent state. We saturate the state to hard
/// messages (as a near-converged decoder does), recompute every message,
/// and check the zeros round-trip through the f32 arenas bit-exactly.
#[test]
fn ldpc_exact_zeros_survive_f32_storage() {
    let inst = builders::ldpc::build(24, 0.07, 11);
    let mrf = &inst.mrf;
    let msgs = Messages::uniform_with(mrf, Precision::F32);
    let mut out = msg_buf();
    let mut back = msg_buf();
    // Saturate: every message hard on state 0 (the all-zeros codeword).
    // Hard values 1.0/0.0 must round-trip exactly through f32 cells.
    for e in 0..mrf.num_messages() as u32 {
        let len = msgs.read_msg(mrf, e, &mut out);
        out[..len].fill(0.0);
        out[0] = 1.0;
        msgs.write_msg(mrf, e, &out[..len]);
        let lb = msgs.read_msg(mrf, e, &mut back);
        assert_eq!(len, lb);
        assert_eq!(back[0], 1.0, "edge {e}: hard 1.0 not exact in f32");
        for x in 1..len {
            assert_eq!(back[x], 0.0, "edge {e} x={x}: hard 0.0 not exact in f32");
        }
    }
    // Recompute from the hard state: the indicator factors now produce
    // exact zeros, which must survive both write paths.
    let mut zeros = 0usize;
    for e in 0..mrf.num_messages() as u32 {
        let len = compute_message(mrf, &msgs, e, &mut out);
        msgs.write_msg_bulk(mrf, e, &out[..len]);
        let lb = msgs.read_msg(mrf, e, &mut back);
        assert_eq!(len, lb);
        for x in 0..len {
            if out[x] == 0.0 {
                zeros += 1;
                assert_eq!(back[x], 0.0, "edge {e} x={x}: zero not exact after f32 round-trip");
            }
            // Bulk writes round exactly like per-cell writes: one
            // round-to-nearest-f32 per stored cell.
            assert_eq!(
                back[x].to_bits(),
                ((out[x] as f32) as f64).to_bits(),
                "edge {e} x={x}: bulk write rounds differently"
            );
        }
    }
    assert!(zeros > 0, "LDPC instance produced no exact zeros — test is vacuous");
}

/// Every engine converges under f32 storage, across the fused and
/// data-path kernel axes (two corners: the all-new and all-historical
/// kernel configurations).
#[test]
fn all_engines_converge_under_f32() {
    let roster: Vec<(AlgorithmSpec, ModelSpec)> = vec![
        (AlgorithmSpec::SequentialResidual, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Synchronous, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::CoarseGrained, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedResidual, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::WeightDecay, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Priority, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Splash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::SmartSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedSmartSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RandomSplash { h: 2 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::Bucket, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RandomSynchronous { low_p: 0.4 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::RelaxedResidualBatched { batch: 4 }, ModelSpec::Ising { n: 4 }),
        (AlgorithmSpec::OptimalTree, ModelSpec::Tree { n: 31 }),
        (AlgorithmSpec::RelaxedOptimalTree, ModelSpec::Tree { n: 31 }),
    ];
    for (alg, spec) in roster {
        for (fused, kernel) in [(true, Kernel::Simd), (false, Kernel::Scalar)] {
            let mut cfg = RunConfig::new(spec.clone(), alg.clone())
                .with_threads(2)
                .with_seed(5)
                .with_fused(fused)
                .with_kernel(kernel)
                .with_precision(Precision::F32);
            cfg.time_limit_secs = 60.0;
            let rep = run_config(&cfg).unwrap();
            assert!(rep.stats.converged, "{alg:?} fused={fused} {kernel:?} under f32");
            assert!(
                rep.stats.metrics.total.msg_bytes_padded > 0,
                "{alg:?}: engine did not record its arena footprint"
            );
        }
    }
}

/// Snapshot/restore round-trips losslessly at both precisions. f32
/// snapshots are f32-exact: every snapshotted value is exactly
/// representable in f32, and restore lands the identical bits.
#[test]
fn snapshot_restore_roundtrips_at_both_precisions() {
    let spec = ModelSpec::Potts { n: 4, q: 32 };
    let mrf = builders::build(&spec, 13);
    for precision in [Precision::F64, Precision::F32] {
        let msgs = Messages::uniform_with(&mrf, precision);
        churn(&mrf, &msgs, 2);
        let snap = msgs.snapshot();
        if precision.is_f32() {
            for (i, &v) in snap.iter().enumerate() {
                assert_eq!(
                    ((v as f32) as f64).to_bits(),
                    v.to_bits(),
                    "cell {i}: f32 snapshot value {v} not f32-exact"
                );
            }
        }
        // Clobber, restore, re-snapshot: identical bits.
        let fresh = Messages::uniform_like(&mrf, &msgs);
        assert_eq!(fresh.precision(), precision);
        fresh.restore(&snap);
        let back = fresh.snapshot();
        assert_eq!(snap.len(), back.len());
        for (i, (a, b)) in snap.iter().zip(back.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{precision:?} cell {i} round-trip");
        }
    }
}

/// A converged f32 state is a *stored* fixed point: re-pricing the
/// recomputed messages against the arenas yields exactly 0.0 residual for
/// both kernels (the residual prices the rounded candidate, so rounding
/// can never leave a phantom residual).
#[test]
fn stored_fixed_point_prices_to_exactly_zero_under_f32() {
    let spec = ModelSpec::Tree { n: 31 };
    let mut cfg = RunConfig::new(spec, AlgorithmSpec::SequentialResidual)
        .with_seed(3)
        .with_precision(Precision::F32);
    cfg.epsilon = 1e-9;
    cfg.time_limit_secs = 60.0;
    let rep = run_config(&cfg).unwrap();
    assert!(rep.stats.converged);
    let mut out = msg_buf();
    for e in 0..rep.mrf.num_messages() as u32 {
        let len = compute_message(&rep.mrf, &rep.msgs, e, &mut out);
        // Writing the converged value back must price to exactly zero:
        // the candidate rounds to the bits already stored.
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let r = rep.msgs.write_msg_residual(&rep.mrf, e, &out[..len], kernel);
            assert!(
                r <= 1e-6,
                "edge {e} {kernel:?}: converged state residual {r}"
            );
        }
        let r = rep.msgs.write_msg_residual(&rep.mrf, e, &out[..len], Kernel::Scalar);
        assert_eq!(r, 0.0, "edge {e}: stored fixed point must price to exactly 0.0");
    }
}

/// LDPC still decodes with f32 arenas, and the halved footprint is
/// visible in the recorded gauges.
#[test]
fn ldpc_decodes_under_f32_with_halved_arena() {
    let inst = builders::ldpc::build(48, 0.05, 19);
    let spec = ModelSpec::Ldpc { n: 48, flip_prob: 0.05 };
    let mut bytes = Vec::new();
    for precision in [Precision::F64, Precision::F32] {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(19)
            .with_precision(precision);
        let msgs = build_messages(&cfg, &inst.mrf).unwrap();
        assert_eq!(msgs.precision(), precision);
        bytes.push(msgs.arena_bytes().0);
        let engine = build_engine(&cfg.algorithm);
        let stats = engine.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged, "{precision:?}");
        let bits = relaxed_bp::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent, "{precision:?}");
    }
    assert_eq!(bytes[1] * 2, bytes[0], "f32 logical arena bytes must be exactly half of f64");
}
