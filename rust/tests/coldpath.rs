//! Cold-path integration suite: model snapshot round-trips across every
//! model family × storage format {v1, v2} × load parallelism, the
//! parallel-vs-serial CSR construction equality per family, the
//! `obtain_model` cache ("generate once, sweep many"), and file-level
//! robustness (corruption / truncation must be clean errors, not panics).

use relaxed_bp::configio::ModelSpec;
use relaxed_bp::model::{builders, io as model_io, GraphBuilder, Mrf};

/// One small instance per model family (all nine builders).
fn families() -> Vec<ModelSpec> {
    vec![
        ModelSpec::Tree { n: 31 },
        ModelSpec::Path { n: 17 },
        ModelSpec::AdversarialTree { n: 15 },
        ModelSpec::UniformTree { n: 40, arity: 3 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Potts { n: 3, q: 32 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
        ModelSpec::PowerLaw { n: 64, m: 2 },
    ]
}

/// Field-by-field bit-exact equality of two models (graph arrays, domains,
/// node factors, and every pairwise factor entry).
fn assert_models_equal(m: &Mrf, back: &Mrf) {
    assert_eq!(back.name, m.name);
    assert_eq!(back.num_nodes(), m.num_nodes());
    assert_eq!(back.num_messages(), m.num_messages());
    assert_eq!(back.domain, m.domain);
    assert_eq!(back.graph.offsets, m.graph.offsets);
    assert_eq!(back.graph.adj_node, m.graph.adj_node);
    assert_eq!(back.graph.adj_out, m.graph.adj_out);
    assert_eq!(back.graph.adj_in, m.graph.adj_in);
    assert_eq!(back.graph.edge_src, m.graph.edge_src);
    assert_eq!(back.graph.edge_dst, m.graph.edge_dst);
    assert_eq!(back.msg_offset, m.msg_offset);
    assert_eq!(back.total_msg_len, m.total_msg_len);
    for i in 0..m.num_nodes() {
        assert_eq!(back.node_factors.of(i), m.node_factors.of(i));
    }
    for e in 0..m.num_messages() {
        let fr_a = m.edge_factor[e];
        let fr_b = back.edge_factor[e];
        assert_eq!(m.pool.shape_of(fr_a), back.pool.shape_of(fr_b));
        let (dr, dc) = m.pool.shape_of(fr_a);
        for a in 0..dr {
            for b in 0..dc {
                assert_eq!(m.pool.get(fr_a, a, b), back.pool.get(fr_b, a, b));
            }
        }
    }
}

fn tmp_path(tag: &str, spec: &ModelSpec, seed: u64) -> String {
    std::env::temp_dir()
        .join(format!("coldpath_{tag}_{}", spec.cache_slug(seed)))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn roundtrip_all_families_v2_across_load_threads() {
    for spec in families() {
        let m = builders::build(&spec, 7);
        let path = tmp_path("v2", &spec, 7);
        let bytes = model_io::save(&m, &path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        for threads in [1, 2, 8] {
            let back = model_io::load_with_threads(&path, threads)
                .unwrap_or_else(|e| panic!("{} (threads={threads}): {e:#}", spec.name()));
            assert_models_equal(&m, &back);
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn roundtrip_all_families_v1() {
    for spec in families() {
        let m = builders::build(&spec, 7);
        let path = tmp_path("v1", &spec, 7);
        let bytes = model_io::save_v1(&m, &path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        // The threads knob must be a no-op for the v1 stream format.
        for threads in [1, 2, 8] {
            let back = model_io::load_with_threads(&path, threads).unwrap();
            assert_models_equal(&m, &back);
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn parallel_csr_build_matches_serial_per_family() {
    for spec in families() {
        let m = builders::build(&spec, 7);
        let g = &m.graph;
        let me = g.num_directed_edges() / 2;
        // Replay the family's frozen edge stream (undirected edge k is the
        // k-th add_edge call, stored as directed pair 2k / 2k+1).
        let mk = || {
            let mut gb = GraphBuilder::with_edge_capacity(g.num_nodes(), me);
            for k in 0..me {
                gb.add_edge(g.edge_src[2 * k] as usize, g.edge_dst[2 * k] as usize);
            }
            gb
        };
        let serial = mk().build_with_threads(1);
        for threads in [2, 8] {
            let par = mk().build_with_threads(threads);
            assert_eq!(par.offsets, serial.offsets, "{}", spec.name());
            assert_eq!(par.adj_node, serial.adj_node, "{}", spec.name());
            assert_eq!(par.adj_out, serial.adj_out, "{}", spec.name());
            assert_eq!(par.adj_in, serial.adj_in, "{}", spec.name());
            assert_eq!(par.edge_src, serial.edge_src, "{}", spec.name());
            assert_eq!(par.edge_dst, serial.edge_dst, "{}", spec.name());
        }
        // And the replay reproduces the original build bit-for-bit.
        assert_eq!(serial.offsets, g.offsets, "{}", spec.name());
        assert_eq!(serial.adj_node, g.adj_node, "{}", spec.name());
        assert_eq!(serial.adj_out, g.adj_out, "{}", spec.name());
        assert_eq!(serial.adj_in, g.adj_in, "{}", spec.name());
    }
}

#[test]
fn obtain_model_cache_roundtrip() {
    let dir = std::env::temp_dir().join("rbp_coldpath_cache");
    let spec = ModelSpec::Ising { n: 5 };
    // Stale entries from an earlier run would turn the miss into a hit.
    std::fs::remove_file(dir.join(spec.cache_slug(9))).ok();
    use relaxed_bp::model::io::LoadMode;
    // First call: cache miss → build + save. The read mode keeps this
    // test pinned to the historical copying path; the map path has its
    // own suite (tests/outofcore.rs).
    let (built, miss) =
        relaxed_bp::run::obtain_model(&spec, 9, Some(&dir), Some(&dir), LoadMode::Read, true)
            .unwrap();
    assert!(miss.model_bytes > 0, "save leg should record the file size");
    assert!(miss.load_secs == 0.0, "cache miss must not record a load");
    assert_eq!(miss.load_mode, LoadMode::Read, "builds report the read path");
    // Second call: cache hit → disk load, bit-identical model.
    let (loaded, hit) =
        relaxed_bp::run::obtain_model(&spec, 9, Some(&dir), None, LoadMode::Read, true).unwrap();
    assert!(hit.build_secs == 0.0, "cache hit must not rebuild");
    assert_eq!(hit.model_bytes, miss.model_bytes);
    assert_eq!(hit.load_mode, LoadMode::Read);
    assert_models_equal(&built, &loaded);
    // A different seed is a different cache entry → build leg again.
    let (_, other) =
        relaxed_bp::run::obtain_model(&spec, 10, Some(&dir), None, LoadMode::Read, true).unwrap();
    assert!(other.load_secs == 0.0);
    std::fs::remove_file(dir.join(spec.cache_slug(9))).ok();
}

#[test]
fn corrupted_and_truncated_files_are_clean_errors() {
    let spec = ModelSpec::Ising { n: 5 };
    let m = builders::build(&spec, 3);
    let path = tmp_path("corrupt", &spec, 3);
    model_io::save(&m, &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Flip a 128-byte window in the payload: inter-section alignment gaps
    // are under 64 bytes, so the window always covers checksummed section
    // data and the per-section checksum must catch the corruption.
    let mut bad = good.clone();
    let mid = bad.len() / 2;
    for b in bad[mid..(mid + 128).min(good.len())].iter_mut() {
        *b ^= 0x40;
    }
    std::fs::write(&path, &bad).unwrap();
    assert!(model_io::load(&path).is_err(), "bit flips must fail the checksum");

    // Truncation at several points must error out, never panic.
    for cut in [6, good.len() / 3, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).unwrap();
        assert!(model_io::load(&path).is_err(), "truncated at {cut}");
    }

    // Wrong magic / unsupported version.
    std::fs::write(&path, b"NOPEnope").unwrap();
    assert!(model_io::load(&path).is_err());
    let mut vbad = good;
    vbad[4..8].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&path, &vbad).unwrap();
    let err = model_io::load(&path).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "got: {err:#}");
    std::fs::remove_file(&path).ok();
}
