//! Integration tests for the telemetry subsystem: trace recording on real
//! engine runs, baseline round-trips, the regression comparator, and the
//! `bench-compare` CLI exit code (the acceptance gate).

use relaxed_bp::configio::{parse, AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::builders;
use relaxed_bp::telemetry::{
    bench_family, compare, run_bench, Baseline, BenchOpts, TraceRecorder, DEFAULT_TOLERANCE,
};
use relaxed_bp::bp::Messages;
use std::path::PathBuf;
use std::time::Duration;

fn tiny_opts(out_dir: &str) -> BenchOpts {
    // The dist2 cell forks worker ranks; they must exec the real CLI
    // binary, not this test harness.
    std::env::set_var("RELAXED_BP_EXE", env!("CARGO_BIN_EXE_relaxed-bp"));
    let mut opts = BenchOpts::quick();
    opts.samples = 1;
    opts.threads = vec![2];
    opts.families = vec!["tree".into(), "ising".into(), "ldpc".into()];
    opts.out_dir = PathBuf::from(out_dir);
    opts
}

#[test]
fn trace_recorder_on_relaxed_engine_run() {
    let spec = ModelSpec::Ising { n: 8 };
    let mrf = builders::build(&spec, 3);
    let msgs = Messages::uniform(&mrf);
    let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual).with_threads(2).with_seed(3);
    let recorder = TraceRecorder::new(Duration::from_millis(1));
    let engine = build_engine(&cfg.algorithm);
    let stats = engine.run_observed(&mrf, &msgs, &cfg, Some(&recorder)).unwrap();
    assert!(stats.converged);
    let trace = recorder.take();
    assert!(!trace.is_empty());
    let last = trace.points.last().unwrap();
    assert_eq!(last.updates, stats.metrics.total.updates, "final point = exact totals");
    assert!(last.max_priority < 1e-5, "converged below epsilon");
    assert!(
        trace.points.windows(2).all(|w| w[0].t_secs <= w[1].t_secs && w[0].updates <= w[1].updates),
        "trace is monotone in time and updates"
    );
}

#[test]
fn trace_recorder_on_sequential_baseline() {
    let spec = ModelSpec::Tree { n: 511 };
    let mrf = builders::build(&spec, 1);
    let msgs = Messages::uniform(&mrf);
    let cfg = RunConfig::new(spec, AlgorithmSpec::SequentialResidual);
    let recorder = TraceRecorder::new(Duration::from_micros(100));
    let engine = build_engine(&cfg.algorithm);
    let stats = engine.run_observed(&mrf, &msgs, &cfg, Some(&recorder)).unwrap();
    assert!(stats.converged);
    let trace = recorder.take();
    assert!(trace.len() >= 2, "start + final samples at minimum, got {}", trace.len());
    assert_eq!(trace.points[0].updates, 0, "start sample precedes the first commit");
    assert_eq!(trace.points.last().unwrap().updates, stats.metrics.total.updates);
}

#[test]
fn baseline_roundtrip_and_self_compare_is_clean() {
    let mut opts = tiny_opts("/tmp/rbp_telemetry_rt");
    opts.families = vec!["tree".into()];
    let b = bench_family("tree", &opts).unwrap();
    // serialize → deserialize → compare returns no diff on identical runs
    let text = b.to_json().to_string_pretty();
    let back = Baseline::from_json(&parse(&text).unwrap()).unwrap();
    assert_eq!(back, b);
    let d = compare(&b, &back, DEFAULT_TOLERANCE).unwrap();
    assert!(!d.has_regression());
    assert!(d.improvements.is_empty() && d.missing.is_empty() && d.added.is_empty());
}

#[test]
fn comparator_flags_injected_slowdown() {
    let mut opts = tiny_opts("/tmp/rbp_telemetry_slow");
    opts.families = vec!["ising".into()];
    let old = bench_family("ising", &opts).unwrap();
    let mut slow = old.clone();
    for c in &mut slow.cells {
        for t in &mut c.wall_secs {
            *t *= 2.0;
        }
    }
    let d = compare(&old, &slow, DEFAULT_TOLERANCE).unwrap();
    assert!(d.has_regression(), "2x slowdown must be flagged");
    assert_eq!(d.regressions.len(), old.cells.len());
}

#[test]
fn run_bench_writes_baseline_files_with_traces() {
    let dir = "/tmp/rbp_telemetry_bench";
    std::fs::remove_dir_all(dir).ok();
    let opts = tiny_opts(dir);
    let outcomes = run_bench(&opts).unwrap();
    assert_eq!(outcomes.len(), 3);
    for o in &outcomes {
        assert!(o.path.exists(), "{} missing", o.path.display());
        assert!(o.diff.is_none(), "first sweep has no previous baseline");
        let loaded = Baseline::load(&o.path).unwrap();
        assert_eq!(loaded, o.baseline);
        assert!(!loaded.cells.is_empty());
        for c in &loaded.cells {
            assert!(!c.trace.is_empty(), "{}: empty trace", c.id);
        }
        // The distributed cell made it through the spawn path: a 2-rank
        // solve with balanced end-to-end boundary counters and a same-run
        // single-process arm.
        let d = loaded
            .cells
            .iter()
            .find(|c| c.id == "relaxed_residual/p2/dist2")
            .expect("dist2 cell missing");
        assert!(d.converged, "dist2 arm did not converge");
        assert_eq!(d.sp_wall_secs.len(), d.wall_secs.len());
        assert_eq!(d.boundary_msgs_sent, d.boundary_msgs_recv);
        assert!(d.boundary_msgs_sent > 0, "2-rank solve exchanged no boundary messages");
        assert!(d.exchange_batches > 0 && d.boundary_bytes > 0);
    }
    // Second sweep finds the stored baselines and diffs against them.
    let outcomes = run_bench(&opts).unwrap();
    for o in &outcomes {
        let d = o.diff.as_ref().expect("second sweep compares");
        assert!(d.missing.is_empty() && d.added.is_empty(), "same roster");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn run_bench_rejects_bad_tolerance() {
    let mut opts = tiny_opts("/tmp/rbp_telemetry_tol");
    opts.tolerance = 1.0;
    assert!(run_bench(&opts).is_err(), "tolerance <= 1.0 must fail before sweeping");
}

#[test]
fn check_mode_keeps_stored_baseline_on_regression() {
    let dir = "/tmp/rbp_telemetry_check";
    std::fs::remove_dir_all(dir).ok();
    let mut opts = tiny_opts(dir);
    opts.families = vec!["tree".into()];
    let outcomes = run_bench(&opts).unwrap();
    let path = outcomes[0].path.clone();

    // Rewrite the stored baseline with implausibly fast times so the next
    // live sweep is a guaranteed regression.
    let mut fast = Baseline::load(&path).unwrap();
    for c in &mut fast.cells {
        for t in &mut c.wall_secs {
            *t /= 1000.0;
        }
    }
    fast.save(&path).unwrap();

    opts.check = true;
    let outcomes = run_bench(&opts).unwrap();
    assert!(outcomes[0].diff.as_ref().unwrap().has_regression());
    let kept = Baseline::load(&path).unwrap();
    assert_eq!(kept, fast, "--check must not overwrite the stored baseline on regression");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn bench_compare_cli_exits_nonzero_on_regression() {
    let dir = PathBuf::from("/tmp/rbp_telemetry_cli");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut opts = tiny_opts(dir.to_str().unwrap());
    opts.families = vec!["tree".into()];
    let old = bench_family("tree", &opts).unwrap();
    let mut slow = old.clone();
    for c in &mut slow.cells {
        for t in &mut c.wall_secs {
            *t *= 2.0;
        }
    }
    let old_path = dir.join("old.json");
    let new_path = dir.join("new.json");
    old.save(&old_path).unwrap();
    slow.save(&new_path).unwrap();

    let bin = env!("CARGO_BIN_EXE_relaxed-bp");
    let ok = std::process::Command::new(bin)
        .args(["bench-compare", old_path.to_str().unwrap(), old_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(ok.status.success(), "identical baselines compare clean");

    let bad = std::process::Command::new(bin)
        .args(["bench-compare", old_path.to_str().unwrap(), new_path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(!bad.status.success(), "synthetic 2x regression must exit non-zero");
    let stdout = String::from_utf8_lossy(&bad.stdout);
    assert!(stdout.contains("REGRESSION"), "stdout: {stdout}");

    std::fs::remove_dir_all(&dir).ok();
}
