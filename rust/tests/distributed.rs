//! Distributed-execution parity and protocol tests (`net::run_spawn`).
//!
//! - **Fixed-point parity**: a `spawn:2` and a `spawn:4` solve reach the
//!   single-process fixed point on {powerlaw, LDPC, Ising} × {fused,
//!   edgewise} × {f64, f32}. Parity runs use the delta suite's tolerance
//!   regime: ε = 1e-12 (far below both arms' discretization), marginal
//!   L∞ ≤ 1e-9 under f64 and ≤ 1e-5 under f32 (f32 cells quantize the
//!   stored fixed point, so bit-identical states are not guaranteed
//!   across different schedules).
//! - **Pop accounting**: the merged report preserves the runtime's
//!   counter identity `pops = stale_pops + claim_failures + updates`
//!   (each rank satisfies it, so the merged sums must too).
//! - **Boundary-counter sanity**: counters are end-to-end (origin +
//!   final destination, relay hops excluded), so summed over ranks
//!   `boundary_msgs_sent == boundary_msgs_recv`, and a genuinely
//!   multi-rank solve exchanges at least one coalesced batch.
//! - **Disconnect**: a peer that handshakes and then drops mid-solve
//!   produces a clean error, not a hang.
//! - **Damping crosses the boundary exactly once**: a damped distributed
//!   solve matches the damped single-process fixed point (boundary
//!   values ship post-blend and apply raw — double-damping would break
//!   this parity).
//!
//! Every spawn test points `RELAXED_BP_EXE` at the real CLI binary so
//! worker ranks don't re-enter this test harness.

use relaxed_bp::bp::{max_marginal_diff, Precision};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::net::{cmd_run_distributed, run_spawn};
use relaxed_bp::run::{run_config, RunReport};

/// Worker ranks must exec the real CLI, not the test binary hosting us.
fn use_real_worker_binary() {
    std::env::set_var("RELAXED_BP_EXE", env!("CARGO_BIN_EXE_relaxed-bp"));
}

/// The parity grid's model families, at property-test sizes.
fn families() -> Vec<ModelSpec> {
    vec![
        ModelSpec::PowerLaw { n: 80, m: 3 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.05 },
        ModelSpec::Ising { n: 6 },
    ]
}

/// A parity config: tiny ε pins the fixed point tightly enough that two
/// independently scheduled solves agree to the comparison bound.
fn parity_cfg(spec: ModelSpec, fused: bool, precision: Precision) -> RunConfig {
    let mut cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
        .with_threads(2)
        .with_seed(7)
        .with_fused(fused)
        .with_precision(precision);
    cfg.epsilon = 1e-12;
    cfg.time_limit_secs = 120.0;
    cfg
}

fn assert_pop_accounting(rep: &RunReport, label: &str) {
    let m = &rep.stats.metrics.total;
    assert_eq!(
        m.pops,
        m.stale_pops + m.claim_failures + m.updates,
        "{label}: merged pop-accounting identity broken"
    );
}

fn assert_boundary_sanity(rep: &RunReport, label: &str) {
    let m = &rep.stats.metrics.total;
    assert_eq!(
        m.boundary_msgs_sent, m.boundary_msgs_recv,
        "{label}: end-to-end counters must balance"
    );
    assert!(m.boundary_msgs_sent > 0, "{label}: no boundary traffic — test is vacuous");
    assert!(m.exchange_batches > 0, "{label}: no coalesced batches recorded");
    assert!(m.boundary_bytes > 0, "{label}: no boundary bytes recorded");
}

/// Run one family through {fused, edgewise} × {f64, f32} at the given
/// rank counts, asserting fixed-point parity against the single-process
/// solve plus the counter invariants on every distributed report.
fn parity_over_axes(spec: ModelSpec, rank_counts: &[u32]) {
    use_real_worker_binary();
    for fused in [true, false] {
        for precision in [Precision::F64, Precision::F32] {
            let cfg = parity_cfg(spec.clone(), fused, precision);
            let single = run_config(&cfg).unwrap();
            assert!(single.stats.converged, "{spec:?} fused={fused} {precision:?}: single");
            let reference = single.marginals();
            let bound = if precision == Precision::F64 { 1e-9 } else { 1e-5 };
            for &nprocs in rank_counts {
                let label = format!("{spec:?} fused={fused} {precision:?} ranks={nprocs}");
                let rep = run_spawn(&cfg, nprocs).unwrap();
                assert!(rep.stats.converged, "{label}: distributed run did not converge");
                let diff = max_marginal_diff(&reference, &rep.marginals());
                assert!(diff <= bound, "{label}: marginal L∞ = {diff} > {bound}");
                assert_pop_accounting(&rep, &label);
                assert_boundary_sanity(&rep, &label);
            }
        }
    }
}

#[test]
fn powerlaw_spawn_parity_2_and_4_ranks() {
    parity_over_axes(ModelSpec::PowerLaw { n: 80, m: 3 }, &[2, 4]);
}

#[test]
fn ldpc_spawn_parity_2_and_4_ranks() {
    parity_over_axes(ModelSpec::Ldpc { n: 24, flip_prob: 0.05 }, &[2, 4]);
}

#[test]
fn ising_spawn_parity_2_and_4_ranks() {
    parity_over_axes(ModelSpec::Ising { n: 6 }, &[2, 4]);
}

/// Boundary values are damped exactly once: the origin rank ships the
/// post-blend stored value and the receiver applies it raw, so a damped
/// distributed solve must land on the damped single-process fixed point.
/// (A double-damped boundary would converge somewhere else.)
#[test]
fn damped_distributed_solve_matches_damped_single_process() {
    use_real_worker_binary();
    let mut cfg = parity_cfg(ModelSpec::Ising { n: 6 }, true, Precision::F64);
    cfg = cfg.with_damping(0.3);
    let single = run_config(&cfg).unwrap();
    assert!(single.stats.converged, "damped single-process run");
    let rep = run_spawn(&cfg, 2).unwrap();
    assert!(rep.stats.converged, "damped 2-rank run");
    let diff = max_marginal_diff(&single.marginals(), &rep.marginals());
    assert!(diff <= 1e-9, "damped distributed vs single L∞ = {diff}");
    assert_boundary_sanity(&rep, "damped 2-rank");
}

/// The merged report is a real merge, not rank 0's view: per-thread
/// update slots from every rank land in the report, and the merged
/// update count splits the work across ranks.
#[test]
fn merged_report_covers_every_rank() {
    use_real_worker_binary();
    let cfg = parity_cfg(ModelSpec::PowerLaw { n: 80, m: 3 }, true, Precision::F64);
    let rep = run_spawn(&cfg, 2).unwrap();
    assert!(rep.stats.converged);
    // Two ranks × two threads each.
    assert_eq!(rep.stats.metrics.per_thread_updates.len(), 4, "per-thread slots from both ranks");
    let from_threads: u64 = rep.stats.metrics.per_thread_updates.iter().sum();
    assert_eq!(from_threads, rep.stats.metrics.total.updates, "merged updates are the rank sum");
    // The merged JSON carries the distributed telemetry fields.
    let json = rep.to_json();
    for field in ["boundary_msgs_sent", "boundary_msgs_recv", "boundary_bytes", "exchange_batches"]
    {
        assert!(
            json.get(field).and_then(|v| v.as_f64()).unwrap_or(-1.0) > 0.0,
            "merged JSON field {field} missing or zero"
        );
    }
    assert!(json.get("net_wait_secs").and_then(|v| v.as_f64()).is_some());
}

/// A peer that completes the handshake and then drops mid-solve is a
/// clean, prompt error on the coordinator — never a hang: the reader
/// sees EOF, latches the failure, and shuts the run down.
#[test]
fn peer_disconnect_is_a_clean_error_not_a_hang() {
    use std::io::Write;
    use std::time::{Duration, Instant};
    // Reserve a port for the coordinator to re-bind (small race window,
    // loopback-only).
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap();
    drop(probe);
    let fake_worker = std::thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            match std::net::TcpStream::connect(addr) {
                Ok(mut s) => {
                    // A valid HELLO frame for rank 1 ([kind][src][dst]),
                    // then drop the connection without ever solving.
                    let payload = [1u8, 1, 0, 0, 0, 0, 0, 0, 0];
                    let mut frame = (payload.len() as u32).to_le_bytes().to_vec();
                    frame.extend_from_slice(&payload);
                    let _ = s.write_all(&frame);
                    return;
                }
                Err(e) => {
                    assert!(Instant::now() < deadline, "never reached coordinator: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
    });
    let mut cfg = parity_cfg(ModelSpec::PowerLaw { n: 80, m: 3 }, true, Precision::F64);
    cfg.time_limit_secs = 60.0;
    let spec = format!("coord:2:0:{addr}");
    let err = cmd_run_distributed(&cfg, &spec, None)
        .expect_err("coordinator must fail when its peer disconnects");
    fake_worker.join().unwrap();
    let msg = format!("{err:#}");
    assert!(msg.contains("rank"), "failure should name the broken link, got: {msg}");
}

/// `spawn:1` degenerates to a plain single-process solve (no peers, no
/// boundary traffic) and still produces a converged merged report.
#[test]
fn spawn_single_rank_degenerates_cleanly() {
    use_real_worker_binary();
    let cfg = parity_cfg(ModelSpec::Ising { n: 6 }, true, Precision::F64);
    let rep = run_spawn(&cfg, 1).unwrap();
    assert!(rep.stats.converged);
    let m = &rep.stats.metrics.total;
    assert_eq!(m.boundary_msgs_sent, 0);
    assert_eq!(m.boundary_msgs_recv, 0);
    assert_eq!(m.exchange_batches, 0);
    assert_pop_accounting(&rep, "spawn:1");
    let single = run_config(&cfg).unwrap();
    let diff = max_marginal_diff(&single.marginals(), &rep.marginals());
    assert!(diff <= 1e-9, "spawn:1 vs single L∞ = {diff}");
}
