//! Out-of-core storage parity suite (the `--load-mode` / `--arena` axes):
//!
//! - mmap-load vs read-load bit-exact `Mrf` equality across all nine
//!   model families;
//! - mmap-arena vs mem-arena fixed points are bit-identical for the
//!   deterministic sequential engine, and every engine in the roster
//!   converges on file-backed arenas;
//! - snapshot/restore round-trips through mmap arenas, interchangeably
//!   with heap snapshots, and `uniform_like` shadows mirror the backing
//!   mode;
//! - truncated / grown / table-corrupt files fail the map path as clean
//!   `anyhow` errors (never panics), and a valid-but-unaligned v2 file
//!   falls back to the read path automatically.

use relaxed_bp::bp::{max_marginal_diff, msg_buf, ArenaMode, Messages, MsgSource, Precision};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, PartitionSpec, RunConfig};
use relaxed_bp::model::io::{self as model_io, LoadMode};
use relaxed_bp::model::{builders, Mrf};
use relaxed_bp::run::run_config;
use relaxed_bp::util::Xoshiro256;

/// One small instance per model family (all nine builders) — the same
/// roster the cold-path suite pins.
fn families() -> Vec<ModelSpec> {
    vec![
        ModelSpec::Tree { n: 31 },
        ModelSpec::Path { n: 17 },
        ModelSpec::AdversarialTree { n: 15 },
        ModelSpec::UniformTree { n: 40, arity: 3 },
        ModelSpec::Ising { n: 5 },
        ModelSpec::Potts { n: 4, q: 3 },
        ModelSpec::Potts { n: 3, q: 32 },
        ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
        ModelSpec::PowerLaw { n: 64, m: 2 },
    ]
}

/// Field-by-field bit-exact equality of two models (graph arrays,
/// domains, node factors, and every pairwise factor entry) — mapped
/// storage must be indistinguishable from owned.
fn assert_models_equal(m: &Mrf, back: &Mrf) {
    assert_eq!(back.name, m.name);
    assert_eq!(back.num_nodes(), m.num_nodes());
    assert_eq!(back.num_messages(), m.num_messages());
    assert_eq!(back.domain, m.domain);
    assert_eq!(back.graph.offsets, m.graph.offsets);
    assert_eq!(back.graph.adj_node, m.graph.adj_node);
    assert_eq!(back.graph.adj_out, m.graph.adj_out);
    assert_eq!(back.graph.adj_in, m.graph.adj_in);
    assert_eq!(back.graph.edge_src, m.graph.edge_src);
    assert_eq!(back.graph.edge_dst, m.graph.edge_dst);
    assert_eq!(back.msg_offset, m.msg_offset);
    assert_eq!(back.total_msg_len, m.total_msg_len);
    for i in 0..m.num_nodes() {
        assert_eq!(back.node_factors.of(i), m.node_factors.of(i));
    }
    for e in 0..m.num_messages() {
        let fr_a = m.edge_factor[e];
        let fr_b = back.edge_factor[e];
        assert_eq!(m.pool.shape_of(fr_a), back.pool.shape_of(fr_b));
        let (dr, dc) = m.pool.shape_of(fr_a);
        for a in 0..dr {
            for b in 0..dc {
                assert_eq!(m.pool.get(fr_a, a, b), back.pool.get(fr_b, a, b));
            }
        }
    }
}

fn tmp_path(tag: &str, spec: &ModelSpec, seed: u64) -> String {
    std::env::temp_dir()
        .join(format!("outofcore_{tag}_{}", spec.cache_slug(seed)))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn mmap_load_equals_read_load_across_all_families() {
    for spec in families() {
        let m = builders::build(&spec, 7);
        let path = tmp_path("map", &spec, 7);
        model_io::save(&m, &path).unwrap();
        let (read, rmode) = model_io::load_with_mode(&path, 2, LoadMode::Read, true)
            .unwrap_or_else(|e| panic!("{} read: {e:#}", spec.name()));
        assert_eq!(rmode, LoadMode::Read);
        for verify in [false, true] {
            let (mapped, mmode) = model_io::load_with_mode(&path, 2, LoadMode::Map, verify)
                .unwrap_or_else(|e| panic!("{} map (verify={verify}): {e:#}", spec.name()));
            if cfg!(unix) {
                assert_eq!(mmode, LoadMode::Map, "{}: map must not fall back", spec.name());
            }
            assert_models_equal(&m, &mapped);
            assert_models_equal(&read, &mapped);
        }
        // Auto prefers the map path but must load the same bits either way.
        let (auto, amode) = model_io::load_with_mode(&path, 2, LoadMode::Auto, false).unwrap();
        if cfg!(unix) {
            assert_eq!(amode, LoadMode::Map);
        }
        assert_models_equal(&m, &auto);
        std::fs::remove_file(&path).ok();
    }
}

/// The deterministic sequential engine must land on a bit-identical
/// fixed point regardless of the arena backing: the mmap arm changes
/// where the bytes live, never what they are.
#[test]
fn mmap_arena_fixed_point_is_bit_identical_to_mem() {
    if !cfg!(unix) {
        return; // file-backed arenas are unix-only
    }
    for spec in [ModelSpec::Ising { n: 5 }, ModelSpec::Tree { n: 31 }] {
        let run = |arena: ArenaMode| {
            let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual)
                .with_seed(11)
                .with_arena(arena);
            let rep = run_config(&cfg).unwrap();
            assert!(rep.stats.converged, "{}", spec.name());
            rep.marginals()
        };
        let mem = run(ArenaMode::Mem);
        let mmap = run(ArenaMode::Mmap { dir: None });
        assert_eq!(mem.len(), mmap.len());
        for (i, (a, b)) in mem.iter().zip(mmap.iter()).enumerate() {
            for (x, (va, vb)) in a.iter().zip(b.iter()).enumerate() {
                assert!(
                    va.to_bits() == vb.to_bits(),
                    "{} node {i} x={x}: {va} vs {vb} differ in bits",
                    spec.name()
                );
            }
        }
    }
}

/// Every engine in the roster (all 15 algorithm specs) runs to
/// convergence on file-backed arenas. A tree instance keeps the two
/// optimal-tree engines in scope; threads = 2 exercises the shared pool
/// runtime over mapped memory.
#[test]
fn all_engines_smoke_on_mmap_arenas() {
    if !cfg!(unix) {
        return;
    }
    let spec = ModelSpec::Tree { n: 31 };
    let roster: Vec<AlgorithmSpec> = vec![
        AlgorithmSpec::SequentialResidual,
        AlgorithmSpec::Synchronous,
        AlgorithmSpec::CoarseGrained,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::WeightDecay,
        AlgorithmSpec::Priority,
        AlgorithmSpec::Splash { h: 2 },
        AlgorithmSpec::SmartSplash { h: 2 },
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        AlgorithmSpec::RandomSplash { h: 2 },
        AlgorithmSpec::Bucket,
        AlgorithmSpec::RandomSynchronous { low_p: 0.4 },
        AlgorithmSpec::RelaxedResidualBatched { batch: 8 },
        AlgorithmSpec::OptimalTree,
        AlgorithmSpec::RelaxedOptimalTree,
    ];
    assert_eq!(roster.len(), 15, "roster must cover every engine");
    let reference = {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(3);
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged);
        rep.marginals()
    };
    for alg in roster {
        let cfg = RunConfig::new(spec.clone(), alg.clone())
            .with_threads(2)
            .with_seed(3)
            .with_arena(ArenaMode::Mmap { dir: None });
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged, "{} on mmap arenas", alg.name());
        let diff = max_marginal_diff(&rep.marginals(), &reference);
        assert!(diff < 1e-3, "{} on mmap arenas: marginal diff {diff}", alg.name());
    }
}

/// Sharded file-backed arenas (locality axis × out-of-core axis): the
/// partitioned Multiqueue path must reach the same fixed point over
/// per-shard mappings as over per-shard heap arenas.
#[test]
fn partitioned_mmap_arenas_reach_the_mem_fixed_point() {
    if !cfg!(unix) {
        return;
    }
    let spec = ModelSpec::Ising { n: 5 };
    let run = |arena: ArenaMode| {
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(4)
            .with_seed(11)
            .with_partition(PartitionSpec::Affine { shards: 7, spill: 0.1, bfs: false })
            .with_arena(arena);
        let rep = run_config(&cfg).unwrap();
        assert!(rep.stats.converged);
        rep.marginals()
    };
    let diff = max_marginal_diff(&run(ArenaMode::Mem), &run(ArenaMode::Mmap { dir: None }));
    assert!(diff < 2e-2, "sharded mem vs mmap marginal diff {diff}");
}

/// Snapshot/restore and `uniform_like` through file-backed arenas:
/// snapshots are interchangeable with heap snapshots bit for bit, and
/// restore rewinds mapped state exactly.
#[test]
fn snapshot_restore_roundtrip_through_mmap_arenas() {
    if !cfg!(unix) {
        return;
    }
    let mrf = builders::build(&ModelSpec::Ising { n: 4 }, 5);
    let arena = ArenaMode::Mmap { dir: None };
    for precision in [Precision::F64, Precision::F32] {
        let mm = Messages::uniform_in(&mrf, precision, &arena).unwrap();
        assert!(mm.arena_mode().is_mmap());
        let heap = Messages::uniform_with(&mrf, precision);
        let mut rng = Xoshiro256::seed_from_u64(13);
        let mut write_round = |seed_rng: &mut Xoshiro256| {
            for _ in 0..200 {
                let e = seed_rng.index(mrf.num_messages()) as u32;
                let a = seed_rng.uniform(0.01, 0.99);
                mm.write_msg(&mrf, e, &[a, 1.0 - a]);
                heap.write_msg(&mrf, e, &[a, 1.0 - a]);
            }
        };
        write_round(&mut rng);
        let snap = mm.snapshot();
        assert_eq!(snap, heap.snapshot(), "mapped and heap snapshots are interchangeable");
        // Diverge, then rewind the mapped state from the snapshot.
        mm.write_msg(&mrf, 0, &[0.25, 0.75]);
        mm.write_msg(&mrf, 1, &[0.75, 0.25]);
        mm.restore(&snap);
        assert_eq!(mm.snapshot(), snap, "restore rewinds mapped cells exactly");
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..mrf.num_messages() as u32 {
            let la = mm.read_msg(&mrf, e, &mut a);
            let lb = heap.read_msg(&mrf, e, &mut b);
            assert_eq!(la, lb);
            assert_eq!(&a[..la], &b[..lb], "edge {e}");
        }
        // Shadow states mirror the backing mode (an out-of-core run must
        // not regain a heap-resident copy through its caches).
        let shadow = Messages::uniform_like(&mrf, &mm);
        assert!(shadow.arena_mode().is_mmap(), "uniform_like mirrors the arena mode");
        assert_eq!(shadow.precision(), precision);
        assert_eq!(shadow.num_shards(), mm.num_shards());
    }
}

/// File-level robustness of the map path: truncation, growth, and a
/// corrupt section table must all surface as clean `anyhow` errors, and
/// a valid-but-unaligned v2 file must fall back to the read path
/// automatically (mapping never changes what loads).
#[test]
fn map_attempts_on_damaged_files_fail_cleanly() {
    let spec = ModelSpec::Ising { n: 5 };
    let m = builders::build(&spec, 3);
    let path = tmp_path("damage", &spec, 3);
    model_io::save(&m, &path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Truncation at several points: below the section area the map probe
    // defers to the read path's canonical error; inside it the section
    // bounds check fires. Either way: error, not panic.
    for cut in [6, 300, good.len() / 3, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).unwrap();
        for mode in [LoadMode::Map, LoadMode::Auto] {
            assert!(
                model_io::load_with_mode(&path, 2, mode, false).is_err(),
                "truncated at {cut} ({mode:?})"
            );
        }
    }

    // A grown file (trailing bytes past the last section) is a layout
    // the mapped reader does not understand: clean error on unix, where
    // the map path actually runs.
    let mut grown = good.clone();
    grown.extend_from_slice(&[0u8; 64]);
    std::fs::write(&path, &grown).unwrap();
    if cfg!(unix) {
        let err = model_io::load_with_mode(&path, 2, LoadMode::Map, false).unwrap_err();
        assert!(format!("{err:#}").contains("layout"), "got: {err:#}");
    }

    // Corrupt section table: point a section past the end of the file.
    let mut bad_table = good.clone();
    let off_pos = 64 + 24; // header (64B) + table row 0 → row 1's offset
    bad_table[off_pos..off_pos + 8].copy_from_slice(&(good.len() as u64 * 2).to_le_bytes());
    std::fs::write(&path, &bad_table).unwrap();
    let err = model_io::load_with_mode(&path, 2, LoadMode::Map, false).unwrap_err();
    assert!(format!("{err:#}").contains("bounds"), "got: {err:#}");

    // Payload corruption is caught by --verify-load on the map path.
    if cfg!(unix) {
        let mut flipped = good.clone();
        let mid = flipped.len() / 2;
        for b in flipped[mid..mid + 128].iter_mut() {
            *b ^= 0x40;
        }
        std::fs::write(&path, &flipped).unwrap();
        let err = model_io::load_with_mode(&path, 2, LoadMode::Map, true).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "got: {err:#}");
    }

    // Valid but unaligned: slide the name section 4 bytes into its
    // padding gap (data + table offset move together, so the read path
    // still verifies). The map probe must decline and fall back.
    let name_off_pos = 64; // table row 0: name section offset
    let name_off = u64::from_le_bytes(good[name_off_pos..name_off_pos + 8].try_into().unwrap());
    let name_len =
        u64::from_le_bytes(good[name_off_pos + 8..name_off_pos + 16].try_into().unwrap());
    let mut unaligned = good.clone();
    let (src, dst) = (name_off as usize, name_off as usize + 4);
    let name_bytes = unaligned[src..src + name_len as usize].to_vec();
    unaligned[src..src + 4].fill(0);
    unaligned[dst..dst + name_len as usize].copy_from_slice(&name_bytes);
    unaligned[name_off_pos..name_off_pos + 8].copy_from_slice(&(name_off + 4).to_le_bytes());
    assert!(dst + name_len as usize <= 512, "name must fit inside its padding gap");
    std::fs::write(&path, &unaligned).unwrap();
    let (back, mode) = model_io::load_with_mode(&path, 2, LoadMode::Map, false)
        .expect("unaligned v2 file falls back to the read path");
    assert_eq!(mode, LoadMode::Read, "fallback must report the read path");
    assert_models_equal(&m, &back);

    std::fs::remove_file(&path).ok();
}
