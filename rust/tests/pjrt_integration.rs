//! End-to-end validation of the three-layer hot path: AOT artifacts
//! (JAX/Pallas → HLO text) executed through the PJRT CPU client must agree
//! with the native Rust engines to f32 tolerance.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially, with a notice) when the artifacts are absent so `cargo test`
//! works on a fresh checkout.

use relaxed_bp::bp::{all_marginals, max_marginal_diff, Messages};
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::engines::batched::{BatchCompute, NativeBatch};
use relaxed_bp::engines::{build_engine, Engine};
use relaxed_bp::model::builders;
use relaxed_bp::runtime::{artifacts_dir, batch::PjrtBatch, grid};

fn have(name: &str) -> bool {
    if !cfg!(pjrt) {
        eprintln!("SKIP: built without `--cfg pjrt` (xla bindings absent)");
        return false;
    }
    let ok = artifacts_dir().join(format!("{name}.hlo.txt")).exists();
    if !ok {
        eprintln!("SKIP: artifact {name} missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn pjrt_batched_matches_native_batch() {
    if !have("batched_update_64") {
        return;
    }
    let mrf = builders::build(&ModelSpec::Ising { n: 8 }, 3);
    let msgs = Messages::uniform(&mrf);
    // Perturb the state so updates are non-trivial.
    for e in 0..mrf.num_messages() as u32 {
        if e % 3 == 0 {
            msgs.write_msg(&mrf, e, &[0.2, 0.8]);
        }
    }
    let edges: Vec<u32> = (0..mrf.num_messages() as u32).step_by(2).collect();
    let stride = mrf.max_domain();

    let pjrt = PjrtBatch::load_default(64).expect("load artifact");
    let mut out_p = vec![0.0; edges.len() * stride];
    let mut res_p = vec![0.0; edges.len()];
    pjrt.compute_batch(&mrf, &msgs, &edges, &mut out_p, &mut res_p);

    let mut out_n = vec![0.0; edges.len() * stride];
    let mut res_n = vec![0.0; edges.len()];
    NativeBatch { kernel: relaxed_bp::bp::Kernel::Scalar }
        .compute_batch(&mrf, &msgs, &edges, &mut out_n, &mut res_n);

    for k in 0..edges.len() {
        for x in 0..2 {
            let (a, b) = (out_p[k * stride + x], out_n[k * stride + x]);
            assert!((a - b).abs() < 1e-5, "edge {k} state {x}: pjrt={a} native={b}");
        }
        assert!((res_p[k] - res_n[k]).abs() < 1e-5, "res {k}");
    }
}

#[test]
fn pjrt_grid_sync_matches_native_sync_marginals() {
    if !have("grid_step_16") {
        return;
    }
    let spec = ModelSpec::Ising { n: 16 };
    let mrf = builders::build(&spec, 5);

    // Native synchronous.
    let msgs_native = Messages::uniform(&mrf);
    let cfg_native = RunConfig::new(spec.clone(), AlgorithmSpec::Synchronous).with_seed(5);
    let eng = build_engine(&AlgorithmSpec::Synchronous);
    let s_native = eng.run(&mrf, &msgs_native, &cfg_native).unwrap();
    assert!(s_native.converged);

    // PJRT synchronous.
    let msgs_pjrt = Messages::uniform(&mrf);
    let mut cfg_pjrt = cfg_native.clone();
    cfg_pjrt.use_pjrt = true;
    let s_pjrt = grid::run_sync_pjrt(&mrf, &msgs_pjrt, &cfg_pjrt).unwrap();
    assert!(s_pjrt.converged);

    // Same schedule, f32 vs f64 arithmetic: marginals agree to ~1e-4.
    let a = all_marginals(&mrf, &msgs_native);
    let b = all_marginals(&mrf, &msgs_pjrt);
    let diff = max_marginal_diff(&a, &b);
    assert!(diff < 1e-3, "pjrt vs native marginal diff {diff}");
    // Round counts should be close (f32 rounding can change the last round).
    let (rn, rp) = (
        s_native.metrics.total.rounds,
        s_pjrt.metrics.total.rounds,
    );
    assert!(
        (rn as i64 - rp as i64).abs() <= 3,
        "native {rn} vs pjrt {rp} rounds"
    );
}

#[test]
fn pallas_flavor_artifact_matches_ref_flavor() {
    // The shipped CPU artifacts are lowered from the jnp reference; the
    // Pallas interpret-mode flavor (`*_pallas`) must compute identical
    // numbers through the same PJRT runtime (see DESIGN.md
    // §Hardware-Adaptation).
    if !have("batched_update_64") || !have("batched_update_64_pallas") {
        return;
    }
    use relaxed_bp::runtime::{Executable, TensorIn};
    let ref_exe = Executable::load_named("batched_update_64").unwrap();
    let pal_exe = Executable::load_named("batched_update_64_pallas").unwrap();
    let mut prod = vec![0.0f64; 64 * 2];
    let mut psi = vec![0.0f64; 64 * 4];
    let mut cur = vec![0.0f64; 64 * 2];
    let mut rng = relaxed_bp::util::Xoshiro256::seed_from_u64(3);
    for v in prod.iter_mut().chain(psi.iter_mut()).chain(cur.iter_mut()) {
        *v = rng.uniform(0.01, 1.0);
    }
    let inputs = || {
        vec![
            TensorIn::new(prod.clone(), &[64, 2]),
            TensorIn::new(psi.clone(), &[64, 2, 2]),
            TensorIn::new(cur.clone(), &[64, 2]),
        ]
    };
    let a = ref_exe.run(inputs()).unwrap();
    let b = pal_exe.run(inputs()).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().flatten().zip(b.iter().flatten()) {
        assert!((x - y).abs() < 1e-6, "{x} vs {y}");
    }
}

#[test]
fn pjrt_batched_engine_converges_and_decodes_grid() {
    if !have("batched_update_64") {
        return;
    }
    let spec = ModelSpec::Ising { n: 10 };
    let mrf = builders::build(&spec, 9);
    let msgs = Messages::uniform(&mrf);
    let mut cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidualBatched { batch: 32 })
        .with_threads(2)
        .with_seed(9);
    cfg.use_pjrt = true;
    let eng = build_engine(&cfg.algorithm.clone());
    let stats = eng.run(&mrf, &msgs, &cfg).unwrap();
    assert!(stats.converged);

    // Against the sequential-residual fixed point.
    let mrf2 = builders::build(&spec, 9);
    let msgs2 = Messages::uniform(&mrf2);
    let cfg2 = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(9);
    let eng2 = build_engine(&AlgorithmSpec::SequentialResidual);
    let s2 = eng2.run(&mrf2, &msgs2, &cfg2).unwrap();
    assert!(s2.converged);

    let diff = max_marginal_diff(&all_marginals(&mrf, &msgs), &all_marginals(&mrf2, &msgs2));
    assert!(diff < 1e-2, "diff {diff}");
}
