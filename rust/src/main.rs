//! `relaxed-bp` — command-line launcher for the relaxed-scheduling BP
//! framework.
//!
//! ```text
//! relaxed-bp run --model ising:300 --algorithm rr --threads 8 [--epsilon 1e-5]
//!                [--seed 42] [--config run.json] [--use-pjrt] [--out report.json]
//! relaxed-bp experiment <table1|table3|table4|table7|fig2|fig4|fig5|fig6|fig7|lemma2|all>
//!                [--scale 0.05] [--threads 1,2,4,8] [--max-threads 8] [--out-dir results]
//! relaxed-bp bench [--quick] [--families tree,ising] [--threads 1,2] [--samples 3]
//!                [--out-dir DIR] [--check] [--tolerance 1.5]
//! relaxed-bp bench-compare BENCH_old.json BENCH_new.json [--tolerance 1.5]
//! relaxed-bp generate --model ldpc:30000 --out model.rbpm [--seed 42] [--format v1|v2]
//! relaxed-bp list-algorithms
//! ```

use anyhow::{anyhow, bail, Result};
use relaxed_bp::cli::Args;
use relaxed_bp::configio::{
    parse_arena_mode, parse_kernel, parse_load_mode, parse_on_off, parse_precision,
    valid_damping, AlgorithmSpec, ModelSpec, PartitionSpec, RunConfig,
};
use relaxed_bp::harness::Harness;
use relaxed_bp::model::{builders, io as model_io, EvidenceDelta};
use relaxed_bp::run::{run_config, run_on_model_prepped, PrepStats};
use relaxed_bp::telemetry;
use relaxed_bp::util::Timer;

const SWITCHES: &[&str] = &["use-pjrt", "verbose", "marginals", "quick", "check", "verify-load"];

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::from_env(SWITCHES)?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("bench") => cmd_bench(&args),
        Some("bench-compare") => cmd_bench_compare(&args),
        Some("generate") => cmd_generate(&args),
        Some("list-algorithms") => {
            for a in [
                "residual (sequential baseline)",
                "synch",
                "coarse_grained | cg",
                "relaxed_residual | rr",
                "weight_decay | wd",
                "priority",
                "splash:H | s:H",
                "smart_splash:H | ss:H",
                "relaxed_smart_splash:H | rss:H",
                "random_splash:H | rs:H",
                "bucket",
                "random_synch:lowP",
                "relaxed_residual_batched:B | rrb:B",
                "optimal_tree / relaxed_optimal_tree (tree models only)",
            ] {
                println!("  {a}");
            }
            Ok(())
        }
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = if let Some(path) = args.opt("config") {
        RunConfig::load(path)?
    } else {
        let model = ModelSpec::parse_cli(
            args.opt("model").ok_or_else(|| anyhow!("--model required (e.g. ising:300)"))?,
        )?;
        let alg = AlgorithmSpec::parse_cli(args.opt("algorithm").unwrap_or("rr"))?;
        RunConfig::new(model, alg)
    };
    if let Some(t) = args.opt_parse::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(e) = args.opt_parse::<f64>("epsilon")? {
        cfg.epsilon = e;
    }
    if let Some(s) = args.opt_parse::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(l) = args.opt_parse::<f64>("time-limit")? {
        cfg.time_limit_secs = l;
    }
    if let Some(m) = args.opt_parse::<u64>("max-updates")? {
        cfg.max_updates = m;
    }
    if args.has_switch("use-pjrt") {
        cfg.use_pjrt = true;
    }
    if let Some(p) = args.opt("partition") {
        cfg.partition = PartitionSpec::parse_cli(p)?;
    }
    if let Some(f) = args.opt("fused") {
        cfg.fused = parse_on_off(f)?;
    }
    if let Some(k) = args.opt("kernel") {
        cfg.kernel = parse_kernel(k)?;
    }
    if let Some(p) = args.opt("precision") {
        cfg.precision = parse_precision(p)?;
    }
    if let Some(m) = args.opt("load-mode") {
        cfg.load_mode = parse_load_mode(m)?;
    }
    if let Some(a) = args.opt("arena") {
        cfg.arena = parse_arena_mode(a)?;
    }
    if args.has_switch("verify-load") {
        cfg.verify_load = true;
    }
    if let Some(d) = args.opt_parse::<f64>("damping")? {
        cfg.damping = valid_damping(d)?;
    }
    if let Some(spec) = args.opt("distributed") {
        return relaxed_bp::net::cmd_run_distributed(&cfg, spec, args.opt("out"));
    }

    // Model cache legs: --load-model replaces the in-process build with a
    // disk load (v1/v2 auto-detected, parallel chunked reads); --save-model
    // persists the model (format v2) after building so later runs can sweep
    // it without regenerating ("generate once, sweep many"). --model is
    // still required: it describes the instance in the report/config.
    let mut report = if args.opt("load-model").is_some() || args.opt("save-model").is_some() {
        let mut prep = PrepStats::default();
        let mrf = if let Some(path) = args.opt("load-model") {
            let t = Timer::start();
            let (mrf, resolved) =
                model_io::load_with_mode(path, cfg.threads, cfg.load_mode, cfg.verify_load)?;
            prep.load_secs = t.elapsed_secs();
            prep.load_mode = resolved;
            prep.model_bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
            mrf
        } else {
            let t = Timer::start();
            let mrf = builders::build(&cfg.model, cfg.seed);
            prep.build_secs = t.elapsed_secs();
            mrf
        };
        if let Some(path) = args.opt("save-model") {
            prep.model_bytes = model_io::save(&mrf, path)?;
        }
        run_on_model_prepped(&cfg, mrf, None, prep)?
    } else {
        run_config(&cfg)?
    };
    let json = report.to_json();
    println!("{}", json.to_string_pretty());
    if args.has_switch("marginals") {
        for (i, m) in report.marginals().iter().enumerate().take(20) {
            println!("marginal[{i}] = {m:?}");
        }
    }
    if let Some(out) = args.opt("out") {
        std::fs::write(out, json.to_string_pretty())?;
        eprintln!("wrote {out}");
    }
    if !report.stats.converged {
        bail!("run did not converge within budget");
    }
    // Delta warm start: perturb a fraction of the priors and re-converge
    // from the resident state, printing a second report whose wall_secs is
    // the time-to-reconverge and whose tasks_touched is the seeded
    // frontier size.
    if let Some(frac) = args.opt_parse::<f64>("delta-fraction")? {
        let delta = EvidenceDelta::random_perturbation(&report.mrf, frac, cfg.seed);
        eprintln!("[run] delta resume: {} node prior(s) perturbed", delta.len());
        report.resume_delta(&delta, None)?;
        println!("{}", report.to_json().to_string_pretty());
        if !report.stats.converged {
            bail!("delta resume did not converge within budget");
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("experiment name required; see --help"))?;
    let mut h = Harness::default();
    if let Some(s) = args.opt_parse::<f64>("scale")? {
        h.scale = s;
    }
    if let Some(t) = args.opt_csv::<usize>("threads")? {
        h.threads = t;
    }
    if let Some(m) = args.opt_parse::<usize>("max-threads")? {
        h.max_threads = m;
    }
    if let Some(d) = args.opt("out-dir") {
        h.out_dir = d.into();
    }
    if let Some(s) = args.opt_parse::<u64>("seed")? {
        h.seed = s;
    }
    if let Some(t) = args.opt_parse::<f64>("time-limit")? {
        h.time_limit = t;
    }
    if args.has_switch("use-pjrt") {
        h.use_pjrt = true;
    }
    if let Some(p) = args.opt("partition") {
        h.partition = PartitionSpec::parse_cli(p)?;
    }
    if let Some(f) = args.opt("fused") {
        h.fused = parse_on_off(f)?;
    }
    if let Some(k) = args.opt("kernel") {
        h.kernel = parse_kernel(k)?;
    }
    if let Some(p) = args.opt("precision") {
        h.precision = parse_precision(p)?;
    }
    h.load_model = args.opt_path("load-model");
    h.save_model = args.opt_path("save-model");
    if let Some(m) = args.opt("load-mode") {
        h.load_mode = parse_load_mode(m)?;
    }
    if let Some(a) = args.opt("arena") {
        h.arena = parse_arena_mode(a)?;
    }
    h.verify_load = args.has_switch("verify-load");
    if let Some(d) = args.opt_parse::<f64>("damping")? {
        h.damping = valid_damping(d)?;
    }

    match which {
        "table1" | "table2" | "table5" | "table6" | "moderate" => {
            h.tables_moderate()?;
        }
        "table3" => {
            h.table3()?;
        }
        "table4" => {
            h.table4()?;
        }
        "table7" => {
            h.table7()?;
        }
        "fig2" => {
            h.fig2()?;
        }
        "fig4" => {
            h.fig_scaling("tree")?;
        }
        "fig5" => {
            h.fig_scaling("ising")?;
        }
        "fig6" => {
            h.fig_scaling("potts")?;
        }
        "fig7" => {
            h.fig_scaling("ldpc")?;
        }
        "lemma2" => {
            h.lemma2()?;
        }
        "locality" => {
            h.locality()?;
        }
        "fused" => {
            h.fused_ab()?;
        }
        "simd" => {
            h.simd_ab()?;
        }
        "precision" => {
            h.precision_ab()?;
        }
        "delta" => {
            h.delta_ab()?;
        }
        "all" => h.all()?,
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

/// `bench`: sweep {engine × scheduler × threads} per model family, write
/// `BENCH_<FAMILY>.json` baselines, and diff against the previous ones.
fn cmd_bench(args: &Args) -> Result<()> {
    let mut opts = if args.has_switch("quick") {
        telemetry::BenchOpts::quick()
    } else {
        telemetry::BenchOpts::full()
    };
    if let Some(s) = args.opt_parse::<usize>("samples")? {
        opts.samples = s.max(1);
    }
    if let Some(t) = args.opt_csv::<usize>("threads")? {
        opts.threads = t;
    }
    if let Some(f) = args.opt_csv::<String>("families")? {
        opts.families = f;
    }
    if let Some(d) = args.opt("out-dir") {
        opts.out_dir = d.into();
    }
    if let Some(s) = args.opt_parse::<u64>("seed")? {
        opts.seed = s;
    }
    if let Some(t) = args.opt_parse::<f64>("time-limit")? {
        opts.time_limit = t;
    }
    if let Some(t) = args.opt_parse::<u64>("tick-ms")? {
        opts.tick_ms = t;
    }
    if let Some(t) = args.opt_parse::<f64>("tolerance")? {
        opts.tolerance = t;
    }
    if let Some(p) = args.opt_csv::<String>("partitions")? {
        opts.partitions = p
            .iter()
            .map(|s| PartitionSpec::parse_cli(s))
            .collect::<Result<Vec<_>>>()?;
    }
    opts.load_model = args.opt_path("load-model");
    opts.save_model = args.opt_path("save-model");
    if let Some(m) = args.opt("load-mode") {
        opts.load_mode = parse_load_mode(m)?;
    }
    if let Some(a) = args.opt("arena") {
        opts.arena = parse_arena_mode(a)?;
    }
    opts.verify_load = args.has_switch("verify-load");
    if let Some(d) = args.opt_parse::<f64>("damping")? {
        opts.damping = valid_damping(d)?;
    }
    opts.check = args.has_switch("check");

    let outcomes = telemetry::run_bench(&opts)?;
    let mut regressed = false;
    for o in &outcomes {
        println!("{}", telemetry::render_summary(&o.baseline));
        match &o.diff {
            Some(d) => {
                println!("vs previous {}:\n{}", o.path.display(), d.render());
                regressed |= d.has_regression();
            }
            None => println!("(no previous baseline at {})\n", o.path.display()),
        }
    }
    if regressed {
        if opts.check {
            bail!(
                "performance regression against stored baselines (see above); \
                 the stored baselines were kept"
            );
        }
        eprintln!(
            "warning: regressions detected; the stored baselines were overwritten with \
             the new numbers (use --check to fail and keep the old baselines instead)"
        );
    }
    Ok(())
}

/// `bench-compare old new`: diff two baseline files; exits non-zero on
/// regression (the CI / acceptance gate).
fn cmd_bench_compare(args: &Args) -> Result<()> {
    let [old_path, new_path] = args.positional.as_slice() else {
        bail!("usage: bench-compare <old.json> <new.json> [--tolerance 1.5]");
    };
    let old = telemetry::Baseline::load(std::path::Path::new(old_path))?;
    let new = telemetry::Baseline::load(std::path::Path::new(new_path))?;
    let tolerance = args.opt_or("tolerance", telemetry::DEFAULT_TOLERANCE)?;
    let diff = telemetry::compare(&old, &new, tolerance)?;
    print!("{}", diff.render());
    if diff.has_regression() {
        bail!("{} regresses against {}", new_path, old_path);
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let model = ModelSpec::parse_cli(
        args.opt("model").ok_or_else(|| anyhow!("--model required"))?,
    )?;
    let seed = args.opt_or("seed", 42u64)?;
    let out = args.opt("out").ok_or_else(|| anyhow!("--out required"))?;
    let format = args.opt("format").unwrap_or("v2");
    let t = Timer::start();
    let mrf = builders::build(&model, seed);
    let build_secs = t.elapsed_secs();
    let t = Timer::start();
    let bytes = match format {
        "v2" => model_io::save(&mrf, out)?,
        "v1" => model_io::save_v1(&mrf, out)?,
        other => bail!("unknown --format '{other}' (expected v1 or v2)"),
    };
    let save_secs = t.elapsed_secs();
    println!(
        "wrote {out} ({format}): {} nodes, {} messages, {} bytes \
         (build {build_secs:.3}s, save {save_secs:.3}s)",
        mrf.num_nodes(),
        mrf.num_messages(),
        bytes
    );
    Ok(())
}

const HELP: &str = "\
relaxed-bp — Relaxed Scheduling for Scalable Belief Propagation (reproduction)

USAGE:
  relaxed-bp run --model <kind:size> --algorithm <alg> [--threads N]
                 [--epsilon E] [--seed S] [--time-limit SECS] [--use-pjrt]
                 [--partition off|affine[:shards[:spill]]|bfs[:shards[:spill]]]
                 [--fused on|off] [--kernel scalar|simd] [--precision f64|f32]
                 [--config cfg.json] [--out report.json] [--marginals]
                 [--delta-fraction F] [--save-model FILE] [--load-model FILE]
                 [--load-mode read|map|auto] [--arena mem|mmap[:dir]]
                 [--verify-load] [--damping F]
                 [--distributed spawn:N | coord:N:0 | worker:N:R:addr]
  relaxed-bp experiment <id> [--scale F] [--threads 1,2,4,8]
                 [--max-threads N] [--out-dir DIR] [--seed S] [--use-pjrt]
                 [--partition MODE] [--fused on|off] [--kernel scalar|simd]
                 [--precision f64|f32] [--save-model DIR] [--load-model DIR]
                 [--load-mode read|map|auto] [--arena mem|mmap[:dir]]
                 [--verify-load] [--damping F]
      ids: table1 table3 table4 table7 fig2 fig4 fig5 fig6 fig7 lemma2
           locality fused simd precision delta all
  relaxed-bp bench [--quick] [--families tree,ising,potts,potts32,ldpc,powerlaw]
                 [--threads 1,2] [--samples N] [--out-dir DIR] [--seed S]
                 [--time-limit SECS] [--tick-ms MS] [--tolerance X]
                 [--partitions off,affine] [--check]
                 [--save-model DIR] [--load-model DIR]
                 [--load-mode read|map|auto] [--arena mem|mmap[:dir]]
                 [--verify-load]
      writes BENCH_<FAMILY>.json baselines (with convergence traces) to the
      repo root and diffs them against the previous revision's baselines;
      --check exits non-zero on regression
  relaxed-bp bench-compare <old.json> <new.json> [--tolerance X]
      diffs two baselines; exits non-zero when <new> regresses
  relaxed-bp generate --model <kind:size> --out model.rbpm [--seed S]
                 [--format v1|v2]
  relaxed-bp list-algorithms

MODEL CACHE (the cold-path axis): generate once, sweep many. run takes
        file paths: --save-model writes the built model (format v2:
        sectioned bulk layout, parallel chunked loads); --load-model skips
        the build and loads from disk (v1/v2 auto-detected). experiment
        and bench take cache directories keyed by <family>_<params>_seedS
        .rbpm: --load-model consults the cache before building, --save-model
        fills it. Reports carry build_secs/load_secs/init_secs/model_bytes.

LOAD MODE (the out-of-core load axis): auto (default) = mmap v2 files
        zero-copy when the platform and file layout allow it, else fall
        back to the threaded read path; map = require the zero-copy path
        (error if unavailable); read = always the threaded read path.
        Mapped loads skip checksum verification so pages fault in lazily;
        --verify-load forces the full checksum + semantic sweep (pages
        everything in). Reports carry load_mode.

ARENA (the out-of-core message axis): mem (default) = heap-allocated
        message arenas; mmap[:dir] = arenas backed by unlinked sparse temp
        files (under dir, default the system temp dir) mapped read-write,
        so message state larger than RAM spills to disk under memory
        pressure instead of OOM-killing the run. Same alignment, atomic
        access, and snapshot semantics as mem — fixed points are
        bit-identical. Reports carry arena and peak_rss_bytes.

MODELS: tree:N ising:N potts:N[:q] ldpc:N[:flip] path:N adversarial_tree:N
        uniform_tree:N[:arity] powerlaw:N[:m]

PARTITION MODES (the locality axis): off = flat arena + locality-blind
        Multiqueue (seed behavior); affine = contiguous task shards, sharded
        message arenas, shard-affine Multiqueue; bfs = shards clustered by
        graph BFS order. shards defaults to the thread count, spill to 0.1.

FUSED (the refresh-shape axis): on (default) = node-centric fused refresh
        (one O(deg) prefix/suffix pass per node touch) + batched scheduler
        inserts; off = the historical edge-wise O(deg²) refresh fan-out,
        kept for A/B measurement.

KERNEL (the data-path axis): simd (default) = lane-tiled inner loops
        (portable 4-lane tiles + runtime-detected AVX2), bulk cache-line
        message I/O, and in-kernel residuals; scalar = the historical
        per-element path, bit-for-bit the pre-SIMD trajectory, kept for
        A/B measurement.

PRECISION (the storage axis): f64 (default) = 8 messages per cache line,
        bit-for-bit the historical trajectory; f32 = 16 messages per line
        at half the arena footprint, computed in f64 registers with one
        rounding point per message store. bench records all four axes per
        baseline (base cells run f32; /f64 cells are the frozen arm).

DAMPING (the update-blend axis): --damping F (default 0.0) blends every
        stored message geometrically with its previous value,
        m' = m^(1-F) * m_old^F, renormalized. F = 0.0 is bit-identical to
        the undamped path; positive F trades per-update step size for
        stability on loopy graphs and smooths the distributed boundary
        exchange. F must lie in [0, 1).

DISTRIBUTED (the multi-process axis): run --distributed spawn:N solves the
        configured model across N local rank processes (rank 0 in this
        process, workers forked from the same binary), each owning a
        contiguous range of shards and exchanging boundary messages in
        batched frames over loopback TCP. Roles for manual launch:
        coord:N:0 listens and prints the chosen port; worker:N:R:addr
        connects rank R to the coordinator. Termination is a Safra-style
        token ring (no timeouts); the merged report adds
        boundary_msgs_sent/recv, boundary_bytes, exchange_batches, and
        net_wait_secs. Requires --partition with at least N shards (shards
        default to the thread count times N when unset).

DELTA (the warm-start axis): run --delta-fraction F converges the model,
        perturbs F of the node priors, then re-converges from the resident
        message state — only the out-edges of perturbed nodes are seeded
        (reported as tasks_touched; the second report's wall_secs is the
        time-to-reconverge). experiment delta prints the warm-vs-scratch
        table; bench records one /delta cell per family.";
