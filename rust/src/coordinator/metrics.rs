//! Per-thread counters, the aggregated run metrics, and the lock-free
//! snapshot board the telemetry sampler reads while a run is live.
//!
//! Workers mutate a plain [`Counters`] (no atomics on the hot path); the
//! coordinator sums them after join. `updates` counts *committed* message
//! updates — the quantity the paper's Tables 2, 3 and 6 report — while
//! `wasted_pops` / `stale_pops` expose the relaxation overhead directly.
//!
//! For live observation (convergence traces), each worker periodically
//! *publishes* its plain counters into its [`CounterBoard`] slot — a
//! relaxed-atomic mirror written only by the owning worker and read by the
//! background sampler. Publication rides the existing budget-flush cadence,
//! so the hot path gains no extra cross-thread traffic beyond what budget
//! enforcement already paid.

use std::sync::atomic::{AtomicU64, Ordering};

/// Plain per-thread event counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    /// Committed message updates (the paper's "updates").
    pub updates: u64,
    /// Updates committed with residual ≥ ε ("useful" in §4's terminology).
    pub useful_updates: u64,
    /// Tasks popped whose priority had already fallen below ε.
    pub wasted_pops: u64,
    /// Entries discarded because their epoch was stale.
    pub stale_pops: u64,
    /// Live entries that lost the claim race to another worker.
    pub claim_failures: u64,
    /// Successful pops (any kind).
    pub pops: u64,
    /// Scheduler inserts performed by this worker, including verifier
    /// repair re-inserts (seed-phase inserts are not attributed to any
    /// worker and are excluded).
    pub inserts: u64,
    /// Rounds (synchronous-style engines only).
    pub rounds: u64,
    /// Splash operations (splash engines only).
    pub splashes: u64,
    /// Lookahead refreshes performed while processing — the commit fan-out
    /// the fused node kernel amortizes (`refreshes / pops` ≈ the mean
    /// refresh fan-out per scheduler access). Verifier sweeps are
    /// excluded so the ratio reflects the hot path.
    pub refreshes: u64,
    /// Batched scheduler insert calls (`ExecCtx::requeue_batch`); the mean
    /// insertion batch size on a fused run is ≈ `inserts / insert_batches`
    /// (exact when every insert goes through the batched path).
    pub insert_batches: u64,
    /// Tasks seeded by an evidence-delta warm start (the re-priced
    /// frontier — out-edges of perturbed nodes, or their node tasks on the
    /// node-centric engines). Zero on scratch runs and on empty deltas, so
    /// it doubles as the "how local was this delta" signal next to
    /// `time_to_reconverge` in the BENCH schema.
    pub tasks_touched: u64,
    /// **Gauge** (not an event count): logical bytes of the run's message
    /// arenas — live state plus any lookahead cache — at the storage
    /// precision (`len × bytes_per_cell`). Workers share one arena, so
    /// [`Counters::add`] takes the max instead of summing.
    pub msg_bytes_logical: u64,
    /// **Gauge**: allocated bytes of the same arenas counting whole
    /// 64-byte cache lines (per-shard tail padding included) — what the
    /// process actually maps for message storage. Max-merged like
    /// [`Counters::msg_bytes_logical`].
    pub msg_bytes_padded: u64,
    /// **Gauge**: serialized size of the model file this run loaded or
    /// saved (`model::io` v1/v2 bytes on disk); zero when the model was
    /// built in process without touching disk. Max-merged like the other
    /// gauges — the model is shared run-wide state, not a per-worker
    /// event.
    pub model_bytes: u64,
    /// **Gauge**: the process's peak resident set (`VmHWM` from
    /// `/proc/self/status`, bytes) sampled by the telemetry ticker and once
    /// at run end — the out-of-core axis's headline number: an mmap-arena
    /// run of a larger-than-RAM model keeps this far below
    /// `msg_bytes_padded + model_bytes`. Process-wide, so max-merged;
    /// zero on platforms without procfs.
    pub peak_rss_bytes: u64,
    /// Boundary messages this rank serialized for a peer (distributed runs
    /// only; zero single-process). Counted at egress-buffer push time, at
    /// the origin rank — relayed frames are not re-counted, so summed over
    /// ranks this must equal [`Counters::boundary_msgs_recv`].
    pub boundary_msgs_sent: u64,
    /// Boundary messages applied into this rank's arena via the ingress
    /// path (counted at the final destination; relay hops excluded).
    pub boundary_msgs_recv: u64,
    /// Payload bytes of boundary-exchange frames sent by this rank
    /// (BATCH frames only; the handshake/token/stats control traffic is
    /// excluded so the number tracks the paper-relevant message volume).
    pub boundary_bytes: u64,
    /// Coalesced BATCH frames flushed to peers by this rank.
    pub exchange_batches: u64,
    /// Microseconds spent blocked on network I/O (egress flushes and the
    /// final gather); reported as `net_wait_secs` in run JSON.
    pub net_wait_us: u64,
}

impl Counters {
    /// Field-wise accumulate `other` into `self`. Event counts sum; the
    /// `msg_bytes_*` gauges max-merge (every worker reports the same
    /// shared arenas, so summing would multiply the footprint by the
    /// thread count).
    pub fn add(&mut self, other: &Counters) {
        self.updates += other.updates;
        self.useful_updates += other.useful_updates;
        self.wasted_pops += other.wasted_pops;
        self.stale_pops += other.stale_pops;
        self.claim_failures += other.claim_failures;
        self.pops += other.pops;
        self.inserts += other.inserts;
        self.rounds += other.rounds;
        self.splashes += other.splashes;
        self.refreshes += other.refreshes;
        self.insert_batches += other.insert_batches;
        self.tasks_touched += other.tasks_touched;
        self.msg_bytes_logical = self.msg_bytes_logical.max(other.msg_bytes_logical);
        self.msg_bytes_padded = self.msg_bytes_padded.max(other.msg_bytes_padded);
        self.model_bytes = self.model_bytes.max(other.model_bytes);
        self.peak_rss_bytes = self.peak_rss_bytes.max(other.peak_rss_bytes);
        self.boundary_msgs_sent += other.boundary_msgs_sent;
        self.boundary_msgs_recv += other.boundary_msgs_recv;
        self.boundary_bytes += other.boundary_bytes;
        self.exchange_batches += other.exchange_batches;
        self.net_wait_us += other.net_wait_us;
    }
}

/// Atomic mirror of one worker's [`Counters`], written only by the owning
/// worker (relaxed stores) and read by the telemetry sampler thread.
///
/// Published values lag the worker's plain counters by at most one budget
/// flush — traces are approximate by design, exactly like budget checks.
#[derive(Debug, Default)]
pub struct AtomicCounters {
    updates: AtomicU64,
    useful_updates: AtomicU64,
    wasted_pops: AtomicU64,
    stale_pops: AtomicU64,
    claim_failures: AtomicU64,
    pops: AtomicU64,
    inserts: AtomicU64,
    rounds: AtomicU64,
    splashes: AtomicU64,
    refreshes: AtomicU64,
    insert_batches: AtomicU64,
    tasks_touched: AtomicU64,
    msg_bytes_logical: AtomicU64,
    msg_bytes_padded: AtomicU64,
    model_bytes: AtomicU64,
    peak_rss_bytes: AtomicU64,
    boundary_msgs_sent: AtomicU64,
    boundary_msgs_recv: AtomicU64,
    boundary_bytes: AtomicU64,
    exchange_batches: AtomicU64,
    net_wait_us: AtomicU64,
}

impl AtomicCounters {
    /// Overwrite the published snapshot with the worker's current counters.
    #[inline]
    pub fn publish(&self, c: &Counters) {
        self.updates.store(c.updates, Ordering::Relaxed);
        self.useful_updates.store(c.useful_updates, Ordering::Relaxed);
        self.wasted_pops.store(c.wasted_pops, Ordering::Relaxed);
        self.stale_pops.store(c.stale_pops, Ordering::Relaxed);
        self.claim_failures.store(c.claim_failures, Ordering::Relaxed);
        self.pops.store(c.pops, Ordering::Relaxed);
        self.inserts.store(c.inserts, Ordering::Relaxed);
        self.rounds.store(c.rounds, Ordering::Relaxed);
        self.splashes.store(c.splashes, Ordering::Relaxed);
        self.refreshes.store(c.refreshes, Ordering::Relaxed);
        self.insert_batches.store(c.insert_batches, Ordering::Relaxed);
        self.tasks_touched.store(c.tasks_touched, Ordering::Relaxed);
        self.msg_bytes_logical.store(c.msg_bytes_logical, Ordering::Relaxed);
        self.msg_bytes_padded.store(c.msg_bytes_padded, Ordering::Relaxed);
        self.model_bytes.store(c.model_bytes, Ordering::Relaxed);
        self.peak_rss_bytes.store(c.peak_rss_bytes, Ordering::Relaxed);
        self.boundary_msgs_sent.store(c.boundary_msgs_sent, Ordering::Relaxed);
        self.boundary_msgs_recv.store(c.boundary_msgs_recv, Ordering::Relaxed);
        self.boundary_bytes.store(c.boundary_bytes, Ordering::Relaxed);
        self.exchange_batches.store(c.exchange_batches, Ordering::Relaxed);
        self.net_wait_us.store(c.net_wait_us, Ordering::Relaxed);
    }

    /// Read the last published snapshot.
    pub fn snapshot(&self) -> Counters {
        Counters {
            updates: self.updates.load(Ordering::Relaxed),
            useful_updates: self.useful_updates.load(Ordering::Relaxed),
            wasted_pops: self.wasted_pops.load(Ordering::Relaxed),
            stale_pops: self.stale_pops.load(Ordering::Relaxed),
            claim_failures: self.claim_failures.load(Ordering::Relaxed),
            pops: self.pops.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            rounds: self.rounds.load(Ordering::Relaxed),
            splashes: self.splashes.load(Ordering::Relaxed),
            refreshes: self.refreshes.load(Ordering::Relaxed),
            insert_batches: self.insert_batches.load(Ordering::Relaxed),
            tasks_touched: self.tasks_touched.load(Ordering::Relaxed),
            msg_bytes_logical: self.msg_bytes_logical.load(Ordering::Relaxed),
            msg_bytes_padded: self.msg_bytes_padded.load(Ordering::Relaxed),
            model_bytes: self.model_bytes.load(Ordering::Relaxed),
            peak_rss_bytes: self.peak_rss_bytes.load(Ordering::Relaxed),
            boundary_msgs_sent: self.boundary_msgs_sent.load(Ordering::Relaxed),
            boundary_msgs_recv: self.boundary_msgs_recv.load(Ordering::Relaxed),
            boundary_bytes: self.boundary_bytes.load(Ordering::Relaxed),
            exchange_batches: self.exchange_batches.load(Ordering::Relaxed),
            net_wait_us: self.net_wait_us.load(Ordering::Relaxed),
        }
    }
}

/// One [`AtomicCounters`] slot per worker: the lock-free bridge between
/// the workers' plain counters and the telemetry sampler.
#[derive(Debug)]
pub struct CounterBoard {
    slots: Vec<AtomicCounters>,
}

impl CounterBoard {
    /// A board with one zeroed slot per worker thread.
    pub fn new(threads: usize) -> Self {
        let mut slots = Vec::with_capacity(threads);
        slots.resize_with(threads, AtomicCounters::default);
        CounterBoard { slots }
    }

    /// Worker `tid`'s publication slot.
    #[inline]
    pub fn slot(&self, tid: usize) -> &AtomicCounters {
        &self.slots[tid]
    }

    /// Sum of the last published snapshots across all workers.
    pub fn snapshot_total(&self) -> Counters {
        let mut total = Counters::default();
        for s in &self.slots {
            total.add(&s.snapshot());
        }
        total
    }
}

/// Aggregated metrics across all workers.
#[derive(Debug, Clone, Default)]
pub struct MetricsReport {
    /// Sum of every worker's counters.
    pub total: Counters,
    /// Per-worker committed-update counts (load-imbalance analysis).
    pub per_thread_updates: Vec<u64>,
}

impl MetricsReport {
    /// Sum per-thread counters into one report.
    pub fn aggregate(per_thread: &[Counters]) -> Self {
        let mut total = Counters::default();
        for c in per_thread {
            total.add(c);
        }
        MetricsReport {
            total,
            per_thread_updates: per_thread.iter().map(|c| c.updates).collect(),
        }
    }

    /// Total committed message updates across all workers.
    pub fn total_updates(&self) -> u64 {
        self.total.updates
    }

    /// Imbalance: max/mean of per-thread update counts (1.0 = perfect).
    pub fn load_imbalance(&self) -> f64 {
        if self.per_thread_updates.is_empty() {
            return 1.0;
        }
        let max = *self.per_thread_updates.iter().max().unwrap() as f64;
        let mean = self.per_thread_updates.iter().sum::<u64>() as f64
            / self.per_thread_updates.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sums_fields() {
        let mut a = Counters { updates: 5, wasted_pops: 1, ..Default::default() };
        let b = Counters { updates: 3, stale_pops: 2, ..Default::default() };
        a.add(&b);
        assert_eq!(a.updates, 8);
        assert_eq!(a.wasted_pops, 1);
        assert_eq!(a.stale_pops, 2);
    }

    #[test]
    fn boundary_counters_sum_merge() {
        // The distributed-exchange counters are event counts (per-rank
        // traffic), not shared-state gauges: aggregation sums them.
        let mut a = Counters {
            boundary_msgs_sent: 10,
            boundary_msgs_recv: 4,
            boundary_bytes: 1200,
            exchange_batches: 2,
            net_wait_us: 150,
            ..Default::default()
        };
        let b = Counters {
            boundary_msgs_sent: 5,
            boundary_msgs_recv: 11,
            boundary_bytes: 800,
            exchange_batches: 3,
            net_wait_us: 50,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.boundary_msgs_sent, 15);
        assert_eq!(a.boundary_msgs_recv, 15);
        assert_eq!(a.boundary_bytes, 2000);
        assert_eq!(a.exchange_batches, 5);
        assert_eq!(a.net_wait_us, 200);
        // And they roundtrip through the atomic board like every field.
        let board = CounterBoard::new(1);
        board.slot(0).publish(&a);
        assert_eq!(board.slot(0).snapshot(), a);
    }

    #[test]
    fn msg_bytes_gauges_max_merge() {
        // Every worker reports the same shared arenas: aggregation must
        // not multiply the footprint by the thread count.
        let per = vec![
            Counters {
                updates: 1,
                msg_bytes_logical: 640,
                msg_bytes_padded: 704,
                peak_rss_bytes: 9000,
                ..Default::default()
            },
            Counters {
                updates: 2,
                msg_bytes_logical: 640,
                msg_bytes_padded: 704,
                peak_rss_bytes: 8000,
                ..Default::default()
            },
        ];
        let m = MetricsReport::aggregate(&per);
        assert_eq!(m.total.updates, 3);
        assert_eq!(m.total.msg_bytes_logical, 640);
        assert_eq!(m.total.msg_bytes_padded, 704);
        assert_eq!(m.total.peak_rss_bytes, 9000, "process-wide gauge max-merges");
    }

    #[test]
    fn aggregate_and_imbalance() {
        let per = vec![
            Counters { updates: 100, ..Default::default() },
            Counters { updates: 300, ..Default::default() },
        ];
        let m = MetricsReport::aggregate(&per);
        assert_eq!(m.total_updates(), 400);
        assert_eq!(m.per_thread_updates, vec![100, 300]);
        assert!((m.load_imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn board_publish_snapshot_roundtrip() {
        let board = CounterBoard::new(2);
        let a = Counters { updates: 10, stale_pops: 3, ..Default::default() };
        let b = Counters { updates: 7, inserts: 2, ..Default::default() };
        board.slot(0).publish(&a);
        board.slot(1).publish(&b);
        assert_eq!(board.slot(0).snapshot(), a);
        let total = board.snapshot_total();
        assert_eq!(total.updates, 17);
        assert_eq!(total.stale_pops, 3);
        assert_eq!(total.inserts, 2);
        // Re-publication overwrites (publish is a snapshot, not an add).
        board.slot(0).publish(&b);
        assert_eq!(board.snapshot_total().updates, 14);
    }

    #[test]
    fn imbalance_degenerate() {
        let m = MetricsReport::aggregate(&[]);
        assert_eq!(m.load_imbalance(), 1.0);
        let m = MetricsReport::aggregate(&[Counters::default()]);
        assert_eq!(m.load_imbalance(), 1.0);
    }
}
