//! Per-thread counters and the aggregated run metrics.
//!
//! Workers mutate a plain [`Counters`] (no atomics on the hot path); the
//! coordinator sums them after join. `updates` counts *committed* message
//! updates — the quantity the paper's Tables 2, 3 and 6 report — while
//! `wasted_pops` / `stale_pops` expose the relaxation overhead directly.

/// Plain per-thread event counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    /// Committed message updates (the paper's "updates").
    pub updates: u64,
    /// Updates committed with residual ≥ ε ("useful" in §4's terminology).
    pub useful_updates: u64,
    /// Tasks popped whose priority had already fallen below ε.
    pub wasted_pops: u64,
    /// Entries discarded because their epoch was stale.
    pub stale_pops: u64,
    /// Live entries that lost the claim race to another worker.
    pub claim_failures: u64,
    /// Successful pops (any kind).
    pub pops: u64,
    /// Scheduler inserts performed by this worker, including verifier
    /// repair re-inserts (seed-phase inserts are not attributed to any
    /// worker and are excluded).
    pub inserts: u64,
    /// Rounds (synchronous-style engines only).
    pub rounds: u64,
    /// Splash operations (splash engines only).
    pub splashes: u64,
}

impl Counters {
    pub fn add(&mut self, other: &Counters) {
        self.updates += other.updates;
        self.useful_updates += other.useful_updates;
        self.wasted_pops += other.wasted_pops;
        self.stale_pops += other.stale_pops;
        self.claim_failures += other.claim_failures;
        self.pops += other.pops;
        self.inserts += other.inserts;
        self.rounds += other.rounds;
        self.splashes += other.splashes;
    }
}

/// Aggregated metrics across all workers.
#[derive(Debug, Clone, Default)]
pub struct MetricsReport {
    pub total: Counters,
    pub per_thread_updates: Vec<u64>,
}

impl MetricsReport {
    pub fn aggregate(per_thread: &[Counters]) -> Self {
        let mut total = Counters::default();
        for c in per_thread {
            total.add(c);
        }
        MetricsReport {
            total,
            per_thread_updates: per_thread.iter().map(|c| c.updates).collect(),
        }
    }

    pub fn total_updates(&self) -> u64 {
        self.total.updates
    }

    /// Imbalance: max/mean of per-thread update counts (1.0 = perfect).
    pub fn load_imbalance(&self) -> f64 {
        if self.per_thread_updates.is_empty() {
            return 1.0;
        }
        let max = *self.per_thread_updates.iter().max().unwrap() as f64;
        let mean = self.per_thread_updates.iter().sum::<u64>() as f64
            / self.per_thread_updates.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sums_fields() {
        let mut a = Counters { updates: 5, wasted_pops: 1, ..Default::default() };
        let b = Counters { updates: 3, stale_pops: 2, ..Default::default() };
        a.add(&b);
        assert_eq!(a.updates, 8);
        assert_eq!(a.wasted_pops, 1);
        assert_eq!(a.stale_pops, 2);
    }

    #[test]
    fn aggregate_and_imbalance() {
        let per = vec![
            Counters { updates: 100, ..Default::default() },
            Counters { updates: 300, ..Default::default() },
        ];
        let m = MetricsReport::aggregate(&per);
        assert_eq!(m.total_updates(), 400);
        assert_eq!(m.per_thread_updates, vec![100, 300]);
        assert!((m.load_imbalance() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn imbalance_degenerate() {
        let m = MetricsReport::aggregate(&[]);
        assert_eq!(m.load_imbalance(), 1.0);
        let m = MetricsReport::aggregate(&[Counters::default()]);
        assert_eq!(m.load_imbalance(), 1.0);
    }
}
