//! Coordination layer: per-thread metrics, run budgets, and the quiescence
//! (termination) protocol shared by all queue-driven engines.
//!
//! Engines do not drive this protocol by hand: the
//! [`exec::WorkerPool`](crate::exec::WorkerPool) runtime is the only
//! caller of the pop/insert accounting and verifier election on the hot
//! path (policies reach it through `ExecCtx`).
//!
//! ## Termination protocol
//!
//! Queue-driven BP has no natural "end of input": the run is over when no
//! task has priority ≥ ε. We detect this with two global counters:
//!
//! - `entries` — entries logically in the scheduler. Incremented *before*
//!   an insert, decremented *after* a successful pop, so `entries == 0`
//!   implies the queues are empty and no insert is in flight.
//! - `in_flight` — workers currently holding a popped task (or attempting a
//!   pop). Incremented before the pop, decremented when processing ends.
//!
//! When a worker observes `entries == 0 && in_flight == 0` (its own
//! contribution removed), it elects itself verifier via CAS and re-scans
//! true task priorities; any task ≥ ε is re-inserted (repairing losses from
//! the benign message races), otherwise the run is converged. This makes
//! the final state's residuals *actually* below ε regardless of races.

pub mod metrics;

pub use metrics::{AtomicCounters, CounterBoard, Counters, MetricsReport};

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Wall-clock + update-count budget for a run.
#[derive(Debug, Clone)]
pub struct Budget {
    start: Instant,
    /// Seconds; `f64::INFINITY` when unlimited.
    limit_secs: f64,
    /// Max total updates; `u64::MAX` when unlimited.
    max_updates: u64,
}

impl Budget {
    /// Budget from a wall-clock limit (≤ 0 = unlimited) and an update cap (0 = unlimited).
    pub fn new(limit_secs: f64, max_updates: u64) -> Self {
        Budget {
            start: Instant::now(),
            limit_secs: if limit_secs <= 0.0 { f64::INFINITY } else { limit_secs },
            max_updates: if max_updates == 0 { u64::MAX } else { max_updates },
        }
    }

    #[inline]
    /// True once either limit is exceeded.
    pub fn expired(&self, updates_so_far: u64) -> bool {
        updates_so_far >= self.max_updates || self.elapsed() > self.limit_secs
    }

    /// Seconds since the budget started.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Shared state for the quiescence protocol.
pub struct Termination {
    /// Entries logically in the scheduler (insert-before / pop-after accounting).
    pub entries: AtomicUsize,
    /// Workers currently popping or holding a popped task.
    pub in_flight: AtomicUsize,
    /// Set once: the run is over.
    pub done: AtomicBool,
    verifier: AtomicBool,
    /// Global (approximate) update counter used for budget checks; workers
    /// flush their local counts in batches.
    pub global_updates: AtomicU64,
}

impl Termination {
    /// Fresh protocol state (no entries, nothing in flight).
    pub fn new() -> Self {
        Termination {
            entries: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            done: AtomicBool::new(false),
            verifier: AtomicBool::new(false),
            global_updates: AtomicU64::new(0),
        }
    }

    /// Account for an entry that is about to be inserted.
    #[inline]
    pub fn before_insert(&self) {
        self.entries.fetch_add(1, Ordering::AcqRel);
    }

    /// Account for a successfully popped entry.
    #[inline]
    pub fn after_pop(&self) {
        self.entries.fetch_sub(1, Ordering::AcqRel);
    }

    #[inline]
    /// A worker is about to pop (or starts holding tasks).
    pub fn enter(&self) {
        self.in_flight.fetch_add(1, Ordering::AcqRel);
    }

    #[inline]
    /// The worker finished processing its held tasks.
    pub fn exit(&self) {
        self.in_flight.fetch_sub(1, Ordering::AcqRel);
    }

    #[inline]
    /// True once the run is over.
    pub fn is_done(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }

    /// End the run (idempotent).
    pub fn set_done(&self) {
        self.done.store(true, Ordering::Release);
    }

    /// Quiescent from this worker's perspective (its own `in_flight`
    /// contribution must already be removed).
    #[inline]
    pub fn quiescent(&self) -> bool {
        self.entries.load(Ordering::Acquire) == 0 && self.in_flight.load(Ordering::Acquire) == 0
    }

    /// Try to become the single verifier; `verify` must return `true` if
    /// the system is converged (then the run ends) or `false` if it found
    /// and re-inserted work. Returns whether this thread ran verification.
    pub fn try_verify<F: FnOnce() -> bool>(&self, verify: F) -> bool {
        if self
            .verifier
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        // Re-check quiescence while holding the verifier role: a racing
        // worker may have popped/inserted in between.
        if self.quiescent() {
            if verify() {
                self.set_done();
            }
        }
        self.verifier.store(false, Ordering::Release);
        true
    }
}

impl Default for Termination {
    fn default() -> Self {
        Self::new()
    }
}

/// Run `worker(thread_id)` on `threads` scoped threads and collect results.
pub fn run_workers<R: Send>(
    threads: usize,
    worker: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    assert!(threads >= 1);
    if threads == 1 {
        return vec![worker(0)];
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let worker = &worker;
                s.spawn(move || worker(t))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn budget_unlimited() {
        let b = Budget::new(0.0, 0);
        assert!(!b.expired(u64::MAX - 1));
    }

    #[test]
    fn budget_updates_cap() {
        let b = Budget::new(0.0, 100);
        assert!(!b.expired(99));
        assert!(b.expired(100));
    }

    #[test]
    fn budget_time_cap() {
        let b = Budget::new(0.001, 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(b.expired(0));
    }

    #[test]
    fn termination_counters() {
        let t = Termination::new();
        assert!(t.quiescent());
        t.before_insert();
        assert!(!t.quiescent());
        t.after_pop();
        assert!(t.quiescent());
        t.enter();
        assert!(!t.quiescent());
        t.exit();
        assert!(t.quiescent());
    }

    #[test]
    fn verifier_is_exclusive_and_sets_done() {
        let t = Termination::new();
        let ran = t.try_verify(|| true);
        assert!(ran);
        assert!(t.is_done());
    }

    #[test]
    fn verifier_aborts_when_not_quiescent() {
        let t = Termination::new();
        t.before_insert();
        let ran = t.try_verify(|| true);
        assert!(ran, "acquired the role");
        assert!(!t.is_done(), "but did not verify: not quiescent");
    }

    #[test]
    fn verifier_reinsertion_keeps_running() {
        let t = Termination::new();
        t.try_verify(|| false);
        assert!(!t.is_done());
    }

    #[test]
    fn run_workers_collects_in_order() {
        let out = run_workers(4, |t| t * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn run_workers_shares_state() {
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        run_workers(8, move |_| {
            c.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }
}
