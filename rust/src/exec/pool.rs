//! The generic worker-pool runner: owns every piece of the concurrent
//! skeleton the engines used to copy-paste.

use super::policy::{ExecCtx, RunObserver, TaskPolicy};
use crate::configio::{PartitionSpec, RunConfig};
use crate::coordinator::{run_workers, Budget, CounterBoard, Counters, MetricsReport, Termination};
use crate::engines::EngineStats;
use crate::model::Partition;
use crate::sched::{SchedChoice, Scheduler, ShardAffinity, TaskStates};
use crate::util::{Timer, Xoshiro256};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

/// RNG stream for the single-threaded seed phase.
const SEED_STREAM: u64 = 0x5EED;
/// Worker `tid` draws from stream `WORKER_STREAM_BASE + tid`.
const WORKER_STREAM_BASE: u64 = 0x1000;
/// How often the sampler re-checks for termination between samples; keeps
/// the sampler thread from outliving the run by more than ~1 ms even with
/// coarse tick intervals.
const SAMPLER_POLL: Duration = Duration::from_millis(1);
/// Publish idle workers' counters to the board every this many idle
/// rounds, so stale-pop/claim-failure streaks stay visible in traces even
/// when no budget flush happens.
const IDLE_PUBLISH_EVERY: u32 = 64;

/// Runtime knobs, uniform across all engines (previously each engine
/// hard-coded its own divergent copies).
#[derive(Debug, Clone, Copy)]
pub struct PoolTuning {
    /// Claimed tasks drained per processing round (1 for classic
    /// task-at-a-time engines; >1 for the batched engine).
    pub batch: usize,
    /// Flush locally counted work units into the global budget counter
    /// once this many accumulate (budget checks are approximate by design;
    /// the counter flush is the only cross-thread traffic on the hot path).
    pub flush_every: u64,
    /// Busy-spin this many consecutive idle rounds before yielding the OS
    /// slice (spinning rides out momentary queue emptiness; yielding keeps
    /// oversubscribed runs live).
    pub spin_limit: u32,
    /// Minimum priority for [`ExecCtx::requeue`] to insert an entry.
    /// Engines mirror `RunConfig::epsilon`; `f64::NEG_INFINITY` keeps every
    /// task resident (the optimal tree schedule's analytical model).
    pub insert_threshold: f64,
}

impl Default for PoolTuning {
    fn default() -> Self {
        PoolTuning { batch: 1, flush_every: 256, spin_limit: 64, insert_threshold: 0.0 }
    }
}

/// The generic relaxed-execution runner.
///
/// Owns scheduler construction, worker spawn, the pop/claim/epoch
/// protocol, quiescence + elected-verifier termination, budget
/// enforcement, idle backoff, and metrics aggregation. Engines supply a
/// [`TaskPolicy`] and run with [`WorkerPool::run`].
pub struct WorkerPool {
    threads: usize,
    seed: u64,
    queues_per_thread: usize,
    time_limit_secs: f64,
    max_updates: u64,
    choice: SchedChoice,
    tuning: PoolTuning,
    /// The run's locality axis (from `RunConfig::partition`).
    partition_spec: PartitionSpec,
    /// Explicit task partition from the engine (e.g. BFS-clustered over
    /// the model graph). When absent and the axis is on, the pool falls
    /// back to a contiguous partition over the policy's task universe.
    partition: Option<Partition>,
}

impl WorkerPool {
    /// Pool for a run described by `cfg`, scheduled by `choice`. The
    /// insert threshold defaults to `cfg.epsilon`; the locality axis
    /// follows `cfg.partition`.
    pub fn from_config(cfg: &RunConfig, choice: SchedChoice) -> Self {
        WorkerPool {
            threads: cfg.threads.max(1),
            seed: cfg.seed,
            queues_per_thread: cfg.queues_per_thread,
            time_limit_secs: cfg.time_limit_secs,
            max_updates: cfg.max_updates,
            choice,
            tuning: PoolTuning { insert_threshold: cfg.epsilon, ..PoolTuning::default() },
            partition_spec: cfg.partition,
            partition: None,
        }
    }

    /// Attach an explicit task partition (built by the engine against its
    /// task universe — directed edges for message engines, nodes for
    /// splash). Its task count must match the policy's `num_tasks`.
    pub fn with_partition(mut self, partition: Option<Partition>) -> Self {
        self.partition = partition;
        self
    }

    /// Drain up to `batch` claimed tasks per processing round.
    pub fn batch(mut self, batch: usize) -> Self {
        self.tuning.batch = batch.max(1);
        self
    }

    /// Override the budget flush granularity.
    pub fn flush_every(mut self, units: u64) -> Self {
        self.tuning.flush_every = units.max(1);
        self
    }

    /// Override the idle spin limit.
    pub fn spin_limit(mut self, spins: u32) -> Self {
        self.tuning.spin_limit = spins;
        self
    }

    /// Override the insert threshold (see [`PoolTuning::insert_threshold`]).
    pub fn insert_threshold(mut self, threshold: f64) -> Self {
        self.tuning.insert_threshold = threshold;
        self
    }

    /// Run `policy` to convergence or budget exhaustion.
    pub fn run<P: TaskPolicy>(&self, policy: &P) -> EngineStats {
        self.run_observed(policy, None)
    }

    /// Like [`WorkerPool::run`], additionally feeding `observer` periodic
    /// samples (elapsed time, counter snapshot, current max priority) from
    /// a dedicated background thread — the hook convergence traces
    /// (`telemetry::TraceRecorder`) are recorded through. The sampler takes
    /// one sample right after the workers start, one per
    /// [`RunObserver::tick`] while the run is live, and a final one from
    /// the exact aggregated counters after the workers join.
    pub fn run_observed<P: TaskPolicy>(
        &self,
        policy: &P,
        observer: Option<&dyn RunObserver>,
    ) -> EngineStats {
        let timer = Timer::start();
        let budget = Budget::new(self.time_limit_secs, self.max_updates);
        let num_tasks = policy.num_tasks();

        // Resolve the locality axis: an engine-supplied partition wins;
        // otherwise, with the axis on, fall back to contiguous task-id
        // blocks over the policy's universe.
        let fallback_partition = match (&self.partition, self.partition_spec) {
            (None, spec @ PartitionSpec::Affine { .. }) => {
                Some(Partition::contiguous(num_tasks, spec.resolved_shards(self.threads)))
            }
            _ => None,
        };
        let partition: Option<&Partition> =
            self.partition.as_ref().or(fallback_partition.as_ref());
        if let Some(p) = partition {
            assert_eq!(
                p.num_tasks(),
                num_tasks,
                "partition universe must match the policy's task universe"
            );
        }
        let spill = match self.partition_spec {
            PartitionSpec::Affine { spill, .. } => spill,
            PartitionSpec::Off => 0.0,
        };
        let affinity = partition
            .map(|p| ShardAffinity { shards: p.num_shards(), spill });

        let sched = self
            .choice
            .build(num_tasks, self.threads, self.queues_per_thread, affinity);
        let sched: &dyn Scheduler = sched.as_ref();
        let ts = TaskStates::new(num_tasks);
        let term = Termination::new();
        let timed_out = AtomicBool::new(false);
        let tuning = self.tuning;
        let board = CounterBoard::new(self.threads);

        // Seed phase: single-threaded, before any worker exists. Seed
        // counters are not attributed to a worker (they would skew
        // per-thread imbalance numbers) and are discarded — except
        // `tasks_touched`, the delta-frontier size a warm-start seed
        // reports, which only the seed phase can produce and is folded
        // into the final totals below. With the locality axis on, the
        // ExecCtx routes every seeded entry to its shard's queue group.
        let seed_tasks_touched = {
            let mut rng = Xoshiro256::stream(self.seed, SEED_STREAM);
            let mut seed_counters = Counters::default();
            let mut entry_buf = Vec::new();
            let mut ctx = ExecCtx::new(
                sched,
                &ts,
                &term,
                &mut rng,
                &mut seed_counters,
                tuning.insert_threshold,
                partition,
                &mut entry_buf,
            );
            policy.seed(&mut ctx);
            seed_counters.tasks_touched
        };

        // The sampler (when an observer is attached) runs beside the
        // workers in an enclosing scope: it wakes every SAMPLER_POLL, emits
        // a sample once per observer tick, and exits as soon as the run is
        // done (`term.is_done()` is exactly the workers' loop condition).
        let per_thread = std::thread::scope(|outer| {
            if let Some(obs) = observer {
                let board = &board;
                let term = &term;
                let timer = &timer;
                let _sampler = outer.spawn(move || {
                    let tick = obs.tick().max(Duration::from_micros(100)).as_secs_f64();
                    // The peak-RSS gauge is process-wide state, not a
                    // per-worker counter: the sampler stamps it into each
                    // snapshot it emits (workers never touch it).
                    let stamped = |mut c: Counters| {
                        c.peak_rss_bytes = crate::util::peak_rss_bytes();
                        c
                    };
                    obs.sample(
                        timer.elapsed_secs(),
                        &stamped(board.snapshot_total()),
                        policy.final_priority(),
                    );
                    let mut last = timer.elapsed_secs();
                    while !term.is_done() {
                        std::thread::sleep(SAMPLER_POLL);
                        let now = timer.elapsed_secs();
                        if now - last >= tick {
                            last = now;
                            obs.sample(
                                now,
                                &stamped(board.snapshot_total()),
                                policy.final_priority(),
                            );
                        }
                    }
                });
            }
            let arena_bytes = policy.arena_bytes();
            run_workers(self.threads, |tid| {
                let mut rng = Xoshiro256::stream(self.seed, WORKER_STREAM_BASE + tid as u64);
                let mut c = Counters::default();
                // Memory-footprint gauges: stamped once per worker (the
                // arenas are shared, so aggregation max-merges them) and
                // published immediately so even the first trace sample
                // carries the footprint.
                c.msg_bytes_logical = arena_bytes.0;
                c.msg_bytes_padded = arena_bytes.1;
                board.slot(tid).publish(&c);
                let mut scratch = policy.make_scratch();
                let mut claimed: Vec<u32> = Vec::with_capacity(tuning.batch);
                let mut popped: Vec<crate::sched::Entry> = Vec::with_capacity(tuning.batch);
                // Per-worker insertion buffer lent to each ExecCtx
                // (requeue_batch): allocated once, reused every round.
                let mut entry_buf: Vec<crate::sched::Entry> = Vec::new();
                let mut since_flush: u64 = 0;
                let mut idle_spins: u32 = 0;
                // Home shards: shard s belongs to worker s mod threads, so
                // every shard has an owner even when shards > threads. A
                // worker owning several shards services them round-robin,
                // one processing round each — without that rotation,
                // low-spill runs would starve the extra shards behind the
                // first one's work (pops reach other groups only through
                // the fallback sweep, which fires when the whole structure
                // looks empty).
                let owned: Vec<u32> = match partition {
                    Some(p) => {
                        let k = p.num_shards().max(1);
                        let mut v: Vec<u32> =
                            (tid..k).step_by(self.threads.max(1)).map(|s| s as u32).collect();
                        if v.is_empty() {
                            // More workers than shards: share a home.
                            v.push((tid % k) as u32);
                        }
                        v
                    }
                    None => Vec::new(),
                };
                let mut home_pos = 0usize;

                while !term.is_done() {
                    let home: Option<u32> = if owned.is_empty() {
                        None
                    } else {
                        Some(owned[home_pos % owned.len()])
                    };
                    if owned.len() > 1 {
                        home_pos = home_pos.wrapping_add(1);
                    }
                    // ---- Drain up to `batch` valid, claimable tasks ----
                    // Batched pops: one two-choice queue visit yields up to
                    // `batch` entries (Multiqueue: one lock per visit); the
                    // epoch-validate + claim protocol is per entry, exactly
                    // as with single pops.
                    claimed.clear();
                    term.enter();
                    // External work first: boundary messages from peer
                    // ranks (distributed runs) are applied and requeued
                    // while this worker counts as active, so the entries
                    // they insert are covered by the quiescence accounting
                    // before the worker can look idle. No-op for local
                    // policies.
                    {
                        let mut ctx = ExecCtx::new(
                            sched,
                            &ts,
                            &term,
                            &mut rng,
                            &mut c,
                            tuning.insert_threshold,
                            partition,
                            &mut entry_buf,
                        );
                        since_flush += policy.drain_ingress(&mut ctx, &mut scratch);
                    }
                    while claimed.len() < tuning.batch {
                        popped.clear();
                        let want = tuning.batch - claimed.len();
                        if sched.pop_batch(&mut rng, home, want, &mut popped) == 0 {
                            break;
                        }
                        for ent in popped.drain(..) {
                            term.after_pop();
                            c.pops += 1;
                            if ent.epoch != ts.epoch(ent.task) {
                                c.stale_pops += 1;
                                continue;
                            }
                            if !ts.try_claim(ent.task, ent.epoch) {
                                c.claim_failures += 1;
                                continue;
                            }
                            claimed.push(ent.task);
                        }
                    }

                    if claimed.is_empty() {
                        term.exit();
                        // `quiescent()` alone is counter-based; the
                        // explicit sweep re-checks every sub-queue under
                        // its lock so a momentarily-unlucky pop sample can
                        // never let the (possibly distributed) termination
                        // decision race a fully inserted entry.
                        if term.quiescent() && sched.is_definitely_empty() {
                            term.try_verify(|| {
                                let mut ctx = ExecCtx::new(
                                    sched,
                                    &ts,
                                    &term,
                                    &mut rng,
                                    &mut c,
                                    tuning.insert_threshold,
                                    partition,
                                    &mut entry_buf,
                                );
                                // Short-circuit: the rank-level termination
                                // gate only runs on a clean local sweep.
                                policy.verify_sweep(&mut ctx) && policy.try_finish()
                            });
                        } else {
                            idle_spins += 1;
                            if idle_spins > tuning.spin_limit {
                                std::thread::yield_now();
                            } else {
                                std::hint::spin_loop();
                            }
                            // Keep stale-pop / claim-failure streaks visible in
                            // traces even when no budget flush happens.
                            if idle_spins % IDLE_PUBLISH_EVERY == 0 {
                                board.slot(tid).publish(&c);
                            }
                            // Idle threads must also enforce the budget, or a
                            // stalled run would never stop.
                            if budget.expired(term.global_updates.load(Ordering::Relaxed)) {
                                timed_out.store(true, Ordering::Release);
                                term.set_done();
                            }
                        }
                        continue;
                    }

                    idle_spins = 0;
                    let work = {
                        let mut ctx = ExecCtx::new(
                            sched,
                            &ts,
                            &term,
                            &mut rng,
                            &mut c,
                            tuning.insert_threshold,
                            partition,
                            &mut entry_buf,
                        );
                        policy.process(&claimed, &mut ctx, &mut scratch)
                    };
                    for &task in &claimed {
                        ts.release(task);
                    }
                    term.exit();

                    since_flush += work;
                    if since_flush >= tuning.flush_every {
                        let global = term.global_updates.fetch_add(since_flush, Ordering::Relaxed)
                            + since_flush;
                        since_flush = 0;
                        board.slot(tid).publish(&c);
                        if budget.expired(global) {
                            timed_out.store(true, Ordering::Release);
                            term.set_done();
                        }
                    }
                }
                c
            })
        });

        let mut metrics = MetricsReport::aggregate(&per_thread);
        // The delta frontier was counted in the (otherwise discarded) seed
        // phase; fold it in before the final observer sample so the
        // trace's last point matches the reported stats.
        metrics.total.tasks_touched += seed_tasks_touched;
        // Stamp the process-wide peak-RSS gauge into the totals (even
        // unobserved runs report it in their stats/JSON).
        metrics.total.peak_rss_bytes =
            metrics.total.peak_rss_bytes.max(crate::util::peak_rss_bytes());
        // Final sample from the exact (post-join) totals: guarantees every
        // observed run yields at least two points (start + end) and that
        // the trace's last point matches the reported stats.
        if let Some(obs) = observer {
            obs.sample(timer.elapsed_secs(), &metrics.total, policy.final_priority());
        }
        EngineStats {
            converged: policy.converged(timed_out.load(Ordering::Acquire)),
            wall_secs: timer.elapsed_secs(),
            metrics,
            final_max_priority: policy.final_priority(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use std::sync::atomic::{AtomicU64, AtomicUsize};

    fn test_cfg(threads: usize) -> RunConfig {
        RunConfig::new(ModelSpec::Path { n: 2 }, AlgorithmSpec::RelaxedResidual)
            .with_threads(threads)
            .with_epsilon(0.5)
    }

    /// Each task is processed exactly once and never requeued.
    struct OneShot {
        n: usize,
        processed: Vec<AtomicUsize>,
    }

    impl OneShot {
        fn new(n: usize) -> Self {
            let mut processed = Vec::with_capacity(n);
            processed.resize_with(n, || AtomicUsize::new(0));
            OneShot { n, processed }
        }
    }

    impl TaskPolicy for OneShot {
        type Scratch = ();

        fn num_tasks(&self) -> usize {
            self.n
        }

        fn make_scratch(&self) -> Self::Scratch {}

        fn seed(&self, ctx: &mut ExecCtx<'_>) {
            for t in 0..self.n as u32 {
                assert!(ctx.requeue(t, 1.0));
            }
        }

        fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
            for &t in tasks {
                self.processed[t as usize].fetch_add(1, Ordering::Relaxed);
                ctx.counters.updates += 1;
            }
            tasks.len() as u64
        }

        fn verify_sweep(&self, _: &mut ExecCtx<'_>) -> bool {
            true
        }

        fn final_priority(&self) -> f64 {
            0.0
        }
    }

    #[test]
    fn one_shot_policy_processes_every_task_once() {
        for threads in [1, 4] {
            let policy = OneShot::new(100);
            let stats = WorkerPool::from_config(&test_cfg(threads), SchedChoice::Relaxed)
                .run(&policy);
            assert!(stats.converged);
            assert_eq!(stats.metrics.total.updates, 100, "threads={threads}");
            for p in &policy.processed {
                assert_eq!(p.load(Ordering::Relaxed), 1);
            }
            // Shared counter semantics: every successful pop is either
            // stale, a lost claim race, or a processed task.
            let m = &stats.metrics.total;
            assert_eq!(m.pops, m.stale_pops + m.claim_failures + m.updates);
        }
    }

    #[test]
    fn one_shot_policy_with_partition_axis() {
        use crate::configio::PartitionSpec;
        // Shard-affine scheduling (auto shards, contiguous fallback
        // partition, and an explicit partition) must preserve the
        // exactly-once processing guarantee and the pop accounting.
        for shards in [0usize, 1, 2, 7] {
            let mut cfg = test_cfg(4);
            cfg.partition = PartitionSpec::Affine { shards, spill: 0.1, bfs: false };
            let policy = OneShot::new(100);
            let pool = WorkerPool::from_config(&cfg, SchedChoice::Relaxed);
            let pool = if shards == 7 {
                // Exercise the explicit-partition path too.
                pool.with_partition(Some(crate::model::Partition::contiguous(100, 7)))
            } else {
                pool
            };
            let stats = pool.run(&policy);
            assert!(stats.converged, "shards={shards}");
            assert_eq!(stats.metrics.total.updates, 100, "shards={shards}");
            for p in &policy.processed {
                assert_eq!(p.load(Ordering::Relaxed), 1);
            }
            let m = &stats.metrics.total;
            assert_eq!(m.pops, m.stale_pops + m.claim_failures + m.updates);
        }
    }

    #[test]
    fn partial_seed_repairs_and_keeps_exactly_once() {
        use crate::configio::PartitionSpec;

        /// Delta-style seed: only the first `seeded` tasks go in (counted
        /// as `tasks_touched`), the rest must be discovered by the verify
        /// sweep. Models a warm-start batch landing on an already-drained
        /// scheduler — including `seeded == 0`, the empty delta, where the
        /// run starts fully quiescent.
        struct PartialSeed {
            n: usize,
            seeded: usize,
            processed: Vec<AtomicUsize>,
        }
        impl TaskPolicy for PartialSeed {
            type Scratch = ();
            fn num_tasks(&self) -> usize {
                self.n
            }
            fn make_scratch(&self) -> Self::Scratch {}
            fn seed(&self, ctx: &mut ExecCtx<'_>) {
                for t in 0..self.seeded as u32 {
                    assert!(ctx.requeue(t, 1.0));
                    ctx.counters.tasks_touched += 1;
                }
            }
            fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
                for &t in tasks {
                    self.processed[t as usize].fetch_add(1, Ordering::Relaxed);
                    ctx.counters.updates += 1;
                }
                tasks.len() as u64
            }
            fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool {
                let mut clean = true;
                for t in 0..self.n as u32 {
                    if self.processed[t as usize].load(Ordering::Relaxed) == 0 {
                        ctx.requeue(t, 1.0);
                        clean = false;
                    }
                }
                clean
            }
            fn final_priority(&self) -> f64 {
                0.0
            }
        }

        let threads = 4;
        for shards in [1usize, 2, 7, threads] {
            for seeded in [0usize, 5] {
                let mut cfg = test_cfg(threads);
                cfg.partition = PartitionSpec::Affine { shards, spill: 0.1, bfs: false };
                let policy = PartialSeed {
                    n: 60,
                    seeded,
                    processed: {
                        let mut v = Vec::with_capacity(60);
                        v.resize_with(60, || AtomicUsize::new(0));
                        v
                    },
                };
                let stats =
                    WorkerPool::from_config(&cfg, SchedChoice::Relaxed).run(&policy);
                assert!(stats.converged, "shards={shards} seeded={seeded}");
                assert_eq!(stats.metrics.total.updates, 60, "shards={shards} seeded={seeded}");
                for p in &policy.processed {
                    assert_eq!(p.load(Ordering::Relaxed), 1, "exactly-once");
                }
                let m = &stats.metrics.total;
                assert_eq!(m.pops, m.stale_pops + m.claim_failures + m.updates);
                assert_eq!(
                    m.tasks_touched, seeded as u64,
                    "seed-phase frontier count must survive into the totals"
                );
            }
        }
    }

    #[test]
    fn observer_receives_start_and_final_samples() {
        use std::sync::Mutex;

        struct Spy {
            samples: Mutex<Vec<(f64, u64, f64)>>,
        }
        impl crate::exec::RunObserver for Spy {
            fn tick(&self) -> std::time::Duration {
                std::time::Duration::from_millis(1)
            }
            fn sample(&self, elapsed_secs: f64, totals: &Counters, max_priority: f64) {
                self.samples.lock().unwrap().push((elapsed_secs, totals.updates, max_priority));
            }
        }

        let spy = Spy { samples: Mutex::new(Vec::new()) };
        let policy = OneShot::new(200);
        let stats = WorkerPool::from_config(&test_cfg(2), SchedChoice::Relaxed)
            .run_observed(&policy, Some(&spy));
        assert!(stats.converged);
        let samples = spy.samples.lock().unwrap();
        assert!(samples.len() >= 2, "start + final sample at minimum");
        let last = samples.last().unwrap();
        assert_eq!(last.1, 200, "final sample carries the exact post-join totals");
        assert!(
            samples.windows(2).all(|w| w[0].0 <= w[1].0),
            "sample timestamps are monotone"
        );
    }

    #[test]
    fn exact_scheduler_processes_in_priority_order_single_thread() {
        struct Ordered {
            n: usize,
            log: std::sync::Mutex<Vec<u32>>,
        }
        impl TaskPolicy for Ordered {
            type Scratch = ();
            fn num_tasks(&self) -> usize {
                self.n
            }
            fn make_scratch(&self) -> Self::Scratch {}
            fn seed(&self, ctx: &mut ExecCtx<'_>) {
                for t in 0..self.n as u32 {
                    ctx.requeue(t, t as f64 + 1.0);
                }
            }
            fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
                self.log.lock().unwrap().extend_from_slice(tasks);
                ctx.counters.updates += tasks.len() as u64;
                tasks.len() as u64
            }
            fn verify_sweep(&self, _: &mut ExecCtx<'_>) -> bool {
                true
            }
            fn final_priority(&self) -> f64 {
                0.0
            }
        }
        let policy = Ordered { n: 20, log: std::sync::Mutex::new(Vec::new()) };
        let stats =
            WorkerPool::from_config(&test_cfg(1), SchedChoice::Exact).run(&policy);
        assert!(stats.converged);
        let log = policy.log.lock().unwrap();
        let expect: Vec<u32> = (0..20u32).rev().collect();
        assert_eq!(*log, expect, "exact queue pops in descending priority");
    }

    #[test]
    fn budget_expiry_reports_timeout() {
        /// Requeues itself forever; only the budget can stop it.
        struct Endless;
        impl TaskPolicy for Endless {
            type Scratch = ();
            fn num_tasks(&self) -> usize {
                4
            }
            fn make_scratch(&self) -> Self::Scratch {}
            fn seed(&self, ctx: &mut ExecCtx<'_>) {
                for t in 0..4 {
                    ctx.requeue(t, 1.0);
                }
            }
            fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
                for &t in tasks {
                    ctx.counters.updates += 1;
                    ctx.requeue(t, 1.0);
                }
                tasks.len() as u64
            }
            fn verify_sweep(&self, _: &mut ExecCtx<'_>) -> bool {
                true
            }
            fn final_priority(&self) -> f64 {
                1.0
            }
        }
        let mut cfg = test_cfg(2);
        cfg.max_updates = 500;
        let stats = WorkerPool::from_config(&cfg, SchedChoice::Relaxed)
            .flush_every(16)
            .run(&Endless);
        assert!(!stats.converged);
        assert!(stats.metrics.total.updates >= 500);
    }

    #[test]
    fn verifier_repair_requeues_lost_work() {
        /// Task 0 "loses" its priority once: the first verify sweep must
        /// find and requeue it, the second must end the run.
        struct Lossy {
            sweeps: AtomicU64,
            extra_processed: AtomicU64,
        }
        impl TaskPolicy for Lossy {
            type Scratch = ();
            fn num_tasks(&self) -> usize {
                1
            }
            fn make_scratch(&self) -> Self::Scratch {}
            fn seed(&self, _: &mut ExecCtx<'_>) {
                // Nothing seeded: the run starts quiescent and the verifier
                // must discover the pending task.
            }
            fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
                self.extra_processed.fetch_add(tasks.len() as u64, Ordering::Relaxed);
                ctx.counters.updates += tasks.len() as u64;
                tasks.len() as u64
            }
            fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool {
                if self.sweeps.fetch_add(1, Ordering::Relaxed) == 0 {
                    ctx.requeue(0, 1.0);
                    false
                } else {
                    true
                }
            }
            fn final_priority(&self) -> f64 {
                0.0
            }
        }
        let policy = Lossy { sweeps: AtomicU64::new(0), extra_processed: AtomicU64::new(0) };
        let stats =
            WorkerPool::from_config(&test_cfg(1), SchedChoice::Relaxed).run(&policy);
        assert!(stats.converged);
        assert_eq!(policy.extra_processed.load(Ordering::Relaxed), 1);
        assert!(policy.sweeps.load(Ordering::Relaxed) >= 2);
    }

    #[test]
    fn batch_draining_hands_multiple_tasks_per_round() {
        struct BatchSpy {
            max_seen: AtomicU64,
        }
        impl TaskPolicy for BatchSpy {
            type Scratch = ();
            fn num_tasks(&self) -> usize {
                64
            }
            fn make_scratch(&self) -> Self::Scratch {}
            fn seed(&self, ctx: &mut ExecCtx<'_>) {
                for t in 0..64 {
                    ctx.requeue(t, 1.0);
                }
            }
            fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
                self.max_seen.fetch_max(tasks.len() as u64, Ordering::Relaxed);
                ctx.counters.updates += tasks.len() as u64;
                tasks.len() as u64
            }
            fn verify_sweep(&self, _: &mut ExecCtx<'_>) -> bool {
                true
            }
            fn final_priority(&self) -> f64 {
                0.0
            }
        }
        let policy = BatchSpy { max_seen: AtomicU64::new(0) };
        let stats = WorkerPool::from_config(&test_cfg(1), SchedChoice::Relaxed)
            .batch(8)
            .run(&policy);
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.updates, 64);
        assert!(policy.max_seen.load(Ordering::Relaxed) > 1, "batch draining engaged");
    }

    #[test]
    fn sub_threshold_requeue_invalidates_without_inserting() {
        /// Processing requeues below threshold: the run must terminate via
        /// the verifier rather than loop.
        struct Decaying;
        impl TaskPolicy for Decaying {
            type Scratch = ();
            fn num_tasks(&self) -> usize {
                8
            }
            fn make_scratch(&self) -> Self::Scratch {}
            fn seed(&self, ctx: &mut ExecCtx<'_>) {
                for t in 0..8 {
                    ctx.requeue(t, 1.0);
                }
            }
            fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, _: &mut ()) -> u64 {
                for &t in tasks {
                    ctx.counters.updates += 1;
                    assert!(!ctx.requeue(t, 0.0), "0.0 is below the 0.5 threshold");
                }
                tasks.len() as u64
            }
            fn verify_sweep(&self, _: &mut ExecCtx<'_>) -> bool {
                true
            }
            fn final_priority(&self) -> f64 {
                0.0
            }
        }
        let stats =
            WorkerPool::from_config(&test_cfg(2), SchedChoice::Relaxed).run(&Decaying);
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.updates, 8);
    }
}
