//! The unified relaxed-execution runtime.
//!
//! Every queue-driven BP engine in this crate runs the same concurrent
//! skeleton: seed the scheduler, then have `p` workers pop → validate
//! epoch → claim → process → requeue affected tasks → release, with the
//! coordinator's quiescence + elected-verifier protocol deciding when the
//! run is over and a batched counter flush enforcing the wall-clock /
//! update budget. Historically each engine re-implemented that skeleton by
//! hand, so fixes to backoff, termination, or metrics had to be ported
//! five times (and drifted — e.g. the idle backoff differed between the
//! residual and priority engines).
//!
//! This module factors the skeleton into two pieces:
//!
//! - [`TaskPolicy`] — what an engine actually contributes: the task
//!   universe, how to seed it, how to process a claimed task (the update
//!   kernel + activation rule), the verifier's repair sweep, and the final
//!   convergence report;
//! - [`WorkerPool`] — everything else: scheduler construction (via
//!   [`crate::sched::SchedChoice`]), scoped thread spawn, the pop / epoch
//!   / claim protocol on [`crate::sched::TaskStates`], multi-task batch
//!   draining, the `entries` / `in_flight` quiescence protocol with the
//!   elected-verifier sweep, batched budget flushes, spin-then-yield idle
//!   backoff, timeout propagation, and per-thread [`Counters`] aggregation
//!   into [`EngineStats`].
//!
//! Policies never touch the scheduler or the termination counters
//! directly; they interact with the runtime only through [`ExecCtx`]
//! (`requeue`, `finish`, counters). This is what keeps the quiescence
//! accounting correct by construction — a policy cannot forget a
//! `before_insert`.
//!
//! ## The pop / epoch / claim protocol
//!
//! Concurrent heaps cannot support `increase_key`, so every priority
//! change inserts a fresh *lazy entry* stamped with the task's bumped
//! epoch; stale entries are discarded at pop time and a claim bit makes
//! processing exclusive:
//!
//! ```text
//!             ┌────────────────────────────────────────────────────┐
//!             │                    worker loop                     │
//!             ▼                                                    │
//!   sched.pop(rng) ──none──▶ quiescent? ──yes──▶ elect verifier ───┤
//!        │                       │no                │              │
//!        │entry                  ▼                  ▼              │
//!        │               spin/yield backoff   verify_sweep():      │
//!        ▼                (budget checked)    re-derive true       │
//!   epoch == TaskStates.epoch(task)?          priorities; requeue  │
//!        │no → stale_pop, retry ──────────▶   lost work, or done   │
//!        │yes                                                      │
//!        ▼                                                         │
//!   TaskStates.try_claim(task, epoch)  (CAS claim bit + epoch)     │
//!        │no → claim_failure, retry ─────────────────────────────▶ │
//!        │yes                                                      │
//!        ▼                                                         │
//!   policy.process(claimed tasks)                                  │
//!     └─ ctx.requeue(k, prio): bump epoch (invalidate all          │
//!        outstanding entries for k) + insert fresh entry if        │
//!        prio ≥ threshold                                          │
//!        ▼                                                         │
//!   TaskStates.release(task) ──────────────────────────────────────┘
//! ```
//!
//! Every successful pop is therefore exactly one of {stale entry, lost
//! claim race, processed task} — the counter identity the parity tests
//! assert on every engine.
//!
//! ## Locality
//!
//! When the run's partition axis ([`crate::configio::PartitionSpec`]) is
//! on, the pool resolves a [`Partition`](crate::model::Partition) over the
//! policy's task universe (engine-supplied — e.g. BFS-clustered over the
//! model graph — or contiguous id blocks as the fallback), builds the
//! relaxed scheduler shard-affine, assigns each worker a home shard for
//! pops, and routes every `requeue`/`activate` insert to the task's shard
//! through [`ExecCtx`]. All of it is advisory: the pop/epoch/claim
//! protocol and the quiescence accounting are identical with the axis on
//! or off.
//!
//! ## Live observation
//!
//! [`WorkerPool::run_observed`] attaches a [`RunObserver`] (e.g. the
//! telemetry trace recorder): workers publish their counters to a
//! lock-free [`CounterBoard`](crate::coordinator::CounterBoard) on each
//! budget flush, and a dedicated sampler thread turns those snapshots
//! plus the policy's current max priority into a convergence trace.
//!
//! See DESIGN.md §Execution-Runtime for the inventory and the mapping
//! from paper algorithms to policies.
//!
//! [`Counters`]: crate::coordinator::Counters
//! [`EngineStats`]: crate::engines::EngineStats

pub mod policy;
pub mod pool;

pub use policy::{ExecCtx, RunObserver, TaskPolicy};
pub use pool::{PoolTuning, WorkerPool};
