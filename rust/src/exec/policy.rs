//! The engine-side half of the execution runtime: [`TaskPolicy`], the
//! capability handle [`ExecCtx`] the pool passes into every policy hook,
//! and the [`RunObserver`] contract for live convergence sampling.

use crate::coordinator::{Counters, Termination};
use crate::model::Partition;
use crate::sched::{Entry, Scheduler, TaskStates};
use crate::util::Xoshiro256;
use std::time::Duration;

/// The per-engine half of a queue-driven BP run.
///
/// A policy owns the task universe (messages for the residual family,
/// nodes for splash) and everything priority-related; the
/// [`WorkerPool`](crate::exec::WorkerPool) owns the concurrency. All
/// scheduler interaction goes through [`ExecCtx`].
///
/// Tasks handed to [`TaskPolicy::process`] are claimed: no other worker
/// can process them until the pool releases them after `process` returns.
pub trait TaskPolicy: Sync {
    /// Per-worker scratch space (BFS buffers, message buffers, …),
    /// created once per worker thread and reused across iterations.
    type Scratch;

    /// Number of schedulable tasks; sizes the epoch/claim table and the
    /// exact queue.
    fn num_tasks(&self) -> usize;

    /// Fresh scratch for one worker thread.
    fn make_scratch(&self) -> Self::Scratch;

    /// Populate the scheduler before the workers start (runs once, on the
    /// coordinating thread). Use [`ExecCtx::requeue`] for every task that
    /// should be live initially.
    fn seed(&self, ctx: &mut ExecCtx<'_>);

    /// Process a non-empty batch of claimed tasks: commit updates, adjust
    /// priorities, and requeue activated tasks. Returns the number of
    /// budget work units consumed (committed message updates for message
    /// engines, nodes visited for splash) — the pool flushes these into
    /// the global budget counter.
    fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, scratch: &mut Self::Scratch) -> u64;

    /// The elected verifier's repair sweep, run under quiescence: re-derive
    /// every task's true priority from ground truth and requeue anything
    /// still above threshold (repairing priority lost to the benign message
    /// write races). Return `true` iff the system is converged (nothing was
    /// requeued), which ends the run.
    fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool;

    /// Apply work that arrived from outside the pool (boundary messages
    /// from peer ranks in distributed runs) and requeue whatever it
    /// activated. Called once per worker loop iteration, before the pop
    /// phase, with the worker counted as active (`Termination::enter`
    /// already holds), so entries inserted here are fully covered by the
    /// quiescence accounting. Returns budget work units consumed, exactly
    /// like [`TaskPolicy::process`]. Default: no external work, 0.
    fn drain_ingress(&self, ctx: &mut ExecCtx<'_>, scratch: &mut Self::Scratch) -> u64 {
        let _ = (ctx, scratch);
        0
    }

    /// Final gate after a clean [`TaskPolicy::verify_sweep`]: may the pool
    /// actually end the run? Local policies have no one else to wait for
    /// (default `true`); a distributed policy uses this hook to run its
    /// rank-level termination protocol — reporting passivity, circulating
    /// the token — and only returns `true` once *global* termination is
    /// established. Returning `false` keeps the workers in their idle loop
    /// (new work may still arrive via [`TaskPolicy::drain_ingress`]).
    fn try_finish(&self) -> bool {
        true
    }

    /// Final convergence verdict. The default equates convergence with
    /// "the budget did not expire"; policies with their own completion
    /// criterion (the optimal tree schedule) override it.
    fn converged(&self, timed_out: bool) -> bool {
        !timed_out
    }

    /// Message-arena footprint of the run's shared state as
    /// `(logical_bytes, padded_bytes)` — the live arenas plus any
    /// lookahead cache the policy holds (see
    /// [`Messages::arena_bytes`](crate::bp::Messages::arena_bytes)). The
    /// pool stamps these into every worker's counters at start; they are
    /// gauges, max-merged on aggregation, so thread count never inflates
    /// the reported footprint. Default: unknown `(0, 0)`.
    fn arena_bytes(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Max task priority at exit (≈ max residual), for [`EngineStats`].
    ///
    /// The telemetry sampler also calls this *during* the run (from its own
    /// thread, concurrently with `process`), so implementations must be
    /// data-race-free — in practice they already are, because priorities
    /// derive from the atomic message/residual cells.
    ///
    /// [`EngineStats`]: crate::engines::EngineStats
    fn final_priority(&self) -> f64;
}

/// A live observer of one run, sampled from a dedicated background thread.
///
/// The pool (or a standalone engine loop) calls [`RunObserver::sample`]
/// roughly every [`RunObserver::tick`]: once near the start, periodically
/// during the run, and once more with the final aggregated counters after
/// the workers join — so even sub-tick runs yield at least one sample.
/// Counter snapshots come from the lock-free
/// [`CounterBoard`](crate::coordinator::CounterBoard) and may lag the
/// workers by up to one budget flush.
pub trait RunObserver: Sync {
    /// Target sampling interval. Implementations should expect jitter; the
    /// sampler never fires faster than this but may fire slower.
    fn tick(&self) -> Duration;

    /// Record one observation: elapsed wall-clock seconds since the run
    /// started, the summed counter snapshot, and the policy's current max
    /// task priority (≈ max residual; the convergence signal).
    fn sample(&self, elapsed_secs: f64, totals: &Counters, max_priority: f64);
}

/// Capability handle through which a [`TaskPolicy`] talks to the runtime.
///
/// Wraps the scheduler, the epoch/claim table, the termination counters,
/// the worker's RNG, and the worker's metrics, so the quiescence
/// accounting (`before_insert`) and the lazy-entry protocol (epoch bump on
/// every priority change) cannot be bypassed or forgotten by a policy.
pub struct ExecCtx<'a> {
    sched: &'a dyn Scheduler,
    ts: &'a TaskStates,
    term: &'a Termination,
    rng: &'a mut Xoshiro256,
    /// This worker's event counters; policies increment `updates`,
    /// `useful_updates`, `wasted_pops`, `splashes`, … as they go.
    pub counters: &'a mut Counters,
    insert_threshold: f64,
    /// The run's locality partition; inserts are routed to the task's
    /// shard (see [`crate::sched::Scheduler::insert_hint`]).
    partition: Option<&'a Partition>,
    /// The per-worker insertion buffer behind [`ExecCtx::requeue_batch`]
    /// — owned by the worker loop (like its claim buffer) and lent to
    /// each context, so steady state allocates nothing.
    entry_buf: &'a mut Vec<Entry>,
}

impl<'a> ExecCtx<'a> {
    pub(crate) fn new(
        sched: &'a dyn Scheduler,
        ts: &'a TaskStates,
        term: &'a Termination,
        rng: &'a mut Xoshiro256,
        counters: &'a mut Counters,
        insert_threshold: f64,
        partition: Option<&'a Partition>,
        entry_buf: &'a mut Vec<Entry>,
    ) -> Self {
        ExecCtx { sched, ts, term, rng, counters, insert_threshold, partition, entry_buf }
    }

    /// The task's shard hint under the run's partition (`None` when the
    /// locality axis is off).
    #[inline]
    fn shard_hint(&self, task: u32) -> Option<u32> {
        self.partition.map(|p| p.shard_of(task))
    }

    /// Announce that `task`'s priority changed to `prio`: bump its epoch
    /// (invalidating all outstanding entries) and, if `prio` reaches the
    /// pool's insert threshold, insert a fresh entry. Returns whether an
    /// entry was inserted.
    ///
    /// The unconditional bump is the lazy-entry protocol's invalidation
    /// rule: a priority change makes every previously inserted entry for
    /// the task stale, whether or not the new priority is schedulable.
    pub fn requeue(&mut self, task: u32, prio: f64) -> bool {
        let epoch = self.ts.bump(task);
        if prio >= self.insert_threshold {
            self.term.before_insert();
            let hint = self.shard_hint(task);
            self.sched.insert_hint(Entry { prio, task, epoch }, self.rng, hint);
            self.counters.inserts += 1;
            true
        } else {
            false
        }
    }

    /// Batched [`ExecCtx::requeue`]: announce a priority change for every
    /// `(task, prio)` pair — one unconditional epoch bump each, exactly
    /// like the unbatched protocol — then hand the above-threshold entries
    /// to [`Scheduler::insert_batch`], which the Multiqueue serves with a
    /// single RNG draw + lock acquisition per call. Returns the number of
    /// entries inserted.
    ///
    /// With the locality axis on, the batch is grouped by shard and
    /// inserted one `insert_batch` call per shard group, so every entry
    /// carries its own correct hint (splash and batch-drain callers
    /// routinely mix shards in one batch). Hints stay advisory; quiescence
    /// accounting (`before_insert`) stays per entry.
    pub fn requeue_batch(&mut self, batch: &[(u32, f64)]) -> usize {
        self.entry_buf.clear();
        for &(task, prio) in batch {
            let epoch = self.ts.bump(task);
            if prio >= self.insert_threshold {
                self.entry_buf.push(Entry { prio, task, epoch });
            }
        }
        let n = self.entry_buf.len();
        if n == 0 {
            return 0;
        }
        for _ in 0..n {
            self.term.before_insert();
        }
        self.counters.inserts += n as u64;
        match self.partition {
            None => {
                self.sched.insert_batch(self.entry_buf.as_slice(), self.rng, None);
                self.counters.insert_batches += 1;
            }
            Some(p) => {
                // Group by shard (cheap O(1) table lookups as sort key;
                // batches are out-set sized) and insert each group with
                // its own hint.
                self.entry_buf.sort_unstable_by_key(|en| p.shard_of(en.task));
                let mut start = 0usize;
                while start < n {
                    let s = p.shard_of(self.entry_buf[start].task);
                    let mut end = start + 1;
                    while end < n && p.shard_of(self.entry_buf[end].task) == s {
                        end += 1;
                    }
                    self.sched.insert_batch(&self.entry_buf[start..end], self.rng, Some(s));
                    self.counters.insert_batches += 1;
                    start = end;
                }
            }
        }
        n
    }

    /// Insert a fresh entry for `task` if `prio` reaches the threshold
    /// (bumping the epoch so older entries yield to it); a sub-threshold
    /// priority is a no-op that leaves existing entries valid. Returns
    /// whether an entry was inserted.
    ///
    /// Use this instead of [`ExecCtx::requeue`] when priorities only grow
    /// between executions (accumulated scores): an already-queued entry is
    /// still a valid claim ticket there, and invalidating it on a
    /// sub-threshold change would strand the task until the verifier's
    /// repair sweep.
    pub fn activate(&mut self, task: u32, prio: f64) -> bool {
        if prio >= self.insert_threshold {
            let epoch = self.ts.bump(task);
            self.term.before_insert();
            let hint = self.shard_hint(task);
            self.sched.insert_hint(Entry { prio, task, epoch }, self.rng, hint);
            self.counters.inserts += 1;
            true
        } else {
            false
        }
    }

    /// The pool's activation threshold (engines usually mirror
    /// `RunConfig::epsilon` here).
    pub fn threshold(&self) -> f64 {
        self.insert_threshold
    }

    /// End the run from inside a policy (used by engines with their own
    /// completion criterion, e.g. the optimal tree schedule's useful-update
    /// target). Does not mark the run as timed out.
    pub fn finish(&self) {
        self.term.set_done();
    }
}
