//! Factor storage: node potentials and a deduplicated pool of edge-factor
//! matrices.
//!
//! Node factors `ψ_i : D_i → ℝ⁺` are stored flat with per-node offsets
//! (domains vary: 2 for binary variables, 64 for LDPC constraint nodes).
//!
//! Edge factors `ψ_ij : D_i × D_j → ℝ⁺` are stored once per undirected edge
//! in a shared pool, row-major in the `(src, dst)` orientation of the
//! *even* directed edge `2k`; the odd edge `2k+1` reads the same matrix
//! transposed. Models with repeated structure (LDPC's six bit-position
//! indicators, the tree's equality factor) register a matrix once and share
//! it across millions of edges.

/// Reference to an edge-factor matrix: pool offset plus a transpose flag
/// packed into one u32 (high bit = transposed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FactorRef(pub u32);

const TRANSPOSE_BIT: u32 = 1 << 31;

impl FactorRef {
    /// Reference to pool entry `pool_index`, optionally transposed.
    pub fn new(pool_index: u32, transposed: bool) -> Self {
        debug_assert!(pool_index < TRANSPOSE_BIT);
        FactorRef(pool_index | if transposed { TRANSPOSE_BIT } else { 0 })
    }

    #[inline]
    /// Index into the factor pool.
    pub fn pool_index(self) -> usize {
        (self.0 & !TRANSPOSE_BIT) as usize
    }

    #[inline]
    /// True when the factor matrix is applied transposed.
    pub fn transposed(self) -> bool {
        self.0 & TRANSPOSE_BIT != 0
    }
}

use crate::model::ModelStorage;

/// Deduplicated pool of edge-factor matrices.
#[derive(Debug, Clone, Default)]
pub struct FactorPool {
    /// Matrix data, concatenated row-major (heap-owned, or borrowed from
    /// a mapped snapshot).
    data: ModelStorage<f64>,
    /// Per-matrix (offset, rows, cols).
    entries: Vec<(u32, u16, u16)>,
}

impl FactorPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a `rows × cols` row-major matrix; returns its pool index.
    pub fn add(&mut self, rows: usize, cols: usize, values: &[f64]) -> u32 {
        assert_eq!(values.len(), rows * cols, "factor matrix shape mismatch");
        assert!(values.iter().all(|v| *v >= 0.0 && v.is_finite()), "factors must be finite ≥ 0");
        let off = self.data.len() as u32;
        self.data.to_mut().extend_from_slice(values);
        let idx = self.entries.len() as u32;
        self.entries.push((off, rows as u16, cols as u16));
        idx
    }

    /// Number of distinct factor matrices.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the pool holds no factors.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Matrix shape `(rows, cols)` in storage orientation.
    pub fn shape(&self, index: usize) -> (usize, usize) {
        let (_, r, c) = self.entries[index];
        (r as usize, c as usize)
    }

    /// Raw matrix slice in storage orientation.
    #[inline]
    pub fn matrix(&self, index: usize) -> &[f64] {
        let (off, r, c) = self.entries[index];
        &self.data[off as usize..off as usize + r as usize * c as usize]
    }

    /// Element access through a [`FactorRef`]: `get(fr, a, b)` returns
    /// `ψ(x_src = a, x_dst = b)` for the directed edge holding `fr`.
    #[inline]
    pub fn get(&self, fr: FactorRef, a: usize, b: usize) -> f64 {
        let (off, r, c) = self.entries[fr.pool_index()];
        let (off, r, c) = (off as usize, r as usize, c as usize);
        if fr.transposed() {
            debug_assert!(b < r && a < c);
            self.data[off + b * c + a]
        } else {
            debug_assert!(a < r && b < c);
            self.data[off + a * c + b]
        }
    }

    /// Shape as seen through the reference: `(|D_src|, |D_dst|)`.
    pub fn shape_of(&self, fr: FactorRef) -> (usize, usize) {
        let (r, c) = self.shape(fr.pool_index());
        if fr.transposed() {
            (c, r)
        } else {
            (r, c)
        }
    }

    /// Total f64s stored (for memory accounting).
    pub fn data_len(&self) -> usize {
        self.data.len()
    }

    /// Raw concatenated matrix data (serialization support).
    pub fn data_raw(&self) -> &[f64] {
        &self.data
    }

    /// Raw per-matrix `(offset, rows, cols)` entries (serialization
    /// support).
    pub fn entries_raw(&self) -> &[(u32, u16, u16)] {
        &self.entries
    }

    /// Reassemble a pool from raw parts (the bulk-load path), validating
    /// every invariant [`FactorPool::add`] enforces incrementally. Errors
    /// instead of panicking — the parts may come from an untrusted file.
    pub fn from_raw(data: Vec<f64>, entries: Vec<(u32, u16, u16)>) -> Result<Self, String> {
        Self::from_storage(data.into(), entries, true)
    }

    /// [`FactorPool::from_raw`] over any [`ModelStorage`] backing (the
    /// zero-copy map path passes a borrowed section). Shape/offset
    /// invariants are always checked (they only touch `entries`);
    /// `verify_values` gates the finite-≥0 scan of the data, which pages
    /// in the whole section on a mapped load — unverified maps
    /// (`--load-mode map` without `--verify-load`) skip it, matching the
    /// checksum policy.
    pub fn from_storage(
        data: ModelStorage<f64>,
        entries: Vec<(u32, u16, u16)>,
        verify_values: bool,
    ) -> Result<Self, String> {
        let mut expect = 0usize;
        for (i, &(off, r, c)) in entries.iter().enumerate() {
            if off as usize != expect {
                return Err(format!("factor pool entry {i}: offset {off}, expected {expect}"));
            }
            if r == 0 || c == 0 {
                return Err(format!("factor pool entry {i}: degenerate shape {r}x{c}"));
            }
            expect += r as usize * c as usize;
        }
        if expect != data.len() {
            return Err(format!(
                "factor pool data length {} does not match entries (expected {expect})",
                data.len()
            ));
        }
        if verify_values && !data.iter().all(|v| *v >= 0.0 && v.is_finite()) {
            return Err("factor pool contains non-finite or negative values".into());
        }
        Ok(Self { data, entries })
    }
}

/// Flat node-factor table with per-node offsets.
#[derive(Debug, Clone, Default)]
pub struct NodeFactors {
    offsets: ModelStorage<u32>,
    data: ModelStorage<f64>,
}

impl NodeFactors {
    /// Build from per-node factor vectors; `domains[i]` must equal
    /// `factors[i].len()`.
    pub fn from_vecs(factors: &[Vec<f64>]) -> Self {
        let mut offsets = Vec::with_capacity(factors.len() + 1);
        let mut data = Vec::new();
        offsets.push(0u32);
        for f in factors {
            assert!(!f.is_empty(), "empty node factor");
            assert!(f.iter().all(|v| *v >= 0.0 && v.is_finite()));
            data.extend_from_slice(f);
            offsets.push(data.len() as u32);
        }
        Self { offsets: offsets.into(), data: data.into() }
    }

    /// Number of nodes with assigned potentials.
    pub fn num_nodes(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    /// `ψ_i` as a slice of length `|D_i|`.
    #[inline]
    pub fn of(&self, i: usize) -> &[f64] {
        &self.data[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Domain size of node `i`.
    pub fn domain(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// Overwrite `ψ_i` in place. `vals` must match the node's domain (the
    /// flat offsets stay valid — evidence deltas change values, never
    /// shapes) and obey the same finite-≥0 invariant as construction.
    pub fn set(&mut self, i: usize, vals: &[f64]) {
        assert_eq!(vals.len(), self.domain(i), "node {i}: prior length must match the domain");
        assert!(vals.iter().all(|v| *v >= 0.0 && v.is_finite()), "priors must be finite ≥ 0");
        let off = self.offsets[i] as usize;
        // Copy-on-write: a mapped table is copied to the heap on the
        // first evidence write (mapped snapshots are read-only).
        self.data.to_mut()[off..off + vals.len()].copy_from_slice(vals);
    }

    /// Raw per-node offsets, length `num_nodes() + 1` (serialization
    /// support).
    pub fn offsets_raw(&self) -> &[u32] {
        &self.offsets
    }

    /// Raw flat factor data (serialization support).
    pub fn data_raw(&self) -> &[f64] {
        &self.data
    }

    /// Reassemble node factors from raw parts (the bulk-load path),
    /// validating the invariants [`NodeFactors::from_vecs`] enforces.
    /// Errors instead of panicking — the parts may come from an untrusted
    /// file.
    pub fn from_raw(offsets: Vec<u32>, data: Vec<f64>) -> Result<Self, String> {
        Self::from_storage(offsets.into(), data.into(), true)
    }

    /// [`NodeFactors::from_raw`] over any [`ModelStorage`] backing (the
    /// zero-copy map path passes borrowed sections). `verify_values`
    /// gates the two full-table scans (offset monotonicity and the
    /// finite-≥0 value check), which page in both sections on a mapped
    /// load; the cheap structural checks (first/last offset vs data
    /// length) always run.
    pub fn from_storage(
        offsets: ModelStorage<u32>,
        data: ModelStorage<f64>,
        verify_values: bool,
    ) -> Result<Self, String> {
        if offsets.first() != Some(&0) {
            return Err("node factor offsets must start at 0".into());
        }
        if verify_values {
            for (i, w) in offsets.windows(2).enumerate() {
                if w[1] <= w[0] {
                    return Err(format!("node {i}: empty or non-monotone factor row"));
                }
            }
        }
        if offsets.last().copied().unwrap_or(0) as usize != data.len() {
            return Err(format!(
                "node factor data length {} does not match final offset",
                data.len()
            ));
        }
        if verify_values && !data.iter().all(|v| *v >= 0.0 && v.is_finite()) {
            return Err("node factors contain non-finite or negative values".into());
        }
        Ok(Self { offsets, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factor_ref_packing() {
        let fr = FactorRef::new(12345, true);
        assert_eq!(fr.pool_index(), 12345);
        assert!(fr.transposed());
        let fr = FactorRef::new(0, false);
        assert_eq!(fr.pool_index(), 0);
        assert!(!fr.transposed());
    }

    #[test]
    fn pool_get_and_transpose() {
        let mut p = FactorPool::new();
        // 2x3 matrix [[1,2,3],[4,5,6]]
        let idx = p.add(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let fwd = FactorRef::new(idx, false);
        let rev = FactorRef::new(idx, true);
        assert_eq!(p.get(fwd, 0, 2), 3.0);
        assert_eq!(p.get(fwd, 1, 0), 4.0);
        // transposed: get(rev, a, b) = M[b][a]
        assert_eq!(p.get(rev, 2, 0), 3.0);
        assert_eq!(p.get(rev, 0, 1), 4.0);
        assert_eq!(p.shape_of(fwd), (2, 3));
        assert_eq!(p.shape_of(rev), (3, 2));
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn pool_rejects_bad_shape() {
        FactorPool::new().add(2, 2, &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn pool_rejects_negative() {
        FactorPool::new().add(1, 2, &[1.0, -0.5]);
    }

    #[test]
    fn node_factors_variable_width() {
        let nf = NodeFactors::from_vecs(&[vec![0.1, 0.9], vec![1.0; 64], vec![0.5, 0.5]]);
        assert_eq!(nf.num_nodes(), 3);
        assert_eq!(nf.domain(0), 2);
        assert_eq!(nf.domain(1), 64);
        assert_eq!(nf.of(0), &[0.1, 0.9]);
        assert_eq!(nf.of(2), &[0.5, 0.5]);
    }

    #[test]
    fn node_factors_set_overwrites_in_place() {
        let mut nf = NodeFactors::from_vecs(&[vec![0.1, 0.9], vec![1.0; 64], vec![0.5, 0.5]]);
        nf.set(2, &[0.3, 0.7]);
        assert_eq!(nf.of(2), &[0.3, 0.7]);
        assert_eq!(nf.of(0), &[0.1, 0.9], "neighboring rows untouched");
        assert_eq!(nf.of(1), &[1.0; 64]);
    }

    #[test]
    #[should_panic(expected = "length must match")]
    fn node_factors_set_rejects_domain_change() {
        let mut nf = NodeFactors::from_vecs(&[vec![0.5, 0.5]]);
        nf.set(0, &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn node_factors_set_rejects_negative() {
        let mut nf = NodeFactors::from_vecs(&[vec![0.5, 0.5]]);
        nf.set(0, &[0.5, -0.5]);
    }

    #[test]
    fn pool_raw_roundtrip() {
        let mut p = FactorPool::new();
        p.add(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        p.add(1, 3, &[0.25, 0.5, 0.25]);
        let back =
            FactorPool::from_raw(p.data_raw().to_vec(), p.entries_raw().to_vec()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.matrix(1), p.matrix(1));
        assert!(FactorPool::from_raw(vec![1.0], vec![(0, 2, 2)]).is_err(), "length mismatch");
        assert!(FactorPool::from_raw(vec![-1.0], vec![(0, 1, 1)]).is_err(), "negative value");
        assert!(FactorPool::from_raw(vec![], vec![(0, 0, 4)]).is_err(), "degenerate shape");
        assert!(
            FactorPool::from_raw(vec![1.0, 1.0], vec![(1, 1, 1)]).is_err(),
            "bad first offset"
        );
    }

    #[test]
    fn node_factors_raw_roundtrip() {
        let nf = NodeFactors::from_vecs(&[vec![0.1, 0.9], vec![1.0; 5]]);
        let back =
            NodeFactors::from_raw(nf.offsets_raw().to_vec(), nf.data_raw().to_vec()).unwrap();
        assert_eq!(back.num_nodes(), 2);
        assert_eq!(back.of(1), nf.of(1));
        assert!(NodeFactors::from_raw(vec![0, 0], vec![]).is_err(), "empty row");
        assert!(NodeFactors::from_raw(vec![1, 2], vec![0.5]).is_err(), "nonzero start");
        assert!(NodeFactors::from_raw(vec![0, 1], vec![0.5, 0.5]).is_err(), "length mismatch");
        assert!(NodeFactors::from_raw(vec![0, 1], vec![f64::NAN]).is_err(), "non-finite");
    }

    #[test]
    fn pool_multiple_matrices() {
        let mut p = FactorPool::new();
        let a = p.add(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let b = p.add(2, 2, &[2.0, 3.0, 4.0, 5.0]);
        assert_ne!(a, b);
        assert_eq!(p.len(), 2);
        assert_eq!(p.matrix(b as usize), &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(p.get(FactorRef::new(a, false), 1, 1), 1.0);
    }
}
