//! Binary MRF serialization.
//!
//! Lets `relaxed-bp generate` write an instance once and have every
//! algorithm/thread-count sweep load the identical model (important for the
//! paper's tables, where all algorithms must see the same random couplings).
//!
//! Two on-disk formats share the `RBPM` magic:
//!
//! * **v1** (frozen compat arm): a streamed scalar-at-a-time layout —
//!   magic, version, name, domains, node factors, undirected edge list
//!   with pool indices, factor pool. Simple and portable, but it re-runs
//!   graph construction on load and moves one scalar per `Read` call, so
//!   it is kept only so old files stay readable.
//! * **v2** (default): a flat *section* layout sized for 100M-edge
//!   models. A 64-byte header (counts) is followed by a 15-entry section
//!   table (offset, byte length, checksum per section) and then the
//!   sections themselves, each 64-byte-aligned: the CSR arrays, domains,
//!   node factors, factor pool, and message offsets — exactly the vectors
//!   an [`Mrf`] holds in memory. Saving is one bulk `write_all` per
//!   section; loading is `read_exact_at` of 4 MiB chunks fanned out over
//!   worker threads straight into the destination vectors, so a load is
//!   a handful of large reads instead of hundreds of millions of tiny
//!   ones, and no graph rebuild happens at all.
//!
//! Integrity: each section carries a checksum computed per 1 MiB block
//! and combined with a commutative `wrapping_add`, so parallel loaders
//! verify blocks in whatever order their chunks arrive and still compare
//! against the same value the (serial or parallel) writer produced. All
//! length fields are validated against the header counts *and* the real
//! file size before any allocation — a hostile length field produces a
//! clean error, never an OOM-sized `Vec` or an out-of-bounds read.
//!
//! v2 files are little-endian (the byte-cast bulk path writes native
//! words); big-endian hosts get a clean refusal rather than silent
//! garbage.

use super::{Csr, FactorPool, FactorRef, GraphBuilder, ModelStorage, Mrf, NodeFactors, MAX_DOMAIN};
use crate::coordinator::run_workers;
use crate::util::cold_path_threads;
use crate::util::mmap::Mmap;
use anyhow::{anyhow, bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::os::unix::fs::FileExt;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"RBPM";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;

/// Section checksum block granularity. Checksums combine across blocks
/// with `wrapping_add`, so any partition of a section into block-aligned
/// chunks verifies to the same value.
const BLOCK: usize = 1 << 20;
/// Parallel-read chunk size (a multiple of [`BLOCK`], so no checksum
/// block ever straddles two chunks).
const CHUNK: usize = 4 << 20;
/// Section payload alignment.
const ALIGN: u64 = 64;
/// Hard ceiling on any count field read from a file; combined with the
/// offset+length ≤ file-size check this bounds every allocation by the
/// actual file size.
const MAX_COUNT: u64 = 1 << 33;
/// Model names are human-readable labels; anything larger is corruption.
const MAX_NAME: u64 = 1 << 16;

const SECTION_COUNT: usize = 15;
const SECTION_NAMES: [&str; SECTION_COUNT] = [
    "name",
    "domain",
    "csr_offsets",
    "adj_node",
    "adj_out",
    "adj_in",
    "edge_src",
    "edge_dst",
    "nf_offsets",
    "nf_data",
    "edge_pool_index",
    "pool_offsets",
    "pool_shapes",
    "pool_data",
    "msg_offset",
];

const HEADER_BYTES: u64 = 64;
const TABLE_BYTES: u64 = (SECTION_COUNT * 24) as u64;
/// First section offset: header + table rounded up to [`ALIGN`].
const FIRST_SECTION: u64 = (HEADER_BYTES + TABLE_BYTES).div_ceil(ALIGN) * ALIGN;

fn align64(x: u64) -> u64 {
    x.div_ceil(ALIGN) * ALIGN
}

/// Marker for types whose in-memory bytes are their on-disk bytes (any
/// bit pattern is a valid value, no padding, little-endian host).
trait Pod: Copy + Send + Sync {}
impl Pod for u32 {}
impl Pod for f64 {}

fn bytes_of<T: Pod>(v: &[T]) -> &[u8] {
    // SAFETY: `Pod` types have no padding and no invalid bit patterns;
    // the returned slice covers exactly the elements of `v`.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast(), std::mem::size_of_val(v)) }
}

fn bytes_of_mut<T: Pod>(v: &mut [T]) -> &mut [u8] {
    // SAFETY: as in `bytes_of`; additionally any byte pattern written
    // through this view leaves `v`'s elements valid (Pod contract).
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr().cast(), std::mem::size_of_val(v)) }
}

/// FNV-style hash of one checksum block, seeded by the block's index so
/// swapped blocks are detected despite the commutative combine.
fn block_hash(block_index: u64, bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ block_index.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut words = bytes.chunks_exact(8);
    for w in words.by_ref() {
        h = (h ^ u64::from_le_bytes(w.try_into().unwrap())).wrapping_mul(0x100_0000_01b3);
    }
    let rem = words.remainder();
    if !rem.is_empty() {
        let mut w = [0u8; 8];
        w[..rem.len()].copy_from_slice(rem);
        h = (h ^ u64::from_le_bytes(w)).wrapping_mul(0x100_0000_01b3);
    }
    h ^ bytes.len() as u64
}

/// Whole-section checksum: `wrapping_add` of per-block hashes. Runs
/// blocks on the cold-path thread pool when the section is large.
fn checksum_bytes(bytes: &[u8]) -> u64 {
    let nblocks = bytes.len().div_ceil(BLOCK);
    let threads = cold_path_threads(bytes.len() / 64).min(nblocks.max(1));
    let hash_range = |lo: usize, hi: usize| {
        let mut s = 0u64;
        for b in lo..hi {
            let end = ((b + 1) * BLOCK).min(bytes.len());
            s = s.wrapping_add(block_hash(b as u64, &bytes[b * BLOCK..end]));
        }
        s
    };
    if threads <= 1 {
        return hash_range(0, nblocks);
    }
    run_workers(threads, |t| hash_range(t * nblocks / threads, (t + 1) * nblocks / threads))
        .into_iter()
        .fold(0u64, u64::wrapping_add)
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

/// Run a range-check over `items` on `threads` workers; the first failure
/// (in range order) becomes an error.
fn par_check(
    threads: usize,
    items: usize,
    check: impl Fn(usize, usize) -> Result<(), String> + Sync,
) -> Result<()> {
    if items == 0 {
        return Ok(());
    }
    let threads = threads.clamp(1, items);
    let errs = run_workers(threads, |t| check(t * items / threads, (t + 1) * items / threads).err());
    if let Some(e) = errs.into_iter().flatten().next() {
        bail!("corrupt model: {e}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// v1: streamed scalar codec (frozen compat arm)
// ---------------------------------------------------------------------------

struct Writer<W: Write>(W);

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes()).map_err(Into::into)
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes()).map_err(Into::into)
    }
    fn f64(&mut self, v: f64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes()).map_err(Into::into)
    }
    fn bytes(&mut self, b: &[u8]) -> Result<()> {
        self.u64(b.len() as u64)?;
        self.0.write_all(b).map_err(Into::into)
    }
    fn f64s(&mut self, xs: &[f64]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.f64(x)?;
        }
        Ok(())
    }
    fn u32s(&mut self, xs: &[u32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.u32(x)?;
        }
        Ok(())
    }
}

struct Reader<R: Read>(R);

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.0.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        if n > 1 << 34 {
            bail!("corrupt file: oversized field");
        }
        let mut b = vec![0u8; n];
        self.0.read_exact(&mut b)?;
        Ok(b)
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        if n > 1 << 31 {
            bail!("corrupt file: oversized array");
        }
        (0..n).map(|_| self.f64()).collect()
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u64()? as usize;
        if n > 1 << 31 {
            bail!("corrupt file: oversized array");
        }
        (0..n).map(|_| self.u32()).collect()
    }
}

/// Serialize an MRF to a writer in the legacy v1 stream format.
pub fn write_mrf<W: Write>(mrf: &Mrf, w: W) -> Result<()> {
    let mut w = Writer(BufWriter::new(w));
    w.0.write_all(MAGIC)?;
    w.u32(VERSION_V1)?;
    w.bytes(mrf.name.as_bytes())?;

    let n = mrf.num_nodes();
    w.u64(n as u64)?;
    w.u32s(&mrf.domain)?;

    // Node factors, flat.
    for i in 0..n {
        w.f64s(mrf.node_factors.of(i))?;
    }

    // Undirected edges: (src, dst, pool index) from the even directed edges.
    let m = mrf.num_messages() / 2;
    w.u64(m as u64)?;
    for k in 0..m {
        let e = 2 * k;
        w.u32(mrf.graph.edge_src[e])?;
        w.u32(mrf.graph.edge_dst[e])?;
        w.u32(mrf.edge_factor[e].pool_index() as u32)?;
    }

    // Pool.
    w.u64(mrf.pool.len() as u64)?;
    for idx in 0..mrf.pool.len() {
        let (r, c) = mrf.pool.shape(idx);
        w.u32(r as u32)?;
        w.u32(c as u32)?;
        w.f64s(mrf.pool.matrix(idx))?;
    }
    w.0.flush()?;
    Ok(())
}

/// Deserialize an MRF from a v1 stream (magic + version included).
pub fn read_mrf<R: Read>(r: R) -> Result<Mrf> {
    let mut r = Reader(BufReader::new(r));
    let mut magic = [0u8; 4];
    r.0.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an RBPM file");
    }
    let version = r.u32()?;
    if version != VERSION_V1 {
        bail!("unsupported RBPM version {version} in v1 stream reader");
    }
    let name = String::from_utf8(r.bytes()?).context("bad name")?;

    let n = r.u64()? as usize;
    let domain = r.u32s()?;
    if domain.len() != n {
        bail!("domain length mismatch");
    }

    let mut factors = Vec::with_capacity(n);
    for i in 0..n {
        let f = r.f64s()?;
        if f.len() != domain[i] as usize {
            bail!("node factor width mismatch at {i}");
        }
        factors.push(f);
    }

    let m = r.u64()? as usize;
    let mut gb = GraphBuilder::new(n);
    let mut edge_pool_index = Vec::with_capacity(m);
    for _ in 0..m {
        let a = r.u32()?;
        let b = r.u32()?;
        let p = r.u32()?;
        if a as usize >= n || b as usize >= n {
            bail!("edge endpoint out of range");
        }
        if a == b {
            bail!("corrupt model: self-loop at node {a}");
        }
        gb.add_edge(a as usize, b as usize);
        edge_pool_index.push(p);
    }

    let pool_len = r.u64()? as usize;
    let mut pool = FactorPool::new();
    for _ in 0..pool_len {
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let data = r.f64s()?;
        if data.len() != rows * cols {
            bail!("pool matrix shape mismatch");
        }
        pool.add(rows, cols, &data);
    }

    Ok(Mrf::assemble(
        &name,
        gb.build(),
        domain,
        NodeFactors::from_vecs(&factors),
        edge_pool_index,
        pool,
    ))
}

// ---------------------------------------------------------------------------
// v2: sectioned bulk format
// ---------------------------------------------------------------------------

/// Serialize an MRF to a writer in the sectioned v2 format; returns the
/// total bytes written.
pub fn write_mrf_v2<W: Write>(mrf: &Mrf, mut w: W) -> Result<u64> {
    #[cfg(target_endian = "big")]
    bail!("RBPM v2 files are little-endian only");

    let n = mrf.num_nodes() as u64;
    let m = (mrf.num_messages() / 2) as u64;
    let epi: Vec<u32> =
        (0..m as usize).map(|k| mrf.edge_factor[2 * k].pool_index() as u32).collect();
    let entries = mrf.pool.entries_raw();
    let pool_offsets: Vec<u32> = entries.iter().map(|&(o, _, _)| o).collect();
    let pool_shapes: Vec<u32> =
        entries.iter().map(|&(_, r, c)| ((r as u32) << 16) | c as u32).collect();

    let sections: [&[u8]; SECTION_COUNT] = [
        mrf.name.as_bytes(),
        bytes_of(&mrf.domain[..]),
        bytes_of(&mrf.graph.offsets[..]),
        bytes_of(&mrf.graph.adj_node[..]),
        bytes_of(&mrf.graph.adj_out[..]),
        bytes_of(&mrf.graph.adj_in[..]),
        bytes_of(&mrf.graph.edge_src[..]),
        bytes_of(&mrf.graph.edge_dst[..]),
        bytes_of(mrf.node_factors.offsets_raw()),
        bytes_of(mrf.node_factors.data_raw()),
        bytes_of(&epi),
        bytes_of(&pool_offsets),
        bytes_of(&pool_shapes),
        bytes_of(mrf.pool.data_raw()),
        bytes_of(&mrf.msg_offset[..]),
    ];

    // Section table: aligned offsets, exact byte lengths, block checksums.
    let mut table = [(0u64, 0u64, 0u64); SECTION_COUNT];
    let mut pos = FIRST_SECTION;
    for (i, s) in sections.iter().enumerate() {
        let off = align64(pos);
        table[i] = (off, s.len() as u64, checksum_bytes(s));
        pos = off + s.len() as u64;
    }
    let total = pos;

    // The zero-copy map loader casts sections in place, so 64-byte file
    // offsets are a format invariant, not a nicety — refuse to emit a
    // file that would silently lose the mmap fast path.
    for (i, &(off, _, _)) in table.iter().enumerate() {
        if off % ALIGN != 0 {
            bail!("internal error: section {} offset {off} unaligned at save", SECTION_NAMES[i]);
        }
    }

    let mut cur = 0u64;
    let put = |w: &mut W, b: &[u8], cur: &mut u64| -> Result<()> {
        w.write_all(b)?;
        *cur += b.len() as u64;
        Ok(())
    };
    let pad_to = |w: &mut W, target: u64, cur: &mut u64| -> Result<()> {
        debug_assert!(target >= *cur);
        let zeros = [0u8; 64];
        let mut gap = (target - *cur) as usize;
        while gap > 0 {
            let k = gap.min(zeros.len());
            w.write_all(&zeros[..k])?;
            gap -= k;
        }
        *cur = target;
        Ok(())
    };

    put(&mut w, MAGIC, &mut cur)?;
    put(&mut w, &VERSION_V2.to_le_bytes(), &mut cur)?;
    for v in [
        n,
        m,
        mrf.pool.len() as u64,
        mrf.node_factors.data_raw().len() as u64,
        mrf.pool.data_len() as u64,
        mrf.total_msg_len as u64,
    ] {
        put(&mut w, &v.to_le_bytes(), &mut cur)?;
    }
    put(&mut w, &[0u8; 8], &mut cur)?; // reserved
    debug_assert_eq!(cur, HEADER_BYTES);
    for &(off, len, sum) in &table {
        put(&mut w, &off.to_le_bytes(), &mut cur)?;
        put(&mut w, &len.to_le_bytes(), &mut cur)?;
        put(&mut w, &sum.to_le_bytes(), &mut cur)?;
    }
    for (i, s) in sections.iter().enumerate() {
        pad_to(&mut w, table[i].0, &mut cur)?;
        put(&mut w, s, &mut cur)?; // one bulk write per section
    }
    debug_assert_eq!(cur, total);
    w.flush()?;
    Ok(total)
}

/// One parallel-read work item: a block-aligned chunk of a section.
struct ChunkTask<'a> {
    sect: usize,
    file_off: u64,
    first_block: u64,
    buf: &'a mut [u8],
}

/// Fill the destination buffers from `f` with `threads` workers and
/// return the per-section checksums of what was read.
fn read_sections(
    f: &File,
    dests: Vec<(usize, u64, &mut [u8])>,
    threads: usize,
) -> Result<[u64; SECTION_COUNT]> {
    let mut tasks: Vec<ChunkTask> = Vec::new();
    for (sect, off, buf) in dests {
        let mut pos = 0usize;
        for piece in buf.chunks_mut(CHUNK) {
            let len = piece.len();
            tasks.push(ChunkTask {
                sect,
                file_off: off + pos as u64,
                first_block: (pos / BLOCK) as u64,
                buf: piece,
            });
            pos += len;
        }
    }

    let run_tasks = |tasks: Vec<ChunkTask>| -> Result<[u64; SECTION_COUNT], String> {
        let mut sums = [0u64; SECTION_COUNT];
        for t in tasks {
            f.read_exact_at(t.buf, t.file_off)
                .map_err(|e| format!("reading section {}: {e}", SECTION_NAMES[t.sect]))?;
            for (b, blk) in t.buf.chunks(BLOCK).enumerate() {
                sums[t.sect] = sums[t.sect].wrapping_add(block_hash(t.first_block + b as u64, blk));
            }
        }
        Ok(sums)
    };

    let partials: Vec<Result<[u64; SECTION_COUNT], String>> = if threads <= 1 {
        vec![run_tasks(tasks)]
    } else {
        let mut per_thread: Vec<Vec<ChunkTask>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, t) in tasks.into_iter().enumerate() {
            per_thread[i % threads].push(t);
        }
        std::thread::scope(|s| {
            let handles: Vec<_> = per_thread
                .into_iter()
                .map(|list| s.spawn(|| run_tasks(list)))
                .collect();
            handles.into_iter().map(|h| h.join().expect("io worker panicked")).collect()
        })
    };

    let mut sums = [0u64; SECTION_COUNT];
    for p in partials {
        let part = p.map_err(|e| anyhow::anyhow!(e))?;
        for (a, b) in sums.iter_mut().zip(part) {
            *a = a.wrapping_add(b);
        }
    }
    Ok(sums)
}

/// Parsed-and-validated v2 header counts plus the section table. Every
/// (offset, length) has been proven inside the real file size and
/// consistent with the header counts before this exists — both readers
/// (positioned bulk reads and zero-copy map) build on it.
struct V2Layout {
    n: u64,
    m: u64,
    pool_len: u64,
    nf_len: u64,
    pool_data_len: u64,
    total_msg_len: u64,
    table: [(u64, u64, u64); SECTION_COUNT],
}

/// Validate a v2 header + section table against the actual `file_len`.
/// `head` must hold [`HEADER_BYTES`] bytes and `table_bytes`
/// [`TABLE_BYTES`] bytes.
fn parse_v2_layout(head: &[u8], table_bytes: &[u8], file_len: u64) -> Result<V2Layout> {
    if &head[0..4] != MAGIC {
        bail!("not an RBPM file");
    }
    let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
    if version != VERSION_V2 {
        bail!("unsupported RBPM version {version}");
    }
    let n = u64_at(head, 8);
    let m = u64_at(head, 16);
    let pool_len = u64_at(head, 24);
    let nf_len = u64_at(head, 32);
    let pool_data_len = u64_at(head, 40);
    let total_msg_len = u64_at(head, 48);
    for (what, v) in [
        ("node count", n),
        ("edge count", m),
        ("pool entry count", pool_len),
        ("node factor length", nf_len),
        ("pool data length", pool_data_len),
        ("message array length", total_msg_len),
    ] {
        if v > MAX_COUNT {
            bail!("corrupt file: oversized {what} ({v})");
        }
    }
    if n > u32::MAX as u64 || 2 * m > u32::MAX as u64 || total_msg_len > u32::MAX as u64 {
        bail!("corrupt file: counts exceed u32 indexing");
    }
    if pool_data_len > u32::MAX as u64 {
        bail!("corrupt file: pool data exceeds u32 offsets");
    }

    let mut table = [(0u64, 0u64, 0u64); SECTION_COUNT];
    for (i, t) in table.iter_mut().enumerate() {
        let b = 24 * i;
        *t = (u64_at(table_bytes, b), u64_at(table_bytes, b + 8), u64_at(table_bytes, b + 16));
    }

    // Expected byte length per section, from the header counts (the name's
    // length is only bounded, not derived).
    let me = 2 * m; // directed edges
    let expected: [Option<u64>; SECTION_COUNT] = [
        None,
        Some(4 * n),
        Some(4 * (n + 1)),
        Some(4 * me),
        Some(4 * me),
        Some(4 * me),
        Some(4 * me),
        Some(4 * me),
        Some(4 * (n + 1)),
        Some(8 * nf_len),
        Some(4 * m),
        Some(4 * pool_len),
        Some(4 * pool_len),
        Some(8 * pool_data_len),
        Some(4 * me),
    ];
    for (i, &(off, len, _)) in table.iter().enumerate() {
        let name = SECTION_NAMES[i];
        match expected[i] {
            Some(want) if len != want => {
                bail!("section {name} length mismatch: header implies {want} bytes, table says {len}")
            }
            None if len > MAX_NAME => bail!("section {name} oversized ({len} bytes)"),
            _ => {}
        }
        // `len ≤ file_len` first, so `file_len - len` cannot underflow.
        if off < FIRST_SECTION || len > file_len || off > file_len - len {
            bail!("section {name} out of bounds (offset {off}, length {len}, file {file_len})");
        }
    }
    Ok(V2Layout { n, m, pool_len, nf_len, pool_data_len, total_msg_len, table })
}

/// Deserialize a v2 file via positioned bulk reads on `threads` workers,
/// validating section bounds and checksums before trusting any content.
fn read_mrf_v2(f: &File, file_len: u64, threads: usize) -> Result<Mrf> {
    #[cfg(target_endian = "big")]
    bail!("RBPM v2 files are little-endian only");

    let mut head = [0u8; HEADER_BYTES as usize];
    f.read_exact_at(&mut head, 0).context("reading v2 header")?;
    let mut table_bytes = [0u8; TABLE_BYTES as usize];
    f.read_exact_at(&mut table_bytes, HEADER_BYTES).context("reading v2 section table")?;
    let V2Layout { n, m, pool_len, nf_len, pool_data_len, total_msg_len, table } =
        parse_v2_layout(&head, &table_bytes, file_len)?;

    // Allocate destinations (every size is now proven ≤ the file size)
    // and pull the sections in parallel chunks.
    let me = 2 * m;
    let (n, m, me) = (n as usize, m as usize, me as usize);
    let mut name_bytes = vec![0u8; table[0].1 as usize];
    let mut domain = vec![0u32; n];
    let mut offsets = vec![0u32; n + 1];
    let mut adj_node = vec![0u32; me];
    let mut adj_out = vec![0u32; me];
    let mut adj_in = vec![0u32; me];
    let mut edge_src = vec![0u32; me];
    let mut edge_dst = vec![0u32; me];
    let mut nf_offsets = vec![0u32; n + 1];
    let mut nf_data = vec![0f64; nf_len as usize];
    let mut epi = vec![0u32; m];
    let mut pool_offsets = vec![0u32; pool_len as usize];
    let mut pool_shapes = vec![0u32; pool_len as usize];
    let mut pool_data = vec![0f64; pool_data_len as usize];
    let mut msg_offset = vec![0u32; me];

    let dests: Vec<(usize, u64, &mut [u8])> = vec![
        (0, table[0].0, &mut name_bytes[..]),
        (1, table[1].0, bytes_of_mut(&mut domain)),
        (2, table[2].0, bytes_of_mut(&mut offsets)),
        (3, table[3].0, bytes_of_mut(&mut adj_node)),
        (4, table[4].0, bytes_of_mut(&mut adj_out)),
        (5, table[5].0, bytes_of_mut(&mut adj_in)),
        (6, table[6].0, bytes_of_mut(&mut edge_src)),
        (7, table[7].0, bytes_of_mut(&mut edge_dst)),
        (8, table[8].0, bytes_of_mut(&mut nf_offsets)),
        (9, table[9].0, bytes_of_mut(&mut nf_data)),
        (10, table[10].0, bytes_of_mut(&mut epi)),
        (11, table[11].0, bytes_of_mut(&mut pool_offsets)),
        (12, table[12].0, bytes_of_mut(&mut pool_shapes)),
        (13, table[13].0, bytes_of_mut(&mut pool_data)),
        (14, table[14].0, bytes_of_mut(&mut msg_offset)),
    ];
    let sums = read_sections(f, dests, threads)?;
    for (i, (&got, &(_, _, want))) in sums.iter().zip(table.iter()).enumerate() {
        if got != want {
            bail!("checksum mismatch in section {}", SECTION_NAMES[i]);
        }
    }

    // Semantic validation, parallel over nodes/edges. Everything the
    // engines index by is proven in-bounds here, so downstream code can
    // trust the model as if it came from a builder.
    let name = String::from_utf8(name_bytes).context("bad model name")?;
    par_check(threads, n, |lo, hi| {
        for i in lo..hi {
            let d = domain[i] as usize;
            if d == 0 || d > MAX_DOMAIN {
                return Err(format!("node {i}: domain {d} out of range"));
            }
            if offsets[i] > offsets[i + 1] {
                return Err(format!("node {i}: CSR offsets not monotone"));
            }
        }
        Ok(())
    })?;
    if offsets.first() != Some(&0) || offsets[n] as usize != me {
        bail!("corrupt model: CSR offsets do not cover the edge list");
    }

    let graph = Csr {
        offsets: offsets.into(),
        adj_node: adj_node.into(),
        adj_out: adj_out.into(),
        adj_in: adj_in.into(),
        edge_src: edge_src.into(),
        edge_dst: edge_dst.into(),
    };
    par_check(threads, n, |lo, hi| graph.check_consistent(lo, hi))?;
    par_check(threads, n, |lo, hi| graph.check_simple(lo, hi))?;

    let node_factors =
        NodeFactors::from_raw(nf_offsets, nf_data).map_err(|e| anyhow::anyhow!("corrupt model: {e}"))?;
    par_check(threads, n, |lo, hi| {
        for i in lo..hi {
            if node_factors.domain(i) != domain[i] as usize {
                return Err(format!("node {i}: factor width does not match domain"));
            }
        }
        Ok(())
    })?;

    let entries: Vec<(u32, u16, u16)> = pool_offsets
        .iter()
        .zip(&pool_shapes)
        .map(|(&o, &s)| (o, (s >> 16) as u16, (s & 0xffff) as u16))
        .collect();
    let pool =
        FactorPool::from_raw(pool_data, entries).map_err(|e| anyhow::anyhow!("corrupt model: {e}"))?;

    let total = total_msg_len as usize;
    par_check(threads, m, |lo, hi| {
        for k in lo..hi {
            let pi = epi[k] as usize;
            if pi >= pool.len() {
                return Err(format!("edge {k}: pool index {pi} out of range"));
            }
            let (r, c) = pool.shape(pi);
            let (src, dst) = (graph.edge_src[2 * k] as usize, graph.edge_dst[2 * k] as usize);
            if r != domain[src] as usize || c != domain[dst] as usize {
                return Err(format!("edge {k}: factor shape does not match endpoint domains"));
            }
            for e in [2 * k, 2 * k + 1] {
                let next =
                    if e + 1 < 2 * m { msg_offset[e + 1] as usize } else { total };
                let want = domain[graph.edge_dst[e] as usize] as usize;
                if next < msg_offset[e] as usize || next - msg_offset[e] as usize != want {
                    return Err(format!("edge {e}: message offset stride mismatch"));
                }
            }
        }
        Ok(())
    })?;
    if m > 0 && msg_offset[0] != 0 {
        bail!("corrupt model: message offsets do not start at 0");
    }
    if m == 0 && total != 0 {
        bail!("corrupt model: message length without edges");
    }

    // Directed-edge factor refs (even = stored orientation, odd =
    // transposed), built in parallel — the one remaining O(edges) fill.
    let mut edge_factor = vec![FactorRef::new(0, false); me];
    if me > 0 {
        let per = (m.div_ceil(threads.max(1))).max(1) * 2;
        std::thread::scope(|s| {
            for (c, chunk) in edge_factor.chunks_mut(per).enumerate() {
                let epi = &epi;
                s.spawn(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        let e = c * per + j;
                        *slot = FactorRef::new(epi[e / 2], e % 2 == 1);
                    }
                });
            }
        });
    }

    Ok(Mrf {
        graph,
        domain: domain.into(),
        node_factors,
        edge_factor,
        pool,
        msg_offset: msg_offset.into(),
        total_msg_len: total,
        name,
    })
}

// ---------------------------------------------------------------------------
// v2 zero-copy map reader
// ---------------------------------------------------------------------------

/// Borrow section `i` out of the mapped file as a typed slice. Alignment
/// and bounds were validated by [`parse_v2_layout`] plus the map-path
/// offset-alignment gate, but [`ModelStorage::from_mapped`] re-checks
/// both before the cast — corruption fails cleanly, never UB.
fn mapped_section<T: Pod>(
    map: &Arc<Mmap>,
    table: &[(u64, u64, u64); SECTION_COUNT],
    i: usize,
) -> Result<ModelStorage<T>> {
    let (off, len, _) = table[i];
    let elems = len as usize / std::mem::size_of::<T>();
    ModelStorage::from_mapped(map.clone(), off as usize, elems)
        .map_err(|e| anyhow!("section {}: {e}", SECTION_NAMES[i]))
}

/// Deserialize a v2 file by mapping it and borrowing every section in
/// place — no copy pass. Returns `Ok(None)` when this file cannot be
/// mapped (v1 format, unaligned sections, platform without mmap): the
/// caller falls back to the positioned-read path. Returns `Err` only for
/// corruption — fallback would just fail again.
///
/// `verify` gates the expensive integrity work (per-section checksums
/// plus the full semantic validation sweeps), each of which pages in
/// every mapped byte and so costs exactly the copy pass this reader
/// exists to delete. Structural validation (header counts, section
/// bounds/alignment, offset endpoints) always runs; with `verify` off, a
/// corrupt payload can still only produce a clean panic on a bounds
/// check downstream, never UB.
fn read_mrf_v2_mapped(f: &File, file_len: u64, threads: usize, verify: bool) -> Result<Option<Mrf>> {
    #[cfg(target_endian = "big")]
    return Ok(None);

    if !cfg!(unix) || file_len < FIRST_SECTION {
        return Ok(None);
    }
    // A short read of the version probe means a truncated header — let
    // the read path produce its canonical error.
    let mut head8 = [0u8; 8];
    if f.read_exact_at(&mut head8, 0).is_err() || &head8[0..4] != MAGIC {
        return Ok(None);
    }
    if u32::from_le_bytes(head8[4..8].try_into().unwrap()) != VERSION_V2 {
        return Ok(None); // v1 stream: only the read path knows it
    }
    let map = match Mmap::map_file(f, file_len) {
        Ok(m) => Arc::new(m),
        Err(_) => return Ok(None), // kernel refused; read path still works
    };
    let bytes = map.as_slice();
    let layout = parse_v2_layout(
        &bytes[..HEADER_BYTES as usize],
        &bytes[HEADER_BYTES as usize..(HEADER_BYTES + TABLE_BYTES) as usize],
        file_len,
    )?;
    let V2Layout { n, m, pool_len: _, nf_len: _, pool_data_len: _, total_msg_len, table } = layout;

    // Unaligned section offsets (a foreign or hand-edited v2 file): not
    // corruption — the read path handles them — so fall back, per the
    // format contract that mapping never changes what loads.
    if table.iter().any(|&(off, _, _)| off % ALIGN != 0) {
        return Ok(None);
    }

    // Our saver ends the file exactly at the last section's end. A tail
    // beyond that means the file was grown or spliced after save — a
    // layout this reader does not understand, so corruption, not
    // fallback (the read path would silently ignore the tail).
    let end = table.iter().map(|&(off, len, _)| off + len).max().unwrap_or(FIRST_SECTION);
    if file_len != end {
        bail!("file length {file_len} does not match section layout end {end}");
    }

    if verify {
        // Sections are few; `checksum_bytes` parallelizes internally over
        // blocks, so the big sections already use the cold-path pool.
        for (i, &(off, len, want)) in table.iter().enumerate() {
            if checksum_bytes(&bytes[off as usize..(off + len) as usize]) != want {
                bail!("checksum mismatch in section {}", SECTION_NAMES[i]);
            }
        }
    }

    let me = 2 * m;
    let (n, m, me) = (n as usize, m as usize, me as usize);
    let name_bytes = bytes[table[0].0 as usize..(table[0].0 + table[0].1) as usize].to_vec();
    let name = String::from_utf8(name_bytes).context("bad model name")?;

    let domain: ModelStorage<u32> = mapped_section(&map, &table, 1)?;
    let offsets: ModelStorage<u32> = mapped_section(&map, &table, 2)?;
    let nf_offsets: ModelStorage<u32> = mapped_section(&map, &table, 8)?;
    let nf_data: ModelStorage<f64> = mapped_section(&map, &table, 9)?;
    let epi: ModelStorage<u32> = mapped_section(&map, &table, 10)?;
    let pool_offsets: ModelStorage<u32> = mapped_section(&map, &table, 11)?;
    let pool_shapes: ModelStorage<u32> = mapped_section(&map, &table, 12)?;
    let pool_data: ModelStorage<f64> = mapped_section(&map, &table, 13)?;
    let msg_offset: ModelStorage<u32> = mapped_section(&map, &table, 14)?;

    // Endpoint structural checks: O(1), touch two pages per section.
    if offsets.first() != Some(&0) || offsets[n] as usize != me {
        bail!("corrupt model: CSR offsets do not cover the edge list");
    }
    let total = total_msg_len as usize;
    if m > 0 && msg_offset[0] != 0 {
        bail!("corrupt model: message offsets do not start at 0");
    }
    if m == 0 && total != 0 {
        bail!("corrupt model: message length without edges");
    }

    let graph = Csr {
        offsets,
        adj_node: mapped_section(&map, &table, 3)?,
        adj_out: mapped_section(&map, &table, 4)?,
        adj_in: mapped_section(&map, &table, 5)?,
        edge_src: mapped_section(&map, &table, 6)?,
        edge_dst: mapped_section(&map, &table, 7)?,
    };

    if verify {
        par_check(threads, n, |lo, hi| {
            for i in lo..hi {
                let d = domain[i] as usize;
                if d == 0 || d > MAX_DOMAIN {
                    return Err(format!("node {i}: domain {d} out of range"));
                }
                if graph.offsets[i] > graph.offsets[i + 1] {
                    return Err(format!("node {i}: CSR offsets not monotone"));
                }
            }
            Ok(())
        })?;
        par_check(threads, n, |lo, hi| graph.check_consistent(lo, hi))?;
        par_check(threads, n, |lo, hi| graph.check_simple(lo, hi))?;
    }

    let node_factors = NodeFactors::from_storage(nf_offsets, nf_data, verify)
        .map_err(|e| anyhow!("corrupt model: {e}"))?;
    if verify {
        par_check(threads, n, |lo, hi| {
            for i in lo..hi {
                if node_factors.domain(i) != domain[i] as usize {
                    return Err(format!("node {i}: factor width does not match domain"));
                }
            }
            Ok(())
        })?;
    }

    // Pool entries are rebuilt from the two u32 sections (pool_len is
    // tiny for shared-factor families, O(edges) for per-edge couplings —
    // either way far smaller than the pool data we leave mapped).
    let entries: Vec<(u32, u16, u16)> = pool_offsets
        .iter()
        .zip(pool_shapes.iter())
        .map(|(&o, &s)| (o, (s >> 16) as u16, (s & 0xffff) as u16))
        .collect();
    drop((pool_offsets, pool_shapes));
    let pool = FactorPool::from_storage(pool_data, entries, verify)
        .map_err(|e| anyhow!("corrupt model: {e}"))?;

    if verify {
        par_check(threads, m, |lo, hi| {
            for k in lo..hi {
                let pi = epi[k] as usize;
                if pi >= pool.len() {
                    return Err(format!("edge {k}: pool index {pi} out of range"));
                }
                let (r, c) = pool.shape(pi);
                let (src, dst) =
                    (graph.edge_src[2 * k] as usize, graph.edge_dst[2 * k] as usize);
                if r != domain[src] as usize || c != domain[dst] as usize {
                    return Err(format!("edge {k}: factor shape does not match endpoint domains"));
                }
                for e in [2 * k, 2 * k + 1] {
                    let next = if e + 1 < 2 * m { msg_offset[e + 1] as usize } else { total };
                    let want = domain[graph.edge_dst[e] as usize] as usize;
                    if next < msg_offset[e] as usize || next - msg_offset[e] as usize != want {
                        return Err(format!("edge {e}: message offset stride mismatch"));
                    }
                }
            }
            Ok(())
        })?;
    }

    // Directed-edge factor refs (even = stored orientation, odd =
    // transposed), materialized in parallel exactly as on the read path
    // (the only O(edges) heap allocation the map load keeps).
    let mut edge_factor = vec![FactorRef::new(0, false); me];
    if me > 0 {
        let threads = threads.max(1);
        let per = (m.div_ceil(threads)).max(1) * 2;
        std::thread::scope(|s| {
            for (c, chunk) in edge_factor.chunks_mut(per).enumerate() {
                let epi = &epi;
                s.spawn(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        let e = c * per + j;
                        *slot = FactorRef::new(epi[e / 2], e % 2 == 1);
                    }
                });
            }
        });
    }

    Ok(Some(Mrf {
        graph,
        domain,
        node_factors,
        edge_factor,
        pool,
        msg_offset,
        total_msg_len: total,
        name,
    }))
}

// ---------------------------------------------------------------------------
// File-level entry points
// ---------------------------------------------------------------------------

/// Save to a file path in the default (v2 sectioned) format; returns the
/// file size in bytes.
pub fn save(mrf: &Mrf, path: &str) -> Result<u64> {
    let f = File::create(path).with_context(|| format!("creating {path}"))?;
    // Header/table writes are small, so buffer them; section payloads
    // pass through `BufWriter` as single large writes.
    write_mrf_v2(mrf, BufWriter::new(f))
}

/// Save to a file path in the legacy v1 stream format; returns the file
/// size in bytes. The scalar-at-a-time codec *requires* buffering here —
/// handing it a raw `File` costs one syscall per scalar.
pub fn save_v1(mrf: &Mrf, path: &str) -> Result<u64> {
    let f = File::create(path).with_context(|| format!("creating {path}"))?;
    write_mrf(mrf, BufWriter::new(f))?;
    Ok(std::fs::metadata(path).with_context(|| format!("sizing {path}"))?.len())
}

/// How a model file is brought into memory (the `--load-mode` axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LoadMode {
    /// Positioned bulk reads copying every section to the heap (the
    /// frozen historical path; always fully validated).
    Read,
    /// Zero-copy: map the file and borrow sections in place, falling
    /// back to `Read` when the file cannot be mapped (v1 format,
    /// unaligned sections, non-unix).
    Map,
    /// Default: same preference order as `Map`. Load mode never changes
    /// the loaded model — both paths are pinned bit-equal — so auto is
    /// safe as a default.
    #[default]
    Auto,
}

impl LoadMode {
    /// Report label (`read` | `map` | `auto`).
    pub fn label(&self) -> &'static str {
        match self {
            LoadMode::Read => "read",
            LoadMode::Map => "map",
            LoadMode::Auto => "auto",
        }
    }
}

/// Parse the load-mode axis value (`--load-mode read|map|auto`).
pub fn parse_load_mode(s: &str) -> Result<LoadMode> {
    match s {
        "read" => Ok(LoadMode::Read),
        "map" => Ok(LoadMode::Map),
        "auto" => Ok(LoadMode::Auto),
        other => bail!("expected read|map|auto, got '{other}'"),
    }
}

/// Load from a file path, auto-detecting the format version, with an
/// automatic cold-path thread count for v2 parallel reads. Always uses
/// the copying read path (the frozen behavior; the map path is opt-in
/// through [`load_with_mode`]).
pub fn load(path: &str) -> Result<Mrf> {
    let len = std::fs::metadata(path).with_context(|| format!("opening {path}"))?.len();
    load_with_threads(path, cold_path_threads((len / 64) as usize))
}

/// Load from a file path, auto-detecting the format version; v2 files
/// are read with `threads` positioned-read workers.
pub fn load_with_threads(path: &str, threads: usize) -> Result<Mrf> {
    load_with_mode(path, threads, LoadMode::Read, true).map(|(mrf, _)| mrf)
}

/// Load from a file path under an explicit [`LoadMode`]; returns the
/// model plus the mode that actually produced it ([`LoadMode::Read`] or
/// [`LoadMode::Map`], for telemetry). `verify` controls checksum +
/// semantic validation on the map path; the read path always verifies
/// (it is touching every byte anyway).
pub fn load_with_mode(
    path: &str,
    threads: usize,
    mode: LoadMode,
    verify: bool,
) -> Result<(Mrf, LoadMode)> {
    let f = File::open(path).with_context(|| format!("opening {path}"))?;
    let file_len = f.metadata().with_context(|| format!("sizing {path}"))?.len();
    let threads = threads.max(1);

    if matches!(mode, LoadMode::Map | LoadMode::Auto) {
        if let Some(mrf) = read_mrf_v2_mapped(&f, file_len, threads, verify)
            .with_context(|| format!("loading {path} (v2, mapped)"))?
        {
            return Ok((mrf, LoadMode::Map));
        }
    }

    let mut head = [0u8; 8];
    f.read_exact_at(&mut head, 0).with_context(|| format!("{path}: file too short"))?;
    if &head[0..4] != MAGIC {
        bail!("{path}: not an RBPM file");
    }
    let mrf = match u32::from_le_bytes(head[4..8].try_into().unwrap()) {
        // Positioned reads left the cursor at 0, so the stream reader
        // (explicitly buffered — the legacy codec reads one scalar at a
        // time) starts from the magic again.
        VERSION_V1 => read_mrf(BufReader::new(f)).with_context(|| format!("loading {path} (v1)"))?,
        VERSION_V2 => read_mrf_v2(&f, file_len, threads)
            .with_context(|| format!("loading {path} (v2)"))?,
        v => bail!("{path}: unsupported RBPM version {v}"),
    };
    Ok((mrf, LoadMode::Read))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::ModelSpec;
    use crate::model::builders;

    fn assert_models_equal(m: &Mrf, back: &Mrf) {
        assert_eq!(back.name, m.name);
        assert_eq!(back.num_nodes(), m.num_nodes());
        assert_eq!(back.num_messages(), m.num_messages());
        assert_eq!(back.domain, m.domain);
        assert_eq!(back.graph.offsets, m.graph.offsets);
        assert_eq!(back.graph.adj_node, m.graph.adj_node);
        assert_eq!(back.graph.adj_out, m.graph.adj_out);
        assert_eq!(back.graph.adj_in, m.graph.adj_in);
        assert_eq!(back.graph.edge_src, m.graph.edge_src);
        assert_eq!(back.graph.edge_dst, m.graph.edge_dst);
        assert_eq!(back.msg_offset, m.msg_offset);
        assert_eq!(back.total_msg_len, m.total_msg_len);
        for i in 0..m.num_nodes() {
            assert_eq!(back.node_factors.of(i), m.node_factors.of(i));
        }
        for e in 0..m.num_messages() {
            let fr_a = m.edge_factor[e];
            let fr_b = back.edge_factor[e];
            assert_eq!(m.pool.shape_of(fr_a), back.pool.shape_of(fr_b));
            let (dr, dc) = m.pool.shape_of(fr_a);
            for a in 0..dr {
                for b in 0..dc {
                    assert_eq!(m.pool.get(fr_a, a, b), back.pool.get(fr_b, a, b));
                }
            }
        }
    }

    fn roundtrip(spec: &ModelSpec) {
        let m = builders::build(spec, 5);
        let mut buf = Vec::new();
        write_mrf(&m, &mut buf).unwrap();
        let back = read_mrf(&buf[..]).unwrap();
        assert_models_equal(&m, &back);
    }

    #[test]
    fn roundtrip_tree() {
        roundtrip(&ModelSpec::Tree { n: 31 });
    }

    #[test]
    fn roundtrip_ising() {
        roundtrip(&ModelSpec::Ising { n: 5 });
    }

    #[test]
    fn roundtrip_ldpc() {
        roundtrip(&ModelSpec::Ldpc { n: 12, flip_prob: 0.07 });
    }

    #[test]
    fn rejects_bad_magic() {
        let res = read_mrf(&b"NOPE"[..]);
        assert!(res.is_err());
    }

    #[test]
    fn rejects_truncated() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let mut buf = Vec::new();
        write_mrf(&m, &mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(read_mrf(&buf[..]).is_err());
    }

    #[test]
    fn file_roundtrip_v2() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let path = "/tmp/rbp_io_test_v2.rbpm";
        let bytes = save(&m, path).unwrap();
        assert_eq!(bytes, std::fs::metadata(path).unwrap().len());
        for threads in [1, 2, 8] {
            let back = load_with_threads(path, threads).unwrap();
            assert_models_equal(&m, &back);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn file_roundtrip_v1() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let path = "/tmp/rbp_io_test_v1.rbpm";
        let bytes = save_v1(&m, path).unwrap();
        assert_eq!(bytes, std::fs::metadata(path).unwrap().len());
        let back = load(path).unwrap();
        assert_models_equal(&m, &back);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_sections_are_aligned() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 3);
        let path = "/tmp/rbp_io_test_align.rbpm";
        save(&m, path).unwrap();
        let bytes = std::fs::read(path).unwrap();
        assert_eq!(&bytes[0..4], MAGIC);
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_V2);
        for i in 0..SECTION_COUNT {
            let off = u64_at(&bytes, (HEADER_BYTES as usize) + 24 * i);
            assert_eq!(off % ALIGN, 0, "section {} misaligned", SECTION_NAMES[i]);
            assert!(off >= FIRST_SECTION);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_rejects_unknown_version() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let path = "/tmp/rbp_io_test_ver.rbpm";
        save(&m, path).unwrap();
        let mut bytes = std::fs::read(path).unwrap();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(path, &bytes).unwrap();
        let err = load(path).unwrap_err().to_string();
        assert!(err.contains("version 99"), "unexpected error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_rejects_checksum_corruption() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 3);
        let path = "/tmp/rbp_io_test_sum.rbpm";
        save(&m, path).unwrap();
        let mut bytes = std::fs::read(path).unwrap();
        // Flip one payload byte in the last section (msg_offset).
        let off = u64_at(&bytes, (HEADER_BYTES as usize) + 24 * (SECTION_COUNT - 1)) as usize;
        bytes[off] ^= 0x40;
        std::fs::write(path, &bytes).unwrap();
        let err = load(path).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"), "unexpected error: {err:#}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_rejects_hostile_length_without_allocating() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let path = "/tmp/rbp_io_test_len.rbpm";
        save(&m, path).unwrap();
        let mut bytes = std::fs::read(path).unwrap();
        // Claim ~u64::MAX nodes in the header: must fail the count guard,
        // not attempt a multi-exabyte allocation.
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(path, &bytes).unwrap();
        let err = format!("{:#}", load(path).unwrap_err());
        assert!(err.contains("oversized") || err.contains("mismatch"), "unexpected error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn v2_rejects_truncated_file() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 3);
        let path = "/tmp/rbp_io_test_trunc.rbpm";
        save(&m, path).unwrap();
        let bytes = std::fs::read(path).unwrap();
        std::fs::write(path, &bytes[..bytes.len() * 2 / 3]).unwrap();
        assert!(load(path).is_err());
        std::fs::remove_file(path).ok();
    }
}
