//! Binary MRF serialization.
//!
//! Lets `relaxed-bp generate` write an instance once and have every
//! algorithm/thread-count sweep load the identical model (important for the
//! paper's tables, where all algorithms must see the same random couplings).
//!
//! Format (little-endian): magic `RBPM`, version, name, node count, domains,
//! node factors, undirected edge list with pool indices, factor pool.

use super::{FactorPool, GraphBuilder, Mrf, NodeFactors};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};

const MAGIC: &[u8; 4] = b"RBPM";
const VERSION: u32 = 1;

struct Writer<W: Write>(W);

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes()).map_err(Into::into)
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes()).map_err(Into::into)
    }
    fn f64(&mut self, v: f64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes()).map_err(Into::into)
    }
    fn bytes(&mut self, b: &[u8]) -> Result<()> {
        self.u64(b.len() as u64)?;
        self.0.write_all(b).map_err(Into::into)
    }
    fn f64s(&mut self, xs: &[f64]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.f64(x)?;
        }
        Ok(())
    }
    fn u32s(&mut self, xs: &[u32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.u32(x)?;
        }
        Ok(())
    }
}

struct Reader<R: Read>(R);

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.0.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        if n > 1 << 34 {
            bail!("corrupt file: oversized field");
        }
        let mut b = vec![0u8; n];
        self.0.read_exact(&mut b)?;
        Ok(b)
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        if n > 1 << 31 {
            bail!("corrupt file: oversized array");
        }
        (0..n).map(|_| self.f64()).collect()
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u64()? as usize;
        if n > 1 << 31 {
            bail!("corrupt file: oversized array");
        }
        (0..n).map(|_| self.u32()).collect()
    }
}

/// Serialize an MRF to a writer.
pub fn write_mrf<W: Write>(mrf: &Mrf, w: W) -> Result<()> {
    let mut w = Writer(BufWriter::new(w));
    w.0.write_all(MAGIC)?;
    w.u32(VERSION)?;
    w.bytes(mrf.name.as_bytes())?;

    let n = mrf.num_nodes();
    w.u64(n as u64)?;
    w.u32s(&mrf.domain)?;

    // Node factors, flat.
    for i in 0..n {
        w.f64s(mrf.node_factors.of(i))?;
    }

    // Undirected edges: (src, dst, pool index) from the even directed edges.
    let m = mrf.num_messages() / 2;
    w.u64(m as u64)?;
    for k in 0..m {
        let e = 2 * k;
        w.u32(mrf.graph.edge_src[e])?;
        w.u32(mrf.graph.edge_dst[e])?;
        w.u32(mrf.edge_factor[e].pool_index() as u32)?;
    }

    // Pool.
    w.u64(mrf.pool.len() as u64)?;
    for idx in 0..mrf.pool.len() {
        let (r, c) = mrf.pool.shape(idx);
        w.u32(r as u32)?;
        w.u32(c as u32)?;
        w.f64s(mrf.pool.matrix(idx))?;
    }
    w.0.flush()?;
    Ok(())
}

/// Deserialize an MRF from a reader.
pub fn read_mrf<R: Read>(r: R) -> Result<Mrf> {
    let mut r = Reader(BufReader::new(r));
    let mut magic = [0u8; 4];
    r.0.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an RBPM file");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported RBPM version {version}");
    }
    let name = String::from_utf8(r.bytes()?).context("bad name")?;

    let n = r.u64()? as usize;
    let domain = r.u32s()?;
    if domain.len() != n {
        bail!("domain length mismatch");
    }

    let mut factors = Vec::with_capacity(n);
    for i in 0..n {
        let f = r.f64s()?;
        if f.len() != domain[i] as usize {
            bail!("node factor width mismatch at {i}");
        }
        factors.push(f);
    }

    let m = r.u64()? as usize;
    let mut gb = GraphBuilder::new(n);
    let mut edge_pool_index = Vec::with_capacity(m);
    for _ in 0..m {
        let a = r.u32()?;
        let b = r.u32()?;
        let p = r.u32()?;
        gb.add_edge(a as usize, b as usize);
        edge_pool_index.push(p);
    }

    let pool_len = r.u64()? as usize;
    let mut pool = FactorPool::new();
    for _ in 0..pool_len {
        let rows = r.u32()? as usize;
        let cols = r.u32()? as usize;
        let data = r.f64s()?;
        if data.len() != rows * cols {
            bail!("pool matrix shape mismatch");
        }
        pool.add(rows, cols, &data);
    }

    Ok(Mrf::assemble(
        &name,
        gb.build(),
        domain,
        NodeFactors::from_vecs(&factors),
        edge_pool_index,
        pool,
    ))
}

/// Save to a file path.
pub fn save(mrf: &Mrf, path: &str) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    write_mrf(mrf, f)
}

/// Load from a file path.
pub fn load(path: &str) -> Result<Mrf> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    read_mrf(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builders;
    use crate::configio::ModelSpec;

    fn roundtrip(spec: &ModelSpec) {
        let m = builders::build(spec, 5);
        let mut buf = Vec::new();
        write_mrf(&m, &mut buf).unwrap();
        let back = read_mrf(&buf[..]).unwrap();
        assert_eq!(back.name, m.name);
        assert_eq!(back.num_nodes(), m.num_nodes());
        assert_eq!(back.num_messages(), m.num_messages());
        assert_eq!(back.domain, m.domain);
        assert_eq!(back.graph.adj_node, m.graph.adj_node);
        assert_eq!(back.msg_offset, m.msg_offset);
        for i in 0..m.num_nodes() {
            assert_eq!(back.node_factors.of(i), m.node_factors.of(i));
        }
        for e in 0..m.num_messages() {
            let fr_a = m.edge_factor[e];
            let fr_b = back.edge_factor[e];
            assert_eq!(m.pool.shape_of(fr_a), back.pool.shape_of(fr_b));
            let (dr, dc) = m.pool.shape_of(fr_a);
            for a in 0..dr {
                for b in 0..dc {
                    assert_eq!(m.pool.get(fr_a, a, b), back.pool.get(fr_b, a, b));
                }
            }
        }
    }

    #[test]
    fn roundtrip_tree() {
        roundtrip(&ModelSpec::Tree { n: 31 });
    }

    #[test]
    fn roundtrip_ising() {
        roundtrip(&ModelSpec::Ising { n: 5 });
    }

    #[test]
    fn roundtrip_ldpc() {
        roundtrip(&ModelSpec::Ldpc { n: 12, flip_prob: 0.07 });
    }

    #[test]
    fn rejects_bad_magic() {
        let res = read_mrf(&b"NOPE"[..]);
        assert!(res.is_err());
    }

    #[test]
    fn rejects_truncated() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let mut buf = Vec::new();
        write_mrf(&m, &mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(read_mrf(&buf[..]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let path = "/tmp/rbp_io_test.rbpm";
        save(&m, path).unwrap();
        let back = load(path).unwrap();
        assert_eq!(back.num_messages(), m.num_messages());
        std::fs::remove_file(path).ok();
    }
}
