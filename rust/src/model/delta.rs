//! Evidence deltas — incremental prior updates on a resident model.
//!
//! An [`EvidenceDelta`] is a small batch of `node → new prior` overwrites
//! applied to an already-built [`Mrf`]. Domains never change (a delta
//! re-weights a node's states, it does not add states), so applying one is
//! an in-place [`NodeFactors::set`](super::NodeFactors::set) per entry and
//! every flat offset, CSR index, and message arena stays valid.
//!
//! Deltas are what the warm-start path re-converges from: residual BP is
//! naturally incremental — changing `ψ_i` perturbs only the messages
//! `μ_{i→j}` on node `i`'s out-edges, so the delta seeder re-prices exactly
//! those tasks against the resident message state and the relaxed scheduler
//! absorbs the rest (see `Engine::resume` and DESIGN.md §Incremental
//! re-convergence).

use super::Mrf;
use crate::util::Xoshiro256;

/// A batch of prior overwrites: `node → new ψ_i`, deduplicated (last write
/// wins) and sorted by node id, so iteration — and therefore seeding — is
/// deterministic in the set of entries regardless of insertion order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EvidenceDelta {
    /// `(node, new prior)`, sorted by node, one entry per node.
    entries: Vec<(u32, Vec<f64>)>,
}

impl EvidenceDelta {
    /// The empty delta (a resume with it is a no-op: zero tasks seeded).
    pub fn new() -> Self {
        Self::default()
    }

    /// Set node `i`'s new prior, replacing any earlier entry for `i`.
    pub fn set(&mut self, node: u32, prior: Vec<f64>) {
        match self.entries.binary_search_by_key(&node, |(n, _)| *n) {
            Ok(k) => self.entries[k].1 = prior,
            Err(k) => self.entries.insert(k, (node, prior)),
        }
    }

    /// Number of perturbed nodes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no node is perturbed.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The perturbed nodes, ascending.
    pub fn nodes(&self) -> impl Iterator<Item = u32> + '_ {
        self.entries.iter().map(|(n, _)| *n)
    }

    /// The `(node, prior)` entries, ascending by node.
    pub fn entries(&self) -> &[(u32, Vec<f64>)] {
        &self.entries
    }

    /// Compose with a later delta: the result applied once reaches the same
    /// model state as `self` then `later` applied in sequence (`later` wins
    /// on nodes both touch).
    pub fn merged(&self, later: &EvidenceDelta) -> EvidenceDelta {
        let mut out = self.clone();
        for (n, p) in &later.entries {
            out.set(*n, p.clone());
        }
        out
    }

    /// Overwrite the priors of every entry's node in `mrf`. Panics if an
    /// entry's length does not match the node's domain (deltas re-weight
    /// states, they never resize domains).
    pub fn apply(&self, mrf: &mut Mrf) {
        for (n, p) in &self.entries {
            mrf.node_factors.set(*n as usize, p);
        }
    }

    /// A deterministic random perturbation of `fraction` of `mrf`'s nodes
    /// (at least one): each chosen node's prior is re-weighted
    /// multiplicatively, `ψ_i(x) ← ψ_i(x)·e^{U[-1,1]}` per state. The
    /// multiplicative form preserves support — exact zeros (LDPC parity
    /// indicators) stay exactly zero, so structural constraints survive the
    /// perturbation. This is the small-delta workload `experiment delta`
    /// and the bench delta cells measure (0.1% of priors by default).
    pub fn random_perturbation(mrf: &Mrf, fraction: f64, seed: u64) -> EvidenceDelta {
        let n = mrf.num_nodes();
        let k = ((n as f64 * fraction).round() as usize).clamp(1, n.max(1));
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut delta = EvidenceDelta::new();
        for i in rng.sample_indices(n, k) {
            let prior: Vec<f64> = mrf
                .node_factors
                .of(i)
                .iter()
                .map(|&v| v * rng.uniform(-1.0, 1.0).exp())
                .collect();
            delta.set(i as u32, prior);
        }
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::ModelSpec;
    use crate::model::builders;

    #[test]
    fn set_dedupes_last_wins_and_sorts() {
        let mut d = EvidenceDelta::new();
        d.set(5, vec![0.2, 0.8]);
        d.set(1, vec![0.5, 0.5]);
        d.set(5, vec![0.9, 0.1]);
        assert_eq!(d.len(), 2);
        assert_eq!(d.nodes().collect::<Vec<_>>(), vec![1, 5]);
        assert_eq!(d.entries()[1], (5, vec![0.9, 0.1]));
    }

    #[test]
    fn merged_is_sequential_application() {
        let mut a = EvidenceDelta::new();
        a.set(0, vec![0.2, 0.8]);
        a.set(3, vec![0.4, 0.6]);
        let mut b = EvidenceDelta::new();
        b.set(3, vec![0.7, 0.3]);
        b.set(7, vec![0.1, 0.9]);
        let m = a.merged(&b);
        assert_eq!(m.len(), 3);
        assert_eq!(m.entries()[1], (3, vec![0.7, 0.3]), "later delta wins on shared nodes");

        let mut mrf1 = builders::build(&ModelSpec::Tree { n: 15 }, 1);
        let mut mrf2 = mrf1.clone();
        a.apply(&mut mrf1);
        b.apply(&mut mrf1);
        m.apply(&mut mrf2);
        for i in 0..15 {
            assert_eq!(mrf1.node_factors.of(i), mrf2.node_factors.of(i), "node {i}");
        }
    }

    #[test]
    fn apply_overwrites_only_listed_nodes() {
        let mut mrf = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let mut d = EvidenceDelta::new();
        d.set(3, vec![0.25, 0.75]);
        d.apply(&mut mrf);
        assert_eq!(mrf.node_factors.of(3), &[0.25, 0.75]);
        assert_eq!(mrf.node_factors.of(0), &[0.1, 0.9]);
        assert_eq!(mrf.node_factors.of(4), &[0.5, 0.5]);
    }

    #[test]
    #[should_panic(expected = "length must match")]
    fn apply_rejects_domain_mismatch() {
        let mut mrf = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let mut d = EvidenceDelta::new();
        d.set(2, vec![1.0, 2.0, 3.0]);
        d.apply(&mut mrf);
    }

    #[test]
    fn random_perturbation_is_deterministic_and_support_preserving() {
        let inst = builders::ldpc::build(24, 0.07, 3);
        let d1 = EvidenceDelta::random_perturbation(&inst.mrf, 0.1, 9);
        let d2 = EvidenceDelta::random_perturbation(&inst.mrf, 0.1, 9);
        assert_eq!(d1, d2, "deterministic in (mrf, fraction, seed)");
        assert_eq!(d1.len(), 4, "10% of 36 nodes, rounded");
        for (n, p) in d1.entries() {
            let old = inst.mrf.node_factors.of(*n as usize);
            assert_eq!(p.len(), old.len());
            for (a, b) in old.iter().zip(p.iter()) {
                assert_eq!(*a == 0.0, *b == 0.0, "node {n}: support must be preserved");
            }
        }
        // Tiny fractions still perturb at least one node.
        assert_eq!(EvidenceDelta::random_perturbation(&inst.mrf, 1e-9, 1).len(), 1);
    }
}
