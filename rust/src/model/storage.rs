//! Storage-agnostic backing for model arrays: heap-owned vectors or
//! typed sections borrowed from a memory-mapped model file.
//!
//! The zero-copy load path (`model::io`, `--load-mode map`) maps a v2
//! snapshot and hands each section out as a [`ModelStorage::Mapped`]
//! slice, so a 100M-edge model's CSR arrays, domains, factors, and
//! message offsets never pass through a heap copy. Everything else —
//! generators, the v1/read load paths, tests — keeps building plain
//! vectors through `From<Vec<T>>`.
//!
//! [`ModelStorage`] derefs to `&[T]`, so consumers index it exactly like
//! the `Vec<T>` it replaced. The rare mutators (evidence deltas writing
//! node priors, builders appending factors) go through
//! [`ModelStorage::to_mut`], which copies a mapped section to the heap
//! on first write (copy-on-write at section granularity).

use crate::util::mmap::Mmap;
use std::sync::Arc;

/// A model array: heap-owned, or borrowed from a mapped model file.
pub enum ModelStorage<T: 'static> {
    /// Heap-allocated (the historical representation).
    Owned(Vec<T>),
    /// A typed view into a shared read-only file mapping. The `Arc`
    /// keeps the mapping alive for as long as any section borrows it.
    Mapped {
        /// The mapping this view borrows from (held only for lifetime).
        map: Arc<Mmap>,
        /// First element of the section (validated aligned + in bounds
        /// at construction).
        ptr: *const T,
        /// Element count.
        len: usize,
    },
}

// SAFETY: `Mapped` is a read-only view of an immutable shared file
// mapping (writes never happen through it — mutation goes through
// `to_mut`, which copies to an owned Vec first), so sharing or sending
// it across threads is as sound as sharing `&[T]`.
unsafe impl<T: Send + Sync> Send for ModelStorage<T> {}
unsafe impl<T: Send + Sync> Sync for ModelStorage<T> {}

impl<T> ModelStorage<T> {
    /// Borrow `len` elements of `T` starting at byte offset `offset` of
    /// the mapping. Errors (no panic, no UB) unless the range is in
    /// bounds and the file offset is aligned for `T` — callers surface
    /// this as a clean "unaligned v2 file" load failure.
    pub fn from_mapped(map: Arc<Mmap>, offset: usize, len: usize) -> Result<Self, String> {
        let bytes = len
            .checked_mul(std::mem::size_of::<T>())
            .ok_or_else(|| "section length overflows".to_string())?;
        if offset > map.len() || bytes > map.len() - offset {
            return Err(format!(
                "section [{offset}, {offset}+{bytes}) exceeds mapped file ({} bytes)",
                map.len()
            ));
        }
        let ptr = map.as_slice()[offset..].as_ptr();
        if (ptr as usize) % std::mem::align_of::<T>() != 0 {
            return Err(format!(
                "section at file offset {offset} is not aligned for {}",
                std::any::type_name::<T>()
            ));
        }
        Ok(ModelStorage::Mapped { map, ptr: ptr.cast(), len })
    }

    /// The elements as a slice (also available through `Deref`).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        match self {
            ModelStorage::Owned(v) => v.as_slice(),
            // SAFETY: ptr/len were validated in-bounds and aligned at
            // construction, and the `map` Arc keeps the backing mapping
            // alive for the life of `self`.
            ModelStorage::Mapped { ptr, len, .. } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
        }
    }

    /// Mutable access, copying a mapped section to the heap first
    /// (copy-on-write). Mutators are all cold paths (evidence deltas,
    /// builder appends), so the copy happens at most once per section.
    pub fn to_mut(&mut self) -> &mut Vec<T>
    where
        T: Clone,
    {
        if let ModelStorage::Mapped { .. } = self {
            *self = ModelStorage::Owned(self.as_slice().to_vec());
        }
        match self {
            ModelStorage::Owned(v) => v,
            ModelStorage::Mapped { .. } => unreachable!("converted to Owned above"),
        }
    }

    /// True when this array borrows from a file mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(self, ModelStorage::Mapped { .. })
    }
}

impl<T> std::ops::Deref for ModelStorage<T> {
    type Target = [T];

    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T> From<Vec<T>> for ModelStorage<T> {
    fn from(v: Vec<T>) -> Self {
        ModelStorage::Owned(v)
    }
}

impl<T: Clone> Clone for ModelStorage<T> {
    fn clone(&self) -> Self {
        match self {
            ModelStorage::Owned(v) => ModelStorage::Owned(v.clone()),
            // Cloning a mapped section clones the view, not the data:
            // the Arc refcount keeps the mapping alive.
            ModelStorage::Mapped { map, ptr, len } => {
                ModelStorage::Mapped { map: map.clone(), ptr: *ptr, len: *len }
            }
        }
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for ModelStorage<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl<T: PartialEq> PartialEq for ModelStorage<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T> Default for ModelStorage<T> {
    fn default() -> Self {
        ModelStorage::Owned(Vec::new())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    #[test]
    fn owned_derefs_and_mutates() {
        let mut s: ModelStorage<u32> = vec![1, 2, 3].into();
        assert_eq!(&s[..], &[1, 2, 3]);
        assert!(!s.is_mapped());
        s.to_mut().push(4);
        assert_eq!(s.len(), 4);
        let c = s.clone();
        assert_eq!(c, s);
    }

    #[cfg(unix)]
    fn mapped_file(bytes: &[u8]) -> Arc<Mmap> {
        let path =
            std::env::temp_dir().join(format!(".rbp-storage-test-{}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.sync_all().unwrap();
        let f = std::fs::File::open(&path).unwrap();
        let m = Mmap::map_file(&f, bytes.len() as u64).unwrap();
        std::fs::remove_file(&path).ok();
        Arc::new(m)
    }

    #[cfg(unix)]
    #[test]
    fn mapped_section_reads_and_cows() {
        let mut bytes = Vec::new();
        for v in [7u32, 8, 9] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        bytes.extend_from_slice(&[0u8; 4]); // pad
        let map = mapped_file(&bytes);
        let mut s: ModelStorage<u32> = ModelStorage::from_mapped(map.clone(), 0, 3).unwrap();
        assert!(s.is_mapped());
        assert_eq!(&s[..], &[7, 8, 9]);
        let c = s.clone();
        assert!(c.is_mapped());
        // Copy-on-write leaves the clone untouched.
        s.to_mut()[0] = 100;
        assert!(!s.is_mapped());
        assert_eq!(s[0], 100);
        assert_eq!(c[0], 7);
    }

    #[cfg(unix)]
    #[test]
    fn mapped_section_rejects_out_of_bounds_and_unaligned() {
        let map = mapped_file(&[0u8; 16]);
        assert!(ModelStorage::<u32>::from_mapped(map.clone(), 0, 4).is_ok());
        assert!(ModelStorage::<u32>::from_mapped(map.clone(), 0, 5).is_err(), "too long");
        assert!(ModelStorage::<u32>::from_mapped(map.clone(), 17, 0).is_err(), "past end");
        assert!(ModelStorage::<u32>::from_mapped(map.clone(), 2, 1).is_err(), "unaligned");
        assert!(
            ModelStorage::<u64>::from_mapped(map, usize::MAX, usize::MAX).is_err(),
            "overflow"
        );
    }
}
