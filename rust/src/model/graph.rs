//! Undirected-graph construction and the CSR (compressed sparse row)
//! adjacency used by the MRF.
//!
//! BP works with *directed* edges (one message per direction), so the
//! builder assigns each undirected edge `{i, j}` two directed-edge ids and
//! records, for every adjacency slot, which directed edge points *into* the
//! node and which points *out*. All ids are `u32` (models up to ~4B edges,
//! far beyond what fits in RAM anyway) to halve index memory.

/// Builder: collect undirected edges, then freeze into a [`Csr`].
#[derive(Debug, Default, Clone)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(u32, u32)>,
}

impl GraphBuilder {
    /// Empty edge list over `n` nodes.
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "node count exceeds u32");
        Self { n, edges: Vec::new() }
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Number of undirected edges added so far.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Add undirected edge `{a, b}`. Self-loops and duplicate edges are
    /// rejected at freeze time (BP's update rule assumes simple graphs).
    pub fn add_edge(&mut self, a: usize, b: usize) {
        debug_assert!(a < self.n && b < self.n, "edge ({a},{b}) out of range");
        self.edges.push((a as u32, b as u32));
    }

    /// Freeze into CSR form. Panics on self-loops or duplicate edges.
    pub fn build(self) -> Csr {
        let n = self.n;
        let m = self.edges.len();
        let mut degree = vec![0u32; n];
        for &(a, b) in &self.edges {
            assert_ne!(a, b, "self-loop at node {a}");
            degree[a as usize] += 1;
            degree[b as usize] += 1;
        }
        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let total = offsets[n] as usize;
        debug_assert_eq!(total, 2 * m);

        // Directed edge ids: undirected edge k gets ids 2k (a→b) and 2k+1 (b→a).
        let mut adj_node = vec![0u32; total];
        let mut adj_out = vec![0u32; total]; // directed edge leaving the row node
        let mut adj_in = vec![0u32; total]; // directed edge entering the row node
        let mut cursor: Vec<u32> = offsets[..n].to_vec();
        for (k, &(a, b)) in self.edges.iter().enumerate() {
            let out_ab = (2 * k) as u32;
            let out_ba = (2 * k + 1) as u32;
            let ca = cursor[a as usize] as usize;
            adj_node[ca] = b;
            adj_out[ca] = out_ab;
            adj_in[ca] = out_ba;
            cursor[a as usize] += 1;
            let cb = cursor[b as usize] as usize;
            adj_node[cb] = a;
            adj_out[cb] = out_ba;
            adj_in[cb] = out_ab;
            cursor[b as usize] += 1;
        }

        // Per-directed-edge endpoints.
        let mut edge_src = vec![0u32; 2 * m];
        let mut edge_dst = vec![0u32; 2 * m];
        for (k, &(a, b)) in self.edges.iter().enumerate() {
            edge_src[2 * k] = a;
            edge_dst[2 * k] = b;
            edge_src[2 * k + 1] = b;
            edge_dst[2 * k + 1] = a;
        }

        let csr = Csr { offsets, adj_node, adj_out, adj_in, edge_src, edge_dst };
        csr.assert_simple();
        csr
    }
}

/// Frozen adjacency structure.
///
/// Directed edge ids: undirected edge `k` yields `2k` and `2k+1`, so the
/// reverse of directed edge `e` is always `e ^ 1` — used heavily in the
/// update rule (exclude the reverse message) with no extra lookup table.
#[derive(Debug, Clone)]
pub struct Csr {
    /// `offsets[i]..offsets[i+1]` indexes node i's adjacency slots.
    pub offsets: Vec<u32>,
    /// Neighbor node id per slot.
    pub adj_node: Vec<u32>,
    /// Directed edge id leaving the row node, per slot.
    pub adj_out: Vec<u32>,
    /// Directed edge id entering the row node, per slot.
    pub adj_in: Vec<u32>,
    /// Source node per directed edge.
    pub edge_src: Vec<u32>,
    /// Destination node per directed edge.
    pub edge_dst: Vec<u32>,
}

impl Csr {
    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of *directed* edges (= 2 × undirected).
    pub fn num_directed_edges(&self) -> usize {
        self.edge_src.len()
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// Adjacency slot range of node `i`.
    #[inline]
    pub fn slots(&self, i: usize) -> std::ops::Range<usize> {
        self.offsets[i] as usize..self.offsets[i + 1] as usize
    }

    /// Neighbors of node `i`.
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.adj_node[self.slots(i)]
    }

    /// Directed edges leaving `i` (one per neighbor, aligned with
    /// [`Csr::neighbors`]).
    pub fn out_edges(&self, i: usize) -> &[u32] {
        &self.adj_out[self.slots(i)]
    }

    /// Directed edges entering `i` (aligned with [`Csr::neighbors`]).
    pub fn in_edges(&self, i: usize) -> &[u32] {
        &self.adj_in[self.slots(i)]
    }

    /// Reverse of a directed edge (constant time by construction).
    #[inline]
    pub fn reverse(&self, e: u32) -> u32 {
        e ^ 1
    }

    /// BFS distances from `root` (u32::MAX = unreachable).
    pub fn bfs_distances(&self, root: usize) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.num_nodes()];
        let mut queue = std::collections::VecDeque::new();
        dist[root] = 0;
        queue.push_back(root as u32);
        while let Some(u) = queue.pop_front() {
            let d = dist[u as usize];
            for &v in self.neighbors(u as usize) {
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = d + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Check the graph is simple (no duplicate edges / self-loops).
    fn assert_simple(&self) {
        for i in 0..self.num_nodes() {
            let nbrs = self.neighbors(i);
            let mut sorted: Vec<u32> = nbrs.to_vec();
            sorted.sort_unstable();
            for w in sorted.windows(2) {
                assert_ne!(w[0], w[1], "duplicate edge at node {i}");
            }
            assert!(!nbrs.contains(&(i as u32)), "self-loop at node {i}");
        }
    }

    /// Sanity-check internal consistency (used by tests and debug builds).
    pub fn validate(&self) {
        let n = self.num_nodes();
        let me = self.num_directed_edges();
        assert_eq!(self.offsets[n] as usize, me);
        for i in 0..n {
            for s in self.slots(i) {
                let j = self.adj_node[s] as usize;
                let out = self.adj_out[s];
                let inn = self.adj_in[s];
                assert_eq!(self.edge_src[out as usize] as usize, i);
                assert_eq!(self.edge_dst[out as usize] as usize, j);
                assert_eq!(self.edge_src[inn as usize] as usize, j);
                assert_eq!(self.edge_dst[inn as usize] as usize, i);
                assert_eq!(self.reverse(out), inn);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Csr {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(0, 2);
        b.build()
    }

    #[test]
    fn csr_shape() {
        let g = triangle();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_directed_edges(), 6);
        for i in 0..3 {
            assert_eq!(g.degree(i), 2);
        }
        g.validate();
    }

    #[test]
    fn reverse_is_involution() {
        let g = triangle();
        for e in 0..g.num_directed_edges() as u32 {
            assert_eq!(g.reverse(g.reverse(e)), e);
            assert_ne!(g.reverse(e), e);
            assert_eq!(g.edge_src[e as usize], g.edge_dst[g.reverse(e) as usize]);
        }
    }

    #[test]
    fn neighbors_and_edges_aligned() {
        let g = triangle();
        for i in 0..3 {
            let nbrs = g.neighbors(i);
            let outs = g.out_edges(i);
            let ins = g.in_edges(i);
            assert_eq!(nbrs.len(), outs.len());
            for k in 0..nbrs.len() {
                assert_eq!(g.edge_dst[outs[k] as usize], nbrs[k]);
                assert_eq!(g.edge_src[ins[k] as usize], nbrs[k]);
                assert_eq!(g.edge_dst[ins[k] as usize] as usize, i);
            }
        }
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loop() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(1, 1);
        b.build();
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn rejects_duplicate() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1);
        b.add_edge(1, 0);
        b.build();
    }

    #[test]
    fn bfs_on_path() {
        let mut b = GraphBuilder::new(5);
        for i in 0..4 {
            b.add_edge(i, i + 1);
        }
        let g = b.build();
        let d = g.bfs_distances(0);
        assert_eq!(d, vec![0, 1, 2, 3, 4]);
        let d2 = g.bfs_distances(2);
        assert_eq!(d2, vec![2, 1, 0, 1, 2]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(4).build();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_directed_edges(), 0);
        for i in 0..4 {
            assert_eq!(g.degree(i), 0);
        }
        let d = g.bfs_distances(1);
        assert_eq!(d[0], u32::MAX);
        assert_eq!(d[1], 0);
    }
}
