//! Undirected-graph construction and the CSR (compressed sparse row)
//! adjacency used by the MRF.
//!
//! BP works with *directed* edges (one message per direction), so the
//! builder assigns each undirected edge `{i, j}` two directed-edge ids and
//! records, for every adjacency slot, which directed edge points *into* the
//! node and which points *out*. All ids are `u32` (models up to ~4B edges,
//! far beyond what fits in RAM anyway) to halve index memory.
//!
//! The builder **streams**: [`GraphBuilder::add_edge`] feeds degree
//! counters and the final per-directed-edge endpoint arrays directly, so
//! no intermediate `(a, b)` edge list is ever materialized — at 10⁸ edges
//! that list was the peak-memory blocker. Freezing
//! ([`GraphBuilder::build`]) is a counting sort whose cursor fill is
//! parallelized over contiguous edge chunks with per-thread degree
//! partials; because chunk `c`'s start cursor for node `v` is exactly the
//! sequential cursor value at the chunk boundary, the parallel fill writes
//! every adjacency slot to the same value as the sequential one — the
//! output is bit-identical for every thread count (pinned by the cold-path
//! equality suite).

use crate::coordinator::run_workers;
use crate::model::ModelStorage;
use crate::util::{cold_path_threads, DisjointWriter};

/// Builder: stream undirected edges into degree counters and endpoint
/// arrays, then freeze into a [`Csr`].
#[derive(Debug, Default, Clone)]
pub struct GraphBuilder {
    n: usize,
    /// Undirected degree per node, maintained incrementally by `add_edge`.
    degree: Vec<u32>,
    /// Source node per *directed* edge: undirected edge `k` contributes
    /// `edge_src[2k] = a` and `edge_src[2k+1] = b`. These become
    /// [`Csr::edge_src`] / [`Csr::edge_dst`] verbatim at freeze time.
    edge_src: Vec<u32>,
    /// Destination node per directed edge (see `edge_src`).
    edge_dst: Vec<u32>,
}

impl GraphBuilder {
    /// Empty edge list over `n` nodes.
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize, "node count exceeds u32");
        Self { n, degree: vec![0u32; n], edge_src: Vec::new(), edge_dst: Vec::new() }
    }

    /// [`GraphBuilder::new`] with capacity reserved for `edges` undirected
    /// edges — generators that know their edge count up front avoid the
    /// doubling-reallocation copies of the endpoint arrays.
    pub fn with_edge_capacity(n: usize, edges: usize) -> Self {
        let mut b = Self::new(n);
        b.edge_src.reserve(2 * edges);
        b.edge_dst.reserve(2 * edges);
        b
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Number of undirected edges added so far.
    pub fn num_edges(&self) -> usize {
        self.edge_src.len() / 2
    }

    /// Add undirected edge `{a, b}`. Self-loops are rejected immediately;
    /// duplicate edges at freeze time (BP's update rule assumes simple
    /// graphs).
    pub fn add_edge(&mut self, a: usize, b: usize) {
        debug_assert!(a < self.n && b < self.n, "edge ({a},{b}) out of range");
        assert_ne!(a, b, "self-loop at node {a}");
        self.degree[a] += 1;
        self.degree[b] += 1;
        let (a, b) = (a as u32, b as u32);
        self.edge_src.push(a);
        self.edge_src.push(b);
        self.edge_dst.push(b);
        self.edge_dst.push(a);
    }

    /// Freeze into CSR form with an automatic cold-path thread count.
    /// Panics on duplicate edges. The result is bit-identical for every
    /// thread count — see [`GraphBuilder::build_with_threads`].
    pub fn build(self) -> Csr {
        let threads = cold_path_threads(self.num_edges());
        self.build_with_threads(threads)
    }

    /// Freeze into CSR form using `threads` worker threads for the
    /// counting-sort cursor fill and the simplicity check.
    ///
    /// Determinism: node `v`'s adjacency slots are filled in global edge
    /// order regardless of `threads`. Each parallel chunk is a contiguous
    /// range of undirected edge ids, chunk `c`'s start cursor for `v` is
    /// `offsets[v] + Σ_{c' < c} count(c', v)` (per-thread degree
    /// partials), and within a chunk edges are processed in id order — so
    /// every slot receives exactly the value the sequential fill writes.
    pub fn build_with_threads(self, threads: usize) -> Csr {
        let GraphBuilder { n, degree, edge_src, edge_dst } = self;
        let me = edge_src.len();
        let m = me / 2;
        let threads = threads.clamp(1, m.max(1));

        let mut offsets = vec![0u32; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        drop(degree);
        debug_assert_eq!(offsets[n] as usize, me);

        // Directed edge ids: undirected edge k gets ids 2k (a→b), 2k+1 (b→a).
        let mut adj_node = vec![0u32; me];
        let mut adj_out = vec![0u32; me]; // directed edge leaving the row node
        let mut adj_in = vec![0u32; me]; // directed edge entering the row node

        if threads == 1 {
            // Sequential reference fill (the parallel path is pinned
            // bit-identical to this one).
            let mut cursor: Vec<u32> = offsets[..n].to_vec();
            for k in 0..m {
                let (a, b) = (edge_src[2 * k] as usize, edge_src[2 * k + 1] as usize);
                let out_ab = (2 * k) as u32;
                let out_ba = (2 * k + 1) as u32;
                let ca = cursor[a] as usize;
                adj_node[ca] = b as u32;
                adj_out[ca] = out_ab;
                adj_in[ca] = out_ba;
                cursor[a] += 1;
                let cb = cursor[b] as usize;
                adj_node[cb] = a as u32;
                adj_out[cb] = out_ba;
                adj_in[cb] = out_ab;
                cursor[b] += 1;
            }
        } else {
            let chunks: Vec<(usize, usize)> =
                (0..threads).map(|t| (t * m / threads, (t + 1) * m / threads)).collect();

            // Per-chunk slot counts (the per-thread degree partials).
            let partials: Vec<Vec<u32>> = run_workers(threads, |t| {
                let (k0, k1) = chunks[t];
                let mut cnt = vec![0u32; n];
                for k in k0..k1 {
                    cnt[edge_src[2 * k] as usize] += 1;
                    cnt[edge_src[2 * k + 1] as usize] += 1;
                }
                cnt
            });

            // Exclusive prefix over chunks turns partial counts into each
            // chunk's start cursors.
            let mut cursors = partials;
            let mut running: Vec<u32> = offsets[..n].to_vec();
            for cur in &mut cursors {
                for (v, c) in cur.iter_mut().enumerate() {
                    let count = *c;
                    *c = running[v];
                    running[v] += count;
                }
            }
            debug_assert_eq!(&running[..], &offsets[1..]);

            let w_node = DisjointWriter::new(&mut adj_node);
            let w_out = DisjointWriter::new(&mut adj_out);
            let w_in = DisjointWriter::new(&mut adj_in);
            std::thread::scope(|s| {
                for (t, mut cur) in cursors.into_iter().enumerate() {
                    let (k0, k1) = chunks[t];
                    let (w_node, w_out, w_in) = (&w_node, &w_out, &w_in);
                    let edge_src = &edge_src;
                    s.spawn(move || {
                        for k in k0..k1 {
                            let a = edge_src[2 * k] as usize;
                            let b = edge_src[2 * k + 1] as usize;
                            let out_ab = (2 * k) as u32;
                            let out_ba = (2 * k + 1) as u32;
                            // SAFETY: chunk-start cursors partition each
                            // node's slot range by chunk, and within a
                            // chunk each slot is taken once — every index
                            // is written by exactly one thread.
                            let ca = cur[a] as usize;
                            unsafe {
                                w_node.write(ca, b as u32);
                                w_out.write(ca, out_ab);
                                w_in.write(ca, out_ba);
                            }
                            cur[a] += 1;
                            let cb = cur[b] as usize;
                            unsafe {
                                w_node.write(cb, a as u32);
                                w_out.write(cb, out_ba);
                                w_in.write(cb, out_ab);
                            }
                            cur[b] += 1;
                        }
                    });
                }
            });
        }

        let csr = Csr {
            offsets: offsets.into(),
            adj_node: adj_node.into(),
            adj_out: adj_out.into(),
            adj_in: adj_in.into(),
            edge_src: edge_src.into(),
            edge_dst: edge_dst.into(),
        };
        csr.assert_simple(threads);
        csr
    }
}

/// Frozen adjacency structure.
///
/// Directed edge ids: undirected edge `k` yields `2k` and `2k+1`, so the
/// reverse of directed edge `e` is always `e ^ 1` — used heavily in the
/// update rule (exclude the reverse message) with no extra lookup table.
#[derive(Debug, Clone)]
pub struct Csr {
    /// `offsets[i]..offsets[i+1]` indexes node i's adjacency slots.
    pub offsets: ModelStorage<u32>,
    /// Neighbor node id per slot.
    pub adj_node: ModelStorage<u32>,
    /// Directed edge id leaving the row node, per slot.
    pub adj_out: ModelStorage<u32>,
    /// Directed edge id entering the row node, per slot.
    pub adj_in: ModelStorage<u32>,
    /// Source node per directed edge.
    pub edge_src: ModelStorage<u32>,
    /// Destination node per directed edge.
    pub edge_dst: ModelStorage<u32>,
}

impl Csr {
    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of *directed* edges (= 2 × undirected).
    pub fn num_directed_edges(&self) -> usize {
        self.edge_src.len()
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        (self.offsets[i + 1] - self.offsets[i]) as usize
    }

    /// Adjacency slot range of node `i`.
    #[inline]
    pub fn slots(&self, i: usize) -> std::ops::Range<usize> {
        self.offsets[i] as usize..self.offsets[i + 1] as usize
    }

    /// Neighbors of node `i`.
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.adj_node[self.slots(i)]
    }

    /// Directed edges leaving `i` (one per neighbor, aligned with
    /// [`Csr::neighbors`]).
    pub fn out_edges(&self, i: usize) -> &[u32] {
        &self.adj_out[self.slots(i)]
    }

    /// Directed edges entering `i` (aligned with [`Csr::neighbors`]).
    pub fn in_edges(&self, i: usize) -> &[u32] {
        &self.adj_in[self.slots(i)]
    }

    /// Reverse of a directed edge (constant time by construction).
    #[inline]
    pub fn reverse(&self, e: u32) -> u32 {
        e ^ 1
    }

    /// BFS distances from `root` (u32::MAX = unreachable).
    pub fn bfs_distances(&self, root: usize) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.num_nodes()];
        let mut queue = std::collections::VecDeque::new();
        dist[root] = 0;
        queue.push_back(root as u32);
        while let Some(u) = queue.pop_front() {
            let d = dist[u as usize];
            for &v in self.neighbors(u as usize) {
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = d + 1;
                    queue.push_back(v);
                }
            }
        }
        dist
    }

    /// Check nodes `lo..hi` for duplicate edges and self-loops (simple
    /// graph requirement). Returns the first violation as a message.
    pub(crate) fn check_simple(&self, lo: usize, hi: usize) -> Result<(), String> {
        let mut sorted: Vec<u32> = Vec::new();
        for i in lo..hi {
            let nbrs = self.neighbors(i);
            sorted.clear();
            sorted.extend_from_slice(nbrs);
            sorted.sort_unstable();
            for w in sorted.windows(2) {
                if w[0] == w[1] {
                    return Err(format!("duplicate edge at node {i}"));
                }
            }
            if nbrs.contains(&(i as u32)) {
                return Err(format!("self-loop at node {i}"));
            }
        }
        Ok(())
    }

    /// Check the slot/edge cross-references of nodes `lo..hi` (bounds
    /// included, so this is safe on untrusted data). Returns the first
    /// inconsistency as a message.
    pub(crate) fn check_consistent(&self, lo: usize, hi: usize) -> Result<(), String> {
        let n = self.num_nodes();
        let me = self.num_directed_edges();
        for i in lo..hi {
            for s in self.slots(i) {
                let j = self.adj_node[s] as usize;
                let out = self.adj_out[s] as usize;
                let inn = self.adj_in[s] as usize;
                if j >= n || out >= me || inn >= me || out ^ 1 != inn {
                    return Err(format!("corrupt adjacency slot {s} at node {i}"));
                }
                if self.edge_src[out] as usize != i
                    || self.edge_dst[out] as usize != j
                    || self.edge_src[inn] as usize != j
                    || self.edge_dst[inn] as usize != i
                {
                    return Err(format!("adjacency/endpoint mismatch at node {i} slot {s}"));
                }
            }
        }
        Ok(())
    }

    /// Panic unless the graph is simple, checking node ranges on `threads`
    /// worker threads (errors are collected and re-raised on the caller's
    /// thread so panic messages stay deterministic).
    fn assert_simple(&self, threads: usize) {
        let n = self.num_nodes();
        let threads = threads.clamp(1, n.max(1));
        let errors = run_workers(threads, |t| {
            self.check_simple(t * n / threads, (t + 1) * n / threads).err()
        });
        if let Some(msg) = errors.into_iter().flatten().next() {
            panic!("{msg}");
        }
    }

    /// Sanity-check internal consistency (used by tests and debug builds).
    pub fn validate(&self) {
        let n = self.num_nodes();
        let me = self.num_directed_edges();
        assert_eq!(self.offsets[n] as usize, me);
        if let Err(msg) = self.check_consistent(0, n) {
            panic!("{msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Csr {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(0, 2);
        b.build()
    }

    #[test]
    fn csr_shape() {
        let g = triangle();
        assert_eq!(g.num_nodes(), 3);
        assert_eq!(g.num_directed_edges(), 6);
        for i in 0..3 {
            assert_eq!(g.degree(i), 2);
        }
        g.validate();
    }

    #[test]
    fn reverse_is_involution() {
        let g = triangle();
        for e in 0..g.num_directed_edges() as u32 {
            assert_eq!(g.reverse(g.reverse(e)), e);
            assert_ne!(g.reverse(e), e);
            assert_eq!(g.edge_src[e as usize], g.edge_dst[g.reverse(e) as usize]);
        }
    }

    #[test]
    fn neighbors_and_edges_aligned() {
        let g = triangle();
        for i in 0..3 {
            let nbrs = g.neighbors(i);
            let outs = g.out_edges(i);
            let ins = g.in_edges(i);
            assert_eq!(nbrs.len(), outs.len());
            for k in 0..nbrs.len() {
                assert_eq!(g.edge_dst[outs[k] as usize], nbrs[k]);
                assert_eq!(g.edge_src[ins[k] as usize], nbrs[k]);
                assert_eq!(g.edge_dst[ins[k] as usize] as usize, i);
            }
        }
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn rejects_self_loop() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(1, 1);
        b.build();
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn rejects_duplicate() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1);
        b.add_edge(1, 0);
        b.build();
    }

    #[test]
    #[should_panic(expected = "duplicate edge")]
    fn rejects_duplicate_parallel() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(1, 0);
        b.build_with_threads(2);
    }

    #[test]
    fn bfs_on_path() {
        let mut b = GraphBuilder::new(5);
        for i in 0..4 {
            b.add_edge(i, i + 1);
        }
        let g = b.build();
        let d = g.bfs_distances(0);
        assert_eq!(d, vec![0, 1, 2, 3, 4]);
        let d2 = g.bfs_distances(2);
        assert_eq!(d2, vec![2, 1, 0, 1, 2]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(4).build();
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_directed_edges(), 0);
        for i in 0..4 {
            assert_eq!(g.degree(i), 0);
        }
        let d = g.bfs_distances(1);
        assert_eq!(d[0], u32::MAX);
        assert_eq!(d[1], 0);
    }

    /// A messy multi-hub graph whose adjacency fill order actually
    /// exercises the chunk-cursor math (hubs receive slots from many
    /// chunks).
    fn hub_builder() -> GraphBuilder {
        let n = 97;
        let mut b = GraphBuilder::with_edge_capacity(n, 4 * n);
        for i in 1..n {
            b.add_edge(0, i); // hub 0 touches every chunk
            if i + 7 < n {
                b.add_edge(i, i + 7);
            }
            if i % 3 == 0 && i + 1 < n {
                b.add_edge(i, i + 1);
            }
        }
        b
    }

    #[test]
    fn parallel_build_is_bit_identical() {
        let reference = hub_builder().build_with_threads(1);
        for threads in [2, 3, 8, 16] {
            let par = hub_builder().build_with_threads(threads);
            assert_eq!(par.offsets, reference.offsets, "threads={threads}");
            assert_eq!(par.adj_node, reference.adj_node, "threads={threads}");
            assert_eq!(par.adj_out, reference.adj_out, "threads={threads}");
            assert_eq!(par.adj_in, reference.adj_in, "threads={threads}");
            assert_eq!(par.edge_src, reference.edge_src, "threads={threads}");
            assert_eq!(par.edge_dst, reference.edge_dst, "threads={threads}");
            par.validate();
        }
    }

    #[test]
    fn builder_counts_edges_incrementally() {
        let mut b = GraphBuilder::new(4);
        assert_eq!(b.num_edges(), 0);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        assert_eq!(b.num_edges(), 2);
        assert_eq!(b.num_nodes(), 4);
    }
}
