//! Model generators for the paper's benchmark families (§5.2) plus the
//! Lemma-2 analytical instances and the large-scale locality workloads.
//!
//! Every generator is deterministic in `(spec, seed)` — all randomness
//! flows through [`Xoshiro256`] — so sweeps can rebuild the identical
//! instance per algorithm and thread count:
//!
//! - **tree / path / adversarial_tree**: binary trees with root prior
//!   `(0.1, 0.9)`, uniform priors elsewhere, and *equality* edge factors —
//!   information flows only away from the root, making useful-update
//!   counts analytically checkable (§4);
//! - **uniform_tree**: the Lemma-2 good case — full `arity`-ary tree with
//!   one shared non-deterministic mixing factor;
//! - **ising / potts**: `n×n` grids with random fields and couplings
//!   (α,β ~ U[-1,1] for Ising, U[-2.5,2.5] for the 3-state Potts model);
//! - **ldpc**: the flagship application — a (3,6)-regular LDPC decoding
//!   MRF (see [`ldpc`]);
//! - **powerlaw**: preferential-attachment spin glass — the large-scale
//!   locality workload (size it to millions of nodes via config, e.g.
//!   `powerlaw:1000000`; an `ising:1000` grid is the matching million-node
//!   grid workload). Hub-dominated topology breaks the grid's id-order
//!   locality, which is exactly what the partition axis
//!   ([`crate::model::partition`]) is measured against.

use super::{FactorPool, GraphBuilder, Mrf, NodeFactors};
use crate::configio::ModelSpec;
use crate::util::Xoshiro256;

/// Build the MRF described by `spec`, deterministically in `(spec, seed)`.
pub fn build(spec: &ModelSpec, seed: u64) -> Mrf {
    match *spec {
        ModelSpec::Tree { n } => binary_tree(n),
        ModelSpec::Path { n } => path(n),
        ModelSpec::AdversarialTree { n } => adversarial_tree(n),
        ModelSpec::UniformTree { n, arity } => uniform_tree(n, arity),
        ModelSpec::Ising { n } => ising(n, seed),
        ModelSpec::Potts { n, q } => potts(n, q, seed),
        ModelSpec::Ldpc { n, flip_prob } => ldpc::build(n, flip_prob, seed).mrf,
        ModelSpec::PowerLaw { n, m } => powerlaw(n, m, seed),
    }
}

/// Assemble a binary-domain tree MRF from an edge sequence oriented away
/// from the root: node 0 carries the `(0.1, 0.9)` root prior, every other
/// node is uniform, and all edges share one factor matrix. Edges stream
/// straight into the builder — no intermediate edge list is materialized.
fn evidence_tree(
    name: &str,
    n: usize,
    edges: impl IntoIterator<Item = (usize, usize)>,
    factor: [f64; 4],
) -> Mrf {
    let mut gb = GraphBuilder::with_edge_capacity(n, n.saturating_sub(1));
    let mut pool = FactorPool::new();
    let f = pool.add(2, 2, &factor);
    for (a, b) in edges {
        gb.add_edge(a, b);
    }
    let edge_idx = vec![f; gb.num_edges()];
    let mut priors = vec![vec![0.5, 0.5]; n];
    if n > 0 {
        priors[0] = vec![0.1, 0.9];
    }
    Mrf::assemble(
        name,
        gb.build(),
        vec![2; n],
        NodeFactors::from_vecs(&priors),
        edge_idx,
        pool,
    )
}

/// Deterministic equality factor (the §4/§5.2 tree instances).
const EQUALITY: [f64; 4] = [1.0, 0.0, 0.0, 1.0];

/// Full binary tree with `n` vertices: node `i`'s children are `2i+1` and
/// `2i+2`; edges oriented parent→child.
fn binary_tree(n: usize) -> Mrf {
    let edges = (0..n).flat_map(|i| {
        [2 * i + 1, 2 * i + 2].into_iter().filter(move |&c| c < n).map(move |c| (i, c))
    });
    evidence_tree("tree", n, edges, EQUALITY)
}

/// Path graph rooted at node 0 (the Lemma-2 bad case).
fn path(n: usize) -> Mrf {
    let edges = (0..n.saturating_sub(1)).map(|i| (i, i + 1));
    evidence_tree("path", n, edges, EQUALITY)
}

/// Lemma-2 adversarial tree (paper Figure 3): a main path of `⌈√n⌉` nodes
/// with side paths hanging off every main-path node, consuming the
/// remaining vertices as evenly as possible.
fn adversarial_tree(n: usize) -> Mrf {
    let mut edges = Vec::with_capacity(n.saturating_sub(1));
    if n > 1 {
        let m = (n as f64).sqrt().ceil() as usize;
        let m = m.clamp(2, n);
        for i in 0..m - 1 {
            edges.push((i, i + 1));
        }
        // Side paths off main nodes 1..m, round-robin lengths.
        let rest = n - m;
        let anchors = m - 1;
        let mut next = m;
        for j in 0..anchors {
            let len = rest / anchors + usize::from(j < rest % anchors);
            let mut prev = j + 1;
            for _ in 0..len {
                edges.push((prev, next));
                prev = next;
                next += 1;
            }
        }
        debug_assert_eq!(next, n);
    }
    evidence_tree("adversarial_tree", n, edges, EQUALITY)
}

/// Lemma-2 good case: full `arity`-ary tree with one shared
/// non-deterministic mixing factor, so information flows from the root
/// with uniform geometric expansion.
fn uniform_tree(n: usize, arity: usize) -> Mrf {
    let arity = arity.max(1);
    let edges = (0..n).flat_map(move |i| {
        (1..=arity).map(move |k| arity * i + k).filter(move |&c| c < n).map(move |c| (i, c))
    });
    evidence_tree("uniform_tree", n, edges, [0.9, 0.1, 0.1, 0.9])
}

/// Binary spin-glass factors for one node/edge sample:
/// `ψ_i = (e^{-α}, e^{α})`, `ψ_ij = [[e^β, e^{-β}], [e^{-β}, e^β]]`.
fn spin_prior(alpha: f64) -> Vec<f64> {
    vec![(-alpha).exp(), alpha.exp()]
}

fn spin_coupling(beta: f64) -> [f64; 4] {
    let (p, m) = (beta.exp(), (-beta).exp());
    [p, m, m, p]
}

/// Ising model on an `n×n` grid, α,β ~ U[-1,1] (paper §5.2). Node
/// `(r, c)` has id `r·n + c`; edges run right and down, so contiguous id
/// blocks are row blocks — the layout the contiguous partitioner exploits.
fn ising(n: usize, seed: u64) -> Mrf {
    grid_spin_glass("ising", n, seed, 1.0)
}

fn grid_spin_glass(name: &str, n: usize, seed: u64, amp: f64) -> Mrf {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let nodes = n * n;
    let priors: Vec<Vec<f64>> =
        (0..nodes).map(|_| spin_prior(rng.uniform(-amp, amp))).collect();
    let grid_edges = 2 * n * n.saturating_sub(1);
    let mut gb = GraphBuilder::with_edge_capacity(nodes, grid_edges);
    let mut pool = FactorPool::new();
    let mut edge_idx = Vec::with_capacity(grid_edges);
    for r in 0..n {
        for c in 0..n {
            let i = r * n + c;
            if c + 1 < n {
                gb.add_edge(i, i + 1);
                edge_idx.push(pool.add(2, 2, &spin_coupling(rng.uniform(-amp, amp))));
            }
            if r + 1 < n {
                gb.add_edge(i, i + n);
                edge_idx.push(pool.add(2, 2, &spin_coupling(rng.uniform(-amp, amp))));
            }
        }
    }
    Mrf::assemble(
        name,
        gb.build(),
        vec![2; nodes],
        NodeFactors::from_vecs(&priors),
        edge_idx,
        pool,
    )
}

/// `q`-state Potts-style model on an `n×n` grid, α,β ~ U[-2.5,2.5] (paper
/// §5.2 uses q = 3): per-state random fields, diagonal (same-state)
/// couplings `e^β`. `q` up to [`MAX_DOMAIN`](crate::model::MAX_DOMAIN) —
/// the wide settings (`potts:n:32`) exercise the SIMD update kernels on
/// dense q×q matvecs, a workload shape LDPC's sparse indicator factors
/// don't cover.
fn potts(n: usize, q: usize, seed: u64) -> Mrf {
    assert!(
        (2..=crate::model::MAX_DOMAIN).contains(&q),
        "potts state count q={q} out of range 2..={}",
        crate::model::MAX_DOMAIN
    );
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let nodes = n * n;
    let priors: Vec<Vec<f64>> = (0..nodes)
        .map(|_| (0..q).map(|_| rng.uniform(-2.5, 2.5).exp()).collect())
        .collect();
    let grid_edges = 2 * n * n.saturating_sub(1);
    let mut gb = GraphBuilder::with_edge_capacity(nodes, grid_edges);
    let mut pool = FactorPool::new();
    let mut edge_idx = Vec::with_capacity(grid_edges);
    let coupling = |rng: &mut Xoshiro256, pool: &mut FactorPool| {
        let b = rng.uniform(-2.5f64, 2.5).exp();
        let mut m = vec![1.0f64; q * q];
        for x in 0..q {
            m[x * q + x] = b;
        }
        pool.add(q, q, &m)
    };
    for r in 0..n {
        for c in 0..n {
            let i = r * n + c;
            if c + 1 < n {
                gb.add_edge(i, i + 1);
                edge_idx.push(coupling(&mut rng, &mut pool));
            }
            if r + 1 < n {
                gb.add_edge(i, i + n);
                edge_idx.push(coupling(&mut rng, &mut pool));
            }
        }
    }
    Mrf::assemble(
        "potts",
        gb.build(),
        vec![q as u32; nodes],
        NodeFactors::from_vecs(&priors),
        edge_idx,
        pool,
    )
}

/// Preferential-attachment (power-law) spin glass: node `t` attaches
/// `min(m, t)` edges to distinct earlier nodes, chosen by degree-biased
/// sampling (an endpoint of a random existing edge) mixed 50/50 with
/// uniform sampling so early graphs stay connected. α,β ~ U[-1,1].
fn powerlaw(n: usize, m: usize, seed: u64) -> Mrf {
    let m = m.max(1);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut gb = GraphBuilder::with_edge_capacity(n, n.saturating_mul(m));
    // One endpoint entry per edge side: sampling uniformly from this list
    // is degree-proportional sampling.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m);
    let mut chosen: Vec<usize> = Vec::with_capacity(m);
    for t in 1..n {
        chosen.clear();
        let want = m.min(t);
        let mut attempts = 0;
        while chosen.len() < want && attempts < 64 * want {
            attempts += 1;
            let cand = if endpoints.is_empty() || rng.bernoulli(0.5) {
                rng.index(t)
            } else {
                endpoints[rng.index(endpoints.len())] as usize
            };
            if !chosen.contains(&cand) {
                chosen.push(cand);
            }
        }
        for &c in &chosen {
            gb.add_edge(c, t);
            endpoints.push(c as u32);
            endpoints.push(t as u32);
        }
    }
    let num_edges = gb.num_edges();
    // The attachment list has served its purpose; free it before the
    // prior/coupling tables are built (it is 8 bytes per edge — real
    // memory at 10⁸ edges). Dropping consumes no RNG draws, so the
    // random stream — and therefore every generated instance — is
    // unchanged.
    drop(endpoints);
    let priors: Vec<Vec<f64>> = (0..n).map(|_| spin_prior(rng.uniform(-1.0, 1.0))).collect();
    let mut pool = FactorPool::new();
    let mut edge_idx = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        edge_idx.push(pool.add(2, 2, &spin_coupling(rng.uniform(-1.0, 1.0))));
    }
    Mrf::assemble(
        "powerlaw",
        gb.build(),
        vec![2; n],
        NodeFactors::from_vecs(&priors),
        edge_idx,
        pool,
    )
}

/// (3,6)-regular LDPC decoding instances (paper §5.2).
///
/// The pairwise-MRF encoding: each of the `n` variable nodes is binary;
/// each of the `n/2` constraint nodes has domain `2^6 = 64`, one state per
/// joint assignment of its six incident bits. The edge factor at bit
/// position `k` is the 2×64 indicator `bit_k(s) = x`, and the constraint's
/// node potential is the even-parity indicator — so the joint puts mass
/// exactly on codewords, weighted by the BSC channel evidence.
pub mod ldpc {
    use super::*;

    /// Bits per (3,6) constraint — fixed by the constraint domain `2^6`.
    const CHECK_DEG: usize = 6;
    /// Edges per variable node.
    const VAR_DEG: usize = 3;

    /// One decoding instance: the MRF plus the channel ground truth.
    pub struct Instance {
        /// The decoding MRF (variables first, then constraint nodes).
        pub mrf: Mrf,
        /// Number of variable nodes (`decode_bits(.., num_vars)` recovers
        /// the codeword estimate).
        pub num_vars: usize,
        /// The transmitted codeword (all zeros — always valid).
        pub sent: Vec<u8>,
        /// The received word after the binary symmetric channel.
        pub received: Vec<u8>,
    }

    /// Build a (3,6)-LDPC decoding instance with `n` variable nodes
    /// (`n` must be even and ≥ 6, so each variable can reach three
    /// distinct constraints), BSC flip probability `flip_prob`.
    ///
    /// The bipartite graph is a random socket matching, re-drawn until it
    /// is simple (no variable touches a constraint twice) — a couple of
    /// attempts suffice even for tiny instances.
    pub fn build(n: usize, flip_prob: f64, seed: u64) -> Instance {
        assert!(n >= 6 && n % 2 == 0, "(3,6)-LDPC needs an even n >= 6, got {n}");
        let checks = n / 2;
        let mut rng = Xoshiro256::seed_from_u64(seed);

        // Socket matching: each constraint owns CHECK_DEG sockets; shuffle
        // and deal VAR_DEG to each variable, retrying until simple.
        let mut sockets: Vec<u32> = Vec::with_capacity(checks * CHECK_DEG);
        for c in 0..checks as u32 {
            for _ in 0..CHECK_DEG {
                sockets.push(c);
            }
        }
        let assignment = loop {
            rng.shuffle(&mut sockets);
            let simple = sockets.chunks(VAR_DEG).all(|chunk| {
                chunk[0] != chunk[1] && chunk[0] != chunk[2] && chunk[1] != chunk[2]
            });
            if simple {
                break sockets.clone();
            }
        };

        // Channel: all-zeros codeword through BSC(flip_prob).
        let sent = vec![0u8; n];
        let received: Vec<u8> = (0..n).map(|_| u8::from(rng.bernoulli(flip_prob))).collect();

        // Graph + factors. Edge insertion order fixes each edge's bit
        // position within its constraint.
        let nodes = n + checks;
        let mut gb = GraphBuilder::with_edge_capacity(nodes, n * VAR_DEG);
        let mut pool = FactorPool::new();
        // Six shared bit-position indicator matrices ψ_k(x, s) = [bit_k(s) = x].
        let bit_factor: Vec<u32> = (0..CHECK_DEG)
            .map(|k| {
                let mut m = vec![0.0f64; 2 * 64];
                for s in 0..64usize {
                    let bit = (s >> k) & 1;
                    m[bit * 64 + s] = 1.0;
                }
                pool.add(2, 64, &m)
            })
            .collect();
        let mut edge_idx = Vec::with_capacity(n * VAR_DEG);
        let mut check_fill = vec![0usize; checks];
        for v in 0..n {
            for &c in &assignment[v * VAR_DEG..(v + 1) * VAR_DEG] {
                let c = c as usize;
                let k = check_fill[c];
                check_fill[c] += 1;
                debug_assert!(k < CHECK_DEG);
                gb.add_edge(v, n + c);
                edge_idx.push(bit_factor[k]);
            }
        }
        debug_assert!(check_fill.iter().all(|&f| f == CHECK_DEG));

        // Node potentials: channel evidence for variables, even-parity
        // indicator for constraints.
        let parity: Vec<f64> = (0..64u32)
            .map(|s| if s.count_ones() % 2 == 0 { 1.0 } else { 0.0 })
            .collect();
        let mut priors: Vec<Vec<f64>> = Vec::with_capacity(nodes);
        for &y in &received {
            priors.push(if y == 0 {
                vec![1.0 - flip_prob, flip_prob]
            } else {
                vec![flip_prob, 1.0 - flip_prob]
            });
        }
        for _ in 0..checks {
            priors.push(parity.clone());
        }

        let mut domain = vec![2u32; n];
        domain.resize(n + checks, 64u32);

        let mrf = Mrf::assemble(
            "ldpc",
            gb.build(),
            domain,
            NodeFactors::from_vecs(&priors),
            edge_idx,
            pool,
        );
        Instance { mrf, num_vars: n, sent, received }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_shapes() {
        let m = build(&ModelSpec::Tree { n: 7 }, 1);
        assert_eq!(m.num_nodes(), 7);
        assert_eq!(m.num_messages(), 12);
        assert!(m.all_binary());
        assert_eq!(m.node_factors.of(0), &[0.1, 0.9]);
        assert_eq!(m.node_factors.of(3), &[0.5, 0.5]);
        // Even directed edges point away from the root.
        for k in 0..m.num_messages() / 2 {
            let e = 2 * k;
            assert!(m.graph.edge_src[e] < m.graph.edge_dst[e]);
        }
        m.graph.validate();
    }

    #[test]
    fn path_is_a_chain() {
        let m = build(&ModelSpec::Path { n: 5 }, 1);
        assert_eq!(m.num_messages(), 8);
        assert_eq!(m.graph.degree(0), 1);
        assert_eq!(m.graph.degree(2), 2);
    }

    #[test]
    fn adversarial_tree_is_a_tree_of_n_nodes() {
        for n in [4, 9, 16, 100, 101] {
            let m = build(&ModelSpec::AdversarialTree { n }, 1);
            assert_eq!(m.num_nodes(), n);
            assert_eq!(m.num_messages(), 2 * (n - 1), "n={n}: must be a tree");
            m.graph.validate();
            // Connected: BFS from the root reaches everything.
            let d = m.graph.bfs_distances(0);
            assert!(d.iter().all(|&x| x != u32::MAX), "n={n}: connected");
        }
    }

    #[test]
    fn uniform_tree_arity() {
        let m = build(&ModelSpec::UniformTree { n: 13, arity: 3 }, 1);
        assert_eq!(m.num_messages(), 24);
        assert_eq!(m.graph.degree(0), 3);
    }

    #[test]
    fn ising_grid_shape_and_determinism() {
        let a = build(&ModelSpec::Ising { n: 4 }, 7);
        assert_eq!(a.num_nodes(), 16);
        assert_eq!(a.num_messages(), 2 * 2 * 4 * 3); // 2·|E|, |E| = 2·4·3
        assert!(a.all_binary());
        let b = build(&ModelSpec::Ising { n: 4 }, 7);
        assert_eq!(a.node_factors.of(5), b.node_factors.of(5));
        let c = build(&ModelSpec::Ising { n: 4 }, 8);
        assert_ne!(a.node_factors.of(5), c.node_factors.of(5));
    }

    #[test]
    fn potts_is_three_state() {
        let m = build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        assert_eq!(m.max_domain(), 3);
        assert!(!m.all_binary());
        assert_eq!(m.num_messages(), 2 * 12);
    }

    #[test]
    fn potts_wide_domain() {
        let m = build(&ModelSpec::Potts { n: 3, q: 32 }, 2);
        assert_eq!(m.max_domain(), 32);
        assert_eq!(m.num_messages(), 2 * 12);
        // Diagonal coupling structure survives the generalization.
        let f = m.edge_factor[0];
        let mat = m.pool.matrix(f.pool_index());
        assert_eq!(mat.len(), 32 * 32);
        assert_eq!(mat[1], 1.0, "off-diagonal is 1");
        assert_ne!(mat[0], 1.0, "diagonal carries e^beta");
        // Deterministic in (spec, seed).
        let m2 = build(&ModelSpec::Potts { n: 3, q: 32 }, 2);
        assert_eq!(m.node_factors.of(4), m2.node_factors.of(4));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn potts_q_above_max_domain_panics() {
        build(&ModelSpec::Potts { n: 3, q: 65 }, 1);
    }

    #[test]
    fn powerlaw_shape_and_determinism() {
        let m = build(&ModelSpec::PowerLaw { n: 200, m: 2 }, 3);
        assert_eq!(m.num_nodes(), 200);
        // Every node past the first attaches at least one edge.
        assert!(m.num_messages() / 2 >= 199);
        m.graph.validate();
        // Hubs exist: max degree well above the attachment constant.
        let max_deg = (0..200).map(|i| m.graph.degree(i)).max().unwrap();
        assert!(max_deg >= 6, "max degree {max_deg}");
        let m2 = build(&ModelSpec::PowerLaw { n: 200, m: 2 }, 3);
        assert_eq!(m.num_messages(), m2.num_messages());
    }

    #[test]
    fn ldpc_instance_is_36_regular() {
        let inst = ldpc::build(24, 0.07, 1);
        let m = &inst.mrf;
        assert_eq!(inst.num_vars, 24);
        assert_eq!(m.num_nodes(), 24 + 12);
        for v in 0..24 {
            assert_eq!(m.graph.degree(v), 3, "variable degree");
            assert_eq!(m.domain[v], 2);
        }
        for c in 24..36 {
            assert_eq!(m.graph.degree(c), 6, "constraint degree");
            assert_eq!(m.domain[c], 64);
        }
        assert_eq!(inst.sent, vec![0u8; 24]);
        assert_eq!(inst.received.len(), 24);
    }

    #[test]
    fn ldpc_tiny_instances_build() {
        // The socket-matching retry loop must terminate even at the
        // smallest size (every variable must hit all 3 constraints).
        for seed in 0..5 {
            let inst = ldpc::build(6, 0.07, seed);
            inst.mrf.graph.validate();
        }
    }

    #[test]
    fn ldpc_flip_rate_tracks_channel() {
        let inst = ldpc::build(10_000, 0.07, 42);
        let flips: usize = inst.received.iter().map(|&b| b as usize).sum();
        let rate = flips as f64 / 10_000.0;
        assert!((rate - 0.07).abs() < 0.02, "rate={rate}");
    }
}
