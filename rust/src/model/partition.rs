//! Deterministic task partitioning — the locality layer.
//!
//! A [`Partition`] groups a task universe (`0..num_tasks`: directed-edge
//! ids for the message engines, node ids for splash) into `k` shards. It
//! is consumed in three places:
//!
//! - [`crate::bp::Messages::uniform_partitioned`] lays each shard's
//!   message vectors out in its own cache-line-aligned arena, so a worker
//!   that stays on its shard walks hot, contiguous memory;
//! - the shard-affine [`crate::sched::Multiqueue`] routes inserts and pops
//!   to the queues owned by the task's shard (with a configurable spill
//!   probability);
//! - [`crate::exec::WorkerPool`] assigns each worker a home shard and
//!   threads the partition through [`crate::exec::ExecCtx`] so policy
//!   seeding and requeues land shard-local.
//!
//! Two deterministic modes (no RNG — the same model always partitions the
//! same way):
//!
//! - **contiguous**: shard `s` owns the id block `[s·n/k, (s+1)·n/k)`.
//!   Matches the flat layouts the builders already emit (grids are
//!   row-major, trees level-ish), and costs O(n).
//! - **BFS-clustered**: order nodes by multi-source BFS from node 0
//!   (restarting on each unvisited component), order edge tasks by the
//!   BFS rank of their *source* node, then cut the order into `k` equal
//!   blocks. Neighboring tasks land in the same shard even when the
//!   builder's id order is not locality-friendly.
//!
//! Every constructor validates the result against the graph it was built
//! from: shard ranges tile `0..num_tasks` and each task belongs to exactly
//! one shard (see [`Partition::validate`]).

use super::graph::Csr;
use super::Mrf;
use crate::configio::{PartitionSpec, RunConfig};

/// A frozen assignment of tasks to shards.
///
/// Stores both directions of the mapping: `task → shard` for O(1) routing
/// on the hot path, and `shard → tasks` (a permutation of `0..num_tasks`
/// grouped by shard, plus offsets) for arena layout and sweeps.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Shard of each task.
    task_shard: Vec<u32>,
    /// Shard `s` owns `tasks_in_order[shard_offsets[s]..shard_offsets[s+1]]`.
    shard_offsets: Vec<u32>,
    /// Permutation of `0..num_tasks`, grouped by shard (contiguous mode:
    /// the identity).
    tasks_in_order: Vec<u32>,
}

impl Partition {
    /// Build from an explicit task order: the first `n/k` ordered tasks go
    /// to shard 0, and so on. `order` must be a permutation of
    /// `0..num_tasks`.
    fn from_order(order: Vec<u32>, shards: usize) -> Partition {
        let n = order.len();
        let k = shards.max(1).min(n.max(1));
        let mut shard_offsets = Vec::with_capacity(k + 1);
        for s in 0..=k {
            shard_offsets.push((s * n / k) as u32);
        }
        let mut task_shard = vec![0u32; n];
        for s in 0..k {
            for i in shard_offsets[s] as usize..shard_offsets[s + 1] as usize {
                task_shard[order[i] as usize] = s as u32;
            }
        }
        let p = Partition { task_shard, shard_offsets, tasks_in_order: order };
        p.validate();
        p
    }

    /// Contiguous id blocks: shard `s` owns `[s·n/k, (s+1)·n/k)`. The
    /// shard count is clamped to `max(1, min(shards, num_tasks))` so every
    /// shard is nonempty.
    pub fn contiguous(num_tasks: usize, shards: usize) -> Partition {
        Self::from_order((0..num_tasks as u32).collect(), shards)
    }

    /// BFS-clustered partition of the **directed-edge** task universe of
    /// `graph` (`num_tasks = graph.num_directed_edges()`): edges sorted by
    /// the BFS rank of their source node (stable on edge id), then cut
    /// into `shards` blocks.
    pub fn bfs_edges(graph: &Csr, shards: usize) -> Partition {
        let rank = bfs_rank(graph);
        let mut order: Vec<u32> = (0..graph.num_directed_edges() as u32).collect();
        order.sort_by_key(|&e| (rank[graph.edge_src[e as usize] as usize], e));
        Self::from_order(order, shards)
    }

    /// BFS-clustered partition of the **node** task universe of `graph`
    /// (`num_tasks = graph.num_nodes()`).
    pub fn bfs_nodes(graph: &Csr, shards: usize) -> Partition {
        let rank = bfs_rank(graph);
        let mut order: Vec<u32> = (0..graph.num_nodes() as u32).collect();
        order.sort_by_key(|&v| rank[v as usize]);
        Self::from_order(order, shards)
    }

    /// Number of tasks partitioned.
    pub fn num_tasks(&self) -> usize {
        self.task_shard.len()
    }

    /// Number of shards (each nonempty, except for the empty universe).
    pub fn num_shards(&self) -> usize {
        self.shard_offsets.len() - 1
    }

    /// Shard owning `task`.
    #[inline]
    pub fn shard_of(&self, task: u32) -> u32 {
        self.task_shard[task as usize]
    }

    /// The tasks owned by `shard`, in layout order.
    pub fn tasks_of(&self, shard: usize) -> &[u32] {
        let lo = self.shard_offsets[shard] as usize;
        let hi = self.shard_offsets[shard + 1] as usize;
        &self.tasks_in_order[lo..hi]
    }

    /// Check the structural invariants: shard ranges tile `0..num_tasks`,
    /// the grouped order is a permutation, and the two mapping directions
    /// agree. Panics on violation (constructors call this; tests call it
    /// on every generated instance).
    pub fn validate(&self) {
        let n = self.num_tasks();
        let k = self.num_shards();
        assert_eq!(self.tasks_in_order.len(), n, "order must cover every task");
        assert_eq!(self.shard_offsets[0], 0);
        assert_eq!(self.shard_offsets[k] as usize, n, "shard ranges must tile 0..num_tasks");
        let mut seen = vec![false; n];
        for s in 0..k {
            assert!(
                self.shard_offsets[s] <= self.shard_offsets[s + 1],
                "shard offsets must be monotone"
            );
            for &t in self.tasks_of(s) {
                assert!(!seen[t as usize], "task {t} appears in more than one shard");
                seen[t as usize] = true;
                assert_eq!(self.task_shard[t as usize], s as u32, "task {t} mapping mismatch");
            }
        }
        assert!(seen.iter().all(|&s| s), "every task must land in exactly one shard");
    }

    /// Validate this partition against the graph universe it should cover:
    /// `num_tasks` must equal the directed-edge count (message engines) or
    /// the node count (splash engines) of `graph`.
    pub fn validate_against(&self, graph: &Csr) {
        let n = self.num_tasks();
        assert!(
            n == graph.num_directed_edges() || n == graph.num_nodes(),
            "partition over {n} tasks matches neither the {} directed edges nor the {} nodes",
            graph.num_directed_edges(),
            graph.num_nodes()
        );
    }
}

/// BFS visit rank of every node, multi-source from node 0 with restarts on
/// unvisited components — total over all nodes, deterministic.
fn bfs_rank(graph: &Csr) -> Vec<u32> {
    let n = graph.num_nodes();
    let mut rank = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for root in 0..n {
        if rank[root] != u32::MAX {
            continue;
        }
        rank[root] = next;
        next += 1;
        queue.push_back(root as u32);
        while let Some(u) = queue.pop_front() {
            for &v in graph.neighbors(u as usize) {
                if rank[v as usize] == u32::MAX {
                    rank[v as usize] = next;
                    next += 1;
                    queue.push_back(v);
                }
            }
        }
    }
    rank
}

/// The partition of `mrf`'s **message** task universe described by
/// `cfg.partition` (`None` when the axis is off). This is what the
/// message-task engines (residual family, priority, batched, optimal
/// tree) attach to the pool, and what sharded [`crate::bp::Messages`]
/// arenas are laid out by.
///
/// Construction is deterministic in `(mrf, cfg)`, so the arena layout
/// (resolved by `run::run_on_model_observed`) and the scheduler routing
/// (resolved again inside the engine) always agree. The duplicate
/// resolution is a deliberate tradeoff: it keeps `Engine::run`'s
/// signature partition-free, at the cost of one extra O(E log E) pass at
/// startup for the BFS mode.
pub fn for_messages(mrf: &Mrf, cfg: &RunConfig) -> Option<Partition> {
    match cfg.partition {
        PartitionSpec::Off => None,
        PartitionSpec::Affine { bfs, .. } => {
            let shards = cfg.partition.resolved_shards(cfg.threads);
            let p = if bfs {
                Partition::bfs_edges(&mrf.graph, shards)
            } else {
                Partition::contiguous(mrf.num_messages(), shards)
            };
            p.validate_against(&mrf.graph);
            Some(p)
        }
    }
}

/// The partition of `mrf`'s **node** task universe described by
/// `cfg.partition` (`None` when the axis is off) — the splash engines'
/// counterpart of [`for_messages`].
pub fn for_nodes(mrf: &Mrf, cfg: &RunConfig) -> Option<Partition> {
    match cfg.partition {
        PartitionSpec::Off => None,
        PartitionSpec::Affine { bfs, .. } => {
            let shards = cfg.partition.resolved_shards(cfg.threads);
            let p = if bfs {
                Partition::bfs_nodes(&mrf.graph, shards)
            } else {
                Partition::contiguous(mrf.num_nodes(), shards)
            };
            p.validate_against(&mrf.graph);
            Some(p)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GraphBuilder;

    fn path(n: usize) -> Csr {
        let mut b = GraphBuilder::new(n);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1);
        }
        b.build()
    }

    #[test]
    fn contiguous_tiles_and_balances() {
        for (n, k) in [(10, 3), (7, 7), (100, 1), (5, 9)] {
            let p = Partition::contiguous(n, k);
            p.validate();
            assert_eq!(p.num_tasks(), n);
            assert!(p.num_shards() <= n.max(1));
            let sizes: Vec<usize> = (0..p.num_shards()).map(|s| p.tasks_of(s).len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "contiguous shards balanced: {sizes:?}");
        }
    }

    #[test]
    fn contiguous_is_identity_order() {
        let p = Partition::contiguous(8, 2);
        assert_eq!(p.tasks_of(0), &[0, 1, 2, 3]);
        assert_eq!(p.tasks_of(1), &[4, 5, 6, 7]);
        assert_eq!(p.shard_of(3), 0);
        assert_eq!(p.shard_of(4), 1);
    }

    #[test]
    fn bfs_edges_keeps_neighboring_edges_together() {
        // On a path, the BFS edge order is the id order, so the two halves
        // of the path land in the two shards.
        let g = path(9); // 8 undirected edges → 16 tasks
        let p = Partition::bfs_edges(&g, 2);
        p.validate();
        p.validate_against(&g);
        assert_eq!(p.num_tasks(), 16);
        // Both directed edges of one undirected edge share a rank-adjacent
        // source, so at most one undirected edge straddles the cut.
        let straddling = (0..8)
            .filter(|&k| p.shard_of(2 * k) != p.shard_of(2 * k + 1))
            .count();
        assert!(straddling <= 1, "straddling undirected edges: {straddling}");
    }

    #[test]
    fn bfs_nodes_covers_disconnected_components() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1);
        // nodes 2..6 isolated
        b.add_edge(4, 5);
        let g = b.build();
        let p = Partition::bfs_nodes(&g, 3);
        p.validate();
        assert_eq!(p.num_tasks(), 6);
    }

    #[test]
    fn shard_count_clamped_to_tasks() {
        let p = Partition::contiguous(3, 10);
        assert_eq!(p.num_shards(), 3);
        for s in 0..3 {
            assert_eq!(p.tasks_of(s).len(), 1);
        }
    }

    #[test]
    fn empty_universe() {
        let p = Partition::contiguous(0, 4);
        assert_eq!(p.num_tasks(), 0);
        p.validate();
    }
}
