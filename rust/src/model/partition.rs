//! Deterministic task partitioning — the locality layer.
//!
//! A [`Partition`] groups a task universe (`0..num_tasks`: directed-edge
//! ids for the message engines, node ids for splash) into `k` shards. It
//! is consumed in three places:
//!
//! - [`crate::bp::Messages::uniform_partitioned`] lays each shard's
//!   message vectors out in its own cache-line-aligned arena, so a worker
//!   that stays on its shard walks hot, contiguous memory;
//! - the shard-affine [`crate::sched::Multiqueue`] routes inserts and pops
//!   to the queues owned by the task's shard (with a configurable spill
//!   probability);
//! - [`crate::exec::WorkerPool`] assigns each worker a home shard and
//!   threads the partition through [`crate::exec::ExecCtx`] so policy
//!   seeding and requeues land shard-local.
//!
//! Two deterministic modes (no RNG — the same model always partitions the
//! same way):
//!
//! - **contiguous**: shard `s` owns the id block `[s·n/k, (s+1)·n/k)`.
//!   Matches the flat layouts the builders already emit (grids are
//!   row-major, trees level-ish), and costs O(n).
//! - **BFS-clustered**: order nodes by multi-source BFS from node 0
//!   (restarting on each unvisited component), order edge tasks by the
//!   BFS rank of their *source* node, then cut the order into `k` equal
//!   blocks. Neighboring tasks land in the same shard even when the
//!   builder's id order is not locality-friendly.
//!
//! Every constructor validates the result against the graph it was built
//! from: shard ranges tile `0..num_tasks` and each task belongs to exactly
//! one shard (see [`Partition::validate`]).

use super::graph::Csr;
use super::Mrf;
use crate::configio::{PartitionSpec, RunConfig};

/// A frozen assignment of tasks to shards.
///
/// Stores both directions of the mapping: `task → shard` for O(1) routing
/// on the hot path, and `shard → tasks` (a permutation of `0..num_tasks`
/// grouped by shard, plus offsets) for arena layout and sweeps.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Shard of each task.
    task_shard: Vec<u32>,
    /// Shard `s` owns `tasks_in_order[shard_offsets[s]..shard_offsets[s+1]]`.
    shard_offsets: Vec<u32>,
    /// Permutation of `0..num_tasks`, grouped by shard (contiguous mode:
    /// the identity).
    tasks_in_order: Vec<u32>,
}

impl Partition {
    /// Build from an explicit task order: the first `n/k` ordered tasks go
    /// to shard 0, and so on. `order` must be a permutation of
    /// `0..num_tasks`.
    fn from_order(order: Vec<u32>, shards: usize) -> Partition {
        let n = order.len();
        let k = shards.max(1).min(n.max(1));
        let mut shard_offsets = Vec::with_capacity(k + 1);
        for s in 0..=k {
            shard_offsets.push((s * n / k) as u32);
        }
        let mut task_shard = vec![0u32; n];
        for s in 0..k {
            for i in shard_offsets[s] as usize..shard_offsets[s + 1] as usize {
                task_shard[order[i] as usize] = s as u32;
            }
        }
        let p = Partition { task_shard, shard_offsets, tasks_in_order: order };
        p.validate();
        p
    }

    /// Contiguous id blocks: shard `s` owns `[s·n/k, (s+1)·n/k)`. The
    /// shard count is clamped to `max(1, min(shards, num_tasks))` so every
    /// shard is nonempty.
    pub fn contiguous(num_tasks: usize, shards: usize) -> Partition {
        Self::from_order((0..num_tasks as u32).collect(), shards)
    }

    /// BFS-clustered partition of the **directed-edge** task universe of
    /// `graph` (`num_tasks = graph.num_directed_edges()`): edges sorted by
    /// the BFS rank of their source node (stable on edge id), then cut
    /// into `shards` blocks.
    pub fn bfs_edges(graph: &Csr, shards: usize) -> Partition {
        let rank = bfs_rank(graph);
        let mut order: Vec<u32> = (0..graph.num_directed_edges() as u32).collect();
        order.sort_by_key(|&e| (rank[graph.edge_src[e as usize] as usize], e));
        Self::from_order(order, shards)
    }

    /// BFS-clustered partition of the **node** task universe of `graph`
    /// (`num_tasks = graph.num_nodes()`).
    pub fn bfs_nodes(graph: &Csr, shards: usize) -> Partition {
        let rank = bfs_rank(graph);
        let mut order: Vec<u32> = (0..graph.num_nodes() as u32).collect();
        order.sort_by_key(|&v| rank[v as usize]);
        Self::from_order(order, shards)
    }

    /// Number of tasks partitioned.
    pub fn num_tasks(&self) -> usize {
        self.task_shard.len()
    }

    /// Number of shards (each nonempty, except for the empty universe).
    pub fn num_shards(&self) -> usize {
        self.shard_offsets.len() - 1
    }

    /// Shard owning `task`.
    #[inline]
    pub fn shard_of(&self, task: u32) -> u32 {
        self.task_shard[task as usize]
    }

    /// The tasks owned by `shard`, in layout order.
    pub fn tasks_of(&self, shard: usize) -> &[u32] {
        let lo = self.shard_offsets[shard] as usize;
        let hi = self.shard_offsets[shard + 1] as usize;
        &self.tasks_in_order[lo..hi]
    }

    /// Check the structural invariants: shard ranges tile `0..num_tasks`,
    /// the grouped order is a permutation, and the two mapping directions
    /// agree. Panics on violation (constructors call this; tests call it
    /// on every generated instance).
    pub fn validate(&self) {
        let n = self.num_tasks();
        let k = self.num_shards();
        assert_eq!(self.tasks_in_order.len(), n, "order must cover every task");
        assert_eq!(self.shard_offsets[0], 0);
        assert_eq!(self.shard_offsets[k] as usize, n, "shard ranges must tile 0..num_tasks");
        let mut seen = vec![false; n];
        for s in 0..k {
            assert!(
                self.shard_offsets[s] <= self.shard_offsets[s + 1],
                "shard offsets must be monotone"
            );
            for &t in self.tasks_of(s) {
                assert!(!seen[t as usize], "task {t} appears in more than one shard");
                seen[t as usize] = true;
                assert_eq!(self.task_shard[t as usize], s as u32, "task {t} mapping mismatch");
            }
        }
        assert!(seen.iter().all(|&s| s), "every task must land in exactly one shard");
    }

    /// Validate this partition against the graph universe it should cover:
    /// `num_tasks` must equal the directed-edge count (message engines) or
    /// the node count (splash engines) of `graph`.
    pub fn validate_against(&self, graph: &Csr) {
        let n = self.num_tasks();
        assert!(
            n == graph.num_directed_edges() || n == graph.num_nodes(),
            "partition over {n} tasks matches neither the {} directed edges nor the {} nodes",
            graph.num_directed_edges(),
            graph.num_nodes()
        );
    }
}

/// Rank-level ownership of a sharded task universe for distributed runs.
///
/// Each of `R` ranks owns the contiguous shard range `[r·k/R, (r+1)·k/R)`
/// of the run's [`Partition`] — and thereby every task in those shards.
/// Built deterministically from `(partition, ranks)` on every rank, so all
/// processes agree on ownership without any exchange.
#[derive(Debug, Clone)]
pub struct RankMap {
    /// Owning rank per task (derived from the partition's task→shard map).
    task_rank: Vec<u32>,
    /// Rank `r` owns shards `shard_bounds[r]..shard_bounds[r+1]`.
    shard_bounds: Vec<u32>,
}

impl RankMap {
    /// Assign `partition`'s shards to `ranks` processes in contiguous
    /// blocks. Requires `1 ≤ ranks ≤ partition.num_shards()` so every rank
    /// owns at least one shard (the distributed launcher validates this
    /// with a proper error before construction).
    pub fn contiguous(partition: &Partition, ranks: usize) -> RankMap {
        let k = partition.num_shards();
        assert!(ranks >= 1 && ranks <= k, "need 1 ≤ ranks ≤ shards, got {ranks} over {k}");
        let mut shard_bounds = Vec::with_capacity(ranks + 1);
        for r in 0..=ranks {
            shard_bounds.push((r * k / ranks) as u32);
        }
        let mut shard_rank = vec![0u32; k];
        for r in 0..ranks {
            for s in shard_bounds[r] as usize..shard_bounds[r + 1] as usize {
                shard_rank[s] = r as u32;
            }
        }
        let task_rank = (0..partition.num_tasks() as u32)
            .map(|t| shard_rank[partition.shard_of(t) as usize])
            .collect();
        RankMap { task_rank, shard_bounds }
    }

    /// Number of ranks.
    pub fn ranks(&self) -> usize {
        self.shard_bounds.len() - 1
    }

    /// Number of tasks mapped.
    pub fn num_tasks(&self) -> usize {
        self.task_rank.len()
    }

    /// Owning rank of `task`.
    #[inline]
    pub fn rank_of(&self, task: u32) -> u32 {
        self.task_rank[task as usize]
    }

    /// True when `rank` owns `task`.
    #[inline]
    pub fn owns(&self, rank: u32, task: u32) -> bool {
        self.task_rank[task as usize] == rank
    }

    /// The contiguous shard range owned by `rank`.
    pub fn shards_of(&self, rank: u32) -> std::ops::Range<u32> {
        self.shard_bounds[rank as usize]..self.shard_bounds[rank as usize + 1]
    }

    /// Number of tasks owned by `rank` (O(n); startup accounting only).
    pub fn num_owned(&self, rank: u32) -> usize {
        self.task_rank.iter().filter(|&&r| r == rank).count()
    }
}

/// Per-edge consumer index for distributed runs: which peer ranks need a
/// directed edge's message value.
///
/// The update of message `e = (u→v)` feeds the gathers (and hence the
/// residual prices) of `v`'s out-going message tasks. A rank that owns any
/// out-edge of `v` therefore consumes `e`'s value; every such rank other
/// than `e`'s owner makes `e` a **boundary edge** whose committed values
/// must be shipped over the exchange. Interior edges (every consumer
/// colocated with the producer) have an empty peer list and never touch
/// the network.
#[derive(Debug, Clone)]
pub struct BoundaryIndex {
    /// Edge `e`'s peer ranks are `peers[offsets[e]..offsets[e+1]]`
    /// (sorted, deduplicated).
    offsets: Vec<u32>,
    peers: Vec<u32>,
}

impl BoundaryIndex {
    /// Build the consumer index of `graph`'s directed-edge universe under
    /// `map`. Cost is O(Σ_v deg(v)) for the per-node rank sets plus
    /// O(edges × ranks-per-node) for the flattening — linear in practice.
    pub fn build(graph: &Csr, map: &RankMap) -> BoundaryIndex {
        let me = graph.num_directed_edges();
        assert_eq!(map.num_tasks(), me, "rank map must cover the edge universe");
        // Per-node consumer set: the ranks owning at least one out-edge of
        // the node, sorted + deduped (node-degree work, done once).
        let n = graph.num_nodes();
        let mut node_ranks: Vec<Vec<u32>> = Vec::with_capacity(n);
        let mut buf: Vec<u32> = Vec::new();
        for v in 0..n {
            buf.clear();
            buf.extend(graph.out_edges(v).iter().map(|&e| map.rank_of(e)));
            buf.sort_unstable();
            buf.dedup();
            node_ranks.push(buf.clone());
        }
        let mut offsets = Vec::with_capacity(me + 1);
        let mut peers = Vec::new();
        offsets.push(0u32);
        for e in 0..me as u32 {
            let owner = map.rank_of(e);
            let dst = graph.edge_dst[e as usize] as usize;
            peers.extend(node_ranks[dst].iter().copied().filter(|&r| r != owner));
            offsets.push(peers.len() as u32);
        }
        BoundaryIndex { offsets, peers }
    }

    /// Peer ranks consuming edge `e`'s value (empty for interior edges).
    #[inline]
    pub fn peers_of(&self, e: u32) -> &[u32] {
        &self.peers[self.offsets[e as usize] as usize..self.offsets[e as usize + 1] as usize]
    }

    /// Number of boundary edges (edges with at least one remote consumer).
    pub fn num_boundary(&self) -> usize {
        self.offsets.windows(2).filter(|w| w[1] > w[0]).count()
    }
}

/// BFS visit rank of every node, multi-source from node 0 with restarts on
/// unvisited components — total over all nodes, deterministic.
fn bfs_rank(graph: &Csr) -> Vec<u32> {
    let n = graph.num_nodes();
    let mut rank = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for root in 0..n {
        if rank[root] != u32::MAX {
            continue;
        }
        rank[root] = next;
        next += 1;
        queue.push_back(root as u32);
        while let Some(u) = queue.pop_front() {
            for &v in graph.neighbors(u as usize) {
                if rank[v as usize] == u32::MAX {
                    rank[v as usize] = next;
                    next += 1;
                    queue.push_back(v);
                }
            }
        }
    }
    rank
}

/// The partition of `mrf`'s **message** task universe described by
/// `cfg.partition` (`None` when the axis is off). This is what the
/// message-task engines (residual family, priority, batched, optimal
/// tree) attach to the pool, and what sharded [`crate::bp::Messages`]
/// arenas are laid out by.
///
/// Construction is deterministic in `(mrf, cfg)`, so the arena layout
/// (resolved by `run::run_on_model_observed`) and the scheduler routing
/// (resolved again inside the engine) always agree. The duplicate
/// resolution is a deliberate tradeoff: it keeps `Engine::run`'s
/// signature partition-free, at the cost of one extra O(E log E) pass at
/// startup for the BFS mode.
pub fn for_messages(mrf: &Mrf, cfg: &RunConfig) -> Option<Partition> {
    match cfg.partition {
        PartitionSpec::Off => None,
        PartitionSpec::Affine { bfs, .. } => {
            let shards = cfg.partition.resolved_shards(cfg.threads);
            let p = if bfs {
                Partition::bfs_edges(&mrf.graph, shards)
            } else {
                Partition::contiguous(mrf.num_messages(), shards)
            };
            p.validate_against(&mrf.graph);
            Some(p)
        }
    }
}

/// The partition of `mrf`'s **node** task universe described by
/// `cfg.partition` (`None` when the axis is off) — the splash engines'
/// counterpart of [`for_messages`].
pub fn for_nodes(mrf: &Mrf, cfg: &RunConfig) -> Option<Partition> {
    match cfg.partition {
        PartitionSpec::Off => None,
        PartitionSpec::Affine { bfs, .. } => {
            let shards = cfg.partition.resolved_shards(cfg.threads);
            let p = if bfs {
                Partition::bfs_nodes(&mrf.graph, shards)
            } else {
                Partition::contiguous(mrf.num_nodes(), shards)
            };
            p.validate_against(&mrf.graph);
            Some(p)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GraphBuilder;

    fn path(n: usize) -> Csr {
        let mut b = GraphBuilder::new(n);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1);
        }
        b.build()
    }

    #[test]
    fn contiguous_tiles_and_balances() {
        for (n, k) in [(10, 3), (7, 7), (100, 1), (5, 9)] {
            let p = Partition::contiguous(n, k);
            p.validate();
            assert_eq!(p.num_tasks(), n);
            assert!(p.num_shards() <= n.max(1));
            let sizes: Vec<usize> = (0..p.num_shards()).map(|s| p.tasks_of(s).len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "contiguous shards balanced: {sizes:?}");
        }
    }

    #[test]
    fn contiguous_is_identity_order() {
        let p = Partition::contiguous(8, 2);
        assert_eq!(p.tasks_of(0), &[0, 1, 2, 3]);
        assert_eq!(p.tasks_of(1), &[4, 5, 6, 7]);
        assert_eq!(p.shard_of(3), 0);
        assert_eq!(p.shard_of(4), 1);
    }

    #[test]
    fn bfs_edges_keeps_neighboring_edges_together() {
        // On a path, the BFS edge order is the id order, so the two halves
        // of the path land in the two shards.
        let g = path(9); // 8 undirected edges → 16 tasks
        let p = Partition::bfs_edges(&g, 2);
        p.validate();
        p.validate_against(&g);
        assert_eq!(p.num_tasks(), 16);
        // Both directed edges of one undirected edge share a rank-adjacent
        // source, so at most one undirected edge straddles the cut.
        let straddling = (0..8)
            .filter(|&k| p.shard_of(2 * k) != p.shard_of(2 * k + 1))
            .count();
        assert!(straddling <= 1, "straddling undirected edges: {straddling}");
    }

    #[test]
    fn bfs_nodes_covers_disconnected_components() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1);
        // nodes 2..6 isolated
        b.add_edge(4, 5);
        let g = b.build();
        let p = Partition::bfs_nodes(&g, 3);
        p.validate();
        assert_eq!(p.num_tasks(), 6);
    }

    #[test]
    fn shard_count_clamped_to_tasks() {
        let p = Partition::contiguous(3, 10);
        assert_eq!(p.num_shards(), 3);
        for s in 0..3 {
            assert_eq!(p.tasks_of(s).len(), 1);
        }
    }

    #[test]
    fn empty_universe() {
        let p = Partition::contiguous(0, 4);
        assert_eq!(p.num_tasks(), 0);
        p.validate();
    }

    #[test]
    fn rank_map_contiguous_covers_all_shards() {
        let p = Partition::contiguous(100, 8);
        let m = RankMap::contiguous(&p, 3);
        assert_eq!(m.ranks(), 3);
        assert_eq!(m.num_tasks(), 100);
        // Shard ranges tile 0..8 and every task's rank matches its
        // shard's range.
        let mut covered = 0u32;
        for r in 0..3u32 {
            let range = m.shards_of(r);
            assert_eq!(range.start, covered);
            covered = range.end;
        }
        assert_eq!(covered, 8);
        for t in 0..100u32 {
            let r = m.rank_of(t);
            assert!(m.shards_of(r).contains(&p.shard_of(t)));
            assert!(m.owns(r, t));
        }
        let total: usize = (0..3).map(|r| m.num_owned(r)).sum();
        assert_eq!(total, 100);
    }

    #[test]
    #[should_panic(expected = "1 ≤ ranks ≤ shards")]
    fn rank_map_rejects_more_ranks_than_shards() {
        let p = Partition::contiguous(10, 2);
        RankMap::contiguous(&p, 3);
    }

    #[test]
    fn boundary_index_on_path() {
        // Path 0-1-2-3: 6 directed edges, contiguous 2-shard split at the
        // edge-id midpoint, one rank per shard. Edges whose destination
        // node has an out-edge owned by the other rank are boundary.
        let g = path(4);
        let p = Partition::contiguous(g.num_directed_edges(), 2);
        let m = RankMap::contiguous(&p, 2);
        let b = BoundaryIndex::build(&g, &m);
        assert!(b.num_boundary() > 0, "the cut must produce boundary edges");
        for e in 0..g.num_directed_edges() as u32 {
            let owner = m.rank_of(e);
            let dst = g.edge_dst[e as usize] as usize;
            let expect: std::collections::BTreeSet<u32> = g
                .out_edges(dst)
                .iter()
                .map(|&o| m.rank_of(o))
                .filter(|&r| r != owner)
                .collect();
            let got: std::collections::BTreeSet<u32> =
                b.peers_of(e).iter().copied().collect();
            assert_eq!(got, expect, "edge {e}");
            assert!(!b.peers_of(e).contains(&owner), "never ships to itself");
        }
    }

    #[test]
    fn boundary_index_single_rank_is_empty() {
        let g = path(6);
        let p = Partition::contiguous(g.num_directed_edges(), 4);
        let m = RankMap::contiguous(&p, 1);
        let b = BoundaryIndex::build(&g, &m);
        assert_eq!(b.num_boundary(), 0, "one rank owns everything");
    }
}
