//! Pairwise Markov random field representation.
//!
//! An [`Mrf`] bundles the graph topology ([`graph::Csr`]), variable domains,
//! node potentials, and the edge-factor pool, plus the per-directed-edge
//! message layout (offset + length) that the BP engines index into.
//!
//! Model generators for all of the paper's benchmark families live in
//! [`builders`]; the locality layer (task → shard partitioning consumed by
//! the sharded message arenas and the shard-affine scheduler) in
//! [`partition`]; binary serialization in [`io`]; incremental prior updates
//! (the warm-start path's [`EvidenceDelta`]) in [`delta`].

pub mod builders;
pub mod delta;
pub mod factors;
pub mod graph;
pub mod io;
pub mod partition;
pub mod storage;

pub use delta::EvidenceDelta;
pub use factors::{FactorPool, FactorRef, NodeFactors};
pub use graph::{Csr, GraphBuilder};
pub use partition::{BoundaryIndex, Partition, RankMap};
pub use storage::ModelStorage;

/// Largest variable domain supported by the stack-buffer update kernels
/// (LDPC constraint nodes need 2^6 = 64).
pub const MAX_DOMAIN: usize = 64;

/// A pairwise Markov random field, frozen for inference.
#[derive(Debug, Clone)]
pub struct Mrf {
    /// Adjacency in CSR form; directed edge `e`'s reverse is `e ^ 1`.
    pub graph: Csr,
    /// `|D_i|` per node (heap-owned, or borrowed from a mapped snapshot).
    pub domain: ModelStorage<u32>,
    /// Node potentials `ψ_i`.
    pub node_factors: NodeFactors,
    /// Edge-factor matrix per directed edge, as a [`FactorRef`] into `pool`.
    /// `edge_factor[e]` is oriented `(src(e), dst(e))`.
    pub edge_factor: Vec<FactorRef>,
    /// Shared matrix pool.
    pub pool: FactorPool,
    /// Message-vector offset per directed edge into the flat message array;
    /// the message for edge `e` has length `domain[dst(e)]`.
    pub msg_offset: ModelStorage<u32>,
    /// Total length of the flat message array.
    pub total_msg_len: usize,
    /// Human-readable model name (for reports).
    pub name: String,
}

impl Mrf {
    /// Assemble and validate an MRF from parts. `edge_pool_index[k]` gives
    /// the pool matrix for undirected edge `k`, stored in the orientation of
    /// directed edge `2k` (src = first endpoint passed to the builder).
    pub fn assemble(
        name: &str,
        graph: Csr,
        domain: Vec<u32>,
        node_factors: NodeFactors,
        edge_pool_index: Vec<u32>,
        pool: FactorPool,
    ) -> Mrf {
        let n = graph.num_nodes();
        let me = graph.num_directed_edges();
        assert_eq!(domain.len(), n);
        assert_eq!(node_factors.num_nodes(), n);
        assert_eq!(edge_pool_index.len() * 2, me);
        for i in 0..n {
            assert_eq!(node_factors.domain(i), domain[i] as usize, "node {i} factor width");
            assert!(
                (domain[i] as usize) <= MAX_DOMAIN,
                "domain of node {i} exceeds MAX_DOMAIN"
            );
        }

        // Directed-edge factor refs: even edge = stored orientation,
        // odd edge = transposed.
        let mut edge_factor = Vec::with_capacity(me);
        for k in 0..edge_pool_index.len() {
            edge_factor.push(FactorRef::new(edge_pool_index[k], false));
            edge_factor.push(FactorRef::new(edge_pool_index[k], true));
        }

        // Validate factor shapes against endpoint domains.
        for e in 0..me {
            let (ds, dd) = pool.shape_of(edge_factor[e]);
            let src = graph.edge_src[e] as usize;
            let dst = graph.edge_dst[e] as usize;
            assert_eq!(ds, domain[src] as usize, "edge {e} src domain");
            assert_eq!(dd, domain[dst] as usize, "edge {e} dst domain");
        }

        // Message layout: message for edge e has |D_dst| entries.
        let mut msg_offset = Vec::with_capacity(me);
        let mut off = 0u64;
        for e in 0..me {
            msg_offset.push(off as u32);
            off += domain[graph.edge_dst[e] as usize] as u64;
        }
        assert!(off <= u32::MAX as u64, "message array exceeds u32 indexing");

        Mrf {
            graph,
            domain: domain.into(),
            node_factors,
            edge_factor,
            pool,
            msg_offset: msg_offset.into(),
            total_msg_len: off as usize,
            name: name.to_string(),
        }
    }

    /// Number of nodes (variables) in the MRF.
    pub fn num_nodes(&self) -> usize {
        self.graph.num_nodes()
    }

    /// Number of directed edges = number of BP messages.
    pub fn num_messages(&self) -> usize {
        self.graph.num_directed_edges()
    }

    /// Message length for directed edge `e` (= `|D_dst(e)|`).
    #[inline]
    pub fn msg_len(&self, e: u32) -> usize {
        self.domain[self.graph.edge_dst[e as usize] as usize] as usize
    }

    /// Byte-range of edge `e`'s message in the flat array.
    #[inline]
    pub fn msg_range(&self, e: u32) -> std::ops::Range<usize> {
        let off = self.msg_offset[e as usize] as usize;
        off..off + self.msg_len(e)
    }

    /// True if every variable is binary (enables the specialized kernels and
    /// the PJRT batched path).
    pub fn all_binary(&self) -> bool {
        self.domain.iter().all(|&d| d == 2)
    }

    /// Largest domain in the model.
    pub fn max_domain(&self) -> usize {
        self.domain.iter().copied().max().unwrap_or(0) as usize
    }

    /// Rough memory footprint of the model + one message array, in bytes
    /// (for the harness's instance-size reporting).
    pub fn approx_bytes(&self) -> usize {
        self.total_msg_len * 8 * 2 // messages + lookahead
            + self.pool.data_len() * 8
            + self.graph.adj_node.len() * 12
            + self.num_messages() * 12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-build a 2-node binary MRF with one edge.
    fn tiny() -> Mrf {
        let mut gb = GraphBuilder::new(2);
        gb.add_edge(0, 1);
        let g = gb.build();
        let mut pool = FactorPool::new();
        let f = pool.add(2, 2, &[0.9, 0.1, 0.2, 0.8]);
        Mrf::assemble(
            "tiny",
            g,
            vec![2, 2],
            NodeFactors::from_vecs(&[vec![0.3, 0.7], vec![0.5, 0.5]]),
            vec![f],
            pool,
        )
    }

    #[test]
    fn layout() {
        let m = tiny();
        assert_eq!(m.num_nodes(), 2);
        assert_eq!(m.num_messages(), 2);
        assert_eq!(m.msg_len(0), 2);
        assert_eq!(m.msg_len(1), 2);
        assert_eq!(m.total_msg_len, 4);
        assert_eq!(m.msg_range(0), 0..2);
        assert_eq!(m.msg_range(1), 2..4);
        assert!(m.all_binary());
        assert_eq!(m.max_domain(), 2);
    }

    #[test]
    fn directed_factor_orientation() {
        let m = tiny();
        // Edge 0 is 0→1 in stored orientation, edge 1 is transposed.
        assert_eq!(m.pool.get(m.edge_factor[0], 0, 1), 0.1); // ψ(x0=0, x1=1)
        assert_eq!(m.pool.get(m.edge_factor[1], 1, 0), 0.1); // ψ(x1=1, x0=0) transposed
        assert_eq!(m.pool.get(m.edge_factor[0], 1, 0), 0.2);
        assert_eq!(m.pool.get(m.edge_factor[1], 0, 1), 0.2);
    }

    #[test]
    #[should_panic(expected = "factor width")]
    fn rejects_mismatched_node_factor() {
        let g = GraphBuilder::new(1).build();
        Mrf::assemble(
            "bad",
            g,
            vec![2],
            NodeFactors::from_vecs(&[vec![1.0, 1.0, 1.0]]),
            vec![],
            FactorPool::new(),
        );
    }

    #[test]
    fn variable_width_messages() {
        // variable (domain 2) — constraint (domain 4)
        let mut gb = GraphBuilder::new(2);
        gb.add_edge(0, 1);
        let g = gb.build();
        let mut pool = FactorPool::new();
        // ψ(x, y): 2x4
        let f = pool.add(2, 4, &[1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        let m = Mrf::assemble(
            "vw",
            g,
            vec![2, 4],
            NodeFactors::from_vecs(&[vec![0.5, 0.5], vec![1.0; 4]]),
            vec![f],
            pool,
        );
        assert_eq!(m.msg_len(0), 4); // 0→1 carries |D_1| = 4
        assert_eq!(m.msg_len(1), 2); // 1→0 carries |D_0| = 2
        assert_eq!(m.total_msg_len, 6);
        assert!(!m.all_binary());
        assert_eq!(m.max_domain(), 4);
    }
}
