//! PJRT runtime: loads AOT-compiled HLO artifacts (produced once, at build
//! time, by `python/compile/aot.py`) and executes them on the XLA CPU
//! client from the Rust hot path. Python is never involved at run time.
//!
//! Interchange format is **HLO text** (`artifacts/*.hlo.txt`): jax ≥ 0.5
//! serializes `HloModuleProto`s with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).
//!
//! ## Threading model
//!
//! The `xla` crate's client/executable handles are `Rc`-based (neither
//! `Send` nor `Sync`), so each [`Executable`] owns a dedicated **executor
//! thread** holding the PJRT client and the compiled program; callers on
//! any thread exchange plain `f64` tensors with it over channels. Calls
//! are serialized per executable — our callers batch enough work per call
//! that pipelining one executable across threads would not pay off.

pub mod batch;
pub mod grid;

use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

/// Directory holding `*.hlo.txt` artifacts. Defaults to `artifacts/`
/// relative to the working directory; override with `RBP_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RBP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A dense input tensor (converted to f32 on the executor thread — the
/// kernels are compiled for f32, ample for residual thresholds ≥ 1e-6).
pub struct TensorIn {
    /// Flat row-major element buffer.
    pub data: Vec<f64>,
    /// Dimension sizes (XLA convention).
    pub dims: Vec<i64>,
}

impl TensorIn {
    /// Tensor from a flat buffer and its dimensions.
    pub fn new(data: Vec<f64>, dims: &[i64]) -> Self {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        TensorIn { data, dims: dims.to_vec() }
    }
}

// Without the executor thread (`pjrt` off) jobs are created but never
// consumed; keep the lint quiet in that configuration.
#[cfg_attr(not(pjrt), allow(dead_code))]
enum Job {
    /// Convert + cache literals that will be prepended to every subsequent
    /// run's inputs (e.g. a grid's factor tensors: uploaded once, not per
    /// round — a 6× round-time win, see EXPERIMENTS.md §Perf).
    SetPrefix(Vec<TensorIn>, mpsc::Sender<Result<()>>),
    Run(Vec<TensorIn>, mpsc::Sender<Result<Vec<Vec<f64>>>>),
}

/// A compiled artifact, ready to execute from any thread.
pub struct Executable {
    tx: Mutex<mpsc::Sender<Job>>,
    /// Path of the HLO text artifact this executable was loaded from.
    pub path: PathBuf,
}

impl Executable {
    /// Load and compile an HLO-text artifact on a fresh executor thread.
    ///
    /// Without the `pjrt` rustc cfg flag (`RUSTFLAGS="--cfg pjrt"`; the
    /// `xla` bindings are only present in the full build image) this
    /// always fails cleanly; callers fall back to the native compute path.
    #[cfg(pjrt)]
    pub fn load(path: &Path) -> Result<Executable> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let p = path.to_path_buf();
        std::thread::Builder::new()
            .name("pjrt-exec".into())
            .spawn(move || executor_thread(p, rx, ready_tx))
            .map_err(|e| anyhow!("spawning executor: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during setup"))??;
        Ok(Executable { tx: Mutex::new(tx), path: path.to_path_buf() })
    }

    /// Stub: built without `--cfg pjrt`.
    #[cfg(not(pjrt))]
    pub fn load(path: &Path) -> Result<Executable> {
        Err(anyhow!(
            "cannot load {}: built without `--cfg pjrt` (xla bindings absent)",
            path.display()
        ))
    }

    /// Load `<artifacts_dir>/<name>.hlo.txt`.
    pub fn load_named(name: &str) -> Result<Executable> {
        let path = artifacts_dir().join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(anyhow!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            ));
        }
        Self::load(&path)
    }

    /// Execute with dense inputs; returns the flattened tuple outputs as
    /// f64 vectors. (aot.py lowers with `return_tuple=True`.) Any inputs
    /// registered via [`Executable::set_prefix`] are prepended.
    pub fn run(&self, inputs: Vec<TensorIn>) -> Result<Vec<Vec<f64>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Job::Run(inputs, reply_tx))
                .map_err(|_| anyhow!("executor thread gone"))?;
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor thread dropped the reply"))?
    }

    /// Upload constant leading inputs once; subsequent [`Executable::run`]
    /// calls only carry the varying suffix.
    pub fn set_prefix(&self, inputs: Vec<TensorIn>) -> Result<()> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().unwrap();
            tx.send(Job::SetPrefix(inputs, reply_tx))
                .map_err(|_| anyhow!("executor thread gone"))?;
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow!("executor thread dropped the reply"))?
    }
}

#[cfg(pjrt)]
fn to_literal(t: &TensorIn) -> Result<xla::Literal> {
    let f32s: Vec<f32> = t.data.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f32s)
        .reshape(&t.dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Body of the executor thread: owns all `Rc`-based xla handles.
#[cfg(pjrt)]
fn executor_thread(path: PathBuf, rx: mpsc::Receiver<Job>, ready: mpsc::Sender<Result<()>>) {
    let setup = (|| -> Result<(xla::PjRtClient, xla::PjRtLoadedExecutable)> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok((client, exe))
    })();

    let (_client, exe) = match setup {
        Ok(pair) => {
            let _ = ready.send(Ok(()));
            pair
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    // Serve jobs until the Executable is dropped (channel closes).
    let mut prefix: Vec<xla::Literal> = Vec::new();
    for job in rx {
        match job {
            Job::SetPrefix(inputs, reply) => {
                let result = inputs.iter().map(to_literal).collect::<Result<Vec<_>>>();
                match result {
                    Ok(lits) => {
                        prefix = lits;
                        let _ = reply.send(Ok(()));
                    }
                    Err(e) => {
                        let _ = reply.send(Err(e));
                    }
                }
            }
            Job::Run(inputs, reply) => {
                let result = (|| -> Result<Vec<Vec<f64>>> {
                    let mut literals: Vec<&xla::Literal> = prefix.iter().collect();
                    let varying =
                        inputs.iter().map(to_literal).collect::<Result<Vec<_>>>()?;
                    literals.extend(varying.iter());
                    let result = exe
                        .execute::<&xla::Literal>(&literals)
                        .map_err(|e| anyhow!("executing {}: {e:?}", path.display()))?;
                    let lit = result[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("fetching result: {e:?}"))?;
                    let parts =
                        lit.to_tuple().map_err(|e| anyhow!("untupling: {e:?}"))?;
                    parts
                        .iter()
                        .map(|p| {
                            let v: Vec<f32> =
                                p.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                            Ok(v.into_iter().map(|x| x as f64).collect())
                        })
                        .collect()
                })();
                let _ = reply.send(result);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_default() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts") || d.is_absolute());
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let err = Executable::load_named("definitely_missing_artifact")
            .err()
            .expect("should fail");
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn tensor_in_shape_check() {
        let t = TensorIn::new(vec![0.0; 6], &[2, 3]);
        assert_eq!(t.dims, vec![2, 3]);
    }
}
