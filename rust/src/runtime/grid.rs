//! PJRT-backed synchronous sweeps for n×n binary grid models.
//!
//! The AOT artifact `grid_step_{n}.hlo.txt` (L2 JAX graph + L1 Pallas
//! kernel) performs one full synchronous BP round over an Ising/Potts-style
//! grid in dense tensor form and returns the round's max L2 residual:
//!
//! - `pot  [n, n, 2]`    — node potentials;
//! - `h    [n, n-1, 2, 2]` — horizontal edge factors ψ(x_{r,c}, x_{r,c+1});
//! - `v    [n-1, n, 2, 2]` — vertical edge factors ψ(x_{r,c}, x_{r+1,c});
//! - `msgs [4, n, n, 2]` — message INTO (r,c) from direction d
//!   (0 = left neighbor, 1 = right, 2 = above, 3 = below); boundary slots
//!   hold the uniform message and are never updated.
//!
//! This module converts between the CSR edge layout (from
//! `model::builders::grid`) and the tensor layout, and drives rounds
//! through the PJRT executable — the three-layer synchronous hot path.

use super::{Executable, TensorIn};
use crate::bp::{Messages, MsgSource};
use crate::configio::RunConfig;
use crate::coordinator::{Budget, Counters, MetricsReport};
use crate::engines::EngineStats;
use crate::model::Mrf;
use crate::util::Timer;
use anyhow::{anyhow, bail, Result};

/// Grid sizes for which `make artifacts` emits a sweep kernel by default.
pub const DEFAULT_GRID_SIZES: &[usize] = &[16, 64, 128];

/// Detect an n×n binary grid model produced by `builders::grid`.
pub fn detect_grid(mrf: &Mrf) -> Option<usize> {
    if !(mrf.name == "ising" || mrf.name == "potts") || !mrf.all_binary() {
        return None;
    }
    let n2 = mrf.num_nodes();
    let n = (n2 as f64).sqrt().round() as usize;
    if n * n != n2 || mrf.num_messages() != 4 * n * (n - 1) {
        return None;
    }
    Some(n)
}

/// Undirected edge index of the right-edge at (r,c) / down-edge at (r,c),
/// replicating the construction order in `builders::grid::grid_edges`.
fn edge_indices(n: usize) -> (Vec<u32>, Vec<u32>) {
    let mut right = vec![u32::MAX; n * n];
    let mut down = vec![u32::MAX; n * n];
    let mut k = 0u32;
    for r in 0..n {
        for c in 0..n {
            if c + 1 < n {
                right[r * n + c] = k;
                k += 1;
            }
            if r + 1 < n {
                down[r * n + c] = k;
                k += 1;
            }
        }
    }
    (right, down)
}

/// Tensor-form state for the PJRT sweep.
pub struct GridTensors {
    /// Grid side length.
    pub n: usize,
    /// Node potentials, row-major `n*n*2`.
    pub pot: Vec<f64>,
    /// Horizontal pairwise factors.
    pub h: Vec<f64>,
    /// Vertical pairwise factors.
    pub v: Vec<f64>,
    /// Message state, packed per direction.
    pub msgs: Vec<f64>,
    right: Vec<u32>,
    down: Vec<u32>,
}

impl GridTensors {
    /// Build tensors from the MRF and current message state.
    pub fn from_mrf(mrf: &Mrf, msgs: &Messages) -> Result<GridTensors> {
        let n = detect_grid(mrf).ok_or_else(|| anyhow!("not a grid model"))?;
        let (right, down) = edge_indices(n);

        let mut pot = vec![0.0f64; n * n * 2];
        for i in 0..n * n {
            let f = mrf.node_factors.of(i);
            pot[2 * i] = f[0];
            pot[2 * i + 1] = f[1];
        }
        let mut h = vec![0.0f64; n * (n - 1) * 4];
        let mut v = vec![0.0f64; (n - 1) * n * 4];
        for r in 0..n {
            for c in 0..n {
                if c + 1 < n {
                    let k = right[r * n + c] as usize;
                    let fr = mrf.edge_factor[2 * k]; // (r,c)→(r,c+1) orientation
                    let base = (r * (n - 1) + c) * 4;
                    for a in 0..2 {
                        for b in 0..2 {
                            h[base + 2 * a + b] = mrf.pool.get(fr, a, b);
                        }
                    }
                }
                if r + 1 < n {
                    let k = down[r * n + c] as usize;
                    let fr = mrf.edge_factor[2 * k];
                    let base = (r * n + c) * 4;
                    for a in 0..2 {
                        for b in 0..2 {
                            v[base + 2 * a + b] = mrf.pool.get(fr, a, b);
                        }
                    }
                }
            }
        }

        let mut gt = GridTensors {
            n,
            pot,
            h,
            v,
            msgs: vec![0.5f64; 4 * n * n * 2],
            right,
            down,
        };
        gt.load_messages(mrf, msgs);
        Ok(gt)
    }

    #[inline]
    fn m_idx(&self, d: usize, r: usize, c: usize, x: usize) -> usize {
        ((d * self.n + r) * self.n + c) * 2 + x
    }

    /// Directed-edge id of the message into (r,c) from direction d, if any.
    fn edge_into(&self, d: usize, r: usize, c: usize) -> Option<u32> {
        let n = self.n;
        match d {
            // from left: (r,c-1)→(r,c) = even id of right-edge at (r,c-1)
            0 if c > 0 => Some(2 * self.right[r * n + c - 1]),
            // from right: (r,c+1)→(r,c) = odd id of right-edge at (r,c)
            1 if c + 1 < n => Some(2 * self.right[r * n + c] + 1),
            // from above: (r-1,c)→(r,c) = even id of down-edge at (r-1,c)
            2 if r > 0 => Some(2 * self.down[(r - 1) * n + c]),
            // from below: (r+1,c)→(r,c) = odd id of down-edge at (r,c)
            3 if r + 1 < n => Some(2 * self.down[r * n + c] + 1),
            _ => None,
        }
    }

    /// Copy live messages into the tensor.
    pub fn load_messages(&mut self, mrf: &Mrf, msgs: &Messages) {
        let n = self.n;
        let mut buf = crate::bp::msg_buf();
        for d in 0..4 {
            for r in 0..n {
                for c in 0..n {
                    if let Some(e) = self.edge_into(d, r, c) {
                        msgs.read_msg(mrf, e, &mut buf);
                        let i0 = self.m_idx(d, r, c, 0);
                        self.msgs[i0] = buf[0];
                        self.msgs[i0 + 1] = buf[1];
                    }
                }
            }
        }
    }

    /// Copy the tensor back into live messages.
    pub fn store_messages(&self, mrf: &Mrf, msgs: &Messages) {
        let n = self.n;
        for d in 0..4 {
            for r in 0..n {
                for c in 0..n {
                    if let Some(e) = self.edge_into(d, r, c) {
                        let i0 = self.m_idx(d, r, c, 0);
                        msgs.write_msg(mrf, e, &[self.msgs[i0], self.msgs[i0 + 1]]);
                    }
                }
            }
        }
    }
}

/// The compiled sweep for one grid size.
pub struct PjrtGridSync {
    exe: Executable,
    /// Grid side length the artifact was lowered for.
    pub n: usize,
}

impl PjrtGridSync {
    /// Load the grid-sweep artifact for an `n`×`n` grid.
    pub fn load(n: usize) -> Result<PjrtGridSync> {
        let exe = Executable::load_named(&format!("grid_step_{n}"))?;
        Ok(PjrtGridSync { exe, n })
    }

    /// Upload the constant factor tensors once (pot/h/v never change
    /// between rounds); subsequent [`PjrtGridSync::step`] calls only carry
    /// the message tensor — a ~6× round-time improvement (§Perf).
    pub fn prepare(&self, gt: &GridTensors) -> Result<()> {
        let n = self.n as i64;
        self.exe.set_prefix(vec![
            TensorIn::new(gt.pot.clone(), &[n, n, 2]),
            TensorIn::new(gt.h.clone(), &[n, n - 1, 2, 2]),
            TensorIn::new(gt.v.clone(), &[n - 1, n, 2, 2]),
        ])
    }

    /// One synchronous round in tensor form; returns the max L2 residual.
    /// Requires [`PjrtGridSync::prepare`] to have been called.
    pub fn step(&self, gt: &mut GridTensors) -> Result<f64> {
        let n = self.n as i64;
        let msgs = std::mem::take(&mut gt.msgs);
        let mut outputs = self.exe.run(vec![TensorIn::new(msgs, &[4, n, n, 2])])?;
        if outputs.len() != 2 {
            bail!("grid_step artifact must return (msgs, max_res)");
        }
        let res = outputs.pop().unwrap();
        gt.msgs = outputs.pop().unwrap();
        Ok(res[0])
    }
}

/// Run synchronous BP entirely through the PJRT sweep. Returns `Err` when
/// no artifact exists for this grid size (caller falls back to native).
pub fn run_sync_pjrt(mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
    let n = detect_grid(mrf).ok_or_else(|| anyhow!("not a grid"))?;
    let sync = PjrtGridSync::load(n)?;
    let timer = Timer::start();
    let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
    let mut gt = GridTensors::from_mrf(mrf, msgs)?;
    sync.prepare(&gt)?;

    let per_round = (4 * n * (n - 1)) as u64;
    let mut c = Counters::default();
    let mut converged = true;
    #[allow(unused_assignments)]
    let mut last_res = f64::INFINITY;
    loop {
        last_res = sync.step(&mut gt)?;
        c.rounds += 1;
        c.updates += per_round;
        if last_res < cfg.epsilon {
            break;
        }
        if budget.expired(c.updates) {
            converged = false;
            break;
        }
    }
    gt.store_messages(mrf, msgs);

    Ok(EngineStats {
        converged,
        wall_secs: timer.elapsed_secs(),
        metrics: MetricsReport::aggregate(&[c]),
        final_max_priority: last_res,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::ModelSpec;
    use crate::model::builders;

    #[test]
    fn detect_grid_models() {
        let m = builders::build(&ModelSpec::Ising { n: 5 }, 1);
        assert_eq!(detect_grid(&m), Some(5));
        let t = builders::build(&ModelSpec::Tree { n: 25 }, 1);
        assert_eq!(detect_grid(&t), None);
    }

    #[test]
    fn tensor_roundtrip_preserves_messages() {
        let m = builders::build(&ModelSpec::Potts { n: 4, q: 3 }, 3);
        let msgs = Messages::uniform(&m);
        // Perturb some messages.
        msgs.write_msg(&m, 0, &[0.3, 0.7]);
        msgs.write_msg(&m, 5, &[0.9, 0.1]);
        let snap = msgs.snapshot();

        let gt = GridTensors::from_mrf(&m, &msgs).unwrap();
        let msgs2 = Messages::uniform(&m);
        gt.store_messages(&m, &msgs2);
        assert_eq!(msgs2.snapshot(), snap);
    }

    #[test]
    fn edge_into_covers_every_message_once() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 1);
        let msgs = Messages::uniform(&m);
        let gt = GridTensors::from_mrf(&m, &msgs).unwrap();
        let mut seen = std::collections::HashSet::new();
        for d in 0..4 {
            for r in 0..4 {
                for c in 0..4 {
                    if let Some(e) = gt.edge_into(d, r, c) {
                        assert!(seen.insert(e), "edge {e} mapped twice");
                        // Verify dst is (r,c).
                        assert_eq!(
                            m.graph.edge_dst[e as usize] as usize,
                            r * 4 + c,
                            "direction {d} at ({r},{c})"
                        );
                    }
                }
            }
        }
        assert_eq!(seen.len(), m.num_messages());
    }

    #[test]
    fn factor_tensors_match_pool() {
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 7);
        let msgs = Messages::uniform(&m);
        let gt = GridTensors::from_mrf(&m, &msgs).unwrap();
        // Check one horizontal factor: right edge at (1,0) connects node 3→4.
        let k = gt.right[3] as usize;
        let fr = m.edge_factor[2 * k];
        let base = (1 * 2 + 0) * 4; // r*(n-1)+c with n-1=2
        for a in 0..2 {
            for b in 0..2 {
                assert_eq!(gt.h[base + 2 * a + b], m.pool.get(fr, a, b));
            }
        }
    }
}
