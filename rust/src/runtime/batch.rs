//! PJRT-backed batched message updates for binary models.
//!
//! The AOT artifact `batched_update_{B}.hlo.txt` (L2 JAX graph wrapping the
//! L1 Pallas kernel) computes, for a batch of `B` binary messages:
//!
//! ```text
//! new[b, j] = normalize_j( Σ_i prod[b, i] · ψ[b, i, j] )
//! res[b]    = ‖new[b, :] − cur[b, :]‖₂
//! ```
//!
//! Rust performs the graph-dependent *gather* (`prod` = node potential ×
//! incoming messages, via [`incoming_product`]) and ships the dense
//! matvec + normalize + residual to the kernel. Partial batches are padded
//! with identity work.

use super::{Executable, TensorIn};
use crate::bp::{incoming_product, msg_buf, Kernel, Messages, MsgSource};
use crate::engines::batched::BatchCompute;
use crate::model::Mrf;
use anyhow::{bail, Result};

/// Batch sizes for which `make artifacts` emits kernels by default.
pub const DEFAULT_BATCH_SIZES: &[usize] = &[64, 256, 1024];

/// Batched message-update frontend over the PJRT executable.
pub struct PjrtBatch {
    exe: Executable,
    /// Compiled batch width (inputs are padded to this).
    width: usize,
}

impl PjrtBatch {
    /// Load the smallest compiled batch width ≥ `batch` (or the largest
    /// available, with multiple kernel calls per batch).
    pub fn load_default(batch: usize) -> Result<PjrtBatch> {
        let width = DEFAULT_BATCH_SIZES
            .iter()
            .copied()
            .find(|&w| w >= batch)
            .unwrap_or(*DEFAULT_BATCH_SIZES.last().unwrap());
        let exe = Executable::load_named(&format!("batched_update_{width}"))?;
        Ok(PjrtBatch { exe, width })
    }

    /// The fixed batch width the artifact was lowered for.
    pub fn width(&self) -> usize {
        self.width
    }

    /// One kernel invocation over ≤ `width` edges.
    fn run_chunk(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        edges: &[u32],
        out: &mut [f64],
        residuals: &mut [f64],
    ) -> Result<()> {
        let w = self.width;
        if edges.len() > w {
            bail!("chunk larger than compiled batch width");
        }
        let stride = mrf.max_domain();
        debug_assert_eq!(stride, 2, "PJRT batch path requires binary domains");

        // Gather prod / psi / cur, padded to width with benign values.
        let mut prod = vec![0.5f64; w * 2];
        let mut psi = vec![0.0f64; w * 4];
        let mut cur = vec![0.5f64; w * 2];
        let mut buf = msg_buf();
        let mut tmp = msg_buf();
        for (k, &e) in edges.iter().enumerate() {
            let d = incoming_product(mrf, msgs, e, &mut buf, &mut tmp, Kernel::Scalar);
            debug_assert_eq!(d, 2);
            prod[2 * k] = buf[0];
            prod[2 * k + 1] = buf[1];
            let fr = mrf.edge_factor[e as usize];
            for a in 0..2 {
                for b in 0..2 {
                    psi[4 * k + 2 * a + b] = mrf.pool.get(fr, a, b);
                }
            }
            msgs.read_msg(mrf, e, &mut buf);
            cur[2 * k] = buf[0];
            cur[2 * k + 1] = buf[1];
        }
        // Identity work in the padding lanes (psi = I keeps them finite).
        for k in edges.len()..w {
            psi[4 * k] = 1.0;
            psi[4 * k + 3] = 1.0;
        }

        let w_i64 = w as i64;
        let outputs = self.exe.run(vec![
            TensorIn::new(prod, &[w_i64, 2]),
            TensorIn::new(psi, &[w_i64, 2, 2]),
            TensorIn::new(cur, &[w_i64, 2]),
        ])?;
        if outputs.len() != 2 {
            bail!("batched_update artifact must return (new, res)");
        }
        let new = &outputs[0];
        let res = &outputs[1];
        for (k, _e) in edges.iter().enumerate() {
            out[k * stride] = new[2 * k];
            out[k * stride + 1] = new[2 * k + 1];
            residuals[k] = res[k];
        }
        Ok(())
    }
}

impl BatchCompute for PjrtBatch {
    fn compute_batch(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        edges: &[u32],
        out: &mut [f64],
        residuals: &mut [f64],
    ) {
        let stride = mrf.max_domain();
        for (ci, chunk) in edges.chunks(self.width).enumerate() {
            let off = ci * self.width;
            if let Err(e) = self.run_chunk(
                mrf,
                msgs,
                chunk,
                &mut out[off * stride..],
                &mut residuals[off..],
            ) {
                // PJRT failure mid-run is unrecoverable for this batch;
                // fall back to the native path so the engine stays correct.
                eprintln!("[runtime] PJRT batch failed ({e}); native fallback");
                crate::engines::batched::NativeBatch { kernel: Kernel::Scalar }.compute_batch(
                    mrf,
                    msgs,
                    chunk,
                    &mut out[off * stride..(off + chunk.len()) * stride],
                    &mut residuals[off..off + chunk.len()],
                );
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent correctness tests live in rust/tests/pjrt_integration.rs
    // (they need `make artifacts` to have run); here we only check the
    // graceful failure path.
    use super::*;

    #[test]
    fn load_without_artifacts_errors() {
        if !super::super::artifacts_dir().join("batched_update_64.hlo.txt").exists() {
            assert!(PjrtBatch::load_default(64).is_err());
        }
    }

    #[test]
    fn width_selection_logic() {
        // Pure logic check (no artifact needed for the arithmetic).
        let pick = |batch: usize| {
            DEFAULT_BATCH_SIZES
                .iter()
                .copied()
                .find(|&w| w >= batch)
                .unwrap_or(*DEFAULT_BATCH_SIZES.last().unwrap())
        };
        assert_eq!(pick(1), 64);
        assert_eq!(pick(64), 64);
        assert_eq!(pick(65), 256);
        assert_eq!(pick(4096), 1024);
    }
}
