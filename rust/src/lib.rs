//! # relaxed-bp
//!
//! A complete reproduction of *Relaxed Scheduling for Scalable Belief
//! Propagation* (Aksenov, Alistarh, Korhonen, 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! - The **coordinator** (this crate) implements the paper's contribution:
//!   priority-based BP schedules parallelized through a relaxed Multiqueue
//!   scheduler, alongside every baseline the paper evaluates.
//! - **Build-time Python** (`python/compile/`) lowers the dense message
//!   update kernels (Pallas) and synchronous-sweep compute graphs (JAX) to
//!   HLO text, which the [`runtime`] module loads and executes through the
//!   PJRT CPU client — Python is never on the inference path.
//!
//! Performance is tracked as data: every run can record a convergence
//! trace ([`telemetry`]), and `relaxed-bp bench` writes versioned
//! `BENCH_<family>.json` baselines that future changes are diffed
//! against.
//!
//! See README.md for the quickstart and repo map, DESIGN.md for the
//! system inventory, and EXPERIMENTS.md for the paper-vs-measured record.

#![warn(missing_docs)]

pub mod benchlib;
pub mod bp;
pub mod cli;
pub mod configio;
pub mod coordinator;
pub mod engines;
pub mod exec;
pub mod harness;
pub mod model;
pub mod net;
pub mod run;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod util;
