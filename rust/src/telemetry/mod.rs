//! Convergence-trace telemetry and machine-readable performance baselines.
//!
//! The paper's contribution is empirical — relaxed Multiqueue scheduling
//! beats exact priority scheduling on wall-clock convergence — so this
//! crate records first-class performance data instead of write-only
//! markdown tables:
//!
//! - [`trace`] — [`TraceRecorder`] attaches to any engine run (through
//!   [`Engine::run_observed`](crate::engines::Engine::run_observed) /
//!   [`WorkerPool::run_observed`](crate::exec::WorkerPool::run_observed))
//!   and samples a [`Trace`] of counter snapshots + max residual on a
//!   background ticker;
//! - [`baseline`] — the versioned [`Baseline`] schema written to
//!   `BENCH_<family>.json` at the repo root, and [`compare`], the
//!   regression comparator future perf PRs are judged against;
//! - this module — the `bench` sweep driver ([`run_bench`]) behind the
//!   `relaxed-bp bench` CLI subcommand.
//!
//! ## `BENCH_<family>.json` schema (v1)
//!
//! ```text
//! {
//!   "schema_version": 1,
//!   "family": "ising",                  // tree | ising | potts | potts32
//!                                       // | ldpc | powerlaw
//!   "model": { "kind": "ising", "n": 8 }, // exact ModelSpec measured
//!   "git_rev": "010aee9",               // provenance
//!   "created_unix": 1753833600,
//!   "quick": true,                      // --quick sweeps never compare
//!                                       // against full ones
//!   "samples_per_cell": 2,
//!   "seed": 42,
//!   "cells": [
//!     {
//!       "id": "relaxed_residual/p2",    // comparator join key; affine
//!                                       // cells append "/<partition>",
//!                                       // fused-off cells "/edgewise",
//!                                       // scalar-kernel cells "/scalar",
//!                                       // warm-start cells "/delta"
//!       "algorithm": "relaxed_residual",
//!       "scheduler": "multiqueue",      // sequential | rounds | exact |
//!                                       // multiqueue | random
//!       "threads": 2,
//!       "partition": "off",             // off | affine | affine_bfs —
//!                                       // the locality axis (absent in
//!                                       // pre-partition baselines ⇒ off)
//!       "fused": true,                  // the refresh-shape axis (absent
//!                                       // in pre-fused baselines ⇒ false:
//!                                       // those measured edge-wise)
//!       "kernel": "simd",               // the data-path axis (absent in
//!                                       // pre-SIMD baselines ⇒ "scalar")
//!       "precision": "f32",             // the storage-precision axis
//!                                       // (absent in pre-precision
//!                                       // baselines ⇒ "f64"); f64 A/B
//!                                       // cells carry the "/f64" suffix
//!       "msg_bytes_logical": 16128,     // message-arena footprint gauges
//!       "msg_bytes_padded": 32768,      // (live + lookahead; absent ⇒ 0)
//!       "build_secs": 0.8,              // cold path: model build seconds
//!                                       // (once per family sweep; absent
//!                                       // in pre-coldpath baselines ⇒ 0)
//!       "load_secs": 0.0,               // cold path: model disk-load
//!                                       // seconds (absent ⇒ 0)
//!       "init_secs": 0.002,             // cold path: message-state init
//!                                       // seconds, last sample (absent ⇒ 0)
//!       "model_bytes": 0,               // cold path: serialized model
//!                                       // bytes on disk; 0 for in-process
//!                                       // builds (absent ⇒ 0)
//!       "load_mode": "read",            // out-of-core axis: how the model
//!                                       // came in — "map" = zero-copy
//!                                       // mapped v2 sections, "read" =
//!                                       // copying loads / in-process
//!                                       // builds (absent ⇒ "read")
//!       "arena": "mem",                 // out-of-core axis: message-arena
//!                                       // backing — "mem" heap, "mmap"
//!                                       // file-backed (absent ⇒ "mem")
//!       "peak_rss_bytes": 73400320,     // out-of-core axis: process VmHWM
//!                                       // after the last sample; a gauge
//!                                       // (absent ⇒ 0)
//!       "damping": 0.0,                 // update-blend axis: the sweep's
//!                                       // damping factor (absent in
//!                                       // pre-damping baselines ⇒ 0.0)
//!       "wall_secs": [0.012, 0.011],    // one entry per sample; on
//!                                       // "/delta" cells these are the
//!                                       // warm re-convergence times, on
//!                                       // "/dist2" cells the 2-rank
//!                                       // spawn times
//!       "updates": [4100, 4080],
//!       "scratch_wall_secs": [0.05, 0.048], // delta cells: cold re-solve
//!                                       // of the same perturbed instance
//!                                       // (empty on non-delta cells)
//!       "time_to_reconverge": 0.011,    // delta cells: median warm secs
//!       "tasks_touched": 24,            // delta cells: seeded frontier
//!                                       // size of the last warm sample
//!       "sp_wall_secs": [0.014, 0.013], // dist2 cells: same-run
//!                                       // single-process arm (empty on
//!                                       // non-dist cells)
//!       "boundary_msgs_sent": 1500,     // dist2 cells: merged boundary
//!       "boundary_msgs_recv": 1500,     // counters of the last 2-rank
//!       "boundary_bytes": 31500,        // sample (0 on non-dist cells;
//!       "exchange_batches": 12,         // absent ⇒ 0)
//!       "converged": true,
//!       "time_summary": { "n": 2, "mean": …, "stddev": …, "min": …,
//!                          "max": …, "median": …, "p05": …, "p95": … },
//!       "updates_summary": { … },       // derived; recomputed on load
//!       "trace": [                      // last sample's convergence trace
//!         { "t_secs": 0.004, "updates": 1500, "useful_updates": 1400,
//!           "wasted_pops": 60, "stale_pops": 35, "claim_failures": 5,
//!           "pops": 1600, "inserts": 1650, "refreshes": 4800,
//!           "insert_batches": 1500, "max_priority": 0.8 },
//!         …                             // refreshes / insert_batches
//!                                       // absent in pre-fused files ⇒ 0
//!       ]
//!     }, …
//!   ]
//! }
//! ```
//!
//! Keys are sorted (the crate's deterministic
//! [`Json`](crate::configio::Json)) so baselines diff cleanly under
//! `git diff`. Traces sample the lock-free
//! [`CounterBoard`](crate::coordinator::CounterBoard) every
//! [`BenchOpts::tick_ms`] milliseconds plus one exact start/end point, so
//! every cell's trace is non-empty regardless of run length. See
//! EXPERIMENTS.md §BENCH baselines for how to interpret the numbers on the
//! single-core reference container.

pub mod baseline;
pub mod trace;

pub use baseline::{
    compare, Baseline, BaselineDiff, CellDiff, CellResult, DEFAULT_TOLERANCE, SCHEMA_VERSION,
};
pub use trace::{Trace, TracePoint, TraceRecorder};

use crate::configio::{
    AlgorithmSpec, ArenaMode, Kernel, LoadMode, ModelSpec, PartitionSpec, Precision, RunConfig,
};
use crate::model::EvidenceDelta;
use crate::run::run_on_model_observed;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// The model families swept by default — the paper's §5.2 roster plus the
/// power-law locality workload and the wide-domain (q = 32) Potts grid
/// that exercises the SIMD kernel axis on dense matvecs (LDPC's indicator
/// factors are the only other wide-domain family).
pub const FAMILIES: &[&str] = &["tree", "ising", "potts", "potts32", "ldpc", "powerlaw"];

/// Configuration of one `bench` sweep.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Smoke-test mode: tiny instances, fewer samples. Quick baselines are
    /// marked in the JSON and never compared against full ones.
    pub quick: bool,
    /// Measured runs per cell.
    pub samples: usize,
    /// Thread counts swept for the concurrent engines.
    pub threads: Vec<usize>,
    /// Families to sweep (subset of [`FAMILIES`]).
    pub families: Vec<String>,
    /// Directory `BENCH_<family>.json` files land in (default: the repo
    /// root, found by walking up to `.git`).
    pub out_dir: PathBuf,
    /// RNG seed for model construction and scheduler randomness.
    pub seed: u64,
    /// Per-sample wall-clock limit in seconds.
    pub time_limit: f64,
    /// Trace sampling interval in milliseconds.
    pub tick_ms: u64,
    /// Regression tolerance passed to [`compare`].
    pub tolerance: f64,
    /// Locality axes swept for the relaxed contenders (the partition
    /// cells; default `{off, affine}` so the locality axis is captured
    /// in every baseline from day one).
    pub partitions: Vec<PartitionSpec>,
    /// Gate mode (`bench --check`): when a family regresses against its
    /// stored baseline, keep the stored file instead of overwriting it, so
    /// the gate stays red on re-runs until the regression is fixed (or the
    /// baseline is regenerated without `--check`).
    pub check: bool,
    /// Model-cache directory consulted before building each family's
    /// instance (`--load-model`): cached models are disk-loaded instead of
    /// rebuilt, and cells record `load_secs`/`model_bytes` for that leg.
    pub load_model: Option<PathBuf>,
    /// Model-cache directory built instances are saved into
    /// (`--save-model`, format v2) so later sweeps can `--load-model` them.
    pub save_model: Option<PathBuf>,
    /// How `--load-model` files are brought in (`--load-mode`): zero-copy
    /// mapped sections, copying reads, or auto (map with read fallback).
    pub load_mode: LoadMode,
    /// Message-arena backing for every cell's runs (`--arena`): heap or
    /// file-backed temp mappings. Sweep-wide, not a per-cell axis — the
    /// baselines measure scheduling, and `mmap` arenas on a fits-in-RAM
    /// instance measure the same thing through the page cache.
    pub arena: ArenaMode,
    /// Run checksum + semantic validation on mapped loads
    /// (`--verify-load`); off by default because full verification pages
    /// in every byte, costing exactly the copy pass mapping avoids.
    pub verify_load: bool,
    /// Damping factor applied to every cell's runs (`--damping`, the
    /// geometric message blend). Sweep-wide like `arena`; 0.0 keeps the
    /// historical undamped trajectories bit-identical.
    pub damping: f64,
}

impl BenchOpts {
    /// Full-sweep defaults (minutes on the reference container).
    pub fn full() -> Self {
        BenchOpts {
            quick: false,
            samples: 3,
            threads: vec![1, 2],
            families: FAMILIES.iter().map(|s| s.to_string()).collect(),
            out_dir: repo_root(),
            seed: 42,
            time_limit: 120.0,
            tick_ms: 25,
            tolerance: DEFAULT_TOLERANCE,
            partitions: vec![PartitionSpec::Off, PartitionSpec::affine()],
            check: false,
            load_model: None,
            save_model: None,
            load_mode: LoadMode::Auto,
            arena: ArenaMode::Mem,
            verify_load: false,
            damping: 0.0,
        }
    }

    /// Smoke-test defaults (seconds end to end; used by CI and the
    /// acceptance gate).
    pub fn quick() -> Self {
        BenchOpts {
            quick: true,
            samples: 2,
            threads: vec![1, 2],
            time_limit: 30.0,
            tick_ms: 2,
            ..Self::full()
        }
    }
}

/// Walk up from the current directory to the first ancestor containing
/// `.git` (the repo root); fall back to `.` when not inside a work tree.
/// `bench` writes its baselines there so the artifact location does not
/// depend on whether cargo was invoked from the repo root or `rust/`.
pub fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join(".git").exists() {
            return dir;
        }
        if !dir.pop() {
            return PathBuf::from(".");
        }
    }
}

/// `git rev-parse --short HEAD`, or `"unknown"` outside a work tree.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The scheduler kind behind an algorithm, for the baseline's
/// `scheduler` field.
pub fn scheduler_kind(alg: &AlgorithmSpec) -> &'static str {
    use AlgorithmSpec::*;
    match alg {
        SequentialResidual => "sequential",
        Synchronous | Bucket | RandomSynchronous { .. } => "rounds",
        CoarseGrained | Splash { .. } | SmartSplash { .. } | OptimalTree => "exact",
        RandomSplash { .. } => "random",
        RelaxedResidual
        | WeightDecay
        | Priority
        | RelaxedSmartSplash { .. }
        | RelaxedResidualBatched { .. }
        | RelaxedOptimalTree => "multiqueue",
    }
}

/// The model instance measured for `family` (tiny for `--quick`, moderate
/// for full sweeps — both far below the paper's sizes; the baselines track
/// *this repo against itself*, not against the paper).
pub fn family_spec(family: &str, quick: bool) -> Result<ModelSpec> {
    Ok(match (family, quick) {
        ("tree", true) => ModelSpec::Tree { n: 127 },
        ("tree", false) => ModelSpec::Tree { n: 20_000 },
        ("ising", true) => ModelSpec::Ising { n: 8 },
        ("ising", false) => ModelSpec::Ising { n: 40 },
        ("potts", true) => ModelSpec::Potts { n: 8, q: 3 },
        ("potts", false) => ModelSpec::Potts { n: 40, q: 3 },
        ("potts32", true) => ModelSpec::Potts { n: 6, q: 32 },
        ("potts32", false) => ModelSpec::Potts { n: 16, q: 32 },
        ("ldpc", true) => ModelSpec::Ldpc { n: 48, flip_prob: 0.05 },
        ("ldpc", false) => ModelSpec::Ldpc { n: 1_000, flip_prob: 0.07 },
        ("powerlaw", true) => ModelSpec::PowerLaw { n: 256, m: 2 },
        ("powerlaw", false) => ModelSpec::PowerLaw { n: 50_000, m: 2 },
        (other, _) => bail!("unknown bench family '{other}' (expected one of {FAMILIES:?})"),
    })
}

/// One swept bench cell: algorithm, thread count, and the four axes
/// (locality partition, fused/edgewise refresh shape, simd/scalar data
/// path, f32/f64 storage precision).
#[derive(Debug, Clone)]
struct RosterCell {
    alg: AlgorithmSpec,
    threads: usize,
    partition: PartitionSpec,
    fused: bool,
    kernel: Kernel,
    precision: Precision,
}

impl RosterCell {
    fn new(alg: AlgorithmSpec, threads: usize, partition: PartitionSpec) -> Self {
        RosterCell {
            alg,
            threads,
            partition,
            fused: true,
            kernel: Kernel::Simd,
            precision: Precision::F32,
        }
    }

    fn edgewise(mut self) -> Self {
        self.fused = false;
        self
    }

    fn scalar(mut self) -> Self {
        self.kernel = Kernel::Scalar;
        self
    }

    fn f64(mut self) -> Self {
        self.precision = Precision::F64;
        self
    }

    /// Cell id: all-axes-default cells keep the historical
    /// `<alg>/p<threads>` form; affine cells append the partition label,
    /// edgewise (fused-off) cells `/edgewise`, scalar-kernel cells
    /// `/scalar`, f64-storage cells `/f64`.
    fn id(&self) -> String {
        let mut id = match self.partition {
            PartitionSpec::Off => format!("{}/p{}", self.alg.name(), self.threads),
            _ => format!("{}/p{}/{}", self.alg.name(), self.threads, self.partition.label()),
        };
        if !self.fused {
            id.push_str("/edgewise");
        }
        if self.kernel == Kernel::Scalar {
            id.push_str("/scalar");
        }
        if self.precision == Precision::F64 {
            id.push_str("/f64");
        }
        id
    }
}

/// The {engine × scheduler × threads × partition × kernel × precision}
/// cells swept per family: the sequential exact baseline, the exact
/// concurrent PQ, the relaxed Multiqueue (once per locality axis in
/// [`BenchOpts::partitions`]), and relaxed smart splash at the highest
/// thread count. The relaxed contenders are additionally measured once
/// with the fused refresh off (`…/edgewise` cells), once with the scalar
/// data-path kernel (`…/scalar` cells), and once with f64 message storage
/// (`…/f64` cells — the bit-frozen arm; base cells store f32), so every
/// baseline records the same-run A/Bs — fused-vs-edgewise,
/// simd-vs-scalar, and f32-vs-f64 — each axis is judged by.
fn roster(opts: &BenchOpts) -> Vec<RosterCell> {
    use AlgorithmSpec::{CoarseGrained, RelaxedResidual, RelaxedSmartSplash, SequentialResidual};
    let mut cells = vec![RosterCell::new(SequentialResidual, 1, PartitionSpec::Off)];
    for &p in &opts.threads {
        cells.push(RosterCell::new(CoarseGrained, p, PartitionSpec::Off));
        for &part in &opts.partitions {
            cells.push(RosterCell::new(RelaxedResidual, p, part));
        }
        cells.push(RosterCell::new(RelaxedResidual, p, PartitionSpec::Off).edgewise());
        cells.push(RosterCell::new(RelaxedResidual, p, PartitionSpec::Off).scalar());
        cells.push(RosterCell::new(RelaxedResidual, p, PartitionSpec::Off).f64());
    }
    if let Some(&max_p) = opts.threads.iter().max() {
        for &part in &opts.partitions {
            cells.push(RosterCell::new(RelaxedSmartSplash { h: 2 }, max_p, part));
        }
        let base = RosterCell::new(RelaxedSmartSplash { h: 2 }, max_p, PartitionSpec::Off);
        cells.push(base.clone().edgewise());
        cells.push(base.clone().scalar());
        cells.push(base.f64());
    }
    cells
}

/// Sweep one family and assemble its [`Baseline`] (nothing is written).
pub fn bench_family(family: &str, opts: &BenchOpts) -> Result<Baseline> {
    let spec = family_spec(family, opts.quick)?;
    let (mrf, prep) = crate::run::obtain_model(
        &spec,
        opts.seed,
        opts.load_model.as_deref(),
        opts.save_model.as_deref(),
        opts.load_mode,
        opts.verify_load,
    )?;
    let recorder = TraceRecorder::new(Duration::from_millis(opts.tick_ms.max(1)));
    let mut cells = Vec::new();
    for rc in roster(opts) {
        let id = rc.id();
        eprintln!("[bench] {family} / {id} …");
        let mut wall_secs = Vec::with_capacity(opts.samples);
        let mut updates = Vec::with_capacity(opts.samples);
        let mut converged = true;
        let mut last_trace = Trace::default();
        let mut msg_bytes = (0u64, 0u64);
        let mut init_secs = 0.0f64;
        let mut peak_rss = 0u64;
        for _ in 0..opts.samples.max(1) {
            let mut cfg = RunConfig::new(spec.clone(), rc.alg.clone())
                .with_threads(rc.threads)
                .with_seed(opts.seed)
                .with_partition(rc.partition)
                .with_fused(rc.fused)
                .with_kernel(rc.kernel)
                .with_precision(rc.precision)
                .with_arena(opts.arena.clone())
                .with_damping(opts.damping);
            cfg.time_limit_secs = opts.time_limit;
            let rep = run_on_model_observed(&cfg, mrf.clone(), Some(&recorder))?;
            wall_secs.push(rep.stats.wall_secs);
            updates.push(rep.stats.metrics.total.updates as f64);
            converged &= rep.stats.converged;
            last_trace = recorder.take();
            msg_bytes = (
                rep.stats.metrics.total.msg_bytes_logical,
                rep.stats.metrics.total.msg_bytes_padded,
            );
            init_secs = rep.prep.init_secs;
            peak_rss = rep.stats.metrics.total.peak_rss_bytes;
        }
        cells.push(CellResult {
            id,
            algorithm: rc.alg.name(),
            scheduler: scheduler_kind(&rc.alg).to_string(),
            threads: rc.threads,
            partition: rc.partition.label().to_string(),
            fused: rc.fused,
            kernel: rc.kernel.label().to_string(),
            precision: rc.precision.label().to_string(),
            msg_bytes_logical: msg_bytes.0,
            msg_bytes_padded: msg_bytes.1,
            build_secs: prep.build_secs,
            load_secs: prep.load_secs,
            init_secs,
            model_bytes: prep.model_bytes,
            load_mode: prep.load_mode.label().to_string(),
            arena: opts.arena.label().to_string(),
            peak_rss_bytes: peak_rss,
            damping: opts.damping,
            wall_secs,
            updates,
            scratch_wall_secs: Vec::new(),
            time_to_reconverge: 0.0,
            tasks_touched: 0,
            sp_wall_secs: Vec::new(),
            boundary_msgs_sent: 0,
            boundary_msgs_recv: 0,
            boundary_bytes: 0,
            exchange_batches: 0,
            converged,
            trace: last_trace,
        });
    }
    cells.push(bench_delta_cell(family, &spec, &mrf, opts, &recorder, &prep)?);
    // Worker ranks exec the current binary unless RELAXED_BP_EXE points at
    // the real CLI, so under the lib's own `cargo test` harness (where the
    // current executable is the unit-test runner) the distributed cell is
    // skipped unless the caller provided the binary path. Integration
    // suites set RELAXED_BP_EXE; the production `bench` subcommand needs
    // no override — its current executable *is* the CLI.
    if !cfg!(test) || std::env::var("RELAXED_BP_EXE").is_ok() {
        cells.push(bench_dist_cell(family, &spec, &mrf, opts, &recorder, &prep)?);
    }
    Ok(Baseline {
        schema_version: SCHEMA_VERSION,
        family: family.to_string(),
        model: spec.to_json(),
        git_rev: git_rev(),
        created_unix: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0),
        quick: opts.quick,
        samples_per_cell: opts.samples.max(1),
        seed: opts.seed,
        cells,
    })
}

/// Prior fraction perturbed by the bench delta cell (the paper-scale
/// "small delta" workload: 0.1% of nodes, clamped to at least one).
pub const DELTA_FRACTION: f64 = 0.001;

/// Measure the warm-start (delta) cell for one family: perturb
/// [`DELTA_FRACTION`] of the priors, then re-converge the relaxed
/// contender at the highest thread count both cold (scratch solve of the
/// perturbed instance from uniform) and warm
/// ([`RunReport::resume_delta`](crate::run::RunReport::resume_delta) from
/// the resident converged state). `wall_secs` holds the warm times,
/// `scratch_wall_secs` the cold ones; `tasks_touched` is the seeded
/// frontier size of the last warm sample.
fn bench_delta_cell(
    family: &str,
    spec: &ModelSpec,
    mrf: &crate::model::Mrf,
    opts: &BenchOpts,
    recorder: &TraceRecorder,
    prep: &crate::run::PrepStats,
) -> Result<CellResult> {
    let max_p = opts.threads.iter().copied().max().unwrap_or(1);
    let rc = RosterCell::new(AlgorithmSpec::RelaxedResidual, max_p, PartitionSpec::Off);
    let id = format!("{}/delta", rc.id());
    eprintln!("[bench] {family} / {id} …");
    let delta = EvidenceDelta::random_perturbation(mrf, DELTA_FRACTION, opts.seed);
    let mut wall_secs = Vec::with_capacity(opts.samples);
    let mut scratch_wall_secs = Vec::with_capacity(opts.samples);
    let mut updates = Vec::with_capacity(opts.samples);
    let mut converged = true;
    let mut last_trace = Trace::default();
    let mut msg_bytes = (0u64, 0u64);
    let mut tasks_touched = 0u64;
    let mut init_secs = 0.0f64;
    let mut peak_rss = 0u64;
    for _ in 0..opts.samples.max(1) {
        let mut cfg = RunConfig::new(spec.clone(), rc.alg.clone())
            .with_threads(rc.threads)
            .with_seed(opts.seed)
            .with_partition(rc.partition)
            .with_fused(rc.fused)
            .with_kernel(rc.kernel)
            .with_precision(rc.precision)
            .with_arena(opts.arena.clone())
            .with_damping(opts.damping);
        cfg.time_limit_secs = opts.time_limit;
        // Cold arm: solve the perturbed instance from uniform messages.
        let mut scratch_mrf = mrf.clone();
        delta.apply(&mut scratch_mrf);
        let cold = run_on_model_observed(&cfg, scratch_mrf, None)?;
        scratch_wall_secs.push(cold.stats.wall_secs);
        converged &= cold.stats.converged;
        // Warm arm: converge the base instance (untimed), then resume
        // across the delta from the resident message state.
        let mut rep = run_on_model_observed(&cfg, mrf.clone(), None)?;
        converged &= rep.stats.converged;
        rep.resume_delta(&delta, Some(recorder))?;
        wall_secs.push(rep.stats.wall_secs);
        updates.push(rep.stats.metrics.total.updates as f64);
        converged &= rep.stats.converged;
        tasks_touched = rep.stats.metrics.total.tasks_touched;
        last_trace = recorder.take();
        msg_bytes = (
            rep.stats.metrics.total.msg_bytes_logical,
            rep.stats.metrics.total.msg_bytes_padded,
        );
        init_secs = rep.prep.init_secs;
        peak_rss = rep.stats.metrics.total.peak_rss_bytes;
    }
    let time_to_reconverge =
        crate::util::stats::Summary::of(&wall_secs).map_or(0.0, |s| s.median);
    Ok(CellResult {
        id,
        algorithm: rc.alg.name(),
        scheduler: scheduler_kind(&rc.alg).to_string(),
        threads: rc.threads,
        partition: rc.partition.label().to_string(),
        fused: rc.fused,
        kernel: rc.kernel.label().to_string(),
        precision: rc.precision.label().to_string(),
        msg_bytes_logical: msg_bytes.0,
        msg_bytes_padded: msg_bytes.1,
        build_secs: prep.build_secs,
        load_secs: prep.load_secs,
        init_secs,
        model_bytes: prep.model_bytes,
        load_mode: prep.load_mode.label().to_string(),
        arena: opts.arena.label().to_string(),
        peak_rss_bytes: peak_rss,
        damping: opts.damping,
        wall_secs,
        updates,
        scratch_wall_secs,
        time_to_reconverge,
        tasks_touched,
        sp_wall_secs: Vec::new(),
        boundary_msgs_sent: 0,
        boundary_msgs_recv: 0,
        boundary_bytes: 0,
        exchange_batches: 0,
        converged,
        trace: last_trace,
    })
}

/// Measure the distributed (`/dist2`) cell for one family: the relaxed
/// contender at the highest thread count solved once per sample as a
/// 2-rank local spawn (rank 0 in-process, the worker rank forked from the
/// CLI binary, boundary messages batched over loopback TCP) and once
/// single-process in the same run — the arm CI's localhost floor is
/// judged against. `wall_secs` holds the 2-rank times, `sp_wall_secs` the
/// single-process ones; the boundary counters come from the merged
/// distributed report of the last sample. The trace is the
/// single-process arm's (the spawn path crosses process boundaries and
/// has no observer hook). Both arms rebuild the model from
/// `(spec, seed)` — the deterministic builders make that the same
/// instance [`bench_family`] measured.
fn bench_dist_cell(
    family: &str,
    spec: &ModelSpec,
    mrf: &crate::model::Mrf,
    opts: &BenchOpts,
    recorder: &TraceRecorder,
    prep: &crate::run::PrepStats,
) -> Result<CellResult> {
    let max_p = opts.threads.iter().copied().max().unwrap_or(1);
    let rc = RosterCell::new(AlgorithmSpec::RelaxedResidual, max_p, PartitionSpec::Off);
    let id = format!("{}/dist2", rc.id());
    eprintln!("[bench] {family} / {id} …");
    let mut wall_secs = Vec::with_capacity(opts.samples);
    let mut sp_wall_secs = Vec::with_capacity(opts.samples);
    let mut updates = Vec::with_capacity(opts.samples);
    let mut converged = true;
    let mut last_trace = Trace::default();
    let mut msg_bytes = (0u64, 0u64);
    let mut boundary = (0u64, 0u64, 0u64, 0u64);
    let mut init_secs = 0.0f64;
    let mut peak_rss = 0u64;
    for _ in 0..opts.samples.max(1) {
        let mut cfg = RunConfig::new(spec.clone(), rc.alg.clone())
            .with_threads(rc.threads)
            .with_seed(opts.seed)
            .with_partition(rc.partition)
            .with_fused(rc.fused)
            .with_kernel(rc.kernel)
            .with_precision(rc.precision)
            .with_arena(opts.arena.clone())
            .with_damping(opts.damping);
        cfg.time_limit_secs = opts.time_limit;
        // Single-process arm (observed: the cell's trace).
        let sp = run_on_model_observed(&cfg, mrf.clone(), Some(recorder))?;
        sp_wall_secs.push(sp.stats.wall_secs);
        converged &= sp.stats.converged;
        last_trace = recorder.take();
        init_secs = sp.prep.init_secs;
        // 2-rank spawn arm (merged report across ranks).
        let dist = crate::net::run_spawn(&cfg, 2)?;
        wall_secs.push(dist.stats.wall_secs);
        updates.push(dist.stats.metrics.total.updates as f64);
        converged &= dist.stats.converged;
        let t = &dist.stats.metrics.total;
        boundary =
            (t.boundary_msgs_sent, t.boundary_msgs_recv, t.boundary_bytes, t.exchange_batches);
        msg_bytes = (t.msg_bytes_logical, t.msg_bytes_padded);
        peak_rss = t.peak_rss_bytes;
    }
    Ok(CellResult {
        id,
        algorithm: rc.alg.name(),
        scheduler: scheduler_kind(&rc.alg).to_string(),
        threads: rc.threads,
        partition: rc.partition.label().to_string(),
        fused: rc.fused,
        kernel: rc.kernel.label().to_string(),
        precision: rc.precision.label().to_string(),
        msg_bytes_logical: msg_bytes.0,
        msg_bytes_padded: msg_bytes.1,
        build_secs: prep.build_secs,
        load_secs: prep.load_secs,
        init_secs,
        model_bytes: prep.model_bytes,
        load_mode: prep.load_mode.label().to_string(),
        arena: opts.arena.label().to_string(),
        peak_rss_bytes: peak_rss,
        damping: opts.damping,
        wall_secs,
        updates,
        scratch_wall_secs: Vec::new(),
        time_to_reconverge: 0.0,
        tasks_touched: 0,
        sp_wall_secs,
        boundary_msgs_sent: boundary.0,
        boundary_msgs_recv: boundary.1,
        boundary_bytes: boundary.2,
        exchange_batches: boundary.3,
        converged,
        trace: last_trace,
    })
}

/// One family's sweep outcome: where the baseline landed and, when a
/// previous baseline existed, the diff against it.
#[derive(Debug)]
pub struct BenchOutcome {
    /// `BENCH_<family>.json` path.
    pub path: PathBuf,
    /// The freshly measured baseline. Written to `path`, except in
    /// [`BenchOpts::check`] mode when a regression was detected — then the
    /// file still holds the previous (stored) baseline.
    pub baseline: Baseline,
    /// Diff against the previous baseline at `path`, when one existed and
    /// was comparable (same quick/full mode).
    pub diff: Option<BaselineDiff>,
}

/// Run the full sweep: measure every requested family, diff against any
/// existing `BENCH_<family>.json`, then overwrite it with the new
/// baseline. Regressions are reported in the returned outcomes (and
/// rendered by the CLI). In gate mode ([`BenchOpts::check`]) a regressed
/// family keeps its stored baseline — otherwise overwriting would make
/// the very next `--check` run compare regressed-vs-regressed and pass —
/// and a stored baseline that cannot be compared at all (e.g. quick vs
/// full) is a fatal gate error rather than a silent overwrite.
pub fn run_bench(opts: &BenchOpts) -> Result<Vec<BenchOutcome>> {
    if opts.tolerance.is_nan() || opts.tolerance <= 1.0 {
        bail!("tolerance must be > 1.0 (got {}); e.g. 1.5 flags a 1.5x slowdown", opts.tolerance);
    }
    std::fs::create_dir_all(&opts.out_dir)?;
    let mut outcomes = Vec::new();
    for family in &opts.families {
        let baseline = bench_family(family, opts)?;
        let path = baseline_path(&opts.out_dir, family);
        let diff = match Baseline::load(&path) {
            Ok(old) => match compare(&old, &baseline, opts.tolerance) {
                Ok(d) => Some(d),
                Err(e) if opts.check => {
                    return Err(e.context(format!(
                        "{}: stored baseline is not comparable; refusing to overwrite it in \
                         --check mode (regenerate without --check first)",
                        path.display()
                    )));
                }
                Err(e) => {
                    eprintln!("[bench] {}: not comparable ({e}); overwriting", path.display());
                    None
                }
            },
            Err(_) if !path.exists() => None,
            Err(e) => {
                eprintln!(
                    "[bench] {}: unreadable previous baseline ({e}); overwriting",
                    path.display()
                );
                None
            }
        };
        let regressed = diff.as_ref().is_some_and(BaselineDiff::has_regression);
        if opts.check && regressed {
            eprintln!(
                "[bench] {}: regression detected; keeping stored baseline (--check)",
                path.display()
            );
        } else {
            baseline.save(&path)?;
            eprintln!("[bench] wrote {}", path.display());
        }
        outcomes.push(BenchOutcome { path, baseline, diff });
    }
    Ok(outcomes)
}

/// `<dir>/BENCH_<FAMILY>.json`.
pub fn baseline_path(dir: &Path, family: &str) -> PathBuf {
    dir.join(format!("BENCH_{}.json", family.to_ascii_uppercase()))
}

/// Render a compact per-family summary table (markdown) of a baseline —
/// the human-facing view printed after a sweep.
pub fn render_summary(b: &Baseline) -> String {
    let mut s = format!(
        "### BENCH {} (rev {}, {} samples/cell{})\n\n",
        b.family,
        b.git_rev,
        b.samples_per_cell,
        if b.quick { ", quick" } else { "" }
    );
    s.push_str(
        "| cell | scheduler | partition | refresh | kernel | prec | arena KiB | median time | updates (median) | trace pts | converged |\n",
    );
    s.push_str("|---|---|---|---|---|---|---|---|---|---|---|\n");
    for c in &b.cells {
        let med = c.median_secs().unwrap_or(f64::NAN);
        let upd = crate::util::stats::Summary::of(&c.updates).map_or(0.0, |u| u.median);
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {:.1} | {} | {:.0} | {} | {} |\n",
            c.id,
            c.scheduler,
            c.partition,
            if c.fused { "fused" } else { "edgewise" },
            c.kernel,
            c.precision,
            c.msg_bytes_padded as f64 / 1024.0,
            crate::util::fmt_duration(med),
            upd,
            c.trace.len(),
            if c.converged { "yes" } else { "NO" },
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_specs_resolve() {
        for f in FAMILIES {
            assert!(family_spec(f, true).is_ok());
            assert!(family_spec(f, false).is_ok());
        }
        assert!(family_spec("nope", true).is_err());
    }

    #[test]
    fn roster_covers_contenders() {
        let opts = BenchOpts::quick();
        let cells = roster(&opts);
        assert!(cells.iter().any(|c| c.alg == AlgorithmSpec::SequentialResidual));
        assert!(cells
            .iter()
            .any(|c| c.alg == AlgorithmSpec::RelaxedResidual && c.threads == 2));
        assert!(cells.iter().any(|c| c.alg == AlgorithmSpec::CoarseGrained));
        // The locality axis is part of the default sweep.
        assert!(cells
            .iter()
            .any(|c| c.alg == AlgorithmSpec::RelaxedResidual && c.partition.is_on()));
        // The refresh-shape axis: every relaxed contender gets a
        // fused-off (edgewise) A/B cell.
        assert!(cells
            .iter()
            .any(|c| c.alg == AlgorithmSpec::RelaxedResidual && !c.fused));
        assert!(cells
            .iter()
            .any(|c| c.alg == AlgorithmSpec::RelaxedSmartSplash { h: 2 } && !c.fused));
        // The data-path axis: every relaxed contender gets a scalar A/B
        // cell, and the default cells run the simd kernel.
        assert!(cells
            .iter()
            .any(|c| c.alg == AlgorithmSpec::RelaxedResidual && c.kernel == Kernel::Scalar));
        assert!(cells.iter().any(|c| {
            c.alg == AlgorithmSpec::RelaxedSmartSplash { h: 2 } && c.kernel == Kernel::Scalar
        }));
        assert!(cells
            .iter()
            .filter(|c| c.kernel == Kernel::Simd)
            .count() > cells.len() / 2);
        // The storage-precision axis: base cells showcase f32, and every
        // relaxed contender gets a bit-frozen f64 A/B twin.
        assert!(cells
            .iter()
            .any(|c| c.alg == AlgorithmSpec::RelaxedResidual && c.precision == Precision::F64));
        assert!(cells.iter().any(|c| {
            c.alg == AlgorithmSpec::RelaxedSmartSplash { h: 2 } && c.precision == Precision::F64
        }));
        assert!(cells
            .iter()
            .filter(|c| c.precision == Precision::F32)
            .count() > cells.len() / 2);
    }

    #[test]
    fn roster_cells_have_distinct_ids() {
        let opts = BenchOpts::quick();
        let cells = roster(&opts);
        let ids: std::collections::HashSet<String> = cells.iter().map(RosterCell::id).collect();
        assert_eq!(ids.len(), cells.len(), "no duplicate cells");
        // Suffix policy: axis-default ids keep the historical form.
        assert!(ids.contains("relaxed_residual/p2"));
        assert!(ids.contains("relaxed_residual/p2/edgewise"));
        assert!(ids.contains("relaxed_residual/p2/scalar"));
        assert!(ids.contains("relaxed_residual/p2/f64"));
    }

    #[test]
    fn scheduler_kinds() {
        assert_eq!(scheduler_kind(&AlgorithmSpec::SequentialResidual), "sequential");
        assert_eq!(scheduler_kind(&AlgorithmSpec::CoarseGrained), "exact");
        assert_eq!(scheduler_kind(&AlgorithmSpec::RelaxedResidual), "multiqueue");
        assert_eq!(scheduler_kind(&AlgorithmSpec::RandomSplash { h: 2 }), "random");
        assert_eq!(scheduler_kind(&AlgorithmSpec::Synchronous), "rounds");
    }

    #[test]
    fn baseline_paths_uppercase_family() {
        assert_eq!(
            baseline_path(Path::new("/x"), "ising"),
            PathBuf::from("/x/BENCH_ISING.json")
        );
    }

    #[test]
    fn bench_family_quick_tree_end_to_end() {
        let mut opts = BenchOpts::quick();
        opts.samples = 1;
        opts.threads = vec![2];
        let b = bench_family("tree", &opts).unwrap();
        assert_eq!(b.family, "tree");
        assert!(b.cells.len() >= 3);
        for c in &b.cells {
            assert!(c.converged, "{} did not converge", c.id);
            assert!(!c.trace.is_empty(), "{} trace is empty", c.id);
            assert_eq!(c.wall_secs.len(), 1);
            assert_eq!(c.load_mode, "read", "in-process builds report the read path");
            assert_eq!(c.arena, "mem", "default sweeps use heap arenas");
            if cfg!(target_os = "linux") {
                assert!(c.peak_rss_bytes > 0, "{}: RSS gauge not sampled", c.id);
            }
            let last = c.trace.points.last().unwrap();
            assert!(last.max_priority < 1e-4, "{}: final priority {}", c.id, last.max_priority);
        }
        let summary = render_summary(&b);
        assert!(summary.contains("relaxed_residual/p2"));
        // The delta axis contributes one warm-start cell per family.
        let d = b.cells.iter().find(|c| c.id == "relaxed_residual/p2/delta").unwrap();
        assert_eq!(d.scratch_wall_secs.len(), d.wall_secs.len());
        assert!(d.tasks_touched > 0, "warm resume seeded no frontier");
        assert!(d.time_to_reconverge > 0.0);
        // Non-delta cells keep the delta fields at their zero defaults.
        let base = b.cells.iter().find(|c| c.id == "relaxed_residual/p2").unwrap();
        assert!(base.scratch_wall_secs.is_empty());
        assert_eq!(base.tasks_touched, 0);
        // The sweep ran undamped, and every cell records the axis.
        assert!(b.cells.iter().all(|c| c.damping == 0.0));
        // The dist2 cell needs RELAXED_BP_EXE to fork worker ranks; under
        // the unit-test harness (no override set) it is skipped — the
        // integration suite exercises it with the real binary.
        if std::env::var("RELAXED_BP_EXE").is_err() {
            assert!(!b.cells.iter().any(|c| c.id.ends_with("/dist2")));
        }
    }
}
