//! Machine-readable performance baselines (`BENCH_<family>.json`) and the
//! regression comparator.
//!
//! A [`Baseline`] is the versioned record of one `bench` sweep over a
//! model family: per-cell wall-clock/update samples, robust summary
//! statistics, a convergence [`Trace`], and enough provenance (git rev,
//! seed, schema version) to interpret it later. Serialization is the
//! crate's deterministic [`Json`] (sorted keys), so baselines diff cleanly
//! under `git diff`.
//!
//! See the `telemetry` module docs for the full schema; EXPERIMENTS.md
//! documents how to read the numbers on this single-core container.

use super::trace::Trace;
use crate::configio::{parse, Json};
use crate::util::stats::Summary;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Version of the `BENCH_*.json` schema; bump on incompatible change.
pub const SCHEMA_VERSION: u64 = 1;

/// Default regression tolerance: a cell is flagged when its median
/// wall-clock grows by more than this factor over the stored baseline.
/// Generous because the reference container is small and shared; perf PRs
/// that need tighter gates can pass their own tolerance.
pub const DEFAULT_TOLERANCE: f64 = 1.5;

/// One benchmark cell: an (algorithm, scheduler, threads) point measured
/// `samples` times on one model family.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    /// Stable identifier, `"<algorithm>/p<threads>"` — the comparator's
    /// join key across baselines.
    pub id: String,
    /// Algorithm display name (`AlgorithmSpec::name`).
    pub algorithm: String,
    /// Scheduler kind behind the algorithm (`exact`, `multiqueue`,
    /// `random`, `sequential`, `rounds`).
    pub scheduler: String,
    /// Worker thread count.
    pub threads: usize,
    /// Locality axis of the cell (`off`, `affine`, `affine_bfs`); cells
    /// from pre-partition baselines parse as `off`.
    pub partition: String,
    /// Whether the node-centric fused update kernel was on for this cell
    /// (`RunConfig::fused`); edgewise A/B cells carry the `/edgewise` id
    /// suffix. Absent in pre-fused baselines ⇒ `true` is *not* assumed —
    /// those cells predate the kernel, so they parse as `false`.
    pub fused: bool,
    /// Data-path kernel of the cell (`RunConfig::kernel`: `simd` or
    /// `scalar`); scalar A/B cells carry the `/scalar` id suffix. Absent
    /// in pre-SIMD baselines ⇒ `scalar` — those cells measured the
    /// historical per-element path.
    pub kernel: String,
    /// Storage precision of the message arenas (`RunConfig::precision`:
    /// `f32` or `f64`); f64 A/B cells carry the `/f64` id suffix. Absent
    /// in pre-precision baselines ⇒ `f64` — the only storage those cells
    /// could have measured.
    pub precision: String,
    /// Logical message-arena bytes (live + lookahead cache) of the last
    /// sample — a gauge; absent in pre-precision baselines ⇒ 0.
    pub msg_bytes_logical: u64,
    /// Allocated (cache-line-padded) message-arena bytes, same scope;
    /// absent ⇒ 0.
    pub msg_bytes_padded: u64,
    /// Cold path: seconds spent building the family's model in process
    /// (amortized across the family's cells — the model is built once per
    /// sweep). Absent in pre-coldpath baselines ⇒ 0.
    pub build_secs: f64,
    /// Cold path: seconds spent loading the model from disk (zero unless
    /// the sweep ran against a `--load-model` file). Absent ⇒ 0.
    pub load_secs: f64,
    /// Cold path: message-state initialization seconds of the last
    /// sample. Absent ⇒ 0.
    pub init_secs: f64,
    /// Cold path: serialized model size on disk in bytes (zero for
    /// in-process builds). Absent ⇒ 0.
    pub model_bytes: u64,
    /// Out-of-core axis: the load path that produced the family's model
    /// (`map` = zero-copy mapped v2 sections, `read` = copying loads and
    /// in-process builds). Absent in pre-outofcore baselines ⇒ `read` —
    /// the only path those cells could have taken.
    pub load_mode: String,
    /// Out-of-core axis: the message-arena backing of the cell's runs
    /// (`mem` = heap, `mmap` = file-backed temp mappings). Absent ⇒ `mem`.
    pub arena: String,
    /// Out-of-core axis: process peak resident set (`VmHWM`, bytes) after
    /// the cell's last sample — a **gauge**; 0 without procfs. Absent ⇒ 0.
    pub peak_rss_bytes: u64,
    /// Update-blend axis: the damping factor of the cell's runs
    /// (`RunConfig::damping`, sweep-wide like `arena`). Absent in
    /// pre-damping baselines ⇒ 0.0 — those cells ran undamped.
    pub damping: f64,
    /// Per-sample wall-clock seconds. For delta cells (`/delta` id
    /// suffix) these are the *warm* re-convergence times; for distributed
    /// cells (`/dist2`) the 2-rank spawn times.
    pub wall_secs: Vec<f64>,
    /// Per-sample committed update counts.
    pub updates: Vec<f64>,
    /// Delta axis: per-sample wall-clock of the scratch (cold, from
    /// uniform) solve of the same perturbed instance the warm samples
    /// re-converged. Empty for non-delta cells; absent in pre-delta
    /// baselines ⇒ empty.
    pub scratch_wall_secs: Vec<f64>,
    /// Delta axis: median warm re-convergence seconds (the primary
    /// warm-start statistic; equals the median of `wall_secs` on delta
    /// cells). 0 for non-delta cells; absent ⇒ 0.
    pub time_to_reconverge: f64,
    /// Delta axis: seeded frontier size of the last warm sample
    /// (`Counters::tasks_touched`). 0 for non-delta cells; absent ⇒ 0.
    pub tasks_touched: u64,
    /// Distributed axis: per-sample wall-clock of the same-run
    /// single-process arm a `/dist2` cell's 2-rank spawn samples are
    /// judged against. Empty for non-distributed cells; absent ⇒ empty.
    pub sp_wall_secs: Vec<f64>,
    /// Distributed axis: boundary messages shipped off-rank over the last
    /// 2-rank sample, summed across ranks (origin-side count). 0 for
    /// non-distributed cells; absent ⇒ 0.
    pub boundary_msgs_sent: u64,
    /// Distributed axis: boundary messages applied from the wire, summed
    /// across ranks — equals `boundary_msgs_sent` on a clean run (the
    /// counters are end-to-end; relay hops are excluded). Absent ⇒ 0.
    pub boundary_msgs_recv: u64,
    /// Distributed axis: boundary payload bytes on the wire over the last
    /// 2-rank sample. 0 for non-distributed cells; absent ⇒ 0.
    pub boundary_bytes: u64,
    /// Distributed axis: coalesced exchange batches flushed over the last
    /// 2-rank sample. 0 for non-distributed cells; absent ⇒ 0.
    pub exchange_batches: u64,
    /// Whether every sample converged within budget.
    pub converged: bool,
    /// Convergence trace of the last sample.
    pub trace: Trace,
}

impl CellResult {
    /// Robust summary of the wall-clock samples (`None` when empty).
    pub fn time_summary(&self) -> Option<Summary> {
        Summary::of(&self.wall_secs)
    }

    /// Median wall-clock seconds — the comparator's primary statistic
    /// (robust to one slow outlier sample).
    pub fn median_secs(&self) -> Option<f64> {
        self.time_summary().map(|s| s.median)
    }

    /// Serialize to the BENCH schema. Summaries are derived from the
    /// samples and included for human diffing; they are recomputed (not
    /// trusted) on load.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::Str(self.id.clone())),
            ("algorithm", Json::Str(self.algorithm.clone())),
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("partition", Json::Str(self.partition.clone())),
            ("fused", Json::Bool(self.fused)),
            ("kernel", Json::Str(self.kernel.clone())),
            ("precision", Json::Str(self.precision.clone())),
            ("msg_bytes_logical", Json::Num(self.msg_bytes_logical as f64)),
            ("msg_bytes_padded", Json::Num(self.msg_bytes_padded as f64)),
            // Cold-path fields are emitted unconditionally (zero when the
            // leg was not exercised) so schema consumers can grep for them.
            ("build_secs", Json::Num(self.build_secs)),
            ("load_secs", Json::Num(self.load_secs)),
            ("init_secs", Json::Num(self.init_secs)),
            ("model_bytes", Json::Num(self.model_bytes as f64)),
            // Out-of-core fields are emitted unconditionally (their
            // defaults when the axis was off) so schema consumers can grep
            // for them.
            ("load_mode", Json::Str(self.load_mode.clone())),
            ("arena", Json::Str(self.arena.clone())),
            ("peak_rss_bytes", Json::Num(self.peak_rss_bytes as f64)),
            // The update-blend axis is emitted unconditionally (0.0 when
            // the sweep ran undamped) so schema consumers can grep for it.
            ("damping", Json::Num(self.damping)),
            ("wall_secs", Json::Arr(self.wall_secs.iter().map(|&t| Json::Num(t)).collect())),
            ("updates", Json::Arr(self.updates.iter().map(|&u| Json::Num(u)).collect())),
            // Delta-axis fields are emitted unconditionally (zero/empty on
            // non-delta cells) so schema consumers can grep for them.
            (
                "scratch_wall_secs",
                Json::Arr(self.scratch_wall_secs.iter().map(|&t| Json::Num(t)).collect()),
            ),
            ("time_to_reconverge", Json::Num(self.time_to_reconverge)),
            ("tasks_touched", Json::Num(self.tasks_touched as f64)),
            // Distributed-axis fields are emitted unconditionally
            // (zero/empty on non-dist cells) so schema consumers can grep
            // for them.
            (
                "sp_wall_secs",
                Json::Arr(self.sp_wall_secs.iter().map(|&t| Json::Num(t)).collect()),
            ),
            ("boundary_msgs_sent", Json::Num(self.boundary_msgs_sent as f64)),
            ("boundary_msgs_recv", Json::Num(self.boundary_msgs_recv as f64)),
            ("boundary_bytes", Json::Num(self.boundary_bytes as f64)),
            ("exchange_batches", Json::Num(self.exchange_batches as f64)),
            ("converged", Json::Bool(self.converged)),
            ("trace", self.trace.to_json()),
        ];
        if let Some(s) = self.time_summary() {
            fields.push(("time_summary", s.to_json()));
        }
        if let Some(s) = Summary::of(&self.updates) {
            fields.push(("updates_summary", s.to_json()));
        }
        Json::obj(fields)
    }

    /// Parse one cell (summaries ignored; recomputed from samples).
    pub fn from_json(v: &Json) -> Result<CellResult> {
        let s = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("cell.{k} missing"))
        };
        let arr = |k: &str| -> Result<Vec<f64>> {
            v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("cell.{k} missing"))?
                .iter()
                .map(|x| x.as_f64().ok_or_else(|| anyhow!("cell.{k}: non-numeric sample")))
                .collect()
        };
        Ok(CellResult {
            id: s("id")?,
            algorithm: s("algorithm")?,
            scheduler: s("scheduler")?,
            threads: v
                .get("threads")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("cell.threads missing"))?,
            partition: v
                .get("partition")
                .and_then(Json::as_str)
                .unwrap_or("off")
                .to_string(),
            fused: v.get("fused").and_then(Json::as_bool).unwrap_or(false),
            kernel: v
                .get("kernel")
                .and_then(Json::as_str)
                .unwrap_or("scalar")
                .to_string(),
            precision: v
                .get("precision")
                .and_then(Json::as_str)
                .unwrap_or("f64")
                .to_string(),
            msg_bytes_logical: v.get("msg_bytes_logical").and_then(Json::as_u64).unwrap_or(0),
            msg_bytes_padded: v.get("msg_bytes_padded").and_then(Json::as_u64).unwrap_or(0),
            build_secs: v.get("build_secs").and_then(Json::as_f64).unwrap_or(0.0),
            load_secs: v.get("load_secs").and_then(Json::as_f64).unwrap_or(0.0),
            init_secs: v.get("init_secs").and_then(Json::as_f64).unwrap_or(0.0),
            model_bytes: v.get("model_bytes").and_then(Json::as_u64).unwrap_or(0),
            load_mode: v
                .get("load_mode")
                .and_then(Json::as_str)
                .unwrap_or("read")
                .to_string(),
            arena: v.get("arena").and_then(Json::as_str).unwrap_or("mem").to_string(),
            peak_rss_bytes: v.get("peak_rss_bytes").and_then(Json::as_u64).unwrap_or(0),
            damping: v.get("damping").and_then(Json::as_f64).unwrap_or(0.0),
            wall_secs: arr("wall_secs")?,
            updates: arr("updates")?,
            scratch_wall_secs: if v.get("scratch_wall_secs").is_some() {
                arr("scratch_wall_secs")?
            } else {
                Vec::new()
            },
            time_to_reconverge: v
                .get("time_to_reconverge")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            tasks_touched: v.get("tasks_touched").and_then(Json::as_u64).unwrap_or(0),
            sp_wall_secs: if v.get("sp_wall_secs").is_some() {
                arr("sp_wall_secs")?
            } else {
                Vec::new()
            },
            boundary_msgs_sent: v.get("boundary_msgs_sent").and_then(Json::as_u64).unwrap_or(0),
            boundary_msgs_recv: v.get("boundary_msgs_recv").and_then(Json::as_u64).unwrap_or(0),
            boundary_bytes: v.get("boundary_bytes").and_then(Json::as_u64).unwrap_or(0),
            exchange_batches: v.get("exchange_batches").and_then(Json::as_u64).unwrap_or(0),
            converged: v
                .get("converged")
                .and_then(Json::as_bool)
                .ok_or_else(|| anyhow!("cell.converged missing"))?,
            trace: Trace::from_json(
                v.get("trace").ok_or_else(|| anyhow!("cell.trace missing"))?,
            )?,
        })
    }
}

/// A versioned per-family benchmark baseline — the content of one
/// `BENCH_<family>.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct Baseline {
    /// Schema version ([`SCHEMA_VERSION`] at write time).
    pub schema_version: u64,
    /// Model family (`tree`, `ising`, `potts`, `ldpc`).
    pub family: String,
    /// Model spec the family was instantiated as (JSON form of
    /// `ModelSpec`), so a future run can rebuild the identical instance.
    pub model: Json,
    /// `git rev-parse --short HEAD` at measurement time (`unknown` outside
    /// a work tree).
    pub git_rev: String,
    /// Unix timestamp (seconds) of the sweep.
    pub created_unix: u64,
    /// Whether this was a `--quick` smoke sweep (quick baselines are not
    /// comparable to full ones; the comparator refuses to mix them).
    pub quick: bool,
    /// Measured samples per cell.
    pub samples_per_cell: usize,
    /// RNG seed shared by model construction and schedulers.
    pub seed: u64,
    /// The measured cells.
    pub cells: Vec<CellResult>,
}

impl Baseline {
    /// Serialize to the BENCH schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::Num(self.schema_version as f64)),
            ("family", Json::Str(self.family.clone())),
            ("model", self.model.clone()),
            ("git_rev", Json::Str(self.git_rev.clone())),
            ("created_unix", Json::Num(self.created_unix as f64)),
            ("quick", Json::Bool(self.quick)),
            ("samples_per_cell", Json::Num(self.samples_per_cell as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("cells", Json::Arr(self.cells.iter().map(CellResult::to_json).collect())),
        ])
    }

    /// Parse a baseline; rejects unknown schema versions.
    pub fn from_json(v: &Json) -> Result<Baseline> {
        let version = v
            .get("schema_version")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("baseline.schema_version missing"))?;
        if version > SCHEMA_VERSION {
            anyhow::bail!(
                "baseline schema v{version} is newer than this binary understands (v{SCHEMA_VERSION})"
            );
        }
        Ok(Baseline {
            schema_version: version,
            family: v
                .get("family")
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("baseline.family missing"))?,
            model: v.get("model").cloned().unwrap_or(Json::Null),
            git_rev: v
                .get("git_rev")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            created_unix: v.get("created_unix").and_then(Json::as_u64).unwrap_or(0),
            quick: v.get("quick").and_then(Json::as_bool).unwrap_or(false),
            samples_per_cell: v
                .get("samples_per_cell")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            cells: v
                .get("cells")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("baseline.cells missing"))?
                .iter()
                .map(CellResult::from_json)
                .collect::<Result<_>>()?,
        })
    }

    /// Load a baseline file.
    pub fn load(path: &Path) -> Result<Baseline> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Baseline::from_json(&v).with_context(|| format!("parsing {}", path.display()))
    }

    /// Write the baseline (pretty-printed, trailing newline).
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// One cell's old-vs-new comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct CellDiff {
    /// Cell id (`"<algorithm>/p<threads>"`).
    pub id: String,
    /// Baseline median wall-clock seconds.
    pub old_secs: f64,
    /// New median wall-clock seconds.
    pub new_secs: f64,
    /// `new_secs / old_secs` (> 1 means slower).
    pub ratio: f64,
}

/// Result of diffing two baselines of the same family.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BaselineDiff {
    /// Cells slower than `tolerance ×` the baseline median.
    pub regressions: Vec<CellDiff>,
    /// Cells faster than `1/tolerance ×` the baseline median.
    pub improvements: Vec<CellDiff>,
    /// Cell ids present in the baseline but not the new run.
    pub missing: Vec<String>,
    /// Cell ids present in the new run but not the baseline.
    pub added: Vec<String>,
    /// Cells that converged in the baseline but not the new run — always a
    /// regression regardless of timing.
    pub lost_convergence: Vec<String>,
}

impl BaselineDiff {
    /// True when the new run regressed (slower cells or lost convergence).
    pub fn has_regression(&self) -> bool {
        !self.regressions.is_empty() || !self.lost_convergence.is_empty()
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.regressions {
            s.push_str(&format!(
                "REGRESSION  {}: {:.4}s -> {:.4}s ({:.2}x)\n",
                d.id, d.old_secs, d.new_secs, d.ratio
            ));
        }
        for id in &self.lost_convergence {
            s.push_str(&format!("REGRESSION  {id}: no longer converges\n"));
        }
        for d in &self.improvements {
            s.push_str(&format!(
                "improvement {}: {:.4}s -> {:.4}s ({:.2}x)\n",
                d.id, d.old_secs, d.new_secs, d.ratio
            ));
        }
        for id in &self.missing {
            s.push_str(&format!("missing     {id}: in baseline, not in new run\n"));
        }
        for id in &self.added {
            s.push_str(&format!("added       {id}: new cell, no baseline\n"));
        }
        if s.is_empty() {
            s.push_str("no differences beyond tolerance\n");
        }
        s
    }
}

/// Diff `new` against the stored `old` baseline.
///
/// Cells are joined by id; a cell regresses when its median wall-clock
/// exceeds `tolerance ×` the old median (`tolerance` must be > 1.0), or
/// when it stops converging. Comparing a quick sweep against a full one
/// (or different families) is an error — the samples measure different
/// instances.
pub fn compare(old: &Baseline, new: &Baseline, tolerance: f64) -> Result<BaselineDiff> {
    if tolerance.is_nan() || tolerance <= 1.0 {
        anyhow::bail!("tolerance must be > 1.0 (got {tolerance}); e.g. 1.5 flags a 1.5x slowdown");
    }
    if old.family != new.family {
        anyhow::bail!("family mismatch: baseline {}, new {}", old.family, new.family);
    }
    if old.quick != new.quick {
        anyhow::bail!(
            "cannot compare a quick sweep against a full one (baseline quick={}, new quick={})",
            old.quick,
            new.quick
        );
    }
    let mut diff = BaselineDiff::default();
    for oc in &old.cells {
        let Some(nc) = new.cells.iter().find(|c| c.id == oc.id) else {
            diff.missing.push(oc.id.clone());
            continue;
        };
        if oc.converged && !nc.converged {
            diff.lost_convergence.push(oc.id.clone());
            continue;
        }
        let (Some(old_secs), Some(new_secs)) = (oc.median_secs(), nc.median_secs()) else {
            continue;
        };
        if old_secs <= 0.0 {
            continue;
        }
        let ratio = new_secs / old_secs;
        let d = CellDiff { id: oc.id.clone(), old_secs, new_secs, ratio };
        if ratio > tolerance {
            diff.regressions.push(d);
        } else if ratio < 1.0 / tolerance {
            diff.improvements.push(d);
        }
    }
    for nc in &new.cells {
        if !old.cells.iter().any(|c| c.id == nc.id) {
            diff.added.push(nc.id.clone());
        }
    }
    Ok(diff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::trace::TracePoint;

    fn cell(id: &str, secs: f64) -> CellResult {
        CellResult {
            id: id.to_string(),
            algorithm: id.split('/').next().unwrap().to_string(),
            scheduler: "multiqueue".into(),
            threads: 2,
            partition: "off".into(),
            fused: true,
            kernel: "simd".into(),
            precision: "f32".into(),
            msg_bytes_logical: 4096,
            msg_bytes_padded: 8192,
            build_secs: 0.02,
            load_secs: 0.0,
            init_secs: 0.001,
            model_bytes: 0,
            load_mode: "read".into(),
            arena: "mem".into(),
            peak_rss_bytes: 1 << 22,
            damping: 0.25,
            wall_secs: vec![secs, secs * 1.05, secs * 0.95],
            updates: vec![1000.0, 1010.0, 990.0],
            scratch_wall_secs: vec![secs * 4.0, secs * 4.2, secs * 3.8],
            time_to_reconverge: secs,
            tasks_touched: 12,
            sp_wall_secs: vec![secs * 0.9, secs * 0.95, secs * 0.85],
            boundary_msgs_sent: 640,
            boundary_msgs_recv: 640,
            boundary_bytes: 13_440,
            exchange_batches: 5,
            converged: true,
            trace: Trace {
                points: vec![TracePoint {
                    t_secs: secs,
                    updates: 1000,
                    useful_updates: 900,
                    wasted_pops: 50,
                    stale_pops: 40,
                    claim_failures: 10,
                    pops: 1100,
                    inserts: 1100,
                    refreshes: 3300,
                    insert_batches: 1000,
                    tasks_touched: 12,
                    msg_bytes_logical: 4096,
                    msg_bytes_padded: 8192,
                    peak_rss_bytes: 1 << 22,
                    max_priority: 1e-6,
                }],
            },
        }
    }

    fn baseline(cells: Vec<CellResult>) -> Baseline {
        Baseline {
            schema_version: SCHEMA_VERSION,
            family: "ising".into(),
            model: Json::obj(vec![("kind", Json::Str("ising".into())), ("n", Json::Num(8.0))]),
            git_rev: "abc1234".into(),
            created_unix: 1_700_000_000,
            quick: true,
            samples_per_cell: 3,
            seed: 42,
            cells,
        }
    }

    #[test]
    fn baseline_json_roundtrip() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5), cell("residual/p1", 1.0)]);
        let text = b.to_json().to_string_pretty();
        let back = Baseline::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back, b);
    }

    #[test]
    fn pre_partition_cells_parse_as_off() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the partition axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("partition");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert_eq!(back.cells[0].partition, "off");
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_fused_cells_parse_as_edgewise() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the fused axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("fused");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert!(!back.cells[0].fused, "pre-fused cells measured the edgewise kernel");
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_simd_cells_parse_as_scalar() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the data-path kernel axis.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("kernel");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert_eq!(back.cells[0].kernel, "scalar", "pre-SIMD cells measured the scalar path");
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_precision_cells_parse_as_f64_with_zero_bytes() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the precision axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("precision");
                    c.remove("msg_bytes_logical");
                    c.remove("msg_bytes_padded");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert_eq!(back.cells[0].precision, "f64", "pre-precision cells stored f64 arenas");
        assert_eq!(back.cells[0].msg_bytes_logical, 0);
        assert_eq!(back.cells[0].msg_bytes_padded, 0);
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_coldpath_cells_parse_as_zero() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the cold-path fields existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("build_secs");
                    c.remove("load_secs");
                    c.remove("init_secs");
                    c.remove("model_bytes");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert_eq!(back.cells[0].build_secs, 0.0);
        assert_eq!(back.cells[0].load_secs, 0.0);
        assert_eq!(back.cells[0].init_secs, 0.0);
        assert_eq!(back.cells[0].model_bytes, 0);
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_outofcore_cells_parse_as_read_mem_zero() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the out-of-core axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("load_mode");
                    c.remove("arena");
                    c.remove("peak_rss_bytes");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert_eq!(back.cells[0].load_mode, "read", "pre-outofcore cells used copying loads");
        assert_eq!(back.cells[0].arena, "mem", "pre-outofcore cells used heap arenas");
        assert_eq!(back.cells[0].peak_rss_bytes, 0);
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_damping_cells_parse_as_zero() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the update-blend axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("damping");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert_eq!(back.cells[0].damping, 0.0, "pre-damping cells ran undamped");
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_distributed_cells_parse_as_zero() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the distributed axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("sp_wall_secs");
                    c.remove("boundary_msgs_sent");
                    c.remove("boundary_msgs_recv");
                    c.remove("boundary_bytes");
                    c.remove("exchange_batches");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert!(back.cells[0].sp_wall_secs.is_empty());
        assert_eq!(back.cells[0].boundary_msgs_sent, 0);
        assert_eq!(back.cells[0].boundary_msgs_recv, 0);
        assert_eq!(back.cells[0].boundary_bytes, 0);
        assert_eq!(back.cells[0].exchange_batches, 0);
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn pre_delta_cells_parse_as_zero() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut j = b.to_json();
        // Simulate a baseline written before the delta axis existed.
        if let Json::Obj(o) = &mut j {
            if let Some(Json::Arr(cells)) = o.get_mut("cells") {
                if let Json::Obj(c) = &mut cells[0] {
                    c.remove("scratch_wall_secs");
                    c.remove("time_to_reconverge");
                    c.remove("tasks_touched");
                }
            }
        }
        let back = Baseline::from_json(&j).unwrap();
        assert!(back.cells[0].scratch_wall_secs.is_empty());
        assert_eq!(back.cells[0].time_to_reconverge, 0.0);
        assert_eq!(back.cells[0].tasks_touched, 0);
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
    }

    #[test]
    fn identical_baselines_diff_clean() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let d = compare(&b, &b.clone(), DEFAULT_TOLERANCE).unwrap();
        assert!(!d.has_regression());
        assert!(d.improvements.is_empty());
        assert!(d.missing.is_empty() && d.added.is_empty());
        assert!(d.render().contains("no differences"));
    }

    #[test]
    fn two_x_slowdown_is_flagged() {
        let old = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut new = old.clone();
        for c in &mut new.cells {
            for t in &mut c.wall_secs {
                *t *= 2.0;
            }
        }
        let d = compare(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert!(d.has_regression());
        assert_eq!(d.regressions.len(), 1);
        assert!((d.regressions[0].ratio - 2.0).abs() < 1e-9);
        assert!(d.render().contains("REGRESSION"));
    }

    #[test]
    fn speedup_is_an_improvement_not_a_regression() {
        let old = baseline(vec![cell("relaxed_residual/p2", 1.0)]);
        let mut new = old.clone();
        for c in &mut new.cells {
            for t in &mut c.wall_secs {
                *t *= 0.4;
            }
        }
        let d = compare(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert!(!d.has_regression());
        assert_eq!(d.improvements.len(), 1);
    }

    #[test]
    fn lost_convergence_is_a_regression() {
        let old = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let mut new = old.clone();
        new.cells[0].converged = false;
        let d = compare(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert!(d.has_regression());
        assert_eq!(d.lost_convergence, vec!["relaxed_residual/p2".to_string()]);
    }

    #[test]
    fn missing_and_added_cells_reported() {
        let old = baseline(vec![cell("a/p1", 0.5), cell("b/p1", 0.5)]);
        let new = baseline(vec![cell("a/p1", 0.5), cell("c/p1", 0.5)]);
        let d = compare(&old, &new, DEFAULT_TOLERANCE).unwrap();
        assert_eq!(d.missing, vec!["b/p1".to_string()]);
        assert_eq!(d.added, vec!["c/p1".to_string()]);
        assert!(!d.has_regression(), "roster drift alone is not a perf regression");
    }

    #[test]
    fn quick_vs_full_refused() {
        let old = baseline(vec![cell("a/p1", 0.5)]);
        let mut new = old.clone();
        new.quick = false;
        assert!(compare(&old, &new, DEFAULT_TOLERANCE).is_err());
    }

    #[test]
    fn tolerance_must_exceed_one() {
        let b = baseline(vec![cell("a/p1", 0.5)]);
        assert!(compare(&b, &b.clone(), 1.0).is_err());
        assert!(compare(&b, &b.clone(), 0.5).is_err());
        assert!(compare(&b, &b.clone(), f64::NAN).is_err());
        assert!(compare(&b, &b.clone(), 1.01).is_ok());
    }

    #[test]
    fn newer_schema_rejected() {
        let b = baseline(vec![]);
        let mut j = b.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("schema_version".into(), Json::Num((SCHEMA_VERSION + 1) as f64));
        }
        assert!(Baseline::from_json(&j).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let b = baseline(vec![cell("relaxed_residual/p2", 0.5)]);
        let path = std::path::PathBuf::from("/tmp/rbp_baseline_test.json");
        b.save(&path).unwrap();
        let back = Baseline::load(&path).unwrap();
        assert_eq!(back, b);
        assert!(!compare(&b, &back, DEFAULT_TOLERANCE).unwrap().has_regression());
        std::fs::remove_file(&path).ok();
    }
}
