//! Convergence traces: time-series of counter snapshots + max priority,
//! recorded by a [`TraceRecorder`] attached to a run as a
//! [`RunObserver`](crate::exec::RunObserver).
//!
//! A trace answers the question the paper's evaluation revolves around —
//! *how fast does each scheduler drive the residuals down, and how much
//! work does it waste doing so* — with one point per sampler tick:
//! elapsed wall-clock, cumulative updates (total/useful), relaxation
//! overhead (stale pops, wasted pops, claim failures), and the current
//! max task priority.

use crate::configio::Json;
use crate::coordinator::Counters;
use crate::exec::RunObserver;
use anyhow::{anyhow, Result};
use std::sync::Mutex;
use std::time::Duration;

/// One sampled observation of a live run.
#[derive(Debug, Clone, PartialEq)]
pub struct TracePoint {
    /// Elapsed wall-clock seconds since the run started.
    pub t_secs: f64,
    /// Cumulative committed message updates.
    pub updates: u64,
    /// Cumulative updates with residual ≥ ε.
    pub useful_updates: u64,
    /// Cumulative pops whose priority had already dropped below ε.
    pub wasted_pops: u64,
    /// Cumulative pops discarded for a stale epoch.
    pub stale_pops: u64,
    /// Cumulative claim races lost to another worker.
    pub claim_failures: u64,
    /// Cumulative successful scheduler pops.
    pub pops: u64,
    /// Cumulative scheduler inserts.
    pub inserts: u64,
    /// Cumulative lookahead refreshes on the processing path
    /// (`refreshes / pops` ≈ the refresh fan-out per scheduler access —
    /// the quantity the fused node kernel amortizes).
    pub refreshes: u64,
    /// Cumulative batched scheduler insert calls (mean insertion batch
    /// size ≈ `inserts / insert_batches` on fused runs).
    pub insert_batches: u64,
    /// Tasks seeded by an evidence-delta warm start (0 on scratch runs);
    /// constant after the seed phase — the delta frontier size.
    pub tasks_touched: u64,
    /// Logical message-arena bytes (live + lookahead cache) — a gauge,
    /// constant over the run; halves under `--precision f32`.
    pub msg_bytes_logical: u64,
    /// Allocated (cache-line-padded) message-arena bytes, same scope.
    pub msg_bytes_padded: u64,
    /// Process peak resident set (`VmHWM`, bytes) at sample time — the
    /// out-of-core gauge; monotone over a run, 0 without procfs.
    pub peak_rss_bytes: u64,
    /// Max task priority at sample time (≈ max residual; the convergence
    /// signal — a converged run ends below ε).
    pub max_priority: f64,
}

impl TracePoint {
    /// Build a point from a counter snapshot.
    pub fn from_counters(t_secs: f64, c: &Counters, max_priority: f64) -> Self {
        TracePoint {
            t_secs,
            updates: c.updates,
            useful_updates: c.useful_updates,
            wasted_pops: c.wasted_pops,
            stale_pops: c.stale_pops,
            claim_failures: c.claim_failures,
            pops: c.pops,
            inserts: c.inserts,
            refreshes: c.refreshes,
            insert_batches: c.insert_batches,
            tasks_touched: c.tasks_touched,
            msg_bytes_logical: c.msg_bytes_logical,
            msg_bytes_padded: c.msg_bytes_padded,
            peak_rss_bytes: c.peak_rss_bytes,
            max_priority,
        }
    }

    /// Serialize to the BENCH JSON schema (`trace[]` element).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t_secs", Json::Num(self.t_secs)),
            ("updates", Json::Num(self.updates as f64)),
            ("useful_updates", Json::Num(self.useful_updates as f64)),
            ("wasted_pops", Json::Num(self.wasted_pops as f64)),
            ("stale_pops", Json::Num(self.stale_pops as f64)),
            ("claim_failures", Json::Num(self.claim_failures as f64)),
            ("pops", Json::Num(self.pops as f64)),
            ("inserts", Json::Num(self.inserts as f64)),
            ("refreshes", Json::Num(self.refreshes as f64)),
            ("insert_batches", Json::Num(self.insert_batches as f64)),
            ("tasks_touched", Json::Num(self.tasks_touched as f64)),
            ("msg_bytes_logical", Json::Num(self.msg_bytes_logical as f64)),
            ("msg_bytes_padded", Json::Num(self.msg_bytes_padded as f64)),
            ("peak_rss_bytes", Json::Num(self.peak_rss_bytes as f64)),
            ("max_priority", Json::Num(self.max_priority)),
        ])
    }

    /// Parse one `trace[]` element. `refreshes` / `insert_batches` were
    /// added by the fused-kernel schema extension, the `msg_bytes_*`
    /// gauges by the precision axis, `tasks_touched` by the delta axis,
    /// and `peak_rss_bytes` by the out-of-core axis; all default to 0
    /// when absent (older baselines).
    pub fn from_json(v: &Json) -> Result<TracePoint> {
        let num =
            |k: &str| v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("trace.{k} missing"));
        let int =
            |k: &str| v.get(k).and_then(Json::as_u64).ok_or_else(|| anyhow!("trace.{k} missing"));
        let opt = |k: &str| v.get(k).and_then(Json::as_u64).unwrap_or(0);
        Ok(TracePoint {
            t_secs: num("t_secs")?,
            updates: int("updates")?,
            useful_updates: int("useful_updates")?,
            wasted_pops: int("wasted_pops")?,
            stale_pops: int("stale_pops")?,
            claim_failures: int("claim_failures")?,
            pops: int("pops")?,
            inserts: int("inserts")?,
            refreshes: opt("refreshes"),
            insert_batches: opt("insert_batches"),
            tasks_touched: opt("tasks_touched"),
            msg_bytes_logical: opt("msg_bytes_logical"),
            msg_bytes_padded: opt("msg_bytes_padded"),
            peak_rss_bytes: opt("peak_rss_bytes"),
            max_priority: num("max_priority")?,
        })
    }
}

/// A recorded convergence trace: sample points in chronological order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Sample points, chronological.
    pub points: Vec<TracePoint>,
}

impl Trace {
    /// Number of sample points.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True when no point was recorded.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Serialize as a JSON array of points.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.points.iter().map(TracePoint::to_json).collect())
    }

    /// Parse a JSON array of points.
    pub fn from_json(v: &Json) -> Result<Trace> {
        let arr = v.as_arr().ok_or_else(|| anyhow!("trace must be an array"))?;
        Ok(Trace { points: arr.iter().map(TracePoint::from_json).collect::<Result<_>>()? })
    }
}

/// Records a [`Trace`] from a live run.
///
/// Implements [`RunObserver`]; attach via
/// [`Engine::run_observed`](crate::engines::Engine::run_observed) or
/// [`WorkerPool::run_observed`](crate::exec::WorkerPool::run_observed),
/// then collect with [`TraceRecorder::take`]. Sampling cadence is the
/// `tick` passed at construction; the runtime adds one sample at start and
/// one from the exact final counters, so every observed run produces a
/// non-empty trace no matter how short.
#[derive(Debug)]
pub struct TraceRecorder {
    tick: Duration,
    points: Mutex<Vec<TracePoint>>,
}

impl TraceRecorder {
    /// Recorder sampling every `tick`.
    pub fn new(tick: Duration) -> Self {
        TraceRecorder { tick, points: Mutex::new(Vec::new()) }
    }

    /// Take the recorded trace, leaving the recorder empty (reusable for
    /// the next run).
    pub fn take(&self) -> Trace {
        Trace { points: std::mem::take(&mut *self.points.lock().unwrap()) }
    }
}

impl RunObserver for TraceRecorder {
    fn tick(&self) -> Duration {
        self.tick
    }

    fn sample(&self, elapsed_secs: f64, totals: &Counters, max_priority: f64) {
        self.points
            .lock()
            .unwrap()
            .push(TracePoint::from_counters(elapsed_secs, totals, max_priority));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::parse;

    fn point(t: f64, updates: u64) -> TracePoint {
        TracePoint {
            t_secs: t,
            updates,
            useful_updates: updates / 2,
            wasted_pops: 1,
            stale_pops: 2,
            claim_failures: 3,
            pops: updates + 6,
            inserts: updates + 1,
            refreshes: updates * 3,
            insert_batches: updates,
            tasks_touched: 4,
            msg_bytes_logical: 4096,
            msg_bytes_padded: 8192,
            peak_rss_bytes: 1 << 20,
            max_priority: 0.5,
        }
    }

    #[test]
    fn pre_fused_points_parse_with_zero_refresh_counters() {
        // Baselines recorded before the fused-kernel counters existed.
        let v = parse(
            r#"[{"t_secs": 0.1, "updates": 10, "useful_updates": 9,
                 "wasted_pops": 0, "stale_pops": 1, "claim_failures": 0,
                 "pops": 11, "inserts": 12, "max_priority": 0.2}]"#,
        )
        .unwrap();
        let t = Trace::from_json(&v).unwrap();
        assert_eq!(t.points[0].refreshes, 0);
        assert_eq!(t.points[0].insert_batches, 0);
        assert_eq!(t.points[0].msg_bytes_logical, 0, "pre-precision baselines carry no gauge");
        assert_eq!(t.points[0].msg_bytes_padded, 0);
        assert_eq!(t.points[0].tasks_touched, 0, "pre-delta baselines carry no frontier count");
        assert_eq!(t.points[0].peak_rss_bytes, 0, "pre-outofcore baselines carry no RSS gauge");
    }

    #[test]
    fn trace_json_roundtrip() {
        let trace = Trace { points: vec![point(0.0, 0), point(0.5, 100)] };
        let j = trace.to_json().to_string_pretty();
        let back = Trace::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, trace);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let v = parse(r#"[{"t_secs": 0.1}]"#).unwrap();
        assert!(Trace::from_json(&v).is_err());
        assert!(Trace::from_json(&parse("{}").unwrap()).is_err());
    }

    #[test]
    fn recorder_collects_and_resets() {
        let rec = TraceRecorder::new(Duration::from_millis(1));
        let c = Counters { updates: 5, ..Default::default() };
        rec.sample(0.1, &c, 2.0);
        rec.sample(0.2, &c, 1.0);
        let t = rec.take();
        assert_eq!(t.len(), 2);
        assert_eq!(t.points[0].updates, 5);
        assert_eq!(t.points[1].max_priority, 1.0);
        assert!(rec.take().is_empty(), "take drains");
    }
}
