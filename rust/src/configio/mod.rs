//! Configuration system: JSON-backed run configs for the CLI, the harness,
//! and the examples. A [`RunConfig`] fully determines a BP run (model,
//! algorithm, thread count, convergence threshold, seed, scheduler knobs),
//! so experiments are reproducible from a single file.

pub mod json;

pub use json::{parse, Json, JsonError};

pub use crate::bp::{ArenaMode, Kernel, Precision};
pub use crate::model::io::{parse_load_mode, LoadMode};

use anyhow::{anyhow, bail, Context, Result};

/// Which Markov random field to build.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    /// Full binary tree with `n` vertices; root prior (0.1, 0.9),
    /// deterministic equality edge factors (paper §5.2).
    Tree { n: usize },
    /// Ising model on an `n×n` grid, α,β ~ U[-1,1] (paper §5.2).
    Ising { n: usize },
    /// Potts-style model on an `n×n` grid with `q` states per node
    /// (paper §5.2 uses q = 3), α,β ~ U[-2.5,2.5]. `q` ranges 2..=64
    /// (`MAX_DOMAIN`); the wide-domain settings (e.g. `potts:40:32`) are
    /// the SIMD kernel axis's natural workload besides LDPC.
    Potts { n: usize, q: usize },
    /// (3,6)-LDPC decoding MRF with `n` variable nodes (n/2 constraints),
    /// BSC error probability `eps` (paper §5.2 uses 0.07).
    Ldpc { n: usize, flip_prob: f64 },
    /// Path graph of `n` vertices rooted at one end (Lemma 2 bad case).
    Path { n: usize },
    /// Lemma 2 adversarial tree: main path of length `sqrt(n)` with side
    /// paths attached (Figure 3).
    AdversarialTree { n: usize },
    /// Uniform-expansion full `arity`-ary tree (Lemma 2 good case): identical
    /// non-deterministic edge factors, information flows from the root.
    UniformTree { n: usize, arity: usize },
    /// Power-law (preferential-attachment) spin glass with `n` nodes and
    /// `m` edges per arriving node, α,β ~ U[-1,1]. The large-scale
    /// locality workload: size it to millions of nodes via config
    /// (`powerlaw:1000000`) to make cache behavior, and therefore the
    /// partition axis, dominate.
    PowerLaw { n: usize, m: usize },
}

impl ModelSpec {
    /// Family name (used in reports and baselines).
    pub fn name(&self) -> &'static str {
        match self {
            ModelSpec::Tree { .. } => "tree",
            ModelSpec::Ising { .. } => "ising",
            ModelSpec::Potts { .. } => "potts",
            ModelSpec::Ldpc { .. } => "ldpc",
            ModelSpec::Path { .. } => "path",
            ModelSpec::AdversarialTree { .. } => "adversarial_tree",
            ModelSpec::UniformTree { .. } => "uniform_tree",
            ModelSpec::PowerLaw { .. } => "powerlaw",
        }
    }

    /// Serialize as a JSON object (`{"kind": …, …}`).
    pub fn to_json(&self) -> Json {
        match self {
            ModelSpec::Tree { n } => Json::obj(vec![
                ("kind", Json::Str("tree".into())),
                ("n", Json::Num(*n as f64)),
            ]),
            ModelSpec::Ising { n } => Json::obj(vec![
                ("kind", Json::Str("ising".into())),
                ("n", Json::Num(*n as f64)),
            ]),
            ModelSpec::Potts { n, q } => Json::obj(vec![
                ("kind", Json::Str("potts".into())),
                ("n", Json::Num(*n as f64)),
                ("q", Json::Num(*q as f64)),
            ]),
            ModelSpec::Ldpc { n, flip_prob } => Json::obj(vec![
                ("kind", Json::Str("ldpc".into())),
                ("n", Json::Num(*n as f64)),
                ("flip_prob", Json::Num(*flip_prob)),
            ]),
            ModelSpec::Path { n } => Json::obj(vec![
                ("kind", Json::Str("path".into())),
                ("n", Json::Num(*n as f64)),
            ]),
            ModelSpec::AdversarialTree { n } => Json::obj(vec![
                ("kind", Json::Str("adversarial_tree".into())),
                ("n", Json::Num(*n as f64)),
            ]),
            ModelSpec::UniformTree { n, arity } => Json::obj(vec![
                ("kind", Json::Str("uniform_tree".into())),
                ("n", Json::Num(*n as f64)),
                ("arity", Json::Num(*arity as f64)),
            ]),
            ModelSpec::PowerLaw { n, m } => Json::obj(vec![
                ("kind", Json::Str("powerlaw".into())),
                ("n", Json::Num(*n as f64)),
                ("m", Json::Num(*m as f64)),
            ]),
        }
    }

    /// Parse the JSON form produced by [`ModelSpec::to_json`].
    pub fn from_json(v: &Json) -> Result<ModelSpec> {
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("model.kind missing"))?;
        let n = v
            .get("n")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("model.n missing"))?;
        Ok(match kind {
            "tree" => ModelSpec::Tree { n },
            "ising" => ModelSpec::Ising { n },
            // Pre-q configs carry no "q" field: they described the fixed
            // 3-state builder.
            "potts" => ModelSpec::Potts {
                n,
                q: valid_potts_q(v.get("q").and_then(Json::as_usize).unwrap_or(3))?,
            },
            "ldpc" => ModelSpec::Ldpc {
                n,
                flip_prob: v.get("flip_prob").and_then(Json::as_f64).unwrap_or(0.07),
            },
            "path" => ModelSpec::Path { n },
            "adversarial_tree" => ModelSpec::AdversarialTree { n },
            "uniform_tree" => ModelSpec::UniformTree {
                n,
                arity: v.get("arity").and_then(Json::as_usize).unwrap_or(2),
            },
            "powerlaw" => ModelSpec::PowerLaw {
                n,
                m: v.get("m").and_then(Json::as_usize).unwrap_or(2),
            },
            other => bail!("unknown model kind '{other}'"),
        })
    }

    /// File-name-safe identity of this instance at `seed`, used by the
    /// `--save-model`/`--load-model` cache to key models on disk
    /// (`<kind>_<params>_seed<seed>.rbpm`). Every spec field participates,
    /// so two specs share a cache file only when they build the identical
    /// model.
    pub fn cache_slug(&self, seed: u64) -> String {
        let params = match self {
            ModelSpec::Tree { n }
            | ModelSpec::Ising { n }
            | ModelSpec::Path { n }
            | ModelSpec::AdversarialTree { n } => format!("{n}"),
            ModelSpec::Potts { n, q } => format!("{n}_q{q}"),
            ModelSpec::Ldpc { n, flip_prob } => format!("{n}_f{flip_prob}"),
            ModelSpec::UniformTree { n, arity } => format!("{n}_a{arity}"),
            ModelSpec::PowerLaw { n, m } => format!("{n}_m{m}"),
        };
        format!("{}_{}_seed{}.rbpm", self.name(), params, seed)
    }

    /// Parse CLI-style `kind:n[:extra]`, e.g. `ising:300` or `ldpc:30000:0.07`.
    pub fn parse_cli(s: &str) -> Result<ModelSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let kind = parts[0];
        let n: usize = parts
            .get(1)
            .ok_or_else(|| anyhow!("model spec '{s}' needs a size, e.g. ising:300"))?
            .parse()
            .context("bad model size")?;
        Ok(match kind {
            "tree" => ModelSpec::Tree { n },
            "ising" => ModelSpec::Ising { n },
            "potts" => {
                let q = parts
                    .get(2)
                    .map(|p| p.parse())
                    .transpose()
                    .context("bad state count")?
                    .unwrap_or(3);
                ModelSpec::Potts { n, q: valid_potts_q(q)? }
            }
            "ldpc" => ModelSpec::Ldpc {
                n,
                flip_prob: parts.get(2).map(|p| p.parse()).transpose()?.unwrap_or(0.07),
            },
            "path" => ModelSpec::Path { n },
            "adversarial_tree" => ModelSpec::AdversarialTree { n },
            "uniform_tree" => ModelSpec::UniformTree {
                n,
                arity: parts.get(2).map(|p| p.parse()).transpose()?.unwrap_or(2),
            },
            "powerlaw" => ModelSpec::PowerLaw {
                n,
                m: parts.get(2).map(|p| p.parse()).transpose()?.unwrap_or(2),
            },
            other => bail!("unknown model kind '{other}'"),
        })
    }
}

/// The locality (partitioning) axis of a run: how tasks and message
/// storage are grouped into shards, and how strongly the relaxed
/// scheduler prefers shard-local queues.
///
/// `Off` reproduces the seed behavior bit for bit: one flat message
/// arena, locality-blind Multiqueue. `Affine` groups tasks into shards
/// (contiguous blocks, or BFS clusters when `bfs` is set), stores each
/// shard's messages in its own cache-line-aligned arena, and makes the
/// Multiqueue prefer shard-local queues with spill probability `spill`
/// (see `sched::Multiqueue::shard_affine`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionSpec {
    /// No partitioning — flat message array, locality-blind scheduling.
    Off,
    /// Shard-affine execution.
    Affine {
        /// Number of shards; 0 = one shard per worker thread.
        shards: usize,
        /// Probability that an insert/pop ignores shard affinity and uses
        /// the global (locality-blind) path. Keeps cross-shard priority
        /// information flowing; the CLI/JSON parsers reject values
        /// outside [0, 1].
        spill: f64,
        /// Cluster tasks by BFS order over the model graph instead of
        /// contiguous id blocks.
        bfs: bool,
    },
}

/// Default spill probability for the shard-affine Multiqueue.
pub const DEFAULT_SPILL: f64 = 0.1;

/// Parse a CLI `on|off` switch value (also accepts `true|false|1|0`) —
/// used by the `--fused` axis.
pub fn parse_on_off(s: &str) -> Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => bail!("expected on|off, got '{other}'"),
    }
}

/// Parse the update-kernel axis value (`--kernel scalar|simd`).
pub fn parse_kernel(s: &str) -> Result<Kernel> {
    match s {
        "scalar" => Ok(Kernel::Scalar),
        "simd" => Ok(Kernel::Simd),
        other => bail!("expected scalar|simd, got '{other}'"),
    }
}

/// Parse the storage-precision axis value (`--precision f64|f32`).
pub fn parse_precision(s: &str) -> Result<Precision> {
    match s {
        "f64" => Ok(Precision::F64),
        "f32" => Ok(Precision::F32),
        other => bail!("expected f64|f32, got '{other}'"),
    }
}

/// Parse the arena-backing axis value (`--arena mem|mmap[:dir]`).
pub fn parse_arena_mode(s: &str) -> Result<ArenaMode> {
    if s == "mem" {
        return Ok(ArenaMode::Mem);
    }
    if s == "mmap" {
        return Ok(ArenaMode::Mmap { dir: None });
    }
    if let Some(dir) = s.strip_prefix("mmap:") {
        if dir.is_empty() {
            bail!("mmap arena directory is empty (use plain 'mmap' for the default temp dir)");
        }
        return Ok(ArenaMode::Mmap { dir: Some(dir.into()) });
    }
    bail!("expected mem|mmap[:dir], got '{s}'")
}

/// Reject Potts state counts outside 2..=MAX_DOMAIN at the config
/// boundary (the builder also asserts, but a config error beats a panic
/// mid-run, and recorded configs then always describe buildable models).
fn valid_potts_q(q: usize) -> Result<usize> {
    if (2..=crate::model::MAX_DOMAIN).contains(&q) {
        Ok(q)
    } else {
        bail!(
            "potts state count must be in 2..={}, got {q}",
            crate::model::MAX_DOMAIN
        )
    }
}

/// Reject spill probabilities outside [0, 1] (and NaN) at the config
/// boundary, so recorded configs always describe the executed behavior.
fn valid_spill(spill: f64) -> Result<f64> {
    if (0.0..=1.0).contains(&spill) {
        Ok(spill)
    } else {
        bail!("spill probability must be in [0, 1], got {spill}")
    }
}

/// Reject damping factors outside [0, 1) (and NaN) at the config
/// boundary: 1.0 would freeze every message, so the run could never
/// make progress.
pub fn valid_damping(damping: f64) -> Result<f64> {
    if (0.0..1.0).contains(&damping) {
        Ok(damping)
    } else {
        bail!("damping factor must be in [0, 1), got {damping}")
    }
}

impl PartitionSpec {
    /// Shard-affine with auto shard count (= threads) and default spill.
    pub fn affine() -> Self {
        PartitionSpec::Affine { shards: 0, spill: DEFAULT_SPILL, bfs: false }
    }

    /// True when partitioning is enabled.
    pub fn is_on(&self) -> bool {
        !matches!(self, PartitionSpec::Off)
    }

    /// Short label for reports and bench cell ids (`off`, `affine`,
    /// `affine_bfs`).
    pub fn label(&self) -> &'static str {
        match self {
            PartitionSpec::Off => "off",
            PartitionSpec::Affine { bfs: false, .. } => "affine",
            PartitionSpec::Affine { bfs: true, .. } => "affine_bfs",
        }
    }

    /// Concrete shard count for a run with `threads` workers (resolves the
    /// `shards = 0` auto setting; at least 1).
    pub fn resolved_shards(&self, threads: usize) -> usize {
        match *self {
            PartitionSpec::Off => 1,
            PartitionSpec::Affine { shards: 0, .. } => threads.max(1),
            PartitionSpec::Affine { shards, .. } => shards,
        }
    }

    /// Serialize as JSON (`"off"` or an object).
    pub fn to_json(&self) -> Json {
        match *self {
            PartitionSpec::Off => Json::Str("off".into()),
            PartitionSpec::Affine { shards, spill, bfs } => Json::obj(vec![
                ("kind", Json::Str("affine".into())),
                ("shards", Json::Num(shards as f64)),
                ("spill", Json::Num(spill)),
                ("bfs", Json::Bool(bfs)),
            ]),
        }
    }

    /// Parse the JSON form produced by [`PartitionSpec::to_json`].
    pub fn from_json(v: &Json) -> Result<PartitionSpec> {
        if let Some(s) = v.as_str() {
            return PartitionSpec::parse_cli(s);
        }
        match v.get("kind").and_then(Json::as_str) {
            Some("affine") => Ok(PartitionSpec::Affine {
                shards: v.get("shards").and_then(Json::as_usize).unwrap_or(0),
                spill: valid_spill(
                    v.get("spill").and_then(Json::as_f64).unwrap_or(DEFAULT_SPILL),
                )?,
                bfs: v.get("bfs").and_then(Json::as_bool).unwrap_or(false),
            }),
            Some("off") | None => Ok(PartitionSpec::Off),
            Some(other) => bail!("unknown partition kind '{other}'"),
        }
    }

    /// Parse CLI-style `off`, `affine[:shards[:spill]]`, or
    /// `bfs[:shards[:spill]]` (BFS-clustered affine).
    pub fn parse_cli(s: &str) -> Result<PartitionSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let shards = || -> Result<usize> {
            parts.get(1).map(|p| p.parse().context("bad shard count")).transpose().map(|o| o.unwrap_or(0))
        };
        let spill = || -> Result<f64> {
            parts
                .get(2)
                .map(|p| p.parse().context("bad spill probability"))
                .transpose()
                .map(|o| o.unwrap_or(DEFAULT_SPILL))
                .and_then(valid_spill)
        };
        Ok(match parts[0] {
            "off" | "none" => PartitionSpec::Off,
            "affine" => PartitionSpec::Affine { shards: shards()?, spill: spill()?, bfs: false },
            "bfs" | "affine_bfs" => {
                PartitionSpec::Affine { shards: shards()?, spill: spill()?, bfs: true }
            }
            other => bail!("unknown partition mode '{other}' (expected off | affine | bfs)"),
        })
    }
}

/// Which BP scheduling algorithm to run. Mirrors the paper's §5.1 roster.
#[derive(Debug, Clone, PartialEq)]
pub enum AlgorithmSpec {
    /// Sequential residual BP — the baseline all tables normalize to.
    SequentialResidual,
    /// Round-based synchronous BP (parallel over message chunks).
    Synchronous,
    /// Exact residual BP on one lock-protected PQ (Coarse-Grained, "CG").
    CoarseGrained,
    /// Relaxed residual BP on the Multiqueue — the headline algorithm.
    RelaxedResidual,
    /// Weight-decay priorities res/m(e) on the Multiqueue ("WD").
    WeightDecay,
    /// Residual-without-lookahead on the Multiqueue ("Priority").
    Priority,
    /// Exact splash with depth `h` on one locked PQ ("S h").
    Splash { h: usize },
    /// Exact smart splash (BFS-tree edges only) on one locked PQ.
    SmartSplash { h: usize },
    /// Relaxed smart splash on the Multiqueue ("RSS h").
    RelaxedSmartSplash { h: usize },
    /// Journal-version randomized splash on naive random queues ("RS h").
    RandomSplash { h: usize },
    /// Yin–Gao bucket algorithm: top 0.1·|V| vertices per round.
    Bucket,
    /// Van der Merwe randomized synchronous with parameter `low_p`.
    RandomSynchronous { low_p: f64 },
    /// Extension: relaxed residual popping batches of `batch` tasks, updates
    /// executed through the AOT PJRT kernel.
    RelaxedResidualBatched { batch: usize },
    /// Appendix A optimal tree schedule (exact scheduler).
    OptimalTree,
    /// Appendix A optimal tree schedule on the Multiqueue.
    RelaxedOptimalTree,
}

impl AlgorithmSpec {
    /// Short display name matching the paper's table headers.
    pub fn name(&self) -> String {
        match self {
            AlgorithmSpec::SequentialResidual => "residual".into(),
            AlgorithmSpec::Synchronous => "synch".into(),
            AlgorithmSpec::CoarseGrained => "coarse_grained".into(),
            AlgorithmSpec::RelaxedResidual => "relaxed_residual".into(),
            AlgorithmSpec::WeightDecay => "weight_decay".into(),
            AlgorithmSpec::Priority => "priority".into(),
            AlgorithmSpec::Splash { h } => format!("splash_{h}"),
            AlgorithmSpec::SmartSplash { h } => format!("smart_splash_{h}"),
            AlgorithmSpec::RelaxedSmartSplash { h } => format!("relaxed_smart_splash_{h}"),
            AlgorithmSpec::RandomSplash { h } => format!("random_splash_{h}"),
            AlgorithmSpec::Bucket => "bucket".into(),
            AlgorithmSpec::RandomSynchronous { low_p } => format!("random_synch_{low_p}"),
            AlgorithmSpec::RelaxedResidualBatched { batch } => {
                format!("relaxed_residual_batched_{batch}")
            }
            AlgorithmSpec::OptimalTree => "optimal_tree".into(),
            AlgorithmSpec::RelaxedOptimalTree => "relaxed_optimal_tree".into(),
        }
    }

    /// Canonical CLI form, parseable by [`AlgorithmSpec::parse_cli`]
    /// (e.g. `smart_splash:2`); used for JSON round-trips.
    pub fn to_cli(&self) -> String {
        match self {
            AlgorithmSpec::Splash { h } => format!("splash:{h}"),
            AlgorithmSpec::SmartSplash { h } => format!("smart_splash:{h}"),
            AlgorithmSpec::RelaxedSmartSplash { h } => format!("relaxed_smart_splash:{h}"),
            AlgorithmSpec::RandomSplash { h } => format!("random_splash:{h}"),
            AlgorithmSpec::RandomSynchronous { low_p } => format!("random_synch:{low_p}"),
            AlgorithmSpec::RelaxedResidualBatched { batch } => {
                format!("relaxed_residual_batched:{batch}")
            }
            other => other.name(),
        }
    }

    /// Parse CLI-style `name[:param]`, e.g. `splash:2`, `random_synch:0.4`.
    pub fn parse_cli(s: &str) -> Result<AlgorithmSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let arg = parts.get(1).copied();
        let h = || -> Result<usize> {
            arg.map(|a| a.parse().context("bad H"))
                .transpose()
                .map(|o| o.unwrap_or(2))
        };
        Ok(match parts[0] {
            "residual" | "sequential_residual" => AlgorithmSpec::SequentialResidual,
            "synch" | "synchronous" => AlgorithmSpec::Synchronous,
            "coarse_grained" | "cg" => AlgorithmSpec::CoarseGrained,
            "relaxed_residual" | "rr" => AlgorithmSpec::RelaxedResidual,
            "weight_decay" | "wd" => AlgorithmSpec::WeightDecay,
            "priority" => AlgorithmSpec::Priority,
            "splash" | "s" => AlgorithmSpec::Splash { h: h()? },
            "smart_splash" | "ss" => AlgorithmSpec::SmartSplash { h: h()? },
            "relaxed_smart_splash" | "rss" => AlgorithmSpec::RelaxedSmartSplash { h: h()? },
            "random_splash" | "rs" => AlgorithmSpec::RandomSplash { h: h()? },
            "bucket" => AlgorithmSpec::Bucket,
            "random_synch" => AlgorithmSpec::RandomSynchronous {
                low_p: arg.map(|a| a.parse()).transpose()?.unwrap_or(0.4),
            },
            "relaxed_residual_batched" | "rrb" => AlgorithmSpec::RelaxedResidualBatched {
                batch: arg.map(|a| a.parse()).transpose()?.unwrap_or(256),
            },
            "optimal_tree" => AlgorithmSpec::OptimalTree,
            "relaxed_optimal_tree" => AlgorithmSpec::RelaxedOptimalTree,
            other => bail!("unknown algorithm '{other}'"),
        })
    }

    /// True for algorithms whose scheduler is relaxed (dashed lines in the
    /// paper's plots).
    pub fn is_relaxed(&self) -> bool {
        matches!(
            self,
            AlgorithmSpec::RelaxedResidual
                | AlgorithmSpec::WeightDecay
                | AlgorithmSpec::Priority
                | AlgorithmSpec::RelaxedSmartSplash { .. }
                | AlgorithmSpec::RelaxedResidualBatched { .. }
                | AlgorithmSpec::RelaxedOptimalTree
        )
    }
}

/// A complete, reproducible description of one BP run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Which MRF to build.
    pub model: ModelSpec,
    /// Which scheduling algorithm to run.
    pub algorithm: AlgorithmSpec,
    /// Worker threads (1 for sequential algorithms).
    pub threads: usize,
    /// Convergence threshold on task priority (paper: 1e-5 grids, 1e-2 LDPC).
    pub epsilon: f64,
    /// RNG seed for model generation and scheduler randomness.
    pub seed: u64,
    /// Multiqueue heaps per thread (paper: 4).
    pub queues_per_thread: usize,
    /// Hard wall-clock limit in seconds (paper uses 5 min); 0 = unlimited.
    pub time_limit_secs: f64,
    /// Safety cap on total updates (guards non-convergent configs); 0 = off.
    pub max_updates: u64,
    /// Use the PJRT/AOT compute path where the engine supports it.
    pub use_pjrt: bool,
    /// Locality axis: graph partitioning + shard-affine scheduling.
    pub partition: PartitionSpec,
    /// Update-kernel *shape* axis: `true` (default) uses the node-centric
    /// fused refresh kernel (O(deg) per node touch, prefix/suffix excluded
    /// products) plus batched scheduler inserts; `false` forces the
    /// historical edge-wise refresh fan-out (O(deg²) per node touch) for
    /// A/B measurement. Both compute the same update rule; values agree
    /// to ≤ 1e-12 (product-order rounding only).
    pub fused: bool,
    /// Update-kernel *data-path* axis (`--kernel scalar|simd`): `Simd`
    /// (default) runs the lane-tiled inner loops with bulk message I/O and
    /// in-kernel residuals; `Scalar` runs the historical per-element path,
    /// whose message trajectory is bit-for-bit the pre-SIMD code. Values
    /// agree to ≤ 1e-12 (reduction-order rounding only).
    pub kernel: Kernel,
    /// Storage-precision axis (`--precision f64|f32`): `F64` (default)
    /// keeps 8-byte message cells and is bit-frozen to the pre-axis
    /// trajectory; `F32` stores 4-byte cells (half the arena bytes, 16
    /// cells per cache line). Compute stays f64 in registers either way —
    /// reads widen exactly, writes round once per stored cell.
    pub precision: Precision,
    /// Model-load axis (`--load-mode read|map|auto`): how `--load-model`
    /// snapshots come into memory. `Auto` (default) memory-maps v2 files
    /// zero-copy and falls back to the copying read path when the file
    /// cannot be mapped; `Read` forces the historical copying path;
    /// `Map` states the zero-copy intent explicitly. The loaded model is
    /// bit-identical either way.
    pub load_mode: LoadMode,
    /// Arena-backing axis (`--arena mem|mmap[:dir]`): heap message
    /// arenas (default) or file-backed arenas in unlinked sparse temp
    /// files, for runs whose message state exceeds RAM. Cell values and
    /// trajectories are identical across modes.
    pub arena: ArenaMode,
    /// Verify checksums + semantic invariants on the mapped load path
    /// (`--verify-load`). Off by default: full verification touches
    /// every page, which defeats the point of a lazy zero-copy map. The
    /// read path always verifies regardless.
    pub verify_load: bool,
    /// Damping axis (`--damping F`): every stored message update blends
    /// geometrically with the old value, `m' = m^{1−F}·m_old^F`, then
    /// renormalizes. `0.0` (default) is bit-frozen to the undamped store
    /// path; positive values trade per-update step size for stability on
    /// loopy graphs and the distributed boundary path. Must lie in
    /// [0, 1).
    pub damping: f64,
}

impl RunConfig {
    /// Config with per-model default ε, seed 42, single thread.
    pub fn new(model: ModelSpec, algorithm: AlgorithmSpec) -> Self {
        // Paper: 1e-5 for grids/trees, 1e-2 for LDPC. We default LDPC to
        // 1e-3 instead: with this pairwise-MRF encoding the residual-family
        // schedules can stop at 1e-2 before all bit flips resolve (see
        // EXPERIMENTS.md §Deviations); 1e-3 decodes reliably for all
        // algorithms while preserving the relative comparisons.
        let epsilon = match model {
            ModelSpec::Ldpc { .. } => 1e-3,
            _ => 1e-5,
        };
        RunConfig {
            model,
            algorithm,
            threads: 1,
            epsilon,
            seed: 42,
            queues_per_thread: 4,
            time_limit_secs: 300.0,
            max_updates: 0,
            use_pjrt: false,
            partition: PartitionSpec::Off,
            fused: true,
            kernel: Kernel::Simd,
            precision: Precision::F64,
            load_mode: LoadMode::Auto,
            arena: ArenaMode::Mem,
            verify_load: false,
            damping: 0.0,
        }
    }

    /// Set the worker thread count.
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Set the convergence threshold.
    pub fn with_epsilon(mut self, e: f64) -> Self {
        self.epsilon = e;
        self
    }

    /// Set the update-count budget (0 = unlimited).
    pub fn with_max_updates(mut self, m: u64) -> Self {
        self.max_updates = m;
        self
    }

    /// Set the locality (partitioning) axis.
    pub fn with_partition(mut self, p: PartitionSpec) -> Self {
        self.partition = p;
        self
    }

    /// Set the update-kernel axis (fused node refresh vs edge-wise).
    pub fn with_fused(mut self, fused: bool) -> Self {
        self.fused = fused;
        self
    }

    /// Set the data-path kernel axis (lane-tiled SIMD vs scalar).
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Set the storage-precision axis (f64 arenas vs f32 arenas).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Set the model-load axis (zero-copy map vs copying read).
    pub fn with_load_mode(mut self, mode: LoadMode) -> Self {
        self.load_mode = mode;
        self
    }

    /// Set the arena-backing axis (heap vs file-backed message arenas).
    pub fn with_arena(mut self, arena: ArenaMode) -> Self {
        self.arena = arena;
        self
    }

    /// Enable checksum + semantic verification on the mapped load path.
    pub fn with_verify_load(mut self, verify: bool) -> Self {
        self.verify_load = verify;
        self
    }

    /// Set the damping axis (geometric blend factor in [0, 1)).
    pub fn with_damping(mut self, damping: f64) -> Self {
        self.damping = damping;
        self
    }

    /// Serialize as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.to_json()),
            ("algorithm", Json::Str(self.algorithm.to_cli())),
            ("threads", Json::Num(self.threads as f64)),
            ("epsilon", Json::Num(self.epsilon)),
            ("seed", Json::Num(self.seed as f64)),
            ("queues_per_thread", Json::Num(self.queues_per_thread as f64)),
            ("time_limit_secs", Json::Num(self.time_limit_secs)),
            ("max_updates", Json::Num(self.max_updates as f64)),
            ("use_pjrt", Json::Bool(self.use_pjrt)),
            ("partition", self.partition.to_json()),
            ("fused", Json::Bool(self.fused)),
            ("kernel", Json::Str(self.kernel.label().into())),
            ("precision", Json::Str(self.precision.label().into())),
            ("load_mode", Json::Str(self.load_mode.label().into())),
            ("arena", Json::Str(self.arena.spec())),
            ("verify_load", Json::Bool(self.verify_load)),
            ("damping", Json::Num(self.damping)),
        ])
    }

    /// Parse the JSON form produced by [`RunConfig::to_json`].
    pub fn from_json(v: &Json) -> Result<RunConfig> {
        let model = ModelSpec::from_json(v.get("model").ok_or_else(|| anyhow!("model missing"))?)?;
        let alg = AlgorithmSpec::parse_cli(
            v.get("algorithm")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("algorithm missing"))?,
        )?;
        let mut cfg = RunConfig::new(model, alg);
        if let Some(t) = v.get("threads").and_then(Json::as_usize) {
            cfg.threads = t;
        }
        if let Some(e) = v.get("epsilon").and_then(Json::as_f64) {
            cfg.epsilon = e;
        }
        if let Some(s) = v.get("seed").and_then(Json::as_u64) {
            cfg.seed = s;
        }
        if let Some(q) = v.get("queues_per_thread").and_then(Json::as_usize) {
            cfg.queues_per_thread = q;
        }
        if let Some(t) = v.get("time_limit_secs").and_then(Json::as_f64) {
            cfg.time_limit_secs = t;
        }
        if let Some(m) = v.get("max_updates").and_then(Json::as_u64) {
            cfg.max_updates = m;
        }
        if let Some(b) = v.get("use_pjrt").and_then(Json::as_bool) {
            cfg.use_pjrt = b;
        }
        if let Some(p) = v.get("partition") {
            cfg.partition = PartitionSpec::from_json(p)?;
        }
        if let Some(f) = v.get("fused") {
            cfg.fused = f
                .as_bool()
                .ok_or_else(|| anyhow!("fused must be a boolean (true|false)"))?;
        }
        if let Some(k) = v.get("kernel") {
            // Configs written before the kernel axis parse with the simd
            // default; a present-but-malformed value is an error.
            cfg.kernel = parse_kernel(
                k.as_str()
                    .ok_or_else(|| anyhow!("kernel must be a string (scalar|simd)"))?,
            )?;
        }
        if let Some(p) = v.get("precision") {
            // Configs written before the precision axis parse with the f64
            // default; a present-but-malformed value is an error.
            cfg.precision = parse_precision(
                p.as_str()
                    .ok_or_else(|| anyhow!("precision must be a string (f64|f32)"))?,
            )?;
        }
        if let Some(l) = v.get("load_mode") {
            // Configs written before the out-of-core axes parse with the
            // defaults; present-but-malformed values are errors.
            cfg.load_mode = parse_load_mode(
                l.as_str()
                    .ok_or_else(|| anyhow!("load_mode must be a string (read|map|auto)"))?,
            )?;
        }
        if let Some(a) = v.get("arena") {
            cfg.arena = parse_arena_mode(
                a.as_str()
                    .ok_or_else(|| anyhow!("arena must be a string (mem|mmap[:dir])"))?,
            )?;
        }
        if let Some(b) = v.get("verify_load") {
            cfg.verify_load = b
                .as_bool()
                .ok_or_else(|| anyhow!("verify_load must be a boolean (true|false)"))?;
        }
        if let Some(d) = v.get("damping") {
            // Configs written before the damping axis parse undamped; a
            // present-but-malformed value is an error.
            cfg.damping = valid_damping(
                d.as_f64()
                    .ok_or_else(|| anyhow!("damping must be a number in [0, 1)"))?,
            )?;
        }
        Ok(cfg)
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let v = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        RunConfig::from_json(&v)
    }

    /// Save to a JSON file (pretty-printed).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_cli_roundtrip() {
        let m = ModelSpec::parse_cli("ising:300").unwrap();
        assert_eq!(m, ModelSpec::Ising { n: 300 });
        let m = ModelSpec::parse_cli("ldpc:30000:0.05").unwrap();
        assert_eq!(m, ModelSpec::Ldpc { n: 30000, flip_prob: 0.05 });
        assert!(ModelSpec::parse_cli("nope:3").is_err());
        assert!(ModelSpec::parse_cli("ising").is_err());
    }

    #[test]
    fn algorithm_cli_parse() {
        assert_eq!(
            AlgorithmSpec::parse_cli("rr").unwrap(),
            AlgorithmSpec::RelaxedResidual
        );
        assert_eq!(
            AlgorithmSpec::parse_cli("splash:10").unwrap(),
            AlgorithmSpec::Splash { h: 10 }
        );
        assert_eq!(
            AlgorithmSpec::parse_cli("random_synch:0.1").unwrap(),
            AlgorithmSpec::RandomSynchronous { low_p: 0.1 }
        );
        assert!(AlgorithmSpec::parse_cli("wat").is_err());
    }

    #[test]
    fn relaxed_flag() {
        assert!(AlgorithmSpec::RelaxedResidual.is_relaxed());
        assert!(!AlgorithmSpec::CoarseGrained.is_relaxed());
        assert!(!AlgorithmSpec::RandomSplash { h: 2 }.is_relaxed());
        assert!(AlgorithmSpec::RelaxedSmartSplash { h: 2 }.is_relaxed());
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 1000, flip_prob: 0.07 },
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        )
        .with_threads(8)
        .with_seed(7);
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn default_epsilon_per_model() {
        let c = RunConfig::new(ModelSpec::Ising { n: 10 }, AlgorithmSpec::RelaxedResidual);
        assert_eq!(c.epsilon, 1e-5);
        let c = RunConfig::new(
            ModelSpec::Ldpc { n: 10, flip_prob: 0.07 },
            AlgorithmSpec::RelaxedResidual,
        );
        assert_eq!(c.epsilon, 1e-3);
    }

    #[test]
    fn partition_cli_parse() {
        assert_eq!(PartitionSpec::parse_cli("off").unwrap(), PartitionSpec::Off);
        assert_eq!(
            PartitionSpec::parse_cli("affine").unwrap(),
            PartitionSpec::Affine { shards: 0, spill: DEFAULT_SPILL, bfs: false }
        );
        assert_eq!(
            PartitionSpec::parse_cli("affine:8:0.25").unwrap(),
            PartitionSpec::Affine { shards: 8, spill: 0.25, bfs: false }
        );
        assert_eq!(
            PartitionSpec::parse_cli("bfs:4").unwrap(),
            PartitionSpec::Affine { shards: 4, spill: DEFAULT_SPILL, bfs: true }
        );
        assert!(PartitionSpec::parse_cli("wat").is_err());
        // Out-of-range spill is rejected at the config boundary.
        assert!(PartitionSpec::parse_cli("affine:4:2.0").is_err());
        assert!(PartitionSpec::parse_cli("affine:4:-0.1").is_err());
        assert!(PartitionSpec::parse_cli("affine:4:NaN").is_err());
    }

    #[test]
    fn partition_json_roundtrip() {
        for p in [
            PartitionSpec::Off,
            PartitionSpec::affine(),
            PartitionSpec::Affine { shards: 7, spill: 0.2, bfs: true },
        ] {
            let back = PartitionSpec::from_json(&p.to_json()).unwrap();
            assert_eq!(back, p);
        }
    }

    #[test]
    fn partition_resolved_shards() {
        assert_eq!(PartitionSpec::Off.resolved_shards(4), 1);
        assert_eq!(PartitionSpec::affine().resolved_shards(4), 4);
        assert_eq!(
            PartitionSpec::Affine { shards: 7, spill: 0.1, bfs: false }.resolved_shards(2),
            7
        );
    }

    #[test]
    fn config_partition_roundtrip_and_back_compat() {
        let cfg = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_partition(PartitionSpec::affine());
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        // Configs written before the partition axis still parse (axis off).
        let legacy = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr"}"#;
        let cfg = RunConfig::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.partition, PartitionSpec::Off);
    }

    #[test]
    fn powerlaw_cli_and_json() {
        let m = ModelSpec::parse_cli("powerlaw:1000:3").unwrap();
        assert_eq!(m, ModelSpec::PowerLaw { n: 1000, m: 3 });
        assert_eq!(m.name(), "powerlaw");
        let back = ModelSpec::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn potts_q_cli_and_json() {
        // Plain potts:n keeps the paper's 3-state builder.
        let m = ModelSpec::parse_cli("potts:40").unwrap();
        assert_eq!(m, ModelSpec::Potts { n: 40, q: 3 });
        let m = ModelSpec::parse_cli("potts:40:32").unwrap();
        assert_eq!(m, ModelSpec::Potts { n: 40, q: 32 });
        let back = ModelSpec::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);
        // Pre-q JSON (no "q" field) parses as the 3-state model.
        let legacy = r#"{"kind": "potts", "n": 7}"#;
        let m = ModelSpec::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(m, ModelSpec::Potts { n: 7, q: 3 });
        // Out-of-range q is a config error, not a mid-run builder panic.
        assert!(ModelSpec::parse_cli("potts:40:1").is_err());
        assert!(ModelSpec::parse_cli("potts:40:65").is_err());
        let bad = r#"{"kind": "potts", "n": 7, "q": 65}"#;
        assert!(ModelSpec::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn kernel_axis_roundtrip_and_back_compat() {
        let cfg = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_kernel(Kernel::Scalar);
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.kernel, Kernel::Scalar);
        // Configs written before the kernel axis parse with the default.
        let legacy = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr"}"#;
        let cfg = RunConfig::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.kernel, Kernel::Simd);
        // CLI values.
        assert_eq!(parse_kernel("simd").unwrap(), Kernel::Simd);
        assert_eq!(parse_kernel("scalar").unwrap(), Kernel::Scalar);
        assert!(parse_kernel("avx9000").is_err());
        // A malformed kernel value is an error, not a silent default.
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "kernel": true}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "kernel": "wat"}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn precision_axis_roundtrip_and_back_compat() {
        let cfg = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_precision(Precision::F32);
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.precision, Precision::F32);
        // Configs written before the precision axis parse with the default.
        let legacy = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr"}"#;
        let cfg = RunConfig::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.precision, Precision::F64);
        // CLI values.
        assert_eq!(parse_precision("f64").unwrap(), Precision::F64);
        assert_eq!(parse_precision("f32").unwrap(), Precision::F32);
        assert!(parse_precision("f16").is_err());
        // A malformed precision value is an error, not a silent default.
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "precision": 32}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
        let bad =
            r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "precision": "single"}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn fused_axis_roundtrip_and_back_compat() {
        let cfg = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_fused(false);
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert!(!back.fused);
        // Configs written before the fused axis parse with the default on.
        let legacy = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr"}"#;
        let cfg = RunConfig::from_json(&parse(legacy).unwrap()).unwrap();
        assert!(cfg.fused);
        // CLI switch values.
        assert!(parse_on_off("on").unwrap());
        assert!(!parse_on_off("off").unwrap());
        assert!(parse_on_off("wat").is_err());
        // A malformed fused value is an error, not a silent default.
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "fused": "off"}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn outofcore_axes_roundtrip_and_back_compat() {
        let cfg = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_load_mode(LoadMode::Map)
            .with_arena(ArenaMode::Mmap { dir: Some("/var/tmp".into()) })
            .with_verify_load(true);
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.load_mode, LoadMode::Map);
        assert_eq!(back.arena, ArenaMode::Mmap { dir: Some("/var/tmp".into()) });
        assert!(back.verify_load);
        // Configs written before the out-of-core axes parse with defaults.
        let legacy = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr"}"#;
        let cfg = RunConfig::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.load_mode, LoadMode::Auto);
        assert_eq!(cfg.arena, ArenaMode::Mem);
        assert!(!cfg.verify_load);
        // CLI values.
        assert_eq!(parse_load_mode("read").unwrap(), LoadMode::Read);
        assert_eq!(parse_load_mode("map").unwrap(), LoadMode::Map);
        assert_eq!(parse_load_mode("auto").unwrap(), LoadMode::Auto);
        assert!(parse_load_mode("lazy").is_err());
        assert_eq!(parse_arena_mode("mem").unwrap(), ArenaMode::Mem);
        assert_eq!(parse_arena_mode("mmap").unwrap(), ArenaMode::Mmap { dir: None });
        assert_eq!(
            parse_arena_mode("mmap:/scratch").unwrap(),
            ArenaMode::Mmap { dir: Some("/scratch".into()) }
        );
        assert!(parse_arena_mode("mmap:").is_err());
        assert!(parse_arena_mode("disk").is_err());
        // Malformed values are errors, not silent defaults.
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "load_mode": 1}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "arena": "tape"}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
        let bad =
            r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "verify_load": "yes"}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn damping_axis_roundtrip_and_back_compat() {
        let cfg = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_damping(0.3);
        let j = cfg.to_json().to_string_pretty();
        let back = RunConfig::from_json(&parse(&j).unwrap()).unwrap();
        assert_eq!(back, cfg);
        assert_eq!(back.damping, 0.3);
        // Configs written before the damping axis parse undamped.
        let legacy = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr"}"#;
        let cfg = RunConfig::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(cfg.damping, 0.0);
        // Out-of-range or malformed values are errors, not silent defaults.
        assert!(valid_damping(0.0).is_ok());
        assert!(valid_damping(0.99).is_ok());
        assert!(valid_damping(1.0).is_err());
        assert!(valid_damping(-0.1).is_err());
        assert!(valid_damping(f64::NAN).is_err());
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "damping": "lots"}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
        let bad = r#"{"model": {"kind": "ising", "n": 5}, "algorithm": "rr", "damping": 1.5}"#;
        assert!(RunConfig::from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn config_file_roundtrip() {
        let cfg = RunConfig::new(ModelSpec::Tree { n: 100 }, AlgorithmSpec::Synchronous);
        let path = "/tmp/relaxed_bp_test_cfg.json";
        cfg.save(path).unwrap();
        let back = RunConfig::load(path).unwrap();
        assert_eq!(back, cfg);
        std::fs::remove_file(path).ok();
    }
}
