//! Minimal JSON parser and serializer.
//!
//! `serde`/`serde_json` are unavailable in the offline build, so the config
//! system and experiment reports use this self-contained implementation. It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) and pretty printing; it is not performance
//! critical (configs and result files only, never the BP hot path).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — important for golden-file tests and diffable results.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integral value, if this is one.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Like [`Json::as_u64`], as `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// String slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key/value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Field lookup on an object; `None` if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset of the error in the input.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage is an error).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.ws();
            arr.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for configs);
                            // map lone surrogates to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"alg":"relaxed_residual","eps":1e-5,"sizes":[10,20],"ok":true}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn reject_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_content() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(5.0).to_string_compact(), "5");
        assert_eq!(Json::Num(5.5).to_string_compact(), "5.5");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert!(v.get("a").unwrap().as_arr().unwrap().is_empty());
        assert_eq!(v.get("missing"), None);
        assert_eq!(v.get("s").unwrap().as_f64(), None);
    }
}
