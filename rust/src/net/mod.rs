//! Multi-process distributed execution over TCP.
//!
//! `run --distributed <role>:<nprocs>:<rank>[:addr]` splits one relaxed
//! residual-BP run across N OS processes ("ranks"). Every rank builds the
//! same model and partition deterministically from the shared config, owns
//! a contiguous range of partition shards ([`RankMap`]), and runs the
//! ordinary relaxed [`WorkerPool`] over its *owned* message tasks only.
//! Non-owned message cells are **mirrors**: local read-only copies kept
//! fresh by the boundary exchange.
//!
//! ## Topology
//!
//! Star, with rank 0 as the hub: workers connect to the coordinator and
//! every frame carries a destination rank; rank 0's reader threads relay
//! frames addressed to other ranks verbatim. The boundary counters are
//! end-to-end (counted at origin and final destination; relay hops are
//! not re-counted), so `boundary_msgs_sent == boundary_msgs_recv` holds
//! in a merged report regardless of routing. A full mesh is a possible
//! future optimization; the star keeps connection setup O(N) and the
//! termination ring trivially routable.
//!
//! ## Boundary exchange
//!
//! When a rank commits an owned edge whose value some other rank reads
//! (the [`BoundaryIndex`]), the freshly stored value is appended to a
//! per-peer egress buffer and shipped in coalesced `BATCH` frames (flushed
//! at a fixed entry budget, and always before the rank reports itself
//! passive). The receiving rank's reader thread applies entries straight
//! into the mirror cells via [`Messages::write_msg_residual_raw`] — raw
//! because the value was already damped by its origin — and parks the
//! arrived edge ids in an inbox. Workers drain the inbox at the top of
//! their loop ([`drain_ingress`](crate::exec::TaskPolicy::drain_ingress)),
//! re-pricing the affected owned out-edges and requeuing them
//! shard-affine in one batch.
//!
//! ## Termination: Safra's algorithm
//!
//! Local quiescence (empty queues + clean verify sweep) is necessary but
//! not sufficient: a boundary batch may be in flight. We run Safra's
//! token-ring termination detection on top of the local protocol — no
//! timeouts anywhere:
//!
//! - every rank keeps a message counter `c_i = sent − received` and a
//!   color (blackened by every boundary receipt);
//! - rank 0, when locally passive, circulates a token `(q, color)` around
//!   the ring 0 → 1 → … → N−1 → 0 (routed through the hub). A passive
//!   rank forwards the token with `q += c_i`, blackens it if the rank
//!   itself is black, then whitens itself. Ranks only touch the token
//!   from the verifier's `try_finish` hook, which runs strictly under
//!   local quiescence with flushed egress and a drained inbox;
//! - when the token returns white to a white rank 0 with
//!   `q + c_0 == 0`, no rank is active and no message is in flight:
//!   rank 0 broadcasts `DONE`. Any receipt after a rank whitened
//!   re-blackens it and forces another round (re-arming on new boundary
//!   arrivals).
//!
//! After `DONE`, every worker ships its owned edges (`FINAL`) and its run
//! stats (`STATS`) to rank 0, which applies them into its own arena —
//! yielding the complete fixed point for marginal extraction — and merges
//! all per-rank counters into the single printed [`RunReport`].
//!
//! ## Wire format
//!
//! Length-prefixed frames over plain [`std::net`] TCP (no dependencies):
//! `[u32 le payload_len][payload]`, payload = `[u8 kind][u32 src][u32
//! dst][body…]`. Batch entries are `[u32 edge][u8 len][len × f64 le]`.

use crate::bp::{Kernel, Messages, MsgSource};
use crate::configio::{AlgorithmSpec, PartitionSpec, RunConfig, DEFAULT_SPILL};
use crate::coordinator::Counters;
use crate::engines::residual_family::ResidualPolicy;
use crate::engines::EngineStats;
use crate::exec::WorkerPool;
use crate::model::{builders, partition, BoundaryIndex, Mrf, RankMap, MAX_DOMAIN};
use crate::run::{PrepStats, RunReport};
use crate::sched::SchedChoice;
use crate::util::Timer;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Hard ceiling on a single frame (corrupt length-prefix guard).
const MAX_FRAME: usize = 1 << 26;
/// Entries per peer buffer before a `BATCH` frame is flushed.
const FLUSH_ENTRIES: usize = 256;
/// Owned-edge entries per `FINAL` gather frame.
const FINAL_CHUNK: usize = 4096;
/// Verifier idle wait between termination-protocol attempts.
const IDLE_WAIT_US: u64 = 50;

const KIND_HELLO: u8 = 1;
const KIND_BATCH: u8 = 2;
const KIND_TOKEN: u8 = 3;
const KIND_DONE: u8 = 4;
const KIND_FINAL: u8 = 5;
const KIND_STATS: u8 = 6;

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    stream.write_all(payload)
}

fn read_frame(stream: &mut TcpStream, buf: &mut Vec<u8>) -> Result<()> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).context("read frame header")?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds limit (corrupt stream?)");
    }
    buf.resize(len, 0);
    stream.read_exact(buf).context("read frame payload")?;
    Ok(())
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian payload reader.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame: wanted {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// `[kind][src][dst]` control payload with no body.
fn control_payload(kind: u8, src: u32, dst: u32) -> Vec<u8> {
    let mut p = vec![kind];
    put_u32(&mut p, src);
    put_u32(&mut p, dst);
    p
}

// ---------------------------------------------------------------------------
// Role spec parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Role {
    Spawn,
    Coord,
    Worker,
}

#[derive(Debug, Clone, PartialEq, Eq)]
struct DistSpec {
    role: Role,
    nprocs: u32,
    rank: u32,
    addr: Option<String>,
}

impl DistSpec {
    /// Parse `spawn:N`, `coord:N:0[:addr]`, or `worker:N:R:addr` (the
    /// address may itself contain a `:port` suffix).
    fn parse(spec: &str) -> Result<DistSpec> {
        let parts: Vec<&str> = spec.splitn(4, ':').collect();
        let nprocs = |s: &str| -> Result<u32> {
            let n: u32 = s.parse().with_context(|| format!("bad rank count {s:?}"))?;
            if n == 0 {
                bail!("--distributed needs at least one rank");
            }
            Ok(n)
        };
        match parts.as_slice() {
            ["spawn", n] => Ok(DistSpec { role: Role::Spawn, nprocs: nprocs(n)?, rank: 0, addr: None }),
            ["coord", n, r] | ["coord", n, r, _] => {
                if *r != "0" {
                    bail!("the coordinator is always rank 0, got {r:?}");
                }
                Ok(DistSpec {
                    role: Role::Coord,
                    nprocs: nprocs(n)?,
                    rank: 0,
                    addr: parts.get(3).map(|s| s.to_string()),
                })
            }
            ["worker", n, r, addr] => {
                let nprocs = nprocs(n)?;
                let rank: u32 = r.parse().with_context(|| format!("bad rank {r:?}"))?;
                if rank == 0 || rank >= nprocs {
                    bail!("worker rank must be in 1..{nprocs}, got {rank}");
                }
                Ok(DistSpec { role: Role::Worker, nprocs, rank, addr: Some(addr.to_string()) })
            }
            _ => bail!(
                "bad --distributed spec {spec:?}: expected spawn:N, coord:N:0[:addr], or worker:N:R:addr"
            ),
        }
    }
}

/// Resolve the partition the distributed run shards ownership over: the
/// locality axis must be on with at least one shard per rank. `Off` and
/// auto (`shards: 0`) resolve to `threads × nprocs` shards; an explicit
/// shard count below the rank count is an error, not a silent re-shard.
fn normalize_partition(cfg: &mut RunConfig, nprocs: u32) -> Result<()> {
    let auto = cfg.threads.max(1) * nprocs as usize;
    match cfg.partition {
        PartitionSpec::Off => {
            cfg.partition = PartitionSpec::Affine { shards: auto, spill: DEFAULT_SPILL, bfs: false };
        }
        PartitionSpec::Affine { shards: 0, spill, bfs } => {
            cfg.partition = PartitionSpec::Affine { shards: auto, spill, bfs };
        }
        PartitionSpec::Affine { shards, .. } => {
            if shards < nprocs as usize {
                bail!(
                    "--distributed with {nprocs} ranks needs a partition with at least \
                     {nprocs} shards, got {shards}"
                );
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

/// Engine-side interface of the distributed runtime. [`ResidualPolicy`]
/// drives everything rank-related through this trait so the policy's
/// single-process paths stay byte-identical when it is absent.
pub(crate) trait DistDriver: Sync {
    /// True when this rank owns message task `e`. Non-owned tasks are
    /// never seeded, requeued, or committed locally.
    fn owns(&self, e: u32) -> bool;
    /// Ship the freshly committed value of owned edge `e` to every remote
    /// consumer (no-op for interior edges). Failures are latched, not
    /// returned: the termination hook surfaces them.
    fn publish(&self, mrf: &Mrf, msgs: &Messages, e: u32);
    /// Move the arrived-edge inbox into `into` (appended; `into` is not
    /// cleared).
    fn take_inbox(&self, into: &mut Vec<u32>);
    /// Monotone counter bumped on every ingress application; lets the
    /// verifier cache a clean sweep while idle-waiting for the token.
    fn activity_epoch(&self) -> u64;
    /// Run one step of the rank-level termination protocol. Called only
    /// under local quiescence with a clean verify sweep; returns true
    /// once the run is globally done (or has failed — the caller checks).
    fn try_finish(&self) -> bool;
}

/// Safra token: accumulated counter sum + color.
#[derive(Debug, Clone, Copy)]
struct Token {
    q: i64,
    black: bool,
}

/// Per-destination egress buffer of serialized batch entries.
struct EgressBuf {
    count: u32,
    body: Vec<u8>,
}

impl EgressBuf {
    fn take(&mut self) -> (u32, Vec<u8>) {
        let c = self.count;
        self.count = 0;
        (c, std::mem::take(&mut self.body))
    }
}

/// Write side of one TCP link. `ctrl` is an un-mutexed clone used only
/// for `shutdown`, so a failure can always unblock a writer stuck inside
/// the `stream` lock.
struct PeerLink {
    stream: Mutex<TcpStream>,
    ctrl: TcpStream,
}

impl PeerLink {
    fn new(stream: TcpStream) -> Result<PeerLink> {
        let ctrl = stream.try_clone().context("clone link for shutdown control")?;
        Ok(PeerLink { stream: Mutex::new(stream), ctrl })
    }
}

/// Per-rank transport + termination state shared between the worker pool
/// (through [`DistDriver`]) and the reader threads.
struct DistRuntime {
    rank: u32,
    nprocs: u32,
    kernel: Kernel,
    map: RankMap,
    boundary: BoundaryIndex,
    /// Rank 0: indexed by peer rank (slot 0 empty). Workers: one slot,
    /// the hub link.
    links: Vec<Option<PeerLink>>,
    /// Pending outgoing batch entries, indexed by destination rank.
    egress: Vec<Mutex<EgressBuf>>,
    /// Edges whose mirror value changed since the workers last drained.
    inbox: Mutex<Vec<u32>>,
    activity: AtomicU64,
    /// Safra color: blackened by every boundary receipt.
    black: AtomicBool,
    /// Safra counter `c_i = sent − received` (batch entries).
    counter: AtomicI64,
    /// Token parked by the reader until the verifier is passive.
    token: Mutex<Option<Token>>,
    /// Rank 0 only: a token is circulating, don't initiate another.
    token_at_large: AtomicBool,
    done: AtomicBool,
    failure: Mutex<Option<String>>,
    n_sent: AtomicU64,
    n_recv: AtomicU64,
    n_bytes: AtomicU64,
    n_batches: AtomicU64,
    n_wait_us: AtomicU64,
}

impl DistRuntime {
    fn new(
        rank: u32,
        nprocs: u32,
        kernel: Kernel,
        map: RankMap,
        boundary: BoundaryIndex,
        links: Vec<Option<PeerLink>>,
    ) -> DistRuntime {
        DistRuntime {
            rank,
            nprocs,
            kernel,
            map,
            boundary,
            links,
            egress: (0..nprocs).map(|_| Mutex::new(EgressBuf { count: 0, body: Vec::new() })).collect(),
            inbox: Mutex::new(Vec::new()),
            activity: AtomicU64::new(0),
            black: AtomicBool::new(false),
            counter: AtomicI64::new(0),
            token: Mutex::new(None),
            token_at_large: AtomicBool::new(false),
            done: AtomicBool::new(false),
            failure: Mutex::new(None),
            n_sent: AtomicU64::new(0),
            n_recv: AtomicU64::new(0),
            n_bytes: AtomicU64::new(0),
            n_batches: AtomicU64::new(0),
            n_wait_us: AtomicU64::new(0),
        }
    }

    fn link(&self, dst: u32) -> Result<&PeerLink> {
        let slot = if self.rank == 0 { dst as usize } else { 0 };
        self.links
            .get(slot)
            .and_then(|o| o.as_ref())
            .ok_or_else(|| anyhow!("rank {}: no link toward rank {dst}", self.rank))
    }

    /// Frame `payload` and write it on the link toward `dst`, counting
    /// the blocked time.
    fn send_payload(&self, dst: u32, payload: &[u8]) -> Result<()> {
        let link = self.link(dst)?;
        let t = Instant::now();
        let res = {
            let mut stream = link.stream.lock().unwrap();
            write_frame(&mut stream, payload)
        };
        self.n_wait_us.fetch_add(t.elapsed().as_micros() as u64, Ordering::Relaxed);
        res.with_context(|| format!("rank {}: send toward rank {dst}", self.rank))
    }

    /// Latch the first failure, end the local run, and shut every socket
    /// down so blocked readers/writers (here and on the peers) wake up.
    fn fail(&self, msg: String) {
        {
            let mut slot = self.failure.lock().unwrap();
            if slot.is_none() {
                *slot = Some(msg);
            }
        }
        self.done.store(true, Ordering::SeqCst);
        for l in self.links.iter().flatten() {
            let _ = l.ctrl.shutdown(Shutdown::Both);
        }
    }

    fn failed(&self) -> Option<String> {
        self.failure.lock().unwrap().clone()
    }

    /// Assemble and send one `BATCH` frame.
    fn send_batch(&self, dst: u32, count: u32, body: &[u8]) -> Result<()> {
        let mut payload = Vec::with_capacity(13 + body.len());
        payload.push(KIND_BATCH);
        put_u32(&mut payload, self.rank);
        put_u32(&mut payload, dst);
        put_u32(&mut payload, count);
        payload.extend_from_slice(body);
        self.n_batches.fetch_add(1, Ordering::Relaxed);
        self.n_bytes.fetch_add(payload.len() as u64, Ordering::Relaxed);
        self.send_payload(dst, &payload)
    }

    /// Flush every non-empty egress buffer (always called before this
    /// rank reports itself passive, so the Safra counter never runs
    /// ahead of the wire). The egress lock is held across the send:
    /// batches toward one destination must hit the wire in buffer
    /// order, or a stale value could overwrite a newer one in the
    /// peer's mirror cell.
    fn flush_all(&self) -> Result<()> {
        for dst in 0..self.nprocs {
            if dst == self.rank {
                continue;
            }
            let mut eg = self.egress[dst as usize].lock().unwrap();
            if eg.count > 0 {
                let (count, body) = eg.take();
                self.send_batch(dst, count, &body)?;
            }
        }
        Ok(())
    }

    /// Apply one incoming `BATCH` frame: store each entry into the mirror
    /// cell (raw — already damped at the origin) and park changed edges
    /// in the inbox.
    fn apply_batch(&self, mrf: &Mrf, msgs: &Messages, cur: &mut Cur<'_>) -> Result<()> {
        // Receipt blackens the rank *before* any counter it could affect
        // is read by a token forward.
        self.black.store(true, Ordering::SeqCst);
        let count = cur.u32()?;
        let mut vals = [0.0f64; MAX_DOMAIN];
        let mut arrived = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let e = cur.u32()?;
            if e as usize >= mrf.num_messages() {
                bail!("corrupt batch: edge {e} out of range");
            }
            let len = cur.u8()? as usize;
            if len != mrf.msg_len(e) {
                bail!("corrupt batch: edge {e} domain {len} != {}", mrf.msg_len(e));
            }
            for v in vals[..len].iter_mut() {
                *v = cur.f64()?;
            }
            let res = msgs.write_msg_residual_raw(mrf, e, &vals[..len], self.kernel);
            self.n_recv.fetch_add(1, Ordering::Relaxed);
            self.counter.fetch_sub(1, Ordering::SeqCst);
            if res > 0.0 {
                arrived.push(e);
            }
        }
        if !arrived.is_empty() {
            self.inbox.lock().unwrap().extend_from_slice(&arrived);
            self.activity.fetch_add(1, Ordering::SeqCst);
        }
        Ok(())
    }

    /// Apply a `FINAL` gather frame (owned-edge values from a worker)
    /// into rank 0's arena.
    fn apply_final(&self, mrf: &Mrf, msgs: &Messages, cur: &mut Cur<'_>) -> Result<()> {
        let count = cur.u32()?;
        let mut vals = [0.0f64; MAX_DOMAIN];
        for _ in 0..count {
            let e = cur.u32()?;
            if e as usize >= mrf.num_messages() {
                bail!("corrupt final frame: edge {e} out of range");
            }
            let len = cur.u8()? as usize;
            if len != mrf.msg_len(e) {
                bail!("corrupt final frame: edge {e} domain {len} != {}", mrf.msg_len(e));
            }
            for v in vals[..len].iter_mut() {
                *v = cur.f64()?;
            }
            msgs.write_msg_residual_raw(mrf, e, &vals[..len], self.kernel);
        }
        Ok(())
    }

    fn send_token(&self, dst: u32, q: i64, black: bool) -> Result<()> {
        let mut p = control_payload(KIND_TOKEN, self.rank, dst);
        p.extend_from_slice(&q.to_le_bytes());
        p.push(black as u8);
        self.send_payload(dst, &p)
    }

    /// One Safra step, run by the passive verifier: judge or forward a
    /// held token, or (rank 0) launch the first probe. Any transport
    /// error bubbles up for the caller to latch via [`DistRuntime::fail`].
    fn advance_token(&self, held: Option<Token>) -> Result<()> {
        match held {
            Some(tok) if self.rank == 0 => {
                let c0 = self.counter.load(Ordering::SeqCst);
                let black0 = self.black.load(Ordering::SeqCst);
                if !tok.black && !black0 && tok.q + c0 == 0 {
                    // Every rank passive, every sent entry received:
                    // global fixed point. Release the fleet.
                    self.done.store(true, Ordering::SeqCst);
                    for r in 1..self.nprocs {
                        self.send_payload(r, &control_payload(KIND_DONE, 0, r))?;
                    }
                } else {
                    // Inconclusive round: start a fresh white probe.
                    self.black.store(false, Ordering::SeqCst);
                    self.send_token(1, 0, false)?;
                }
            }
            Some(tok) => {
                let q = tok.q + self.counter.load(Ordering::SeqCst);
                let black = tok.black || self.black.load(Ordering::SeqCst);
                self.black.store(false, Ordering::SeqCst);
                self.send_token((self.rank + 1) % self.nprocs, q, black)?;
            }
            None => {
                if self.rank == 0 && !self.token_at_large.swap(true, Ordering::SeqCst) {
                    self.black.store(false, Ordering::SeqCst);
                    self.send_token(1, 0, false)?;
                }
            }
        }
        Ok(())
    }

    /// Fold this rank's transport counters into the run's counter block.
    fn fold_net(&self, c: &mut Counters) {
        c.boundary_msgs_sent += self.n_sent.load(Ordering::Relaxed);
        c.boundary_msgs_recv += self.n_recv.load(Ordering::Relaxed);
        c.boundary_bytes += self.n_bytes.load(Ordering::Relaxed);
        c.exchange_batches += self.n_batches.load(Ordering::Relaxed);
        c.net_wait_us += self.n_wait_us.load(Ordering::Relaxed);
    }
}

impl DistDriver for DistRuntime {
    fn owns(&self, e: u32) -> bool {
        self.map.owns(self.rank, e)
    }

    fn publish(&self, mrf: &Mrf, msgs: &Messages, e: u32) {
        let peers = self.boundary.peers_of(e);
        if peers.is_empty() {
            return;
        }
        let mut buf = [0.0f64; MAX_DOMAIN];
        let len = msgs.read_msg(mrf, e, &mut buf);
        for &p in peers {
            let mut eg = self.egress[p as usize].lock().unwrap();
            eg.body.extend_from_slice(&e.to_le_bytes());
            eg.body.push(len as u8);
            for v in &buf[..len] {
                eg.body.extend_from_slice(&v.to_le_bytes());
            }
            eg.count += 1;
            // Count while the entry is still unsent: Safra's counter must
            // never run behind the wire, or a receipt could be decremented
            // before its send was incremented and a token round could see
            // a spuriously balanced sum.
            self.n_sent.fetch_add(1, Ordering::Relaxed);
            self.counter.fetch_add(1, Ordering::SeqCst);
            if eg.count as usize >= FLUSH_ENTRIES {
                let (count, body) = eg.take();
                // Send while still holding the egress lock — see
                // `flush_all` for the per-destination ordering argument.
                let sent = self.send_batch(p, count, &body);
                drop(eg);
                if let Err(err) = sent {
                    self.fail(format!("{err:#}"));
                    return;
                }
            }
        }
    }

    fn take_inbox(&self, into: &mut Vec<u32>) {
        let mut inbox = self.inbox.lock().unwrap();
        into.append(&mut inbox);
    }

    fn activity_epoch(&self) -> u64 {
        self.activity.load(Ordering::SeqCst)
    }

    fn try_finish(&self) -> bool {
        if self.done.load(Ordering::SeqCst) {
            return true;
        }
        if self.nprocs == 1 {
            // Degenerate single-rank run: local quiescence is global.
            return true;
        }
        // Passivity: everything this rank counted as sent must be on the
        // wire before the counter can feed a token.
        if let Err(e) = self.flush_all() {
            self.fail(format!("{e:#}"));
            return true;
        }
        // An undrained inbox means the worker loop still has seeding to
        // do; come back after the next drain.
        if !self.inbox.lock().unwrap().is_empty() {
            return false;
        }
        let held = self.token.lock().unwrap().take();
        if let Err(e) = self.advance_token(held) {
            self.fail(format!("{e:#}"));
            return true;
        }
        // Idle briefly so the verifier doesn't spin while the token is
        // elsewhere in the ring; counted as network wait.
        std::thread::sleep(std::time::Duration::from_micros(IDLE_WAIT_US));
        self.n_wait_us.fetch_add(IDLE_WAIT_US, Ordering::Relaxed);
        self.done.load(Ordering::SeqCst)
    }
}

// ---------------------------------------------------------------------------
// Stats gather
// ---------------------------------------------------------------------------

/// One worker rank's run outcome, shipped to rank 0 in a `STATS` frame.
struct RankResult {
    counters: Counters,
    per_thread: Vec<u64>,
    wall: f64,
    final_prio: f64,
    converged: bool,
}

fn encode_counters(c: &Counters, p: &mut Vec<u8>) {
    for v in [
        c.updates,
        c.useful_updates,
        c.wasted_pops,
        c.stale_pops,
        c.claim_failures,
        c.pops,
        c.inserts,
        c.rounds,
        c.splashes,
        c.refreshes,
        c.insert_batches,
        c.tasks_touched,
        c.msg_bytes_logical,
        c.msg_bytes_padded,
        c.model_bytes,
        c.peak_rss_bytes,
        c.boundary_msgs_sent,
        c.boundary_msgs_recv,
        c.boundary_bytes,
        c.exchange_batches,
        c.net_wait_us,
    ] {
        put_u64(p, v);
    }
}

fn decode_counters(cur: &mut Cur<'_>) -> Result<Counters> {
    let mut c = Counters::default();
    for f in [
        &mut c.updates,
        &mut c.useful_updates,
        &mut c.wasted_pops,
        &mut c.stale_pops,
        &mut c.claim_failures,
        &mut c.pops,
        &mut c.inserts,
        &mut c.rounds,
        &mut c.splashes,
        &mut c.refreshes,
        &mut c.insert_batches,
        &mut c.tasks_touched,
        &mut c.msg_bytes_logical,
        &mut c.msg_bytes_padded,
        &mut c.model_bytes,
        &mut c.peak_rss_bytes,
        &mut c.boundary_msgs_sent,
        &mut c.boundary_msgs_recv,
        &mut c.boundary_bytes,
        &mut c.exchange_batches,
        &mut c.net_wait_us,
    ] {
        *f = cur.u64()?;
    }
    Ok(c)
}

fn encode_stats(src: u32, stats: &EngineStats) -> Vec<u8> {
    let mut p = control_payload(KIND_STATS, src, 0);
    encode_counters(&stats.metrics.total, &mut p);
    put_u32(&mut p, stats.metrics.per_thread_updates.len() as u32);
    for &u in &stats.metrics.per_thread_updates {
        put_u64(&mut p, u);
    }
    put_f64(&mut p, stats.wall_secs);
    put_f64(&mut p, stats.final_max_priority);
    p.push(stats.converged as u8);
    p
}

fn decode_stats(cur: &mut Cur<'_>) -> Result<RankResult> {
    let counters = decode_counters(cur)?;
    let n = cur.u32()? as usize;
    if n > 4096 {
        bail!("corrupt stats frame: {n} threads");
    }
    let mut per_thread = Vec::with_capacity(n);
    for _ in 0..n {
        per_thread.push(cur.u64()?);
    }
    let wall = cur.f64()?;
    let final_prio = cur.f64()?;
    let converged = cur.u8()? != 0;
    Ok(RankResult { counters, per_thread, wall, final_prio, converged })
}

// ---------------------------------------------------------------------------
// Reader loop
// ---------------------------------------------------------------------------

/// Drain one incoming link. On rank 0 this also relays frames addressed
/// to other ranks and terminates once the peer's `STATS` landed; on a
/// worker it terminates on `DONE`. An I/O error after `done` is the
/// normal teardown; before it, it's a failure the caller latches.
fn reader_loop(
    rt: &DistRuntime,
    mrf: &Mrf,
    msgs: &Messages,
    stream: &mut TcpStream,
    results: Option<(&Mutex<Vec<Option<RankResult>>>, u32)>,
) -> Result<()> {
    let mut buf = Vec::new();
    loop {
        if let Err(e) = read_frame(stream, &mut buf) {
            if rt.done.load(Ordering::SeqCst) {
                return Ok(());
            }
            return Err(e);
        }
        let mut cur = Cur::new(&buf);
        let kind = cur.u8()?;
        let _src = cur.u32()?;
        let dst = cur.u32()?;
        if dst != rt.rank {
            // Star relay: forward the payload verbatim. End-to-end
            // counters are accounted at origin and destination only.
            rt.send_payload(dst, &buf)?;
            continue;
        }
        match kind {
            KIND_BATCH => rt.apply_batch(mrf, msgs, &mut cur)?,
            KIND_TOKEN => {
                let q = cur.i64()?;
                let black = cur.u8()? != 0;
                // Park it; only the passive verifier may forward.
                *rt.token.lock().unwrap() = Some(Token { q, black });
            }
            KIND_DONE => {
                rt.done.store(true, Ordering::SeqCst);
                return Ok(());
            }
            KIND_FINAL => rt.apply_final(mrf, msgs, &mut cur)?,
            KIND_STATS => {
                let r = decode_stats(&mut cur)?;
                if let Some((slots, peer)) = results {
                    slots.lock().unwrap()[peer as usize] = Some(r);
                }
                return Ok(());
            }
            KIND_HELLO => {}
            k => bail!("unknown frame kind {k}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Per-rank engine run
// ---------------------------------------------------------------------------

/// Deterministic per-rank setup shared by every role: model, messages,
/// partition, rank map, boundary index. All of it is a pure function of
/// the (normalized) config, so every rank reconstructs identical state.
fn build_rank_state(
    cfg: &RunConfig,
    nprocs: u32,
) -> Result<(Mrf, Messages, RankMap, BoundaryIndex, PrepStats)> {
    let mut prep = PrepStats::default();
    let t = Timer::start();
    let mrf = builders::build(&cfg.model, cfg.seed);
    prep.build_secs = t.elapsed_secs();
    let t = Timer::start();
    let msgs = crate::run::build_messages(cfg, &mrf)?;
    prep.init_secs = t.elapsed_secs();
    let part = partition::for_messages(&mrf, cfg)
        .ok_or_else(|| anyhow!("distributed runs require the locality axis (partition)"))?;
    let map = RankMap::contiguous(&part, nprocs as usize);
    let boundary = BoundaryIndex::build(&mrf.graph, &map);
    Ok((mrf, msgs, map, boundary, prep))
}

/// Run the relaxed worker pool on this rank's owned tasks, then fold the
/// transport counters into the stats and surface any latched failure.
fn run_rank(cfg: &RunConfig, mrf: &Mrf, msgs: &Messages, rt: &DistRuntime) -> Result<EngineStats> {
    let policy = ResidualPolicy::new_dist(mrf, msgs, cfg, rt);
    let mut stats = WorkerPool::from_config(cfg, SchedChoice::Relaxed)
        .with_partition(partition::for_messages(mrf, cfg))
        .run(&policy);
    if let Some(msg) = rt.failed() {
        bail!("{msg}");
    }
    rt.fold_net(&mut stats.metrics.total);
    Ok(stats)
}

// ---------------------------------------------------------------------------
// Roles
// ---------------------------------------------------------------------------

/// `spawn:N`: fork N−1 worker processes against a pre-bound loopback
/// port, run rank 0 in-process, reap the children. The listener is bound
/// *before* the children exist, so there is no connect race to retry
/// around. Tests (and the bench harness, when re-invoking from inside a
/// test binary) can override the child executable via `RELAXED_BP_EXE`.
fn cmd_spawn(cfg: &RunConfig, nprocs: u32) -> Result<RunReport> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind spawn listener")?;
    let port = listener.local_addr()?.port();
    let tmp = std::env::temp_dir()
        .join(format!("relaxed-bp-dist-{}-{port}.json", std::process::id()));
    let tmp_s = tmp.to_string_lossy().into_owned();
    cfg.save(&tmp_s).context("write spawn config")?;
    let exe = match std::env::var("RELAXED_BP_EXE") {
        Ok(p) => std::path::PathBuf::from(p),
        Err(_) => std::env::current_exe().context("locate own executable")?,
    };
    let mut children = Vec::new();
    let mut res: Result<RunReport> = Err(anyhow!("no worker spawned"));
    let mut spawn_ok = true;
    for r in 1..nprocs {
        match std::process::Command::new(&exe)
            .arg("run")
            .arg("--config")
            .arg(&tmp_s)
            .arg("--distributed")
            .arg(format!("worker:{nprocs}:{r}:127.0.0.1:{port}"))
            .stdout(std::process::Stdio::null())
            .spawn()
            .with_context(|| format!("spawn worker rank {r}"))
        {
            Ok(child) => children.push(child),
            Err(e) => {
                res = Err(e);
                spawn_ok = false;
                break;
            }
        }
    }
    if spawn_ok {
        res = coordinate(cfg, listener, nprocs);
    }
    for mut child in children {
        if res.is_err() {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if !status.success() && res.is_ok() => {
                res = Err(anyhow!("worker process exited with {status}"));
            }
            Ok(_) => {}
            Err(e) if res.is_ok() => res = Err(e.into()),
            Err(_) => {}
        }
    }
    let _ = std::fs::remove_file(&tmp);
    res
}

/// Rank 0: accept the N−1 workers, run the local shard range, detect
/// global termination, gather `FINAL` + `STATS`, and assemble the single
/// merged report exactly like a single-process `run` would.
fn coordinate(cfg: &RunConfig, listener: TcpListener, nprocs: u32) -> Result<RunReport> {
    let (mrf, msgs, map, boundary, prep) = build_rank_state(cfg, nprocs)?;
    let mut links: Vec<Option<PeerLink>> = (0..nprocs).map(|_| None).collect();
    let mut reader_streams = Vec::new();
    for _ in 1..nprocs {
        let (mut stream, _) = listener.accept().context("accept worker")?;
        stream.set_nodelay(true).ok();
        let mut buf = Vec::new();
        read_frame(&mut stream, &mut buf).context("read worker hello")?;
        let mut cur = Cur::new(&buf);
        if cur.u8()? != KIND_HELLO {
            bail!("worker sent a non-hello first frame");
        }
        let rank = cur.u32()?;
        if rank == 0 || rank >= nprocs || links[rank as usize].is_some() {
            bail!("bad or duplicate hello from rank {rank}");
        }
        links[rank as usize] = Some(PeerLink::new(stream.try_clone()?)?);
        reader_streams.push((rank, stream));
    }
    let rt = Arc::new(DistRuntime::new(0, nprocs, cfg.kernel, map, boundary, links));
    let mrf = Arc::new(mrf);
    let msgs = Arc::new(msgs);
    let results: Arc<Mutex<Vec<Option<RankResult>>>> =
        Arc::new(Mutex::new((0..nprocs).map(|_| None).collect()));
    let mut readers = Vec::new();
    for (rank, mut stream) in reader_streams {
        let (rt, mrf, msgs, results) =
            (Arc::clone(&rt), Arc::clone(&mrf), Arc::clone(&msgs), Arc::clone(&results));
        readers.push(std::thread::spawn(move || {
            if let Err(e) = reader_loop(&rt, &mrf, &msgs, &mut stream, Some((&*results, rank))) {
                rt.fail(format!("rank 0: link to rank {rank} failed: {e:#}"));
            }
        }));
    }
    let run_res = run_rank(cfg, &mrf, &msgs, &rt);
    for h in readers {
        let _ = h.join();
    }
    let mut stats = run_res?;
    if let Some(msg) = rt.failed() {
        bail!("{msg}");
    }
    {
        let mut slots = results.lock().unwrap();
        for r in 1..nprocs as usize {
            let peer = slots[r]
                .take()
                .ok_or_else(|| anyhow!("rank {r} never reported its stats"))?;
            stats.metrics.total.add(&peer.counters);
            stats.metrics.per_thread_updates.extend(peer.per_thread);
            stats.wall_secs = stats.wall_secs.max(peer.wall);
            stats.final_max_priority = stats.final_max_priority.max(peer.final_prio);
            stats.converged &= peer.converged;
        }
    }
    drop(results);
    drop(rt);
    let mrf = Arc::try_unwrap(mrf).map_err(|_| anyhow!("internal: model still shared"))?;
    let msgs = Arc::try_unwrap(msgs).map_err(|_| anyhow!("internal: messages still shared"))?;
    Ok(RunReport { stats, mrf, msgs, config: cfg.clone(), prep })
}

/// A worker rank: connect to the hub, run the owned shard range, and on
/// global termination ship the owned fixed-point slice + run stats back.
fn run_worker(cfg: &RunConfig, nprocs: u32, rank: u32, addr: &str) -> Result<()> {
    let (mrf, msgs, map, boundary, _prep) = build_rank_state(cfg, nprocs)?;
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("rank {rank}: connect to coordinator at {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut reader_stream = stream.try_clone()?;
    let links = vec![Some(PeerLink::new(stream)?)];
    let rt = Arc::new(DistRuntime::new(rank, nprocs, cfg.kernel, map, boundary, links));
    rt.send_payload(0, &{
        let mut p = vec![KIND_HELLO];
        put_u32(&mut p, rank);
        put_u32(&mut p, 0);
        p
    })?;
    let mrf = Arc::new(mrf);
    let msgs = Arc::new(msgs);
    {
        // Detached on purpose: after DONE the reader returns; on the
        // failure path it may still be blocked in a read when the process
        // exits, and must not keep it alive.
        let (rt, mrf, msgs) = (Arc::clone(&rt), Arc::clone(&mrf), Arc::clone(&msgs));
        std::thread::spawn(move || {
            if let Err(e) = reader_loop(&rt, &mrf, &msgs, &mut reader_stream, None) {
                rt.fail(format!("rank {}: hub link failed: {e:#}", rt.rank));
            }
        });
    }
    let stats = run_rank(cfg, &mrf, &msgs, &rt)?;
    send_results(&rt, &mrf, &msgs, &stats)?;
    Ok(())
}

/// Ship this rank's owned edges (`FINAL`, chunked) then its `STATS`
/// frame — the stats double as the rank's end-of-stream marker.
fn send_results(rt: &DistRuntime, mrf: &Mrf, msgs: &Messages, stats: &EngineStats) -> Result<()> {
    let mut vals = [0.0f64; MAX_DOMAIN];
    let mut body = Vec::new();
    let mut count = 0u32;
    for e in 0..mrf.num_messages() as u32 {
        if !rt.map.owns(rt.rank, e) {
            continue;
        }
        let len = msgs.read_msg(mrf, e, &mut vals);
        body.extend_from_slice(&e.to_le_bytes());
        body.push(len as u8);
        for v in &vals[..len] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        count += 1;
        if count as usize == FINAL_CHUNK {
            send_final_frame(rt, count, &body)?;
            body.clear();
            count = 0;
        }
    }
    if count > 0 {
        send_final_frame(rt, count, &body)?;
    }
    rt.send_payload(0, &encode_stats(rt.rank, stats))
}

fn send_final_frame(rt: &DistRuntime, count: u32, body: &[u8]) -> Result<()> {
    let mut payload = control_payload(KIND_FINAL, rt.rank, 0);
    put_u32(&mut payload, count);
    payload.extend_from_slice(body);
    rt.send_payload(0, &payload)
}

// ---------------------------------------------------------------------------
// CLI entry
// ---------------------------------------------------------------------------

/// Programmatic `spawn:N` entry: solve with rank 0 in-process and N−1
/// forked local worker processes, returning the merged [`RunReport`].
/// The child executable defaults to the current one and can be overridden
/// via the `RELAXED_BP_EXE` environment variable (how the test suite and
/// the bench harness spawn workers from inside a test binary). The
/// partition is normalized exactly like the CLI path.
pub fn run_spawn(cfg: &RunConfig, nprocs: u32) -> Result<RunReport> {
    if !matches!(cfg.algorithm, AlgorithmSpec::RelaxedResidual) {
        bail!(
            "--distributed supports only the relaxed_residual algorithm, got {}",
            cfg.algorithm.name()
        );
    }
    let mut cfg = cfg.clone();
    normalize_partition(&mut cfg, nprocs)?;
    cmd_spawn(&cfg, nprocs)
}

/// Entry point for `run --distributed <spec>`: parse the role, normalize
/// the partition (ownership needs ≥ 1 shard per rank; unset shards
/// default to `threads × nprocs`), and dispatch. Only rank 0 (and the
/// `spawn` launcher hosting it) prints the merged report; workers exit
/// silently on success.
pub fn cmd_run_distributed(cfg: &RunConfig, spec: &str, out: Option<&str>) -> Result<()> {
    let spec = DistSpec::parse(spec)?;
    if !matches!(cfg.algorithm, AlgorithmSpec::RelaxedResidual) {
        bail!(
            "--distributed supports only the relaxed_residual algorithm, got {}",
            cfg.algorithm.name()
        );
    }
    let mut cfg = cfg.clone();
    normalize_partition(&mut cfg, spec.nprocs)?;
    let report = match spec.role {
        Role::Spawn => cmd_spawn(&cfg, spec.nprocs)?,
        Role::Coord => {
            let addr = spec.addr.as_deref().unwrap_or("127.0.0.1:0");
            let listener =
                TcpListener::bind(addr).with_context(|| format!("bind coordinator on {addr}"))?;
            eprintln!("coordinator listening on {}", listener.local_addr()?);
            coordinate(&cfg, listener, spec.nprocs)?
        }
        Role::Worker => {
            return run_worker(&cfg, spec.nprocs, spec.rank, spec.addr.as_deref().unwrap_or_default());
        }
    };
    let json = report.to_json();
    println!("{}", json.to_string_pretty());
    if let Some(path) = out {
        std::fs::write(path, json.to_string_pretty())?;
    }
    if !report.stats.converged {
        bail!("run did not converge within budget");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::ModelSpec;

    #[test]
    fn dist_spec_parses_all_roles() {
        assert_eq!(
            DistSpec::parse("spawn:4").unwrap(),
            DistSpec { role: Role::Spawn, nprocs: 4, rank: 0, addr: None }
        );
        assert_eq!(
            DistSpec::parse("coord:2:0").unwrap(),
            DistSpec { role: Role::Coord, nprocs: 2, rank: 0, addr: None }
        );
        assert_eq!(
            DistSpec::parse("coord:2:0:0.0.0.0:7000").unwrap(),
            DistSpec { role: Role::Coord, nprocs: 2, rank: 0, addr: Some("0.0.0.0:7000".into()) }
        );
        assert_eq!(
            DistSpec::parse("worker:4:3:127.0.0.1:7000").unwrap(),
            DistSpec {
                role: Role::Worker,
                nprocs: 4,
                rank: 3,
                addr: Some("127.0.0.1:7000".into())
            }
        );
    }

    #[test]
    fn dist_spec_rejects_bad_specs() {
        assert!(DistSpec::parse("spawn:0").is_err());
        assert!(DistSpec::parse("coord:2:1").is_err());
        assert!(DistSpec::parse("worker:2:0:addr").is_err());
        assert!(DistSpec::parse("worker:2:2:addr").is_err());
        assert!(DistSpec::parse("worker:2:1").is_err());
        assert!(DistSpec::parse("mesh:2").is_err());
    }

    #[test]
    fn frame_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();
        let mut payload = control_payload(KIND_TOKEN, 1, 2);
        payload.extend_from_slice(&(-7i64).to_le_bytes());
        payload.push(1);
        write_frame(&mut tx, &payload).unwrap();
        let mut buf = Vec::new();
        read_frame(&mut rx, &mut buf).unwrap();
        assert_eq!(buf, payload);
        let mut cur = Cur::new(&buf);
        assert_eq!(cur.u8().unwrap(), KIND_TOKEN);
        assert_eq!(cur.u32().unwrap(), 1);
        assert_eq!(cur.u32().unwrap(), 2);
        assert_eq!(cur.i64().unwrap(), -7);
        assert_eq!(cur.u8().unwrap(), 1);
        assert!(cur.u8().is_err(), "cursor is exhausted");
    }

    #[test]
    fn stats_frame_roundtrip() {
        let mut stats = EngineStats {
            converged: true,
            wall_secs: 1.25,
            metrics: crate::coordinator::MetricsReport {
                total: Counters::default(),
                per_thread_updates: vec![10, 20, 30],
            },
            final_max_priority: 3.5e-7,
        };
        stats.metrics.total.updates = 42;
        stats.metrics.total.boundary_msgs_sent = 7;
        stats.metrics.total.net_wait_us = 99;
        let payload = encode_stats(3, &stats);
        let mut cur = Cur::new(&payload);
        assert_eq!(cur.u8().unwrap(), KIND_STATS);
        assert_eq!(cur.u32().unwrap(), 3);
        assert_eq!(cur.u32().unwrap(), 0);
        let r = decode_stats(&mut cur).unwrap();
        assert_eq!(r.counters.updates, 42);
        assert_eq!(r.counters.boundary_msgs_sent, 7);
        assert_eq!(r.counters.net_wait_us, 99);
        assert_eq!(r.per_thread, vec![10, 20, 30]);
        assert_eq!(r.wall, 1.25);
        assert_eq!(r.final_prio, 3.5e-7);
        assert!(r.converged);
    }

    #[test]
    fn normalize_partition_defaults_and_validates() {
        let base = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_threads(3);
        // Off → affine with threads × nprocs shards.
        let mut cfg = base.clone();
        normalize_partition(&mut cfg, 2).unwrap();
        assert_eq!(
            cfg.partition,
            PartitionSpec::Affine { shards: 6, spill: DEFAULT_SPILL, bfs: false }
        );
        // Auto shard count resolves the same way, keeping spill/bfs.
        let mut cfg = base
            .clone()
            .with_partition(PartitionSpec::Affine { shards: 0, spill: 0.2, bfs: true });
        normalize_partition(&mut cfg, 4).unwrap();
        assert_eq!(cfg.partition, PartitionSpec::Affine { shards: 12, spill: 0.2, bfs: true });
        // Explicit-but-too-few shards is an error, not a silent re-shard.
        let explicit =
            |shards| PartitionSpec::Affine { shards, spill: DEFAULT_SPILL, bfs: false };
        let mut cfg = base.with_partition(explicit(3));
        assert!(normalize_partition(&mut cfg, 4).is_err());
        // Enough explicit shards pass through untouched.
        let mut cfg2 = RunConfig::new(ModelSpec::Ising { n: 6 }, AlgorithmSpec::RelaxedResidual)
            .with_partition(explicit(8));
        normalize_partition(&mut cfg2, 4).unwrap();
        assert_eq!(cfg2.partition, explicit(8));
    }

    #[test]
    fn counters_encode_decode_roundtrip() {
        let c = Counters {
            updates: 1,
            useful_updates: 2,
            wasted_pops: 3,
            stale_pops: 4,
            claim_failures: 5,
            pops: 6,
            inserts: 7,
            rounds: 8,
            splashes: 9,
            refreshes: 10,
            insert_batches: 11,
            tasks_touched: 12,
            msg_bytes_logical: 13,
            msg_bytes_padded: 14,
            model_bytes: 15,
            peak_rss_bytes: 16,
            boundary_msgs_sent: 17,
            boundary_msgs_recv: 18,
            boundary_bytes: 19,
            exchange_batches: 20,
            net_wait_us: 21,
        };
        let mut p = Vec::new();
        encode_counters(&c, &mut p);
        assert_eq!(p.len(), 21 * 8);
        let d = decode_counters(&mut Cur::new(&p)).unwrap();
        assert_eq!(c, d);
    }
}
