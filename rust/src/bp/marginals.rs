//! Marginal extraction and comparison metrics.
//!
//! After convergence, the belief at node `i` is
//! `P(X_i = x) ∝ ψ_i(x) · Π_{j ∈ N(i)} μ_{j→i}(x)`.

use super::state::{msg_buf, MsgSource};
use super::update::normalize;
use crate::coordinator::run_workers;
use crate::model::Mrf;
use crate::util::cold_path_threads;

/// Compute the belief at node `i` into `out[..d_i]`; returns `d_i`.
pub fn node_marginal<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    i: usize,
    out: &mut [f64],
) -> usize {
    let d = mrf.domain[i] as usize;
    out[..d].copy_from_slice(mrf.node_factors.of(i));
    let mut buf = msg_buf();
    for s in mrf.graph.slots(i) {
        let e_in = mrf.graph.adj_in[s];
        src.read_msg(mrf, e_in, &mut buf);
        for x in 0..d {
            out[x] *= buf[x];
        }
    }
    normalize(&mut out[..d]);
    d
}

/// All node marginals as owned vectors, extracted in parallel over
/// contiguous node ranges above the cold-path threshold. Each node's
/// belief is computed independently, so the result is identical for
/// every thread count.
pub fn all_marginals<S: MsgSource + Sync + ?Sized>(mrf: &Mrf, src: &S) -> Vec<Vec<f64>> {
    let n = mrf.num_nodes();
    let threads = cold_path_threads(n);
    let chunks = run_workers(threads, |t| {
        let lo = t * n / threads;
        let hi = (t + 1) * n / threads;
        let mut part = Vec::with_capacity(hi - lo);
        let mut buf = msg_buf();
        for i in lo..hi {
            let d = node_marginal(mrf, src, i, &mut buf);
            part.push(buf[..d].to_vec());
        }
        part
    });
    let mut out = Vec::with_capacity(n);
    for part in chunks {
        out.extend(part);
    }
    out
}

/// L∞ distance between two marginal sets (max over nodes and states).
pub fn max_marginal_diff(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut worst = 0.0f64;
    for (ma, mb) in a.iter().zip(b) {
        assert_eq!(ma.len(), mb.len());
        for (x, y) in ma.iter().zip(mb) {
            worst = worst.max((x - y).abs());
        }
    }
    worst
}

/// Hard-decision decode: argmax belief per node, over the first `n` nodes
/// (for LDPC: the variable nodes).
pub fn decode_bits<S: MsgSource + ?Sized>(mrf: &Mrf, src: &S, n: usize) -> Vec<u8> {
    let mut buf = msg_buf();
    (0..n)
        .map(|i| {
            let d = node_marginal(mrf, src, i, &mut buf);
            let mut best = 0usize;
            for x in 1..d {
                if buf[x] > buf[best] {
                    best = x;
                }
            }
            best as u8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::state::Messages;
    use crate::model::builders;
    use crate::configio::ModelSpec;

    #[test]
    fn marginal_of_isolated_prior() {
        // Before any propagation (uniform messages), the belief is the prior.
        let m = builders::build(&ModelSpec::Tree { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        let d = node_marginal(&m, &msgs, 0, &mut buf);
        assert_eq!(d, 2);
        assert!((buf[0] - 0.1).abs() < 1e-12);
        assert!((buf[1] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn marginals_sum_to_one() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 3);
        let msgs = Messages::uniform(&m);
        for mg in all_marginals(&m, &msgs) {
            let s: f64 = mg.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn diff_metric() {
        let a = vec![vec![0.5, 0.5], vec![0.9, 0.1]];
        let b = vec![vec![0.5, 0.5], vec![0.7, 0.3]];
        assert!((max_marginal_diff(&a, &b) - 0.2).abs() < 1e-12);
        assert_eq!(max_marginal_diff(&a, &a), 0.0);
    }

    #[test]
    fn decode_prefers_larger_belief() {
        let m = builders::build(&ModelSpec::Tree { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let bits = decode_bits(&m, &msgs, 1);
        assert_eq!(bits, vec![1]); // prior (0.1, 0.9) → argmax 1
    }
}
