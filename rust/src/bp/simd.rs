//! Lane-tiled f64 primitives behind the update-kernel axis
//! (`RunConfig::kernel`).
//!
//! Every inner `|D|`-wide loop of the message data path (source-product
//! accumulation, the edge-factor matrix apply, normalization, and the L2
//! residual) is available in two implementations selected by [`Kernel`]:
//!
//! - [`Kernel::Scalar`] — the historical per-element loops, kept
//!   bit-for-bit identical to the pre-SIMD code path. This is the A/B
//!   reference: a `--kernel scalar` run reproduces the exact message
//!   trajectory of the code before the vectorized data path landed.
//! - [`Kernel::Simd`] — the functions in this module: fixed-width 4-lane
//!   tiles written so LLVM reliably auto-vectorizes them (independent lane
//!   accumulators, `chunks_exact`, no cross-lane dependencies), plus a
//!   runtime-detected AVX2 path (`is_x86_feature_detected!`) using
//!   `std::arch` intrinsics.
//!
//! The AVX2 variants use separate multiply and add (no FMA) and the same
//! lane grouping as the portable tiles, so the two SIMD implementations
//! produce **bit-identical** results — which machine ran the kernel never
//! changes the numbers, only how fast they arrive. Versus the scalar
//! kernel the tiled reductions reassociate the sums (4 independent lane
//! accumulators combined pairwise at the end), so simd-vs-scalar values
//! agree to ≤ 1e-12 relative on normalized messages, not bit-for-bit;
//! `rust/tests/simd.rs` pins that bound across every model family.

/// Number of f64 lanes per tile (one AVX2 vector). Exposed so the fused
/// atomic-cell loops in `bp::state` tile with the same width.
pub const LANES: usize = 4;

/// Number of f32 lanes per convert tile (one AVX2 `ps` vector — two `pd`
/// vectors after widening). The precision axis's f32 bulk I/O paths
/// (`bp::state`) tile with this width: one 32-byte load covers 8 stored
/// cells, which then widen to two 4-lane f64 vectors in registers.
pub const WIDE_LANES: usize = 8;

/// Which inner-loop implementation the message kernels use — the
/// update-kernel axis (`--kernel scalar|simd`, default `simd`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Kernel {
    /// The historical per-element loops and per-cell message I/O —
    /// bit-for-bit the pre-SIMD code path, kept as the A/B reference.
    Scalar,
    /// Lane-tiled arithmetic (portable tiles + runtime-detected AVX2),
    /// bulk message I/O, and in-kernel residuals. The default.
    #[default]
    Simd,
}

impl Kernel {
    /// Short label for reports, bench cell ids, and JSON (`scalar`/`simd`).
    pub fn label(&self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Simd => "simd",
        }
    }

    /// True for the vectorized kernel.
    pub fn is_simd(&self) -> bool {
        matches!(self, Kernel::Simd)
    }
}

/// Runtime AVX2 detection. `is_x86_feature_detected!` caches the CPUID
/// result in an atomic, so this is a relaxed load + test on the hot path.
/// On non-x86 targets every call site is compiled out and the portable
/// tiles run unconditionally.
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// `acc[i] *= x[i]` — the source-product accumulation step.
#[inline]
pub fn mul_assign(acc: &mut [f64], x: &[f64]) {
    // Hard slice (not just a debug assert): the AVX2 path reads through
    // raw pointers, so a short `x` must panic here, never read past the
    // end in release builds.
    let x = &x[..acc.len()];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: avx2() verified the CPU supports the target
            // feature, and both slices are exactly acc.len() long.
            unsafe { mul_assign_avx2(acc, x) };
            return;
        }
    }
    mul_assign_tiled(acc, x);
}

#[inline]
fn mul_assign_tiled(acc: &mut [f64], x: &[f64]) {
    let n = acc.len();
    let mut chunks = acc.chunks_exact_mut(LANES);
    let mut xs = x[..n].chunks_exact(LANES);
    for (a, b) in chunks.by_ref().zip(xs.by_ref()) {
        for l in 0..LANES {
            a[l] *= b[l];
        }
    }
    for (a, b) in chunks.into_remainder().iter_mut().zip(xs.remainder()) {
        *a *= b;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn mul_assign_avx2(acc: &mut [f64], x: &[f64]) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let mut k = 0;
    while k + LANES <= n {
        let a = _mm256_loadu_pd(acc.as_ptr().add(k));
        let b = _mm256_loadu_pd(x.as_ptr().add(k));
        _mm256_storeu_pd(acc.as_mut_ptr().add(k), _mm256_mul_pd(a, b));
        k += LANES;
    }
    while k < n {
        acc[k] *= x[k];
        k += 1;
    }
}

/// `out[i] = a[i] * b[i]` — the prefix-product step of the fused kernel.
#[inline]
pub fn mul_into(out: &mut [f64], a: &[f64], b: &[f64]) {
    let n = out.len();
    debug_assert!(a.len() >= n && b.len() >= n);
    for ((o, x), y) in out.iter_mut().zip(&a[..n]).zip(&b[..n]) {
        *o = x * y;
    }
}

/// `out[i] += s * x[i]` — one row of the non-transposed factor apply.
#[inline]
pub fn axpy(out: &mut [f64], s: f64, x: &[f64]) {
    // Hard slice: the AVX2 path must never read past a short `x`.
    let x = &x[..out.len()];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: avx2() verified the CPU supports the target
            // feature, and both slices are exactly out.len() long.
            unsafe { axpy_avx2(out, s, x) };
            return;
        }
    }
    axpy_tiled(out, s, x);
}

#[inline]
fn axpy_tiled(out: &mut [f64], s: f64, x: &[f64]) {
    let n = out.len();
    let mut chunks = out.chunks_exact_mut(LANES);
    let mut xs = x[..n].chunks_exact(LANES);
    for (o, b) in chunks.by_ref().zip(xs.by_ref()) {
        for l in 0..LANES {
            o[l] += s * b[l];
        }
    }
    for (o, b) in chunks.into_remainder().iter_mut().zip(xs.remainder()) {
        *o += s * b;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(out: &mut [f64], s: f64, x: &[f64]) {
    use std::arch::x86_64::*;
    let n = out.len();
    let vs = _mm256_set1_pd(s);
    let mut k = 0;
    while k + LANES <= n {
        let o = _mm256_loadu_pd(out.as_ptr().add(k));
        let b = _mm256_loadu_pd(x.as_ptr().add(k));
        // mul + add (no FMA) keeps results bit-identical to the tiles.
        _mm256_storeu_pd(out.as_mut_ptr().add(k), _mm256_add_pd(o, _mm256_mul_pd(vs, b)));
        k += LANES;
    }
    while k < n {
        out[k] += s * x[k];
        k += 1;
    }
}

/// Combine one tile of lane accumulators + the scalar tail the way every
/// reduction here does: pairwise over lanes, then the tail. Keeping this
/// in one place — it is also what the fused atomic-cell reductions in
/// `bp::state` use — guarantees every SIMD-kernel reduction in the crate
/// shares one grouping, so the portable tiles, the AVX2 paths, and the
/// in-kernel residuals agree bit-for-bit.
#[inline]
pub fn reduce(acc: [f64; LANES], tail: f64) -> f64 {
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
}

/// Dot product `Σ a[i]·b[i]` — one output row of the transposed factor
/// apply.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    // Hard slice: the AVX2 path must never read past a short `b`.
    let b = &b[..a.len()];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: avx2() verified the CPU supports the target
            // feature, and both slices are exactly a.len() long.
            return unsafe { dot_avx2(a, b) };
        }
    }
    dot_tiled(a, b)
}

#[inline]
fn dot_tiled(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let mut acc = [0.0f64; LANES];
    let mut chunks = a.chunks_exact(LANES);
    let mut bs = b[..n].chunks_exact(LANES);
    for (x, y) in chunks.by_ref().zip(bs.by_ref()) {
        for l in 0..LANES {
            acc[l] += x[l] * y[l];
        }
    }
    let mut tail = 0.0;
    for (x, y) in chunks.remainder().iter().zip(bs.remainder()) {
        tail += x * y;
    }
    reduce(acc, tail)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut vacc = _mm256_setzero_pd();
    let mut k = 0;
    while k + LANES <= n {
        let x = _mm256_loadu_pd(a.as_ptr().add(k));
        let y = _mm256_loadu_pd(b.as_ptr().add(k));
        vacc = _mm256_add_pd(vacc, _mm256_mul_pd(x, y));
        k += LANES;
    }
    let mut acc = [0.0f64; LANES];
    _mm256_storeu_pd(acc.as_mut_ptr(), vacc);
    let mut tail = 0.0;
    while k < n {
        tail += a[k] * b[k];
        k += 1;
    }
    reduce(acc, tail)
}

/// Lane-tiled sum (the normalizer).
#[inline]
pub fn sum(v: &[f64]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let mut chunks = v.chunks_exact(LANES);
    for x in chunks.by_ref() {
        for l in 0..LANES {
            acc[l] += x[l];
        }
    }
    let mut tail = 0.0;
    for x in chunks.remainder() {
        tail += x;
    }
    reduce(acc, tail)
}

/// `v[i] *= s` (the normalization scale).
#[inline]
pub fn scale(v: &mut [f64], s: f64) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

/// Sum of squared differences `Σ (a[i] − b[i])²` — the L2 residual before
/// the square root.
#[inline]
pub fn sq_diff_sum(a: &[f64], b: &[f64]) -> f64 {
    // Hard slice: the AVX2 path must never read past a short `b`.
    let b = &b[..a.len()];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: avx2() verified the CPU supports the target
            // feature, and both slices are exactly a.len() long.
            return unsafe { sq_diff_sum_avx2(a, b) };
        }
    }
    sq_diff_sum_tiled(a, b)
}

#[inline]
fn sq_diff_sum_tiled(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let mut acc = [0.0f64; LANES];
    let mut chunks = a.chunks_exact(LANES);
    let mut bs = b[..n].chunks_exact(LANES);
    for (x, y) in chunks.by_ref().zip(bs.by_ref()) {
        for l in 0..LANES {
            let d = x[l] - y[l];
            acc[l] += d * d;
        }
    }
    let mut tail = 0.0;
    for (x, y) in chunks.remainder().iter().zip(bs.remainder()) {
        let d = x - y;
        tail += d * d;
    }
    reduce(acc, tail)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sq_diff_sum_avx2(a: &[f64], b: &[f64]) -> f64 {
    use std::arch::x86_64::*;
    let n = a.len();
    let mut vacc = _mm256_setzero_pd();
    let mut k = 0;
    while k + LANES <= n {
        let x = _mm256_loadu_pd(a.as_ptr().add(k));
        let y = _mm256_loadu_pd(b.as_ptr().add(k));
        let d = _mm256_sub_pd(x, y);
        vacc = _mm256_add_pd(vacc, _mm256_mul_pd(d, d));
        k += LANES;
    }
    let mut acc = [0.0f64; LANES];
    _mm256_storeu_pd(acc.as_mut_ptr(), vacc);
    let mut tail = 0.0;
    while k < n {
        let d = a[k] - b[k];
        tail += d * d;
        k += 1;
    }
    reduce(acc, tail)
}

/// Convert-on-load widen tile: `out[i] = src[i] as f64`.
///
/// The gather half of the f32 message arena's bulk I/O (the precision
/// axis): stored cells stream out as full cache lines of `f32` and widen
/// to `f64` in 8-lane tiles, so compute stays double precision in
/// registers while memory traffic is halved. `f32 → f64` is exact, so the
/// portable and AVX2 paths are trivially bit-identical.
#[inline]
pub fn widen(out: &mut [f64], src: &[f32]) {
    // Hard slice: the AVX2 path must never read past a short `src`.
    let src = &src[..out.len()];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: avx2() verified the CPU supports the target
            // feature, and both slices are exactly out.len() long.
            unsafe { widen_avx2(out, src) };
            return;
        }
    }
    widen_tiled(out, src);
}

#[inline]
fn widen_tiled(out: &mut [f64], src: &[f32]) {
    let n = out.len();
    let mut chunks = out.chunks_exact_mut(WIDE_LANES);
    let mut xs = src[..n].chunks_exact(WIDE_LANES);
    for (o, s) in chunks.by_ref().zip(xs.by_ref()) {
        for l in 0..WIDE_LANES {
            o[l] = s[l] as f64;
        }
    }
    for (o, s) in chunks.into_remainder().iter_mut().zip(xs.remainder()) {
        *o = *s as f64;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn widen_avx2(out: &mut [f64], src: &[f32]) {
    use std::arch::x86_64::*;
    let n = out.len();
    let mut k = 0;
    while k + WIDE_LANES <= n {
        // One 8-wide f32 load, widened to two 4-wide f64 vectors.
        let s = _mm256_loadu_ps(src.as_ptr().add(k));
        let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(s));
        let hi = _mm256_cvtps_pd(_mm256_extractf128_ps(s, 1));
        _mm256_storeu_pd(out.as_mut_ptr().add(k), lo);
        _mm256_storeu_pd(out.as_mut_ptr().add(k + LANES), hi);
        k += WIDE_LANES;
    }
    while k < n {
        out[k] = src[k] as f64;
        k += 1;
    }
}

/// Round-on-store narrow tile: `out[i] = src[i] as f32` (round to nearest
/// even — the precision axis's single rounding point per stored cell).
///
/// The scatter half of the f32 arena's bulk I/O. `as f32` and
/// `_mm256_cvtpd_ps` both round to nearest even, so the portable and AVX2
/// paths are bit-identical.
#[inline]
pub fn narrow(out: &mut [f32], src: &[f64]) {
    // Hard slice: the AVX2 path must never read past a short `src`.
    let src = &src[..out.len()];
    #[cfg(target_arch = "x86_64")]
    {
        if avx2() {
            // SAFETY: avx2() verified the CPU supports the target
            // feature, and both slices are exactly out.len() long.
            unsafe { narrow_avx2(out, src) };
            return;
        }
    }
    narrow_tiled(out, src);
}

#[inline]
fn narrow_tiled(out: &mut [f32], src: &[f64]) {
    let n = out.len();
    let mut chunks = out.chunks_exact_mut(WIDE_LANES);
    let mut xs = src[..n].chunks_exact(WIDE_LANES);
    for (o, s) in chunks.by_ref().zip(xs.by_ref()) {
        for l in 0..WIDE_LANES {
            o[l] = s[l] as f32;
        }
    }
    for (o, s) in chunks.into_remainder().iter_mut().zip(xs.remainder()) {
        *o = *s as f32;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn narrow_avx2(out: &mut [f32], src: &[f64]) {
    use std::arch::x86_64::*;
    let n = out.len();
    let mut k = 0;
    while k + WIDE_LANES <= n {
        // Two 4-wide f64 loads, narrowed into one 8-wide f32 store.
        let lo = _mm256_cvtpd_ps(_mm256_loadu_pd(src.as_ptr().add(k)));
        let hi = _mm256_cvtpd_ps(_mm256_loadu_pd(src.as_ptr().add(k + LANES)));
        let s = _mm256_set_m128(hi, lo);
        _mm256_storeu_ps(out.as_mut_ptr().add(k), s);
        k += WIDE_LANES;
    }
    while k < n {
        out[k] = src[k] as f32;
        k += 1;
    }
}

/// Tiled normalize-to-sum-1 with the same uniform fallback convention as
/// the scalar [`normalize`](crate::bp::update::normalize): a zero or
/// non-finite normalizer (possible with deterministic factors) yields the
/// uniform distribution.
#[inline]
pub fn normalize_simd(v: &mut [f64]) {
    let s = sum(v);
    if s > 0.0 && s.is_finite() {
        scale(v, 1.0 / s);
    } else {
        let u = 1.0 / v.len() as f64;
        v.fill(u);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(n: usize, salt: f64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64) * 0.37 + salt).sin().abs() + 0.01).collect()
    }

    #[test]
    fn kernel_labels() {
        assert_eq!(Kernel::Scalar.label(), "scalar");
        assert_eq!(Kernel::Simd.label(), "simd");
        assert_eq!(Kernel::default(), Kernel::Simd);
        assert!(Kernel::Simd.is_simd() && !Kernel::Scalar.is_simd());
    }

    #[test]
    fn mul_assign_matches_scalar() {
        for n in [0, 1, 2, 3, 4, 5, 7, 8, 31, 32, 64] {
            let mut a = seq(n, 0.1);
            let b = seq(n, 0.9);
            let expect: Vec<f64> = a.iter().zip(&b).map(|(x, y)| x * y).collect();
            mul_assign(&mut a, &b);
            assert_eq!(a, expect, "n={n}");
        }
    }

    #[test]
    fn dot_matches_scalar_closely() {
        for n in [1, 3, 4, 9, 32, 64] {
            let a = seq(n, 0.2);
            let b = seq(n, 0.8);
            let expect: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!((got - expect).abs() <= 1e-12 * expect.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn tiled_and_dispatch_agree_bitwise() {
        // Whatever backend dispatch picks (AVX2 when present), the result
        // must be bit-identical to the portable tiles.
        for n in [1, 4, 6, 32, 63] {
            let a = seq(n, 0.3);
            let b = seq(n, 0.7);
            assert_eq!(dot(&a, &b).to_bits(), dot_tiled(&a, &b).to_bits(), "dot n={n}");
            assert_eq!(
                sq_diff_sum(&a, &b).to_bits(),
                sq_diff_sum_tiled(&a, &b).to_bits(),
                "sq_diff n={n}"
            );
            let mut x = a.clone();
            let mut y = a.clone();
            mul_assign(&mut x, &b);
            mul_assign_tiled(&mut y, &b);
            assert_eq!(x, y, "mul n={n}");
            let mut x = a.clone();
            let mut y = a.clone();
            axpy(&mut x, 1.25, &b);
            axpy_tiled(&mut y, 1.25, &b);
            assert_eq!(x, y, "axpy n={n}");
        }
    }

    #[test]
    fn sq_diff_sum_basic() {
        assert_eq!(sq_diff_sum(&[1.0, 0.0], &[0.0, 1.0]), 2.0);
        assert_eq!(sq_diff_sum(&[], &[]), 0.0);
    }

    #[test]
    fn normalize_simd_sums_to_one_and_falls_back() {
        let mut v = seq(37, 0.4);
        normalize_simd(&mut v);
        assert!((sum(&v) - 1.0).abs() < 1e-12);
        let mut z = vec![0.0; 5];
        normalize_simd(&mut z);
        assert_eq!(z, vec![0.2; 5]);
        let mut nan = vec![f64::NAN, 1.0];
        normalize_simd(&mut nan);
        assert_eq!(nan, vec![0.5, 0.5]);
    }

    #[test]
    fn widen_is_exact_and_narrow_rounds_to_nearest() {
        for n in [0, 1, 4, 7, 8, 9, 15, 16, 17, 63, 64] {
            let src64 = seq(n, 0.5);
            let src32: Vec<f32> = src64.iter().map(|&v| v as f32).collect();
            // widen: f32 → f64 is exact.
            let mut wide = vec![0.0f64; n];
            widen(&mut wide, &src32);
            let expect: Vec<f64> = src32.iter().map(|&v| v as f64).collect();
            assert_eq!(wide, expect, "widen n={n}");
            // narrow: same round-to-nearest-even as `as f32`.
            let mut nar = vec![0.0f32; n];
            narrow(&mut nar, &src64);
            assert_eq!(nar, src32, "narrow n={n}");
            // Dispatch (AVX2 when present) vs portable tiles: bitwise.
            let mut wide_t = vec![0.0f64; n];
            widen_tiled(&mut wide_t, &src32);
            assert_eq!(wide, wide_t, "widen dispatch n={n}");
            let mut nar_t = vec![0.0f32; n];
            narrow_tiled(&mut nar_t, &src64);
            assert_eq!(nar, nar_t, "narrow dispatch n={n}");
        }
    }

    #[test]
    fn widen_narrow_roundtrip_preserves_f32_values() {
        let src32: Vec<f32> = seq(33, 0.6).iter().map(|&v| v as f32).collect();
        let mut wide = vec![0.0f64; 33];
        widen(&mut wide, &src32);
        let mut back = vec![0.0f32; 33];
        narrow(&mut back, &wide);
        assert_eq!(back, src32);
        // Special values survive the convert tiles.
        let specials = [0.0f32, -0.0, f32::INFINITY, 1.0e-40 /* subnormal */];
        let mut w = vec![0.0f64; 4];
        widen(&mut w, &specials);
        assert_eq!(w[1].to_bits(), (-0.0f64).to_bits());
        let mut b = vec![0.0f32; 4];
        narrow(&mut b, &w);
        assert_eq!(b, specials);
    }

    #[test]
    fn exact_zeros_stay_exact() {
        // Deterministic-factor zeros must survive the tiled products.
        let mut a = vec![0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0, 5.0, 0.0];
        let b = vec![7.0; 9];
        mul_assign(&mut a, &b);
        for (i, v) in a.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(*v, 0.0, "lane {i}");
            }
        }
    }
}
