//! The belief-propagation message update rule (paper Eq. 2) and residuals.
//!
//! For a directed edge `e = (i → j)`:
//!
//! ```text
//! μ'_{i→j}(x_j) ∝ Σ_{x_i} ψ_i(x_i) · ψ_ij(x_i, x_j) · Π_{k ∈ N(i)\{j}} μ_{k→i}(x_i)
//! ```
//!
//! The implementation first accumulates the product vector
//! `prod[x_i] = ψ_i(x_i) · Π μ_{k→i}(x_i)` over the incoming messages, then
//! applies the edge-factor matrix and normalizes to sum 1. A zero
//! normalizer (possible with deterministic factors, e.g. LDPC parity
//! indicators under conflicting evidence) falls back to the uniform
//! distribution, matching libDAI's convention.
//!
//! The residual (paper Eq. 3) is the L2 distance between the current and
//! recomputed message — the priority used by residual BP.

use super::state::{msg_buf, MsgSource};
use crate::model::Mrf;

/// Compute `μ'_e` into `out[..len]`; returns `len`. Reads the incoming
/// messages through `src` (live atomics or a snapshot).
pub fn compute_message<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    e: u32,
    out: &mut [f64],
) -> usize {
    let out_len = mrf.msg_len(e);
    let i = mrf.graph.edge_src[e as usize] as usize;

    // Fast path for binary↔binary messages (every edge in the tree / Ising /
    // Potts / denoising models): fully unrolled gather + 2×2 matvec with no
    // 64-wide scratch buffers. ~1.8× the generic path (EXPERIMENTS.md §Perf).
    if out_len == 2 && mrf.domain[i] == 2 {
        let nf = mrf.node_factors.of(i);
        let (mut p0, mut p1) = (nf[0], nf[1]);
        let rev = mrf.graph.reverse(e);
        let mut b = [0.0f64; 2];
        for s in mrf.graph.slots(i) {
            let e_in = mrf.graph.adj_in[s];
            if e_in == rev {
                continue;
            }
            src.read_msg(mrf, e_in, &mut b);
            p0 *= b[0];
            p1 *= b[1];
        }
        let fr = mrf.edge_factor[e as usize];
        let m = mrf.pool.matrix(fr.pool_index());
        let (u0, u1) = if fr.transposed() {
            // ψ(a, b) stored as m[b*2 + a]
            (p0 * m[0] + p1 * m[1], p0 * m[2] + p1 * m[3])
        } else {
            (p0 * m[0] + p1 * m[2], p0 * m[1] + p1 * m[3])
        };
        let z = u0 + u1;
        if z > 0.0 && z.is_finite() {
            out[0] = u0 / z;
            out[1] = u1 / z;
        } else {
            out[0] = 0.5;
            out[1] = 0.5;
        }
        return 2;
    }

    let mut prod = msg_buf();
    let d_i = incoming_product(mrf, src, e, &mut prod);

    // out[x_j] = Σ_{x_i} prod[x_i] · ψ(x_i, x_j)
    let fr = mrf.edge_factor[e as usize];
    if !fr.transposed() {
        // Row-major (d_i × d_j): accumulate row by row — sequential reads.
        let mat = mrf.pool.matrix(fr.pool_index());
        out[..out_len].fill(0.0);
        for xi in 0..d_i {
            let p = prod[xi];
            if p == 0.0 {
                continue;
            }
            let row = &mat[xi * out_len..(xi + 1) * out_len];
            for xj in 0..out_len {
                out[xj] += p * row[xj];
            }
        }
    } else {
        // Stored as (d_j × d_i): out[xj] is a dot product with row xj.
        let mat = mrf.pool.matrix(fr.pool_index());
        for xj in 0..out_len {
            let row = &mat[xj * d_i..(xj + 1) * d_i];
            let mut acc = 0.0;
            for xi in 0..d_i {
                acc += prod[xi] * row[xi];
            }
            out[xj] = acc;
        }
    }

    normalize(&mut out[..out_len]);
    out_len
}

/// The gather half of the update rule:
/// `prod[x_i] = ψ_i(x_i) · Π_{k ∈ N(i)\{j}} μ_{k→i}(x_i)` for `e = (i→j)`.
/// Returns `|D_i|`. Exposed separately so the PJRT batched backend can do
/// the gather natively and ship only the dense matvec+normalize to the
/// AOT kernel.
#[inline]
pub fn incoming_product<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    e: u32,
    prod: &mut [f64],
) -> usize {
    let i = mrf.graph.edge_src[e as usize] as usize;
    let d_i = mrf.domain[i] as usize;
    prod[..d_i].copy_from_slice(mrf.node_factors.of(i));
    let rev = mrf.graph.reverse(e); // the (j→i) message to exclude
    let mut incoming = msg_buf();
    for s in mrf.graph.slots(i) {
        let e_in = mrf.graph.adj_in[s];
        if e_in == rev {
            continue;
        }
        let len = src.read_msg(mrf, e_in, &mut incoming);
        debug_assert_eq!(len, d_i);
        for x in 0..d_i {
            prod[x] *= incoming[x];
        }
    }
    d_i
}

/// Normalize `v` to sum 1; uniform fallback when the sum is 0 or non-finite.
#[inline]
pub fn normalize(v: &mut [f64]) {
    let sum: f64 = v.iter().sum();
    if sum > 0.0 && sum.is_finite() {
        let inv = 1.0 / sum;
        for x in v.iter_mut() {
            *x *= inv;
        }
    } else {
        let u = 1.0 / v.len() as f64;
        v.fill(u);
    }
}

/// L2 residual between two message vectors (paper Eq. 3 with the L2 norm).
#[inline]
pub fn residual_l2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for k in 0..a.len() {
        let d = a[k] - b[k];
        acc += d * d;
    }
    acc.sqrt()
}

/// L∞ residual (used by some termination criteria and tests).
#[inline]
pub fn residual_linf(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::state::{msg_buf, Messages};
    use crate::model::builders;
    use crate::configio::ModelSpec;

    #[test]
    fn leaf_message_is_prior_through_factor() {
        // Path 0-1-2; node 0 has prior (0.1, 0.9), equality factors.
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        // Edge 0 is 0→1: no other incoming messages at node 0, so
        // μ'_{0→1} = ψ_0 through the identity factor = (0.1, 0.9).
        let len = compute_message(&m, &msgs, 0, &mut out);
        assert_eq!(len, 2);
        assert!((out[0] - 0.1).abs() < 1e-12 && (out[1] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn interior_message_with_uniform_inputs_is_uniform() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        // Edge 1→2 (directed id 2): incoming 0→1 is still uniform, node 1
        // prior uniform, equality factor → uniform.
        let e = m.graph.out_edges(1)[1]; // second neighbor of 1 is 2
        compute_message(&m, &msgs, e, &mut out);
        assert!((out[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn propagates_after_commit() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        compute_message(&m, &msgs, 0, &mut out);
        msgs.write_msg(&m, 0, &out);
        // Now 1→2 sees the root's information through the equality factor.
        let e = m
            .graph
            .out_edges(1)
            .iter()
            .copied()
            .find(|&e| m.graph.edge_dst[e as usize] == 2)
            .unwrap();
        compute_message(&m, &msgs, e, &mut out);
        assert!((out[0] - 0.1).abs() < 1e-12 && (out[1] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn transposed_edge_matches_manual() {
        // Asymmetric factor on one edge; check the odd (transposed) edge.
        use crate::model::{FactorPool, GraphBuilder, Mrf, NodeFactors};
        let mut gb = GraphBuilder::new(2);
        gb.add_edge(0, 1);
        let g = gb.build();
        let mut pool = FactorPool::new();
        let f = pool.add(2, 2, &[0.7, 0.3, 0.1, 0.9]); // ψ(x0, x1)
        let m = Mrf::assemble(
            "asym",
            g,
            vec![2, 2],
            NodeFactors::from_vecs(&[vec![0.5, 0.5], vec![0.2, 0.8]]),
            vec![f],
            pool,
        );
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        // Edge 1 is 1→0: μ(x0) ∝ Σ_{x1} ψ_1(x1) ψ(x0,x1)  (no other neighbors)
        compute_message(&m, &msgs, 1, &mut out);
        let un0 = 0.2 * 0.7 + 0.8 * 0.3; // x0 = 0
        let un1 = 0.2 * 0.1 + 0.8 * 0.9; // x0 = 1
        let z = un0 + un1;
        assert!((out[0] - un0 / z).abs() < 1e-12);
        assert!((out[1] - un1 / z).abs() < 1e-12);
    }

    #[test]
    fn zero_normalizer_falls_back_to_uniform() {
        use crate::model::{FactorPool, GraphBuilder, Mrf, NodeFactors};
        let mut gb = GraphBuilder::new(2);
        gb.add_edge(0, 1);
        let g = gb.build();
        let mut pool = FactorPool::new();
        let f = pool.add(2, 2, &[0.0, 0.0, 0.0, 0.0]);
        let m = Mrf::assemble(
            "zero",
            g,
            vec![2, 2],
            NodeFactors::from_vecs(&[vec![1.0, 1.0], vec![1.0, 1.0]]),
            vec![f],
            pool,
        );
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        compute_message(&m, &msgs, 0, &mut out);
        assert_eq!(&out[..2], &[0.5, 0.5]);
    }

    #[test]
    fn ldpc_constraint_update_respects_parity() {
        // Constraint message to a variable: with all incoming uniform, the
        // marginal over the variable's bit must be uniform by symmetry.
        let inst = builders::ldpc::build(12, 0.07, 3);
        let m = &inst.mrf;
        let msgs = Messages::uniform(m);
        let chk = inst.num_vars; // first constraint node
        let e = m.graph.out_edges(chk)[0]; // constraint → variable
        let mut out = msg_buf();
        let len = compute_message(m, &msgs, e, &mut out);
        assert_eq!(len, 2);
        assert!((out[0] - 0.5).abs() < 1e-9, "out={:?}", &out[..2]);
    }

    #[test]
    fn residuals() {
        assert_eq!(residual_l2(&[0.5, 0.5], &[0.5, 0.5]), 0.0);
        let r = residual_l2(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(residual_linf(&[0.1, 0.9], &[0.5, 0.5]), 0.4);
    }

    #[test]
    fn normalize_handles_nan() {
        let mut v = [f64::NAN, 1.0];
        normalize(&mut v);
        assert_eq!(v, [0.5, 0.5]);
    }

    #[test]
    fn messages_always_normalized() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let len = compute_message(&m, &msgs, e, &mut out);
            let sum: f64 = out[..len].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "edge {e} sum {sum}");
            assert!(out[..len].iter().all(|&v| v >= 0.0));
        }
    }
}
