//! The belief-propagation message update rule (paper Eq. 2) and residuals.
//!
//! For a directed edge `e = (i → j)`:
//!
//! ```text
//! μ'_{i→j}(x_j) ∝ Σ_{x_i} ψ_i(x_i) · ψ_ij(x_i, x_j) · Π_{k ∈ N(i)\{j}} μ_{k→i}(x_i)
//! ```
//!
//! Two kernels implement it:
//!
//! - [`compute_message_with`] — the **edge-wise** kernel: accumulate the
//!   product vector `prod[x_i] = ψ_i(x_i) · Π μ_{k→i}(x_i)` over the
//!   incoming messages, apply the edge-factor matrix, normalize to sum 1.
//! - [`fused_node_refresh`] — the **node-centric fused** kernel: compute
//!   the *full* node product `ψ_j · Π_{l∈N(j)} μ_{l→j}` once, derive every
//!   out-edge's excluded product via prefix/suffix products (no division,
//!   so exact zeros in messages stay numerically exact), and emit all
//!   `μ'_{j→·}` in one O(deg·|D|) pass. Refreshing a node's whole out-set
//!   edge-by-edge is O(deg²·|D|) — the dominant cost of residual-style BP
//!   on high-degree models (power-law hubs, LDPC constraints); see
//!   DESIGN.md §Update kernels.
//!
//! Orthogonally to the edge-wise/fused choice, every inner `|D|`-wide loop
//! runs under a [`Kernel`]: `Scalar` is the historical per-element path
//! (bit-for-bit the pre-SIMD behavior, kept for A/B), `Simd` the
//! lane-tiled data path (`bp::simd`) with bulk message I/O
//! ([`MsgSource::read_msg_bulk`] / zero-copy [`MsgSource::borrow_msg`])
//! and in-kernel residuals ([`MsgSource::residual_l2_against`]).
//!
//! A zero normalizer (possible with deterministic factors, e.g. LDPC
//! parity indicators under conflicting evidence) falls back to the uniform
//! distribution, matching libDAI's convention.
//!
//! The residual (paper Eq. 3) is the L2 distance between the current and
//! recomputed message — the priority used by residual BP.

use super::simd::{self, Kernel};
use super::state::{msg_buf, MsgBuf, MsgSource};
use crate::model::Mrf;

/// Reusable gather buffers for [`compute_message_with`] /
/// [`incoming_product`]. Hot loops hold one per worker and reuse it, so
/// the two MAX_DOMAIN-wide buffers are zero-initialized once per worker
/// instead of once per update (the per-call memset was ~12% of baseline
/// cycles on wide-domain models; EXPERIMENTS.md §Perf).
pub struct MsgScratch {
    /// Source-product accumulator (`prod[x_i]`).
    pub prod: MsgBuf,
    /// Per-neighbor incoming-message read buffer.
    pub tmp: MsgBuf,
}

impl MsgScratch {
    /// Fresh zeroed buffers.
    pub fn new() -> Self {
        MsgScratch { prod: msg_buf(), tmp: msg_buf() }
    }
}

impl Default for MsgScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// Compute `μ'_e` into `out[..len]`; returns `len`. Reads the incoming
/// messages through `src` (live atomics or a snapshot).
///
/// **Test-only convenience wrapper**: allocates a fresh [`MsgScratch`] per
/// call on the generic path and always runs the scalar kernel, so it is a
/// convenient bit-stable reference in unit tests and nothing more. Every
/// production caller goes through [`compute_message_with`] with a
/// per-worker scratch and the run's configured [`Kernel`].
pub fn compute_message<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    e: u32,
    out: &mut [f64],
) -> usize {
    let i = mrf.graph.edge_src[e as usize] as usize;
    if mrf.msg_len(e) == 2 && mrf.domain[i] == 2 {
        return binary_update(mrf, src, e, i, out, Kernel::Scalar);
    }
    let mut scratch = MsgScratch::new();
    compute_message_with(mrf, src, e, out, &mut scratch, Kernel::Scalar)
}

/// The edge-wise update kernel with caller-provided gather buffers (no
/// per-call MAX_DOMAIN-wide zeroing on the generic path) and an explicit
/// update [`Kernel`].
pub fn compute_message_with<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    e: u32,
    out: &mut [f64],
    scratch: &mut MsgScratch,
    kernel: Kernel,
) -> usize {
    let out_len = mrf.msg_len(e);
    let i = mrf.graph.edge_src[e as usize] as usize;
    if out_len == 2 && mrf.domain[i] == 2 {
        return binary_update(mrf, src, e, i, out, kernel);
    }
    let d_i = incoming_product(mrf, src, e, &mut scratch.prod, &mut scratch.tmp, kernel);
    apply_factor(mrf, e, &scratch.prod[..d_i], out, kernel)
}

/// Fast path for binary↔binary messages (every edge in the tree / Ising /
/// Potts / denoising models): fully unrolled gather + 2×2 matvec with no
/// 64-wide scratch buffers. ~1.8× the generic path (EXPERIMENTS.md §Perf).
/// Shared by both kernels — 2-wide vectors have no lanes to tile; the SIMD
/// kernel only adds the zero-copy borrow path for snapshot sources.
#[inline]
fn binary_update<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    e: u32,
    i: usize,
    out: &mut [f64],
    kernel: Kernel,
) -> usize {
    let nf = mrf.node_factors.of(i);
    let (mut p0, mut p1) = (nf[0], nf[1]);
    let rev = mrf.graph.reverse(e);
    let mut b = [0.0f64; 2];
    for s in mrf.graph.slots(i) {
        let e_in = mrf.graph.adj_in[s];
        if e_in == rev {
            continue;
        }
        if kernel.is_simd() {
            if let Some(v) = src.borrow_msg(mrf, e_in) {
                p0 *= v[0];
                p1 *= v[1];
                continue;
            }
        }
        src.read_msg(mrf, e_in, &mut b);
        p0 *= b[0];
        p1 *= b[1];
    }
    binary_matvec(mrf, e, p0, p1, out);
    2
}

/// The 2×2 matvec + normalize of the binary fast path: `out[..2]` from the
/// excluded source product `(p0, p1)` through edge `e`'s factor.
#[inline]
fn binary_matvec(mrf: &Mrf, e: u32, p0: f64, p1: f64, out: &mut [f64]) {
    let fr = mrf.edge_factor[e as usize];
    let m = mrf.pool.matrix(fr.pool_index());
    let (u0, u1) = if fr.transposed() {
        // ψ(a, b) stored as m[b*2 + a]
        (p0 * m[0] + p1 * m[1], p0 * m[2] + p1 * m[3])
    } else {
        (p0 * m[0] + p1 * m[2], p0 * m[1] + p1 * m[3])
    };
    let z = u0 + u1;
    if z > 0.0 && z.is_finite() {
        out[0] = u0 / z;
        out[1] = u1 / z;
    } else {
        out[0] = 0.5;
        out[1] = 0.5;
    }
}

/// Apply edge `e`'s factor matrix to the gathered (excluded) source
/// product `prod[..d_i]` and normalize:
/// `out[x_j] ∝ Σ_{x_i} prod[x_i] · ψ(x_i, x_j)`. Returns `|D_dst(e)|`.
/// Shared by the edge-wise and fused kernels. The SIMD kernel runs the
/// row accumulation / row dots / normalization as lane tiles.
#[inline]
fn apply_factor(mrf: &Mrf, e: u32, prod: &[f64], out: &mut [f64], kernel: Kernel) -> usize {
    let out_len = mrf.msg_len(e);
    let d_i = prod.len();
    let fr = mrf.edge_factor[e as usize];
    let mat = mrf.pool.matrix(fr.pool_index());
    if !fr.transposed() {
        // Row-major (d_i × d_j): accumulate row by row — sequential reads.
        out[..out_len].fill(0.0);
        for (xi, &p) in prod.iter().enumerate() {
            if p == 0.0 {
                continue;
            }
            let row = &mat[xi * out_len..(xi + 1) * out_len];
            match kernel {
                Kernel::Scalar => {
                    for xj in 0..out_len {
                        out[xj] += p * row[xj];
                    }
                }
                Kernel::Simd => simd::axpy(&mut out[..out_len], p, row),
            }
        }
    } else {
        // Stored as (d_j × d_i): out[xj] is a dot product with row xj.
        for xj in 0..out_len {
            let row = &mat[xj * d_i..(xj + 1) * d_i];
            out[xj] = match kernel {
                Kernel::Scalar => {
                    let mut acc = 0.0;
                    for xi in 0..d_i {
                        acc += prod[xi] * row[xi];
                    }
                    acc
                }
                Kernel::Simd => simd::dot(prod, row),
            };
        }
    }
    match kernel {
        Kernel::Scalar => normalize(&mut out[..out_len]),
        Kernel::Simd => simd::normalize_simd(&mut out[..out_len]),
    }
    out_len
}

/// The gather half of the update rule:
/// `prod[x_i] = ψ_i(x_i) · Π_{k ∈ N(i)\{j}} μ_{k→i}(x_i)` for `e = (i→j)`.
/// Returns `|D_i|`. Exposed separately so the PJRT batched backend can do
/// the gather natively and ship only the dense matvec+normalize to the
/// AOT kernel. `tmp` is the per-neighbor read buffer (caller-provided so
/// hot loops reuse one allocation; see [`MsgScratch`]). The SIMD kernel
/// reads each neighbor through [`MsgSource::read_msg_bulk`] — or borrows
/// it zero-copy from snapshot sources — and multiplies in lane tiles.
#[inline]
pub fn incoming_product<S: MsgSource + ?Sized>(
    mrf: &Mrf,
    src: &S,
    e: u32,
    prod: &mut [f64],
    tmp: &mut MsgBuf,
    kernel: Kernel,
) -> usize {
    let i = mrf.graph.edge_src[e as usize] as usize;
    let d_i = mrf.domain[i] as usize;
    prod[..d_i].copy_from_slice(mrf.node_factors.of(i));
    let rev = mrf.graph.reverse(e); // the (j→i) message to exclude
    for s in mrf.graph.slots(i) {
        let e_in = mrf.graph.adj_in[s];
        if e_in == rev {
            continue;
        }
        match kernel {
            Kernel::Scalar => {
                let len = src.read_msg(mrf, e_in, tmp);
                debug_assert_eq!(len, d_i);
                for x in 0..d_i {
                    prod[x] *= tmp[x];
                }
            }
            Kernel::Simd => {
                if let Some(v) = src.borrow_msg(mrf, e_in) {
                    simd::mul_assign(&mut prod[..d_i], v);
                } else {
                    let len = src.read_msg_bulk(mrf, e_in, tmp);
                    debug_assert_eq!(len, d_i);
                    simd::mul_assign(&mut prod[..d_i], &tmp[..d_i]);
                }
            }
        }
    }
    d_i
}

/// Reusable buffers for [`fused_node_refresh`]: grown on demand to the hot
/// node's `degree × |D|` and reused across calls, so steady-state
/// refreshes allocate nothing and only ever touch live prefixes.
#[derive(Default)]
pub struct NodeScratch {
    /// Incoming messages of the node, stride `|D_j|` (slot-ordered).
    inc: Vec<f64>,
    /// Per-slot excluded products, stride `|D_j|`.
    excl: Vec<f64>,
    /// Running suffix product (`|D_j|` entries).
    suf: Vec<f64>,
    /// Output staging for one emitted message (`MAX_DOMAIN` entries).
    out: Vec<f64>,
}

impl NodeScratch {
    /// Empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }
}

/// The node-centric fused refresh kernel.
///
/// For node `j`, computes every outgoing update `μ'_{j→·}` in one pass:
/// gather each incoming message once, build per-slot *excluded* products
/// `ψ_j · Π_{t≠s} μ_{in(t)}` with a prefix/suffix sweep (no division —
/// exact zeros from deterministic factors stay exact), then apply each
/// out-edge's factor matrix and normalize. Total work is O(deg·|D|) plus
/// the matvecs, versus O(deg²·|D|) for per-edge [`compute_message_with`]
/// over the same out-set, and each incoming message is read from the
/// shared state exactly once.
///
/// `emit(e, new, res)` is called once per out-edge of `j` (slot order)
/// with the normalized new message and the **in-kernel residual**
/// `res = ‖new − μ_e‖₂` against the edge's current value in `src`
/// (computed via [`MsgSource::residual_l2_against`] in one pass over the
/// source cells, so residual-priced engines never recompute or rebuffer a
/// message purely to price it) — except `skip`, typically the reverse of
/// a just-committed edge `(i→j)`, whose recomputed value cannot have
/// changed (it excludes the `i→j` input by definition).
///
/// The binary fast path (|D_j| = 2) runs the prefix/suffix sweep on
/// scalars and keeps the unrolled 2×2 matvec of the edge-wise kernel.
/// Under [`Kernel::Simd`] the gathers use bulk reads and the generic
/// prefix/suffix/matvec loops run as lane tiles.
pub fn fused_node_refresh<S, F>(
    mrf: &Mrf,
    src: &S,
    j: u32,
    skip: Option<u32>,
    scratch: &mut NodeScratch,
    kernel: Kernel,
    mut emit: F,
) where
    S: MsgSource + ?Sized,
    F: FnMut(u32, &[f64], f64),
{
    let ju = j as usize;
    let d_j = mrf.domain[ju] as usize;
    let slots = mrf.graph.slots(ju);
    let deg = slots.len();
    if deg == 0 {
        return;
    }
    let nf = mrf.node_factors.of(ju);
    let inc = &mut scratch.inc;
    if inc.len() < deg * d_j {
        inc.resize(deg * d_j, 0.0);
    }
    let excl = &mut scratch.excl;
    if excl.len() < deg * d_j {
        excl.resize(deg * d_j, 0.0);
    }
    let out = &mut scratch.out;
    if out.len() < crate::model::MAX_DOMAIN {
        out.resize(crate::model::MAX_DOMAIN, 0.0);
    }

    // Binary fast path: scalar prefix/suffix, unrolled 2×2 matvec.
    if d_j == 2 {
        let mut b = [0.0f64; 2];
        for (k, s) in slots.clone().enumerate() {
            let e_in = mrf.graph.adj_in[s];
            if kernel.is_simd() {
                if let Some(v) = src.borrow_msg(mrf, e_in) {
                    inc[2 * k] = v[0];
                    inc[2 * k + 1] = v[1];
                    continue;
                }
            }
            src.read_msg(mrf, e_in, &mut b);
            inc[2 * k] = b[0];
            inc[2 * k + 1] = b[1];
        }
        let (mut p0, mut p1) = (nf[0], nf[1]);
        for k in 0..deg {
            excl[2 * k] = p0;
            excl[2 * k + 1] = p1;
            p0 *= inc[2 * k];
            p1 *= inc[2 * k + 1];
        }
        let (mut s0, mut s1) = (1.0f64, 1.0f64);
        for k in (0..deg).rev() {
            excl[2 * k] *= s0;
            excl[2 * k + 1] *= s1;
            s0 *= inc[2 * k];
            s1 *= inc[2 * k + 1];
        }
        for (k, s) in slots.clone().enumerate() {
            let e_out = mrf.graph.adj_out[s];
            if skip == Some(e_out) {
                continue;
            }
            let (q0, q1) = (excl[2 * k], excl[2 * k + 1]);
            let len = if mrf.msg_len(e_out) == 2 {
                binary_matvec(mrf, e_out, q0, q1, out);
                2
            } else {
                // Binary source, wide destination (e.g. LDPC var→check).
                apply_factor(mrf, e_out, &[q0, q1], out, kernel)
            };
            let res = src.residual_l2_against(mrf, e_out, &out[..len], kernel);
            emit(e_out, &out[..len], res);
        }
        return;
    }

    // Generic path: vector prefix/suffix over the slot-ordered incoming
    // messages.
    let suf = &mut scratch.suf;
    suf.clear();
    suf.resize(d_j, 1.0);
    for (k, s) in slots.clone().enumerate() {
        let e_in = mrf.graph.adj_in[s];
        let dst = &mut inc[k * d_j..(k + 1) * d_j];
        let len = match kernel {
            Kernel::Scalar => src.read_msg(mrf, e_in, dst),
            Kernel::Simd => match src.borrow_msg(mrf, e_in) {
                Some(v) => {
                    dst.copy_from_slice(v);
                    v.len()
                }
                None => src.read_msg_bulk(mrf, e_in, dst),
            },
        };
        debug_assert_eq!(len, d_j);
    }
    excl[..d_j].copy_from_slice(nf);
    for k in 1..deg {
        let (head, tail) = excl.split_at_mut(k * d_j);
        let prev = &head[(k - 1) * d_j..];
        let inc_prev = &inc[(k - 1) * d_j..k * d_j];
        match kernel {
            Kernel::Scalar => {
                for x in 0..d_j {
                    tail[x] = prev[x] * inc_prev[x];
                }
            }
            Kernel::Simd => simd::mul_into(&mut tail[..d_j], prev, inc_prev),
        }
    }
    for k in (0..deg).rev() {
        let ex = &mut excl[k * d_j..(k + 1) * d_j];
        match kernel {
            Kernel::Scalar => {
                for x in 0..d_j {
                    ex[x] *= suf[x];
                }
                if k > 0 {
                    for x in 0..d_j {
                        suf[x] *= inc[k * d_j + x];
                    }
                }
            }
            Kernel::Simd => {
                simd::mul_assign(ex, suf);
                if k > 0 {
                    simd::mul_assign(suf, &inc[k * d_j..(k + 1) * d_j]);
                }
            }
        }
    }
    for (k, s) in slots.clone().enumerate() {
        let e_out = mrf.graph.adj_out[s];
        if skip == Some(e_out) {
            continue;
        }
        let len = apply_factor(mrf, e_out, &excl[k * d_j..(k + 1) * d_j], out, kernel);
        let res = src.residual_l2_against(mrf, e_out, &out[..len], kernel);
        emit(e_out, &out[..len], res);
    }
}

/// Normalize `v` to sum 1; uniform fallback when the sum is 0 or non-finite.
#[inline]
pub fn normalize(v: &mut [f64]) {
    let sum: f64 = v.iter().sum();
    if sum > 0.0 && sum.is_finite() {
        let inv = 1.0 / sum;
        for x in v.iter_mut() {
            *x *= inv;
        }
    } else {
        let u = 1.0 / v.len() as f64;
        v.fill(u);
    }
}

/// L2 residual between two message vectors (paper Eq. 3 with the L2 norm).
#[inline]
pub fn residual_l2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for k in 0..a.len() {
        let d = a[k] - b[k];
        acc += d * d;
    }
    acc.sqrt()
}

/// L∞ residual (used by some termination criteria and tests).
#[inline]
pub fn residual_linf(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::state::{msg_buf, Messages};
    use crate::model::builders;
    use crate::configio::ModelSpec;

    #[test]
    fn leaf_message_is_prior_through_factor() {
        // Path 0-1-2; node 0 has prior (0.1, 0.9), equality factors.
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        // Edge 0 is 0→1: no other incoming messages at node 0, so
        // μ'_{0→1} = ψ_0 through the identity factor = (0.1, 0.9).
        let len = compute_message(&m, &msgs, 0, &mut out);
        assert_eq!(len, 2);
        assert!((out[0] - 0.1).abs() < 1e-12 && (out[1] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn interior_message_with_uniform_inputs_is_uniform() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        // Edge 1→2 (directed id 2): incoming 0→1 is still uniform, node 1
        // prior uniform, equality factor → uniform.
        let e = m.graph.out_edges(1)[1]; // second neighbor of 1 is 2
        compute_message(&m, &msgs, e, &mut out);
        assert!((out[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn propagates_after_commit() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        compute_message(&m, &msgs, 0, &mut out);
        msgs.write_msg(&m, 0, &out);
        // Now 1→2 sees the root's information through the equality factor.
        let e = m
            .graph
            .out_edges(1)
            .iter()
            .copied()
            .find(|&e| m.graph.edge_dst[e as usize] == 2)
            .unwrap();
        compute_message(&m, &msgs, e, &mut out);
        assert!((out[0] - 0.1).abs() < 1e-12 && (out[1] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn transposed_edge_matches_manual() {
        // Asymmetric factor on one edge; check the odd (transposed) edge.
        use crate::model::{FactorPool, GraphBuilder, Mrf, NodeFactors};
        let mut gb = GraphBuilder::new(2);
        gb.add_edge(0, 1);
        let g = gb.build();
        let mut pool = FactorPool::new();
        let f = pool.add(2, 2, &[0.7, 0.3, 0.1, 0.9]); // ψ(x0, x1)
        let m = Mrf::assemble(
            "asym",
            g,
            vec![2, 2],
            NodeFactors::from_vecs(&[vec![0.5, 0.5], vec![0.2, 0.8]]),
            vec![f],
            pool,
        );
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        // Edge 1 is 1→0: μ(x0) ∝ Σ_{x1} ψ_1(x1) ψ(x0,x1)  (no other neighbors)
        compute_message(&m, &msgs, 1, &mut out);
        let un0 = 0.2 * 0.7 + 0.8 * 0.3; // x0 = 0
        let un1 = 0.2 * 0.1 + 0.8 * 0.9; // x0 = 1
        let z = un0 + un1;
        assert!((out[0] - un0 / z).abs() < 1e-12);
        assert!((out[1] - un1 / z).abs() < 1e-12);
    }

    #[test]
    fn zero_normalizer_falls_back_to_uniform() {
        use crate::model::{FactorPool, GraphBuilder, Mrf, NodeFactors};
        let mut gb = GraphBuilder::new(2);
        gb.add_edge(0, 1);
        let g = gb.build();
        let mut pool = FactorPool::new();
        let f = pool.add(2, 2, &[0.0, 0.0, 0.0, 0.0]);
        let m = Mrf::assemble(
            "zero",
            g,
            vec![2, 2],
            NodeFactors::from_vecs(&[vec![1.0, 1.0], vec![1.0, 1.0]]),
            vec![f],
            pool,
        );
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        compute_message(&m, &msgs, 0, &mut out);
        assert_eq!(&out[..2], &[0.5, 0.5]);
    }

    #[test]
    fn ldpc_constraint_update_respects_parity() {
        // Constraint message to a variable: with all incoming uniform, the
        // marginal over the variable's bit must be uniform by symmetry.
        let inst = builders::ldpc::build(12, 0.07, 3);
        let m = &inst.mrf;
        let msgs = Messages::uniform(m);
        let chk = inst.num_vars; // first constraint node
        let e = m.graph.out_edges(chk)[0]; // constraint → variable
        let mut out = msg_buf();
        let len = compute_message(m, &msgs, e, &mut out);
        assert_eq!(len, 2);
        assert!((out[0] - 0.5).abs() < 1e-9, "out={:?}", &out[..2]);
    }

    #[test]
    fn residuals() {
        assert_eq!(residual_l2(&[0.5, 0.5], &[0.5, 0.5]), 0.0);
        let r = residual_l2(&[1.0, 0.0], &[0.0, 1.0]);
        assert!((r - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(residual_linf(&[0.1, 0.9], &[0.5, 0.5]), 0.4);
    }

    #[test]
    fn normalize_handles_nan() {
        let mut v = [f64::NAN, 1.0];
        normalize(&mut v);
        assert_eq!(v, [0.5, 0.5]);
    }

    /// Fused refresh of a node must reproduce the edge-wise kernel on
    /// every out-edge (≤ 1e-12; the product grouping differs by design),
    /// and the emitted in-kernel residual must match the recomputed
    /// residual against the live value. Checked for both update kernels.
    fn assert_fused_matches_edgewise(m: &crate::model::Mrf, msgs: &Messages) {
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let mut sc = NodeScratch::new();
            let mut expect = msg_buf();
            let mut live_val = msg_buf();
            for j in 0..m.num_nodes() as u32 {
                let mut seen = 0usize;
                fused_node_refresh(m, msgs, j, None, &mut sc, kernel, |e, vals, res| {
                    seen += 1;
                    let len = compute_message(m, msgs, e, &mut expect);
                    assert_eq!(len, vals.len(), "edge {e}");
                    for x in 0..len {
                        assert!(
                            (vals[x] - expect[x]).abs() <= 1e-12,
                            "node {j} edge {e} x={x} ({kernel:?}): fused {} vs edgewise {}",
                            vals[x],
                            expect[x]
                        );
                    }
                    // The emitted residual prices vals against the live
                    // value, matching the recomputed reference.
                    let ll = msgs.read_msg(m, e, &mut live_val);
                    assert_eq!(ll, len);
                    let want = residual_l2(vals, &live_val[..ll]);
                    assert!(
                        (res - want).abs() <= 1e-12,
                        "edge {e} ({kernel:?}) residual {res} vs {want}"
                    );
                });
                assert_eq!(seen, m.graph.degree(j as usize));
            }
        }
    }

    #[test]
    fn fused_matches_edgewise_binary_grid() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 11);
        let msgs = Messages::uniform(&m);
        // Perturb the state so products are non-trivial.
        let mut out = msg_buf();
        for e in 0..m.num_messages() as u32 {
            compute_message(&m, &msgs, e, &mut out);
            msgs.write_msg(&m, e, &out);
        }
        assert_fused_matches_edgewise(&m, &msgs);
    }

    #[test]
    fn fused_matches_edgewise_wide_domains() {
        // LDPC: binary variables ↔ 64-state constraints, transposed
        // factors on every odd edge, zero entries from parity indicators.
        let inst = builders::ldpc::build(24, 0.07, 5);
        let m = &inst.mrf;
        let msgs = Messages::uniform(m);
        let mut out = msg_buf();
        for e in 0..m.num_messages() as u32 {
            compute_message(m, &msgs, e, &mut out);
            msgs.write_msg(m, e, &out);
        }
        assert_fused_matches_edgewise(m, &msgs);
    }

    #[test]
    fn fused_skip_edge_is_not_emitted() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let msgs = Messages::uniform(&m);
        let mut sc = NodeScratch::new();
        let j = 1u32; // interior node
        let skip = m.graph.adj_out[m.graph.slots(1).next().unwrap()];
        let mut emitted = Vec::new();
        fused_node_refresh(&m, &msgs, j, Some(skip), &mut sc, Kernel::Scalar, |e, _, _| {
            emitted.push(e)
        });
        assert_eq!(emitted.len(), m.graph.degree(1) - 1);
        assert!(!emitted.contains(&skip));
    }

    #[test]
    fn fused_exact_zero_excluded_products() {
        // Node with one zero incoming message: the out-edge excluding it
        // must see a nonzero product, all others exact zero — without any
        // division the fused path preserves this exactly.
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 3);
        let msgs = Messages::uniform(&m);
        // Center node of the 3×3 grid has degree 4.
        let j = (0..m.num_nodes()).max_by_key(|&v| m.graph.degree(v)).unwrap();
        let first_in = m.graph.adj_in[m.graph.slots(j).next().unwrap()];
        msgs.write_msg(&m, first_in, &[0.0, 0.0]);
        assert_fused_matches_edgewise(&m, &msgs);
    }

    #[test]
    fn compute_message_with_reuses_scratch() {
        let inst = builders::ldpc::build(12, 0.07, 3);
        let m = &inst.mrf;
        let msgs = Messages::uniform(m);
        let mut scratch = MsgScratch::new();
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let la = compute_message_with(m, &msgs, e, &mut a, &mut scratch, Kernel::Scalar);
            let lb = compute_message(m, &msgs, e, &mut b);
            assert_eq!(la, lb);
            assert_eq!(&a[..la], &b[..lb], "edge {e}");
        }
    }

    #[test]
    fn scalar_kernel_is_bit_identical_to_wrapper() {
        // The scalar kernel IS the historical code path: exact equality,
        // not an epsilon, including through snapshot sources.
        let inst = builders::ldpc::build(24, 0.07, 9);
        let m = &inst.mrf;
        let msgs = Messages::uniform(m);
        let mut out = msg_buf();
        for e in 0..m.num_messages() as u32 {
            compute_message(m, &msgs, e, &mut out);
            msgs.write_msg(m, e, &out);
        }
        let snap = msgs.snapshot();
        let mut scratch = MsgScratch::new();
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let la =
                compute_message_with(m, snap.as_slice(), e, &mut a, &mut scratch, Kernel::Scalar);
            let lb = compute_message(m, snap.as_slice(), e, &mut b);
            assert_eq!(la, lb);
            assert_eq!(&a[..la], &b[..lb], "edge {e}");
        }
    }

    #[test]
    fn messages_always_normalized() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let msgs = Messages::uniform(&m);
        let mut out = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let len = compute_message(&m, &msgs, e, &mut out);
            let sum: f64 = out[..len].iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "edge {e} sum {sum}");
            assert!(out[..len].iter().all(|&v| v >= 0.0));
        }
    }
}
