//! Shared message state.
//!
//! One flat array of [`AtomicF64`] cells holds every message vector
//! (layout from [`Mrf::msg_offset`]). Worker threads read and write cells
//! with relaxed atomics — the same benign-race discipline as the paper's
//! Java implementation. A message read can observe a concurrent writer's
//! partial update; BP tolerates such races (they act as slightly stale
//! inputs) and the engines' claim flags prevent two threads from *writing*
//! one message concurrently.

use crate::model::{Mrf, MAX_DOMAIN};
use crate::util::AtomicF64;

/// Fixed-size stack buffer for one message / one domain's worth of values.
pub type MsgBuf = [f64; MAX_DOMAIN];

/// Allocate a zeroed message buffer.
#[inline]
pub fn msg_buf() -> MsgBuf {
    [0.0; MAX_DOMAIN]
}

/// Something messages can be read from: the live atomic state or a plain
/// snapshot (used by the synchronous engine's double buffering and by
/// marginal computation on frozen state).
pub trait MsgSource {
    /// Copy message `e` into `out[..len]`; returns `len`.
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize;
}

/// The live, concurrently-updatable message state.
pub struct Messages {
    data: Vec<AtomicF64>,
}

impl Messages {
    /// All messages initialized uniform (1/|D|).
    pub fn uniform(mrf: &Mrf) -> Self {
        let mut data = Vec::with_capacity(mrf.total_msg_len);
        data.resize_with(mrf.total_msg_len, AtomicF64::default);
        let m = Messages { data };
        for e in 0..mrf.num_messages() as u32 {
            let len = mrf.msg_len(e);
            let v = 1.0 / len as f64;
            let off = mrf.msg_offset[e as usize] as usize;
            for k in 0..len {
                m.data[off + k].store(v);
            }
        }
        m
    }

    /// Write message `e` from `vals[..len]`.
    #[inline]
    pub fn write_msg(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        for k in 0..len {
            self.data[off + k].store(vals[k]);
        }
    }

    /// Copy the full state into a plain vector (for snapshots/tests).
    pub fn snapshot(&self) -> Vec<f64> {
        self.data.iter().map(|c| c.load()).collect()
    }

    /// Overwrite the full state from a snapshot.
    pub fn restore(&self, snap: &[f64]) {
        assert_eq!(snap.len(), self.data.len());
        for (c, &v) in self.data.iter().zip(snap) {
            c.store(v);
        }
    }

    /// Raw cell access (used by the lookahead cache which shares layout).
    #[inline]
    pub fn cell(&self, idx: usize) -> &AtomicF64 {
        &self.data[idx]
    }

    /// Number of f64 cells.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the state holds no cells.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

impl MsgSource for Messages {
    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        for k in 0..len {
            out[k] = self.data[off + k].load();
        }
        len
    }
}

/// A frozen snapshot (flat `Vec<f64>` in the same layout) is also a source.
impl MsgSource for [f64] {
    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        out[..len].copy_from_slice(&self[off..off + len]);
        len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builders;
    use crate::configio::ModelSpec;

    #[test]
    fn uniform_init() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let len = msgs.read_msg(&m, e, &mut buf);
            assert_eq!(len, 2);
            assert_eq!(&buf[..2], &[0.5, 0.5]);
        }
    }

    #[test]
    fn uniform_init_wide_domain() {
        let m = builders::build(&ModelSpec::Ldpc { n: 12, flip_prob: 0.07 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        // find a variable→constraint edge (length 64)
        let e = (0..m.num_messages() as u32).find(|&e| m.msg_len(e) == 64).unwrap();
        let len = msgs.read_msg(&m, e, &mut buf);
        assert_eq!(len, 64);
        assert!((buf[..64].iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn write_read_roundtrip() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 1, &[0.25, 0.75]);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 1, &mut buf);
        assert_eq!(&buf[..2], &[0.25, 0.75]);
        // neighbors untouched
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.5, 0.5]);
    }

    #[test]
    fn snapshot_restore() {
        let m = builders::build(&ModelSpec::Path { n: 4 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 0, &[0.9, 0.1]);
        let snap = msgs.snapshot();
        msgs.write_msg(&m, 0, &[0.5, 0.5]);
        msgs.restore(&snap);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.9, 0.1]);
    }

    #[test]
    fn slice_source_matches_layout() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 2, &[0.3, 0.7]);
        let snap = msgs.snapshot();
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..m.num_messages() as u32 {
            msgs.read_msg(&m, e, &mut a);
            snap.as_slice().read_msg(&m, e, &mut b);
            assert_eq!(&a[..2], &b[..2]);
        }
    }
}
