//! Shared message state.
//!
//! Message vectors live in per-shard, cache-line-aligned **arenas** of
//! atomic cells. The default ([`Messages::uniform`]) is one arena whose
//! cell order is exactly the flat layout from [`Mrf::msg_offset`] —
//! bit-for-bit the historical flat-array behavior. A locality-aware run
//! ([`Messages::uniform_partitioned`]) lays each
//! [`Partition`](crate::model::Partition) shard's messages out
//! contiguously in that shard's own arena, so a worker that stays on its
//! shard walks hot, contiguous cache lines instead of striding a single
//! model-sized array.
//!
//! The **storage precision** of the cells is a run axis
//! ([`Precision`], `RunConfig::precision`): an arena holds either
//! [`AtomicF64`] cells (8 per 64-byte line — the default, bit-frozen arm)
//! or [`AtomicF32`](crate::util::AtomicF32) cells (16 per line — half the
//! message bytes, double the lanes per vector load). Compute always stays
//! `f64` in registers: reads widen (`f32 → f64` is exact) and writes round
//! once (`as f32`, round-to-nearest-even), so each stored cell has exactly
//! one rounding point per message write and the scalar/SIMD kernels need
//! no numeric forking. Residual pricing compares the *rounded* candidate
//! against the stored cell, so an f32 fixed point prices to an exact zero
//! residual in every engine.
//!
//! Either way, worker threads read and write cells with relaxed atomics —
//! the same benign-race discipline as the paper's Java implementation. A
//! message read can observe a concurrent writer's partial update; BP
//! tolerates such races (they act as slightly stale inputs) and the
//! engines' claim flags prevent two threads from *writing* one message
//! concurrently.
//!
//! Snapshots ([`Messages::snapshot`] / [`Messages::restore`] and the
//! `MsgSource for [f64]` impl) always use the *flat* `msg_offset` layout
//! regardless of the arena sharding, so frozen state is interchangeable
//! across layouts. A snapshot of an f32 run is f32-exact: every stored
//! value is exactly representable in `f32`, so widening into the `f64`
//! snapshot and restoring (which re-rounds) round-trips bit-for-bit.
//!
//! Orthogonally to precision and sharding, the arenas' **backing
//! allocation** is a run axis ([`ArenaMode`], `--arena`): heap boxes
//! (the default) or file-backed mappings of unlinked sparse temp files
//! ([`ArenaMode::Mmap`]) for runs whose message state exceeds RAM. A
//! mapped arena holds exactly the same 64-byte-aligned atomic lines at
//! the same indices — only the allocator differs — so cell values,
//! relaxed-atomic semantics, and snapshot layout are identical; the
//! kernel pages cold lines to disk instead of OOM-killing the run, and
//! the relaxed schedulers tolerate the extra page-fault latency the same
//! way they tolerate stale reads.

use super::simd::{self, Kernel};
use crate::coordinator::run_workers;
use crate::model::{Mrf, Partition, MAX_DOMAIN};
use crate::util::mmap::MmapMut;
use crate::util::{cold_path_threads, AtomicF32, AtomicF64, DisjointWriter};
use anyhow::{Context, Result};

/// Fixed-size stack buffer for one message / one domain's worth of values.
pub type MsgBuf = [f64; MAX_DOMAIN];

/// Allocate a zeroed message buffer.
///
/// This zero-initializes all `MAX_DOMAIN` (64) entries — a 512-byte
/// memset — regardless of the live domain, so hot loops must not call it
/// per update: hold one buffer (or a
/// [`MsgScratch`](crate::bp::MsgScratch) /
/// [`NodeScratch`](crate::bp::NodeScratch)) per worker and reuse it. The
/// kernels themselves only read/write the live `|D|`-prefix.
#[inline]
pub fn msg_buf() -> MsgBuf {
    [0.0; MAX_DOMAIN]
}

/// Storage precision of the live message arenas (`--precision`).
///
/// [`Precision::F64`] is the bit-frozen reference arm: arenas hold
/// [`AtomicF64`] cells and a run's trajectory is bit-identical to the
/// pre-axis code. [`Precision::F32`] halves message bytes (16 cells per
/// cache line instead of 8): compute stays `f64` in registers, values
/// round to `f32` once per store and widen exactly on load.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// 8-byte cells, bit-frozen reference arm (default).
    #[default]
    F64,
    /// 4-byte cells: half the arena bytes, one rounding per store.
    F32,
}

impl Precision {
    /// Stable label used by the CLI, JSON configs, and bench cell ids.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }

    /// True for the reduced-precision arm.
    pub fn is_f32(self) -> bool {
        matches!(self, Precision::F32)
    }

    /// Bytes of one stored message cell (excludes arena line padding).
    pub fn bytes_per_cell(self) -> usize {
        match self {
            Precision::F64 => 8,
            Precision::F32 => 4,
        }
    }
}

/// Backing allocation of the message arenas (`--arena`).
///
/// [`ArenaMode::Mem`] is the historical heap allocation. With
/// [`ArenaMode::Mmap`] each shard's arena lives in a file-backed mapping
/// of an unlinked sparse temp file, so message state larger than RAM
/// spills to disk under kernel page replacement instead of failing to
/// allocate. Cell values, indices, 64-byte line alignment (mappings are
/// page-aligned, 4096 ⊇ 64), and the relaxed-atomic access contract are
/// identical across modes; snapshots are interchangeable.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum ArenaMode {
    /// Heap-allocated arenas (default; bit- and behavior-frozen arm).
    #[default]
    Mem,
    /// File-backed arenas in unlinked sparse temp files. The files are
    /// unlinked at creation, so the kernel reclaims the blocks when the
    /// state drops — even on crash — with no cleanup pass.
    Mmap {
        /// Directory for the temp files; `None` means
        /// `std::env::temp_dir()`. Point this at a filesystem with room
        /// for the padded arena bytes.
        dir: Option<std::path::PathBuf>,
    },
}

impl ArenaMode {
    /// Stable kind label used by telemetry and bench cell JSON
    /// (directory-independent): `"mem"` or `"mmap"`.
    pub fn label(&self) -> &'static str {
        match self {
            ArenaMode::Mem => "mem",
            ArenaMode::Mmap { .. } => "mmap",
        }
    }

    /// Full round-trippable spec string as accepted by the CLI/config
    /// parser: `"mem"`, `"mmap"`, or `"mmap:<dir>"`.
    pub fn spec(&self) -> String {
        match self {
            ArenaMode::Mem => "mem".to_string(),
            ArenaMode::Mmap { dir: None } => "mmap".to_string(),
            ArenaMode::Mmap { dir: Some(d) } => format!("mmap:{}", d.display()),
        }
    }

    /// True for the file-backed arm.
    pub fn is_mmap(&self) -> bool {
        matches!(self, ArenaMode::Mmap { .. })
    }

    /// Resolved temp-file directory for the file-backed arm.
    fn dir(&self) -> std::path::PathBuf {
        match self {
            ArenaMode::Mem => unreachable!("no directory for heap arenas"),
            ArenaMode::Mmap { dir: Some(d) } => d.clone(),
            ArenaMode::Mmap { dir: None } => std::env::temp_dir(),
        }
    }
}

/// Something messages can be read from: the live atomic state or a plain
/// snapshot (used by the synchronous engine's double buffering and by
/// marginal computation on frozen state).
///
/// Values always surface as `f64` regardless of the source's storage
/// precision — an f32-backed source widens on load (exact), so kernels
/// downstream never fork on precision.
pub trait MsgSource {
    /// Copy message `e` into `out[..len]`; returns `len`.
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize;

    /// Bulk variant of [`MsgSource::read_msg`] used by the SIMD kernel:
    /// implementations stream whole cache-line tiles instead of one
    /// cell-index computation per element. Always returns the same values
    /// as `read_msg` — only the access pattern differs — so the scalar
    /// kernel keeps calling `read_msg` and stays bit-for-bit the pre-SIMD
    /// path while the SIMD kernel reads through this.
    #[inline]
    fn read_msg_bulk(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        self.read_msg(mrf, e, out)
    }

    /// Zero-copy borrowed view of message `e`, when the source can hand
    /// one out (plain snapshot slices can; the live atomic state cannot).
    /// Lets the SIMD kernel's gather loops consume snapshot messages in
    /// place instead of round-tripping through `MsgScratch::tmp`.
    #[inline]
    fn borrow_msg(&self, _mrf: &Mrf, _e: u32) -> Option<&[f64]> {
        None
    }

    /// In-kernel L2 residual: `‖round(new) − μ_e‖₂` computed in one pass
    /// over the source's cells, without materializing the current value in
    /// a caller buffer. `new` is priced *through the source's storage
    /// precision* (identity for f64 sources, so the scalar f64 path stays
    /// bit-for-bit the historical read-then-`residual_l2` composition;
    /// `as f32 as f64` for f32-backed state, so a value that would store
    /// unchanged prices to exactly zero). The scalar kernel accumulates in
    /// exactly the order of
    /// [`residual_l2`](crate::bp::update::residual_l2); the SIMD kernel
    /// uses the lane-tiled reduction.
    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        let mut cur = msg_buf();
        let len = self.read_msg(mrf, e, &mut cur);
        debug_assert_eq!(len, new.len());
        match kernel {
            Kernel::Scalar => crate::bp::update::residual_l2(new, &cur[..len]),
            Kernel::Simd => simd::sq_diff_sum(new, &cur[..len]).sqrt(),
        }
    }
}

/// One storage cell type of the message arenas. Sealed by privacy: the
/// only implementors are [`CellF64`] (the bit-frozen default) and
/// [`CellF32`]; everything generic over this trait is module-internal and
/// surfaces through the precision-dispatching [`Messages`] facade.
trait MsgCell: 'static {
    /// Cells per 64-byte cache line.
    const PER_LINE: usize;
    /// The [`Precision`] tag this cell type implements.
    const PRECISION: Precision;
    /// One cache-line-aligned array of atomic cells.
    type Line: Sync + Send;

    /// Build one full line from `vals[base..]`, zero-padding past the end
    /// (a single non-atomic initialization pass over freshly owned cells).
    fn line_from(vals: &[f64], base: usize) -> Self::Line;
    /// Relaxed load of cell `k`, widened to `f64` (exact).
    fn load(line: &Self::Line, k: usize) -> f64;
    /// Relaxed store of cell `k`, rounded to the storage precision.
    fn store(line: &Self::Line, k: usize, v: f64);
    /// The value `v` would hold after a store: identity for f64,
    /// `v as f32 as f64` (round-to-nearest-even) for f32. Residual
    /// pricing uses this so candidates compare against what storage
    /// actually keeps.
    fn round(v: f64) -> f64;
    /// Bulk-read a full line into `out[..PER_LINE]` — the convert-on-load
    /// gather tile of the SIMD bulk I/O path.
    fn read_line(line: &Self::Line, out: &mut [f64]);
    /// Bulk-write a full line from `vals[..PER_LINE]` — the round-on-store
    /// scatter tile.
    fn write_line(line: &Self::Line, vals: &[f64]);
}

/// One cache line of f64 message cells. The alignment guarantee is what
/// makes per-shard arenas genuinely private at the cache level: two shards
/// never share a line, so cross-shard false sharing cannot occur.
#[repr(align(64))]
struct LineF64([AtomicF64; 8]);

/// One cache line of f32 message cells — 16 per line, half the bytes per
/// message. Same alignment/no-false-sharing guarantee as [`LineF64`].
#[repr(align(64))]
struct LineF32([AtomicF32; 16]);

/// The bit-frozen f64 storage arm.
struct CellF64;

impl MsgCell for CellF64 {
    const PER_LINE: usize = 8;
    const PRECISION: Precision = Precision::F64;
    type Line = LineF64;

    #[inline]
    fn line_from(vals: &[f64], base: usize) -> LineF64 {
        LineF64(std::array::from_fn(|k| {
            AtomicF64::new(vals.get(base + k).copied().unwrap_or(0.0))
        }))
    }

    #[inline]
    fn load(line: &LineF64, k: usize) -> f64 {
        line.0[k].load()
    }

    #[inline]
    fn store(line: &LineF64, k: usize, v: f64) {
        line.0[k].store(v);
    }

    #[inline]
    fn round(v: f64) -> f64 {
        v
    }

    #[inline]
    fn read_line(line: &LineF64, out: &mut [f64]) {
        // Unrolled relaxed loads of the whole line (atomic loads never
        // auto-vectorize; removing per-cell index math is the win).
        for (o, c) in out.iter_mut().zip(&line.0) {
            *o = c.load();
        }
    }

    #[inline]
    fn write_line(line: &LineF64, vals: &[f64]) {
        for (c, v) in line.0.iter().zip(vals) {
            c.store(*v);
        }
    }
}

/// The reduced-precision f32 storage arm.
struct CellF32;

impl MsgCell for CellF32 {
    const PER_LINE: usize = 16;
    const PRECISION: Precision = Precision::F32;
    type Line = LineF32;

    #[inline]
    fn line_from(vals: &[f64], base: usize) -> LineF32 {
        LineF32(std::array::from_fn(|k| {
            AtomicF32::new(vals.get(base + k).copied().unwrap_or(0.0) as f32)
        }))
    }

    #[inline]
    fn load(line: &LineF32, k: usize) -> f64 {
        line.0[k].load() as f64
    }

    #[inline]
    fn store(line: &LineF32, k: usize, v: f64) {
        line.0[k].store(v as f32);
    }

    #[inline]
    fn round(v: f64) -> f64 {
        (v as f32) as f64
    }

    #[inline]
    fn read_line(line: &LineF32, out: &mut [f64]) {
        // Gather the 16 relaxed cells to a stack tile, then widen with the
        // 8-lane convert tiles (AVX2: one 32-byte load → two f64 vectors).
        let mut tmp = [0.0f32; 16];
        for (t, c) in tmp.iter_mut().zip(&line.0) {
            *t = c.load();
        }
        simd::widen(&mut out[..16], &tmp);
    }

    #[inline]
    fn write_line(line: &LineF32, vals: &[f64]) {
        let mut tmp = [0.0f32; 16];
        simd::narrow(&mut tmp, &vals[..16]);
        for (c, t) in line.0.iter().zip(&tmp) {
            c.store(*t);
        }
    }
}

/// Backing allocation of one shard's arena: a heap box
/// ([`ArenaMode::Mem`]) or a file-backed mapping ([`ArenaMode::Mmap`]).
/// Derefs to the line slice, so all arena indexing is mode-agnostic.
enum ArenaBuf<L> {
    /// Heap-allocated lines (historical representation).
    Heap(Box<[L]>),
    /// `len` fully initialized `L`s at the (page-aligned) base of an
    /// unlinked temp-file mapping. Initialization happens before the
    /// buffer is shared; afterwards all access goes through the atomic
    /// cells inside `L`, exactly as for the heap arm.
    Mapped { map: MmapMut, len: usize },
}

impl<L> ArenaBuf<L> {
    #[inline]
    fn as_slice(&self) -> &[L] {
        match self {
            ArenaBuf::Heap(b) => b,
            // SAFETY: `map` holds `len * size_of::<L>()` mapped bytes
            // (sized at construction), page alignment satisfies `L`'s
            // 64-byte alignment, every element was initialized before
            // the buffer was published, and the mapping lives until
            // `self` drops.
            ArenaBuf::Mapped { map, len } => unsafe {
                std::slice::from_raw_parts(map.as_ptr() as *const L, *len)
            },
        }
    }
}

impl<L> std::ops::Deref for ArenaBuf<L> {
    type Target = [L];

    #[inline]
    fn deref(&self) -> &[L] {
        self.as_slice()
    }
}

/// Initialize `slots` (line `l` ← `vals[l * PER_LINE ..]`) — a
/// non-atomic pass over freshly owned, not-yet-shared cells,
/// parallelized over line ranges. Values are position-determined, so
/// the result is identical for every thread count.
fn fill_lines<C: MsgCell>(
    slots: &mut [std::mem::MaybeUninit<C::Line>],
    vals: &[f64],
    threads: usize,
) {
    let nlines = slots.len();
    if threads <= 1 || nlines < 2 {
        for (l, slot) in slots.iter_mut().enumerate() {
            slot.write(C::line_from(vals, l * C::PER_LINE));
        }
        return;
    }
    let threads = threads.min(nlines);
    let mut rest = slots;
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * nlines / threads;
            let hi = (t + 1) * nlines / threads;
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
            rest = tail;
            s.spawn(move || {
                for (j, slot) in chunk.iter_mut().enumerate() {
                    slot.write(C::line_from(vals, (lo + j) * C::PER_LINE));
                }
            });
        }
    });
}

/// Build one arena from plain values under the given [`ArenaMode`], at
/// an explicit thread count (1 inside workers that are themselves
/// already parallel over shards). Heap allocation is infallible; the
/// file-backed arm fails cleanly if the temp file cannot be created.
fn arena_from_values_in<C: MsgCell>(
    vals: &[f64],
    threads: usize,
    mode: &ArenaMode,
) -> Result<ArenaBuf<C::Line>> {
    let nlines = vals.len().div_ceil(C::PER_LINE);
    if matches!(mode, ArenaMode::Mmap { .. }) && nlines > 0 {
        let bytes = nlines * std::mem::size_of::<C::Line>();
        let map = MmapMut::temp(&mode.dir(), "msgs", bytes)
            .context("allocating file-backed message arena")?;
        debug_assert_eq!(map.as_ptr() as usize % 64, 0, "mappings are page-aligned");
        // SAFETY: the mapping is exactly `nlines` lines long, exclusive
        // to this call until returned, and page alignment satisfies the
        // line alignment; `fill_lines` initializes every slot.
        let slots = unsafe {
            std::slice::from_raw_parts_mut(
                map.as_ptr() as *mut std::mem::MaybeUninit<C::Line>,
                nlines,
            )
        };
        fill_lines::<C>(slots, vals, threads);
        return Ok(ArenaBuf::Mapped { map, len: nlines });
    }
    // Heap arm (also the zero-line degenerate case of the mmap arm:
    // nothing to map, and `mmap` rejects zero-length mappings anyway).
    let mut lines: Vec<C::Line> = Vec::with_capacity(nlines);
    fill_lines::<C>(&mut lines.spare_capacity_mut()[..nlines], vals, threads);
    // SAFETY: `fill_lines` initialized all `nlines` slots.
    unsafe { lines.set_len(nlines) };
    Ok(ArenaBuf::Heap(lines.into_boxed_slice()))
}

/// Split `out` (a flat-layout array tiled by `offsets`, which carries one
/// entry per message plus a trailing total) into per-thread pieces at
/// message boundaries and run `work(piece, e0, e1, base)` on each —
/// `piece` holds the flat range `[base, offsets[e1])` covering messages
/// `e0..e1`. Writes are position-determined, so results are identical
/// for every thread count.
fn for_flat_chunks(
    offsets: &[u32],
    out: &mut [f64],
    threads: usize,
    work: impl Fn(&mut [f64], usize, usize, usize) + Sync,
) {
    let me = offsets.len() - 1;
    if threads <= 1 || me == 0 {
        work(out, 0, me, 0);
        return;
    }
    let threads = threads.min(me);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut base = 0usize;
        for t in 0..threads {
            let e0 = t * me / threads;
            let e1 = (t + 1) * me / threads;
            let end = offsets[e1] as usize;
            let (piece, tail) = std::mem::take(&mut rest).split_at_mut(end - base);
            rest = tail;
            let work = &work;
            let b = base;
            base = end;
            s.spawn(move || work(piece, e0, e1, b));
        }
    });
}

/// The generic storage engine behind [`Messages`]: per-shard arenas of one
/// concrete cell type. All indexing/tiling logic lives here once; the f64
/// monomorphization is line-for-line the historical code (identity
/// rounding, 8 cells per line), which is what keeps the f64 arm
/// bit-frozen.
struct ArenaSet<C: MsgCell> {
    /// One cache-line-aligned cell arena per shard.
    arenas: Vec<ArenaBuf<C::Line>>,
    /// Shard holding each message.
    edge_shard: Box<[u32]>,
    /// Cell offset of each message within its shard's arena.
    edge_local: Box<[u32]>,
    /// Flat-layout offsets (= `Mrf::msg_offset` plus a trailing total):
    /// the snapshot/restore layout, shared across all arena shardings.
    flat_offset: Box<[u32]>,
    /// Backing-allocation mode, kept so shadow states
    /// ([`ArenaSet::uniform_like`]) mirror it.
    mode: ArenaMode,
}

impl<C: MsgCell> ArenaSet<C> {
    fn uniform(mrf: &Mrf, mode: &ArenaMode) -> Result<Self> {
        let me = mrf.num_messages();
        let flat_offset = flat_offsets(mrf);
        let mut vals = vec![0.0f64; mrf.total_msg_len];
        let threads = cold_path_threads(me);
        for_flat_chunks(&flat_offset, &mut vals, threads, |piece, e0, e1, base| {
            for e in e0..e1 {
                let len = mrf.msg_len(e as u32);
                let off = mrf.msg_offset[e] as usize - base;
                piece[off..off + len].fill(1.0 / len as f64);
            }
        });
        let init_threads = cold_path_threads(vals.len().div_ceil(C::PER_LINE));
        Ok(ArenaSet {
            arenas: vec![arena_from_values_in::<C>(&vals, init_threads, mode)?],
            edge_shard: vec![0u32; me].into_boxed_slice(),
            edge_local: mrf.msg_offset.to_vec().into_boxed_slice(),
            flat_offset,
            mode: mode.clone(),
        })
    }

    fn uniform_partitioned(mrf: &Mrf, partition: &Partition, mode: &ArenaMode) -> Result<Self> {
        let me = mrf.num_messages();
        assert_eq!(
            partition.num_tasks(),
            me,
            "partition must cover the message universe"
        );
        let k = partition.num_shards();
        let mut edge_shard = vec![0u32; me];
        let mut edge_local = vec![0u32; me];
        let threads = cold_path_threads(me).min(k.max(1));
        let arenas: Vec<ArenaBuf<C::Line>> = if threads <= 1 {
            let mut arenas = Vec::with_capacity(k);
            let mut vals: Vec<f64> = Vec::new();
            for s in 0..k {
                vals.clear();
                for &e in partition.tasks_of(s) {
                    edge_shard[e as usize] = s as u32;
                    edge_local[e as usize] = vals.len() as u32;
                    let len = mrf.msg_len(e);
                    vals.resize(vals.len() + len, 1.0 / len as f64);
                }
                let t = cold_path_threads(vals.len().div_ceil(C::PER_LINE));
                arenas.push(arena_from_values_in::<C>(&vals, t, mode)?);
            }
            arenas
        } else {
            let shard_w = DisjointWriter::new(&mut edge_shard);
            let local_w = DisjointWriter::new(&mut edge_local);
            let per_worker = run_workers(threads, |t| -> Result<Vec<_>> {
                let mut built: Vec<(usize, ArenaBuf<C::Line>)> = Vec::new();
                let mut vals: Vec<f64> = Vec::new();
                for s in (t..k).step_by(threads) {
                    vals.clear();
                    for &e in partition.tasks_of(s) {
                        // SAFETY: a partition assigns each task id to
                        // exactly one shard, and each shard is visited by
                        // exactly one worker, so slot `e` is written once.
                        unsafe {
                            shard_w.write(e as usize, s as u32);
                            local_w.write(e as usize, vals.len() as u32);
                        }
                        let len = mrf.msg_len(e);
                        vals.resize(vals.len() + len, 1.0 / len as f64);
                    }
                    built.push((s, arena_from_values_in::<C>(&vals, 1, mode)?));
                }
                Ok(built)
            });
            let mut slots: Vec<Option<ArenaBuf<C::Line>>> = (0..k).map(|_| None).collect();
            for worker in per_worker {
                for (s, arena) in worker? {
                    slots[s] = Some(arena);
                }
            }
            slots
                .into_iter()
                .map(|a| a.expect("every shard built exactly once"))
                .collect()
        };
        Ok(ArenaSet {
            arenas,
            edge_shard: edge_shard.into_boxed_slice(),
            edge_local: edge_local.into_boxed_slice(),
            flat_offset: flat_offsets(mrf),
            mode: mode.clone(),
        })
    }

    fn uniform_like(mrf: &Mrf, layout: &ArenaSet<C>) -> Result<Self> {
        let me = layout.edge_shard.len();
        assert_eq!(mrf.num_messages(), me, "layout built for a different model");
        let k = layout.arenas.len();
        let mode = &layout.mode;
        let threads = cold_path_threads(me).min(k.max(1));
        let arenas: Vec<ArenaBuf<C::Line>> = if threads <= 1 {
            let mut vals: Vec<Vec<f64>> = layout
                .arenas
                .iter()
                .map(|a| vec![0.0f64; a.len() * C::PER_LINE])
                .collect();
            for e in 0..me as u32 {
                let s = layout.edge_shard[e as usize] as usize;
                let off = layout.edge_local[e as usize] as usize;
                let len = mrf.msg_len(e);
                vals[s][off..off + len].fill(1.0 / len as f64);
            }
            vals.iter()
                .map(|v| {
                    let t = cold_path_threads(v.len().div_ceil(C::PER_LINE));
                    arena_from_values_in::<C>(v, t, mode)
                })
                .collect::<Result<_>>()?
        } else {
            // Each worker owns the shards `s ≡ t (mod threads)`: it scans
            // the edge table once, fills the value images of its own
            // shards, then builds their arenas. Reads are shared, writes
            // stay worker-local.
            let per_worker = run_workers(threads, |t| -> Result<Vec<_>> {
                let mut mine: Vec<(usize, Vec<f64>)> = (t..k)
                    .step_by(threads)
                    .map(|s| (s, vec![0.0f64; layout.arenas[s].len() * C::PER_LINE]))
                    .collect();
                for e in 0..me {
                    let s = layout.edge_shard[e] as usize;
                    if s % threads != t {
                        continue;
                    }
                    let off = layout.edge_local[e] as usize;
                    let len = mrf.msg_len(e as u32);
                    mine[(s - t) / threads].1[off..off + len].fill(1.0 / len as f64);
                }
                mine.into_iter()
                    .map(|(s, v)| Ok((s, arena_from_values_in::<C>(&v, 1, mode)?)))
                    .collect::<Result<Vec<_>>>()
            });
            let mut slots: Vec<Option<ArenaBuf<C::Line>>> = (0..k).map(|_| None).collect();
            for worker in per_worker {
                for (s, arena) in worker? {
                    slots[s] = Some(arena);
                }
            }
            slots
                .into_iter()
                .map(|a| a.expect("every shard built exactly once"))
                .collect()
        };
        Ok(ArenaSet {
            arenas,
            edge_shard: layout.edge_shard.clone(),
            edge_local: layout.edge_local.clone(),
            flat_offset: layout.flat_offset.clone(),
            mode: mode.clone(),
        })
    }

    #[inline]
    fn line(&self, shard: usize, idx: usize) -> (&C::Line, usize) {
        (&self.arenas[shard][idx / C::PER_LINE], idx % C::PER_LINE)
    }

    #[inline]
    fn cell_load(&self, shard: usize, idx: usize) -> f64 {
        let (line, k) = self.line(shard, idx);
        C::load(line, k)
    }

    #[inline]
    fn cell_store(&self, shard: usize, idx: usize, v: f64) {
        let (line, k) = self.line(shard, idx);
        C::store(line, k, v);
    }

    fn len(&self) -> usize {
        self.flat_offset.last().map_or(0, |&t| t as usize)
    }

    /// (logical bytes, padded bytes): logical counts the live cells at the
    /// storage width; padded counts whole allocated 64-byte lines.
    fn arena_bytes(&self) -> (usize, usize) {
        let logical = self.len() * C::PRECISION.bytes_per_cell();
        let padded = self.arenas.iter().map(|a| a.len()).sum::<usize>() * 64;
        (logical, padded)
    }

    #[inline]
    fn write_msg(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        for k in 0..len {
            self.cell_store(shard, off + k, vals[k]);
        }
    }

    #[inline]
    fn write_msg_bulk(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        let arena = &self.arenas[shard];
        let mut k = 0;
        while k < len && (off + k) % C::PER_LINE != 0 {
            self.cell_store(shard, off + k, vals[k]);
            k += 1;
        }
        while k + C::PER_LINE <= len {
            C::write_line(&arena[(off + k) / C::PER_LINE], &vals[k..k + C::PER_LINE]);
            k += C::PER_LINE;
        }
        while k < len {
            self.cell_store(shard, off + k, vals[k]);
            k += 1;
        }
    }

    fn write_msg_residual(&self, mrf: &Mrf, e: u32, vals: &[f64], kernel: Kernel) -> f64 {
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        match kernel {
            Kernel::Scalar => {
                let mut acc = 0.0f64;
                for k in 0..len {
                    let d = C::round(vals[k]) - self.cell_load(shard, off + k);
                    acc += d * d;
                    self.cell_store(shard, off + k, vals[k]);
                }
                acc.sqrt()
            }
            Kernel::Simd => {
                // Same lane tiling + reduction grouping as
                // `simd::sq_diff_sum`, so the fused form prices exactly
                // like the unfused simd reference.
                let mut acc = [0.0f64; simd::LANES];
                let mut k = 0;
                while k + simd::LANES <= len {
                    for l in 0..simd::LANES {
                        let d = C::round(vals[k + l]) - self.cell_load(shard, off + k + l);
                        acc[l] += d * d;
                        self.cell_store(shard, off + k + l, vals[k + l]);
                    }
                    k += simd::LANES;
                }
                let mut tail = 0.0f64;
                while k < len {
                    let d = C::round(vals[k]) - self.cell_load(shard, off + k);
                    tail += d * d;
                    self.cell_store(shard, off + k, vals[k]);
                    k += 1;
                }
                simd::reduce(acc, tail).sqrt()
            }
        }
    }

    fn snapshot(&self) -> Vec<f64> {
        let me = self.edge_shard.len();
        let mut out = vec![0.0f64; self.len()];
        let threads = cold_path_threads(me);
        for_flat_chunks(&self.flat_offset, &mut out, threads, |piece, e0, e1, base| {
            for e in e0..e1 {
                let flat = self.flat_offset[e] as usize - base;
                let len = (self.flat_offset[e + 1] - self.flat_offset[e]) as usize;
                let shard = self.edge_shard[e] as usize;
                let off = self.edge_local[e] as usize;
                for k in 0..len {
                    piece[flat + k] = self.cell_load(shard, off + k);
                }
            }
        });
        out
    }

    fn restore(&self, snap: &[f64]) {
        assert_eq!(snap.len(), self.len());
        let me = self.edge_shard.len();
        let threads = cold_path_threads(me);
        run_workers(threads, |t| {
            for e in (t * me / threads)..((t + 1) * me / threads) {
                let flat = self.flat_offset[e] as usize;
                let len = (self.flat_offset[e + 1] - self.flat_offset[e]) as usize;
                let shard = self.edge_shard[e] as usize;
                let off = self.edge_local[e] as usize;
                for k in 0..len {
                    self.cell_store(shard, off + k, snap[flat + k]);
                }
            }
        });
    }

    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let len = mrf.msg_len(e);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        for k in 0..len {
            out[k] = self.cell_load(shard, off + k);
        }
        len
    }

    #[inline]
    fn read_msg_bulk(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let len = mrf.msg_len(e);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        let arena = &self.arenas[shard];
        let mut k = 0;
        while k < len && (off + k) % C::PER_LINE != 0 {
            out[k] = self.cell_load(shard, off + k);
            k += 1;
        }
        while k + C::PER_LINE <= len {
            C::read_line(&arena[(off + k) / C::PER_LINE], &mut out[k..k + C::PER_LINE]);
            k += C::PER_LINE;
        }
        while k < len {
            out[k] = self.cell_load(shard, off + k);
            k += 1;
        }
        len
    }

    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        let len = mrf.msg_len(e);
        debug_assert_eq!(len, new.len());
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        match kernel {
            Kernel::Scalar => {
                let mut acc = 0.0f64;
                for k in 0..len {
                    let d = C::round(new[k]) - self.cell_load(shard, off + k);
                    acc += d * d;
                }
                acc.sqrt()
            }
            Kernel::Simd => {
                // Same lane tiling + reduction grouping as
                // `simd::sq_diff_sum` (see `simd::reduce`).
                let mut acc = [0.0f64; simd::LANES];
                let mut k = 0;
                while k + simd::LANES <= len {
                    for l in 0..simd::LANES {
                        let d = C::round(new[k + l]) - self.cell_load(shard, off + k + l);
                        acc[l] += d * d;
                    }
                    k += simd::LANES;
                }
                let mut tail = 0.0f64;
                while k < len {
                    let d = C::round(new[k]) - self.cell_load(shard, off + k);
                    tail += d * d;
                    k += 1;
                }
                simd::reduce(acc, tail).sqrt()
            }
        }
    }
}

fn flat_offsets(mrf: &Mrf) -> Box<[u32]> {
    let mut flat = Vec::with_capacity(mrf.num_messages() + 1);
    flat.extend_from_slice(&mrf.msg_offset);
    flat.push(mrf.total_msg_len as u32);
    flat.into_boxed_slice()
}

/// Precision-tagged storage behind [`Messages`].
enum Store {
    /// 8-byte cells, bit-frozen default arm.
    F64(ArenaSet<CellF64>),
    /// 4-byte cells, one rounding per store.
    F32(ArenaSet<CellF32>),
}

/// Dispatch a method body over the two storage monomorphizations.
macro_rules! dispatch {
    ($self:expr, $a:ident => $body:expr) => {
        match &$self.store {
            Store::F64($a) => $body,
            Store::F32($a) => $body,
        }
    };
}

/// The live, concurrently-updatable message state.
///
/// A thin precision-dispatching facade over the per-shard arena engine:
/// the storage cell type ([`Precision`]) is chosen at construction and
/// every access dispatches once per *message* (not per cell) to the
/// matching monomorphization.
pub struct Messages {
    store: Store,
    /// Geometric damping factor applied by every write path: a store of
    /// candidate `m` first blends `m' = m^{1−F}·m_old^F` (renormalized)
    /// against the cell's current value. `0.0` (the constructor default)
    /// skips the blend entirely, keeping the undamped path bit-frozen.
    damping: f64,
}

impl Messages {
    /// All messages initialized uniform (1/|D|), in one flat arena whose
    /// cell order is the `Mrf::msg_offset` layout, stored at the default
    /// [`Precision::F64`] in heap arenas. Initialization is a single bulk
    /// pass — no per-cell atomic stores on the freshly owned allocation.
    pub fn uniform(mrf: &Mrf) -> Self {
        Self::uniform_with(mrf, Precision::F64)
    }

    /// [`Messages::uniform`] at an explicit storage precision. Under
    /// [`Precision::F32`] the uniform values round once at initialization
    /// (e.g. `1/3` stores as the nearest `f32`), exactly as a store of the
    /// same value would.
    pub fn uniform_with(mrf: &Mrf, precision: Precision) -> Self {
        Self::uniform_in(mrf, precision, &ArenaMode::Mem)
            .expect("heap arena allocation is infallible")
    }

    /// [`Messages::uniform_with`] at an explicit [`ArenaMode`]. The only
    /// fallible arm is [`ArenaMode::Mmap`] (arena temp-file creation);
    /// cell values and layout are identical across modes.
    pub fn uniform_in(mrf: &Mrf, precision: Precision, arena: &ArenaMode) -> Result<Self> {
        let store = match precision {
            Precision::F64 => Store::F64(ArenaSet::uniform(mrf, arena)?),
            Precision::F32 => Store::F32(ArenaSet::uniform(mrf, arena)?),
        };
        Ok(Messages { store, damping: 0.0 })
    }

    /// All messages initialized uniform, with each shard of `partition`
    /// (over the message universe: `partition.num_tasks()` must equal
    /// `mrf.num_messages()`) stored contiguously in its own cache-line-
    /// aligned arena, at the default [`Precision::F64`] in heap arenas.
    /// Behaviorally identical to [`Messages::uniform`] through
    /// [`MsgSource`] / [`Messages::write_msg`]; only the physical layout
    /// differs.
    pub fn uniform_partitioned(mrf: &Mrf, partition: &Partition) -> Self {
        Self::uniform_partitioned_with(mrf, partition, Precision::F64)
    }

    /// [`Messages::uniform_partitioned`] at an explicit storage precision.
    pub fn uniform_partitioned_with(
        mrf: &Mrf,
        partition: &Partition,
        precision: Precision,
    ) -> Self {
        Self::uniform_partitioned_in(mrf, partition, precision, &ArenaMode::Mem)
            .expect("heap arena allocation is infallible")
    }

    /// [`Messages::uniform_partitioned_with`] at an explicit
    /// [`ArenaMode`]: each shard's arena gets its own file-backed
    /// mapping under [`ArenaMode::Mmap`].
    pub fn uniform_partitioned_in(
        mrf: &Mrf,
        partition: &Partition,
        precision: Precision,
        arena: &ArenaMode,
    ) -> Result<Self> {
        let store = match precision {
            Precision::F64 => Store::F64(ArenaSet::uniform_partitioned(mrf, partition, arena)?),
            Precision::F32 => Store::F32(ArenaSet::uniform_partitioned(mrf, partition, arena)?),
        };
        Ok(Messages { store, damping: 0.0 })
    }

    /// Uniform state sharing `layout`'s arena sharding, storage
    /// precision, **and** backing [`ArenaMode`] — used by caches that
    /// shadow the live state (the residual lookahead, the synchronous
    /// engine's double buffers) so their locality, rounding, and
    /// spill-to-disk behavior match the state they mirror. An mmap-mode
    /// shadow that would otherwise stay heap-resident is exactly the
    /// allocation that defeats an out-of-core run.
    ///
    /// # Panics
    ///
    /// If `layout` is file-backed and the shadow's arena temp files
    /// cannot be created (the live state already succeeded in the same
    /// directory moments earlier, so this is disk-full territory).
    ///
    /// The shadow does **not** inherit `layout`'s damping factor: caches
    /// like the lookahead hold *candidate* values, and damping them again
    /// on store would double-apply the blend the live state already paid.
    pub fn uniform_like(mrf: &Mrf, layout: &Messages) -> Self {
        let store = match &layout.store {
            Store::F64(a) => Store::F64(
                ArenaSet::uniform_like(mrf, a).expect("allocating shadow message arenas"),
            ),
            Store::F32(a) => Store::F32(
                ArenaSet::uniform_like(mrf, a).expect("allocating shadow message arenas"),
            ),
        };
        Messages { store, damping: 0.0 }
    }

    /// Set the geometric damping factor the write paths apply (`0.0` =
    /// undamped, bit-frozen to the pre-axis store path). Set once at
    /// construction time — [`crate::run::build_messages`] wires it from
    /// the config before the state is shared with workers.
    pub fn set_damping(&mut self, damping: f64) {
        self.damping = damping;
    }

    /// The geometric damping factor the write paths apply.
    pub fn damping(&self) -> f64 {
        self.damping
    }

    /// Fill `buf` with the renormalized geometric blend of the candidate
    /// `vals` against message `e`'s current value; returns the domain
    /// length. Exact zeros survive (0^x = 0), so hard-factor support sets
    /// are preserved; a degenerate blend (zero or non-finite mass) falls
    /// back to the undamped candidate rather than storing garbage.
    fn damp_into(&self, mrf: &Mrf, e: u32, vals: &[f64], buf: &mut MsgBuf) -> usize {
        let f = self.damping;
        let mut old = msg_buf();
        let len = self.read_msg(mrf, e, &mut old);
        let mut sum = 0.0;
        for i in 0..len {
            let b = vals[i].powf(1.0 - f) * old[i].powf(f);
            buf[i] = b;
            sum += b;
        }
        if sum > 0.0 && sum.is_finite() {
            for v in &mut buf[..len] {
                *v /= sum;
            }
        } else {
            buf[..len].copy_from_slice(&vals[..len]);
        }
        len
    }

    /// Storage precision of the arenas.
    pub fn precision(&self) -> Precision {
        match &self.store {
            Store::F64(_) => Precision::F64,
            Store::F32(_) => Precision::F32,
        }
    }

    /// Backing-allocation mode of the arenas.
    pub fn arena_mode(&self) -> &ArenaMode {
        dispatch!(self, a => &a.mode)
    }

    /// Message-arena footprint as `(logical_bytes, padded_bytes)`:
    /// logical counts live cells at the storage width (`len() ×`
    /// [`Precision::bytes_per_cell`]); padded counts the allocated
    /// 64-byte lines including per-shard tail padding — what the process
    /// actually maps.
    pub fn arena_bytes(&self) -> (usize, usize) {
        dispatch!(self, a => a.arena_bytes())
    }

    /// Number of messages tracked.
    pub fn num_messages(&self) -> usize {
        dispatch!(self, a => a.edge_shard.len())
    }

    /// Number of arena shards (1 for the flat [`Messages::uniform`] layout).
    pub fn num_shards(&self) -> usize {
        dispatch!(self, a => a.arenas.len())
    }

    /// Write message `e` from `vals[..len]`, rounding each value once to
    /// the storage precision. Under a nonzero damping factor the stored
    /// value is the geometric blend against the cell's current value (see
    /// [`Messages::set_damping`]).
    #[inline]
    pub fn write_msg(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        if self.damping != 0.0 {
            let mut buf = msg_buf();
            let len = self.damp_into(mrf, e, vals, &mut buf);
            dispatch!(self, a => a.write_msg(mrf, e, &buf[..len]));
            return;
        }
        dispatch!(self, a => a.write_msg(mrf, e, vals));
    }

    /// Bulk [`Messages::write_msg`]: stores stream whole cache-line tiles
    /// (one line lookup per 8 f64 / 16 f32 cells instead of one index
    /// computation per cell; the f32 tile narrows with the 8-lane convert
    /// kernels before storing). Identical stored values and relaxed
    /// ordering; used by the SIMD kernel's write pass.
    #[inline]
    pub fn write_msg_bulk(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        if self.damping != 0.0 {
            let mut buf = msg_buf();
            let len = self.damp_into(mrf, e, vals, &mut buf);
            dispatch!(self, a => a.write_msg_bulk(mrf, e, &buf[..len]));
            return;
        }
        dispatch!(self, a => a.write_msg_bulk(mrf, e, vals));
    }

    /// Fused write + residual: store `vals` into message `e` while
    /// accumulating `‖round(vals) − μ_e^{old}‖₂` against the value each
    /// cell held just before its store — one pass over the cells instead
    /// of the historical read-current / `residual_l2` / write triple. The
    /// candidate is priced through the storage rounding (identity on the
    /// f64 arm, so with [`Kernel::Scalar`] the returned residual is
    /// bit-for-bit the value the unfused triple computes; on f32 a store
    /// that doesn't change the cell prices to exactly zero).
    /// [`Kernel::Simd`] uses the lane-tiled reduction. Returns the
    /// residual.
    pub fn write_msg_residual(&self, mrf: &Mrf, e: u32, vals: &[f64], kernel: Kernel) -> f64 {
        if self.damping != 0.0 {
            // The blended value is what actually lands in the cell, so it
            // is also what gets priced: the returned residual measures the
            // damped step, which is the step the schedulers should see.
            let mut buf = msg_buf();
            let len = self.damp_into(mrf, e, vals, &mut buf);
            return dispatch!(self, a => a.write_msg_residual(mrf, e, &buf[..len], kernel));
        }
        dispatch!(self, a => a.write_msg_residual(mrf, e, vals, kernel))
    }

    /// [`Messages::write_msg_residual`] minus the damping blend: store
    /// `vals` verbatim (rounded once to the storage precision) regardless
    /// of the configured damping factor, returning the residual against
    /// the values the cells held before the store. This is the
    /// distributed ingress path: a boundary value arrives *already
    /// damped* by the rank that committed it, so applying it through the
    /// damped facade would blend the factor in twice and the mirrored
    /// cell would drift from the owner's.
    pub fn write_msg_residual_raw(&self, mrf: &Mrf, e: u32, vals: &[f64], kernel: Kernel) -> f64 {
        dispatch!(self, a => a.write_msg_residual(mrf, e, vals, kernel))
    }

    /// Copy the full state into a plain vector in the flat `msg_offset`
    /// layout (for snapshots/tests) — identical across arena shardings.
    /// Under f32 storage the snapshot is **f32-exact**: every stored value
    /// widens exactly, so [`Messages::restore`] of the snapshot (which
    /// re-rounds) reproduces the arenas bit-for-bit.
    pub fn snapshot(&self) -> Vec<f64> {
        dispatch!(self, a => a.snapshot())
    }

    /// Overwrite the full state from a flat-layout snapshot, rounding each
    /// value once to the storage precision.
    pub fn restore(&self, snap: &[f64]) {
        dispatch!(self, a => a.restore(snap));
    }

    /// Number of message cells (logical — excludes arena padding).
    pub fn len(&self) -> usize {
        dispatch!(self, a => a.len())
    }

    /// True when the state holds no cells.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl MsgSource for Messages {
    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        dispatch!(self, a => a.read_msg(mrf, e, out))
    }

    /// Line-tiled bulk read: one arena-line lookup per 8 f64 / 16 f32
    /// cells, with the relaxed loads of a full line unrolled (atomic loads
    /// never auto-vectorize, so removing the per-cell index arithmetic and
    /// bounds checks is where the win is; the f32 tile additionally widens
    /// through the 8-lane convert kernels). Same values as `read_msg`.
    #[inline]
    fn read_msg_bulk(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        dispatch!(self, a => a.read_msg_bulk(mrf, e, out))
    }

    /// Single-pass residual against the live cells: no `cur` buffer, one
    /// load per cell, candidate priced through the storage rounding.
    /// Scalar accumulation order matches `residual_l2` exactly
    /// (bit-for-bit on the f64 arm); SIMD uses the 4-lane grouping.
    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        dispatch!(self, a => a.residual_l2_against(mrf, e, new, kernel))
    }
}

/// A frozen snapshot (flat `Vec<f64>` in the `msg_offset` layout) is also
/// a source. Snapshot slices are plain f64 storage: reads are exact and
/// residuals price unrounded, regardless of the precision of the run the
/// snapshot came from.
impl MsgSource for [f64] {
    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        out[..len].copy_from_slice(&self[off..off + len]);
        len
    }

    /// Snapshots hand out zero-copy views — the SIMD gather loops consume
    /// them in place instead of copying through `MsgScratch::tmp`.
    #[inline]
    fn borrow_msg(&self, mrf: &Mrf, e: u32) -> Option<&[f64]> {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        Some(&self[off..off + len])
    }

    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        debug_assert_eq!(len, new.len());
        let cur = &self[off..off + len];
        match kernel {
            Kernel::Scalar => crate::bp::update::residual_l2(new, cur),
            Kernel::Simd => simd::sq_diff_sum(new, cur).sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::ModelSpec;
    use crate::model::builders;

    #[test]
    fn uniform_init() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let len = msgs.read_msg(&m, e, &mut buf);
            assert_eq!(len, 2);
            assert_eq!(&buf[..2], &[0.5, 0.5]);
        }
    }

    #[test]
    fn uniform_init_wide_domain() {
        let m = builders::build(&ModelSpec::Ldpc { n: 12, flip_prob: 0.07 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        // find a variable→constraint edge (length 64)
        let e = (0..m.num_messages() as u32).find(|&e| m.msg_len(e) == 64).unwrap();
        let len = msgs.read_msg(&m, e, &mut buf);
        assert_eq!(len, 64);
        assert!((buf[..64].iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn write_read_roundtrip() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 1, &[0.25, 0.75]);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 1, &mut buf);
        assert_eq!(&buf[..2], &[0.25, 0.75]);
        // neighbors untouched
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.5, 0.5]);
    }

    #[test]
    fn snapshot_restore() {
        let m = builders::build(&ModelSpec::Path { n: 4 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 0, &[0.9, 0.1]);
        let snap = msgs.snapshot();
        msgs.write_msg(&m, 0, &[0.5, 0.5]);
        msgs.restore(&snap);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.9, 0.1]);
    }

    #[test]
    fn slice_source_matches_layout() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 2, &[0.3, 0.7]);
        let snap = msgs.snapshot();
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..m.num_messages() as u32 {
            msgs.read_msg(&m, e, &mut a);
            snap.as_slice().read_msg(&m, e, &mut b);
            assert_eq!(&a[..2], &b[..2]);
        }
    }

    #[test]
    fn cache_line_is_aligned() {
        assert_eq!(std::mem::align_of::<LineF64>(), 64);
        assert_eq!(std::mem::size_of::<LineF64>(), 64);
        assert_eq!(std::mem::align_of::<LineF32>(), 64);
        assert_eq!(std::mem::size_of::<LineF32>(), 64);
    }

    #[test]
    fn sharded_arenas_behave_like_flat() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        for shards in [1, 2, 7] {
            let p = Partition::contiguous(m.num_messages(), shards);
            let sharded = Messages::uniform_partitioned(&m, &p);
            assert_eq!(sharded.num_shards(), shards.min(m.num_messages()));
            let flat = Messages::uniform(&m);
            assert_eq!(sharded.snapshot(), flat.snapshot(), "shards={shards}");
            // Writes through the shared API land identically.
            sharded.write_msg(&m, 5, &[0.2, 0.8]);
            flat.write_msg(&m, 5, &[0.2, 0.8]);
            assert_eq!(sharded.snapshot(), flat.snapshot(), "shards={shards}");
            let mut a = msg_buf();
            sharded.read_msg(&m, 5, &mut a);
            assert_eq!(&a[..2], &[0.2, 0.8]);
        }
    }

    #[test]
    fn sharded_snapshot_restores_into_flat() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let p = Partition::bfs_edges(&m.graph, 3);
        let sharded = Messages::uniform_partitioned(&m, &p);
        sharded.write_msg(&m, 3, &[0.1, 0.2, 0.7]);
        let flat = Messages::uniform(&m);
        flat.restore(&sharded.snapshot());
        let mut buf = msg_buf();
        flat.read_msg(&m, 3, &mut buf);
        assert_eq!(&buf[..3], &[0.1, 0.2, 0.7]);
    }

    #[test]
    fn uniform_like_mirrors_layout() {
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 1);
        let p = Partition::contiguous(m.num_messages(), 2);
        let live = Messages::uniform_partitioned(&m, &p);
        let shadow = Messages::uniform_like(&m, &live);
        assert_eq!(shadow.num_shards(), live.num_shards());
        assert_eq!(shadow.snapshot(), Messages::uniform(&m).snapshot());
    }

    #[test]
    fn default_precision_is_f64() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        assert_eq!(Messages::uniform(&m).precision(), Precision::F64);
        assert_eq!(Precision::default(), Precision::F64);
        assert_eq!(Precision::F64.label(), "f64");
        assert_eq!(Precision::F32.label(), "f32");
        assert!(Precision::F32.is_f32());
        assert!(!Precision::F64.is_f32());
    }

    #[test]
    fn f32_write_read_rounds_once_to_storage() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform_with(&m, Precision::F32);
        assert_eq!(msgs.precision(), Precision::F32);
        let third = 1.0 / 3.0;
        msgs.write_msg(&m, 1, &[third, 1.0 - third]);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 1, &mut buf);
        // Exactly one rounding point: read-back is `v as f32 as f64`.
        assert_eq!(buf[0], (third as f32) as f64);
        assert_eq!(buf[1], ((1.0 - third) as f32) as f64);
        // Exact dyadic values survive untouched.
        msgs.write_msg(&m, 0, &[0.25, 0.75]);
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.25, 0.75]);
    }

    #[test]
    fn f32_uniform_rounds_like_a_store() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let msgs = Messages::uniform_with(&m, Precision::F32);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(buf[0], ((1.0f64 / 3.0) as f32) as f64);
    }

    #[test]
    fn f32_bulk_io_matches_per_cell() {
        let m = builders::build(&ModelSpec::Ldpc { n: 12, flip_prob: 0.07 }, 3);
        let a = Messages::uniform_with(&m, Precision::F32);
        let b = Messages::uniform_with(&m, Precision::F32);
        let e = (0..m.num_messages() as u32).find(|&e| m.msg_len(e) == 64).unwrap();
        let vals: Vec<f64> = (0..64).map(|k| 1.0 / (k as f64 + 3.0)).collect();
        a.write_msg(&m, e, &vals);
        b.write_msg_bulk(&m, e, &vals);
        let mut x = msg_buf();
        let mut y = msg_buf();
        a.read_msg(&m, e, &mut x);
        b.read_msg_bulk(&m, e, &mut y);
        assert_eq!(&x[..64], &y[..64]);
        b.read_msg(&m, e, &mut y);
        assert_eq!(&x[..64], &y[..64]);
    }

    #[test]
    fn f32_snapshot_restore_is_exact() {
        let m = builders::build(&ModelSpec::Path { n: 4 }, 1);
        let msgs = Messages::uniform_with(&m, Precision::F32);
        msgs.write_msg(&m, 0, &[1.0 / 3.0, 2.0 / 3.0]);
        let snap = msgs.snapshot();
        msgs.write_msg(&m, 0, &[0.5, 0.5]);
        msgs.restore(&snap);
        // Snapshot values are f32-exact, so the round-trip is bitwise.
        assert_eq!(msgs.snapshot(), snap);
    }

    #[test]
    fn f32_residual_zero_at_stored_fixed_point() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform_with(&m, Precision::F32);
        let vals = [1.0 / 3.0, 2.0 / 3.0];
        msgs.write_msg(&m, 1, &vals);
        // Re-pricing the same (unrounded) candidate must give exactly 0:
        // the candidate rounds to what storage already holds.
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            assert_eq!(msgs.residual_l2_against(&m, 1, &vals, kernel), 0.0);
            assert_eq!(msgs.write_msg_residual(&m, 1, &vals, kernel), 0.0);
        }
    }

    #[test]
    fn fused_residual_prices_against_stored_cells() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        for precision in [Precision::F64, Precision::F32] {
            let msgs = Messages::uniform_with(&m, precision);
            let old = [0.3f64, 0.7];
            let new = [1.0 / 3.0, 2.0 / 3.0];
            msgs.write_msg(&m, 0, &old);
            let round = |v: f64| match precision {
                Precision::F64 => v,
                Precision::F32 => (v as f32) as f64,
            };
            let d0 = round(new[0]) - round(old[0]);
            let d1 = round(new[1]) - round(old[1]);
            let expect = (d0 * d0 + d1 * d1).sqrt();
            assert_eq!(
                msgs.write_msg_residual(&m, 0, &new, Kernel::Scalar),
                expect,
                "{precision:?}"
            );
        }
    }

    #[test]
    fn arena_bytes_halved_under_f32() {
        let m = builders::build(&ModelSpec::Ldpc { n: 24, flip_prob: 0.07 }, 1);
        let f64m = Messages::uniform(&m);
        let f32m = Messages::uniform_with(&m, Precision::F32);
        let (log64, pad64) = f64m.arena_bytes();
        let (log32, pad32) = f32m.arena_bytes();
        assert_eq!(log64, f64m.len() * 8);
        assert_eq!(log32, log64 / 2);
        assert!(pad64 >= log64 && pad32 >= log32);
        // Padded bytes halve up to one 64-byte line of tail padding/shard.
        assert!(pad32 <= pad64 / 2 + 64 * f32m.num_shards());
    }

    #[test]
    fn f32_partitioned_matches_flat() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let p = Partition::contiguous(m.num_messages(), 3);
        let sharded = Messages::uniform_partitioned_with(&m, &p, Precision::F32);
        assert_eq!(sharded.precision(), Precision::F32);
        let flat = Messages::uniform_with(&m, Precision::F32);
        sharded.write_msg(&m, 5, &[0.2, 0.8]);
        flat.write_msg(&m, 5, &[0.2, 0.8]);
        assert_eq!(sharded.snapshot(), flat.snapshot());
    }

    #[test]
    fn uniform_like_mirrors_precision() {
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 1);
        let p = Partition::contiguous(m.num_messages(), 2);
        let live = Messages::uniform_partitioned_with(&m, &p, Precision::F32);
        let shadow = Messages::uniform_like(&m, &live);
        assert_eq!(shadow.precision(), Precision::F32);
        assert_eq!(shadow.num_shards(), live.num_shards());
    }

    #[test]
    fn arena_mode_labels_and_specs() {
        assert_eq!(ArenaMode::default(), ArenaMode::Mem);
        assert_eq!(ArenaMode::Mem.label(), "mem");
        assert_eq!(ArenaMode::Mem.spec(), "mem");
        assert!(!ArenaMode::Mem.is_mmap());
        let plain = ArenaMode::Mmap { dir: None };
        assert_eq!(plain.label(), "mmap");
        assert_eq!(plain.spec(), "mmap");
        assert!(plain.is_mmap());
        let dir = ArenaMode::Mmap { dir: Some("/x/y".into()) };
        assert_eq!(dir.label(), "mmap");
        assert_eq!(dir.spec(), "mmap:/x/y");
    }

    #[test]
    fn default_arena_mode_is_mem() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        assert_eq!(*Messages::uniform(&m).arena_mode(), ArenaMode::Mem);
    }

    #[cfg(unix)]
    #[test]
    fn mmap_arena_matches_mem_bitwise() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let mode = ArenaMode::Mmap { dir: None };
        for precision in [Precision::F64, Precision::F32] {
            let mapped = Messages::uniform_in(&m, precision, &mode).unwrap();
            assert!(mapped.arena_mode().is_mmap());
            let mem = Messages::uniform_with(&m, precision);
            assert_eq!(mapped.snapshot(), mem.snapshot(), "{precision:?}");
            // Writes land identically through the shared cell contract.
            mapped.write_msg(&m, 5, &[0.2, 0.8]);
            mem.write_msg(&m, 5, &[0.2, 0.8]);
            assert_eq!(mapped.snapshot(), mem.snapshot(), "{precision:?}");
            assert_eq!(mapped.arena_bytes(), mem.arena_bytes());
        }
    }

    #[cfg(unix)]
    #[test]
    fn mmap_arena_partitioned_and_snapshot_restore() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let p = Partition::bfs_edges(&m.graph, 3);
        let mode = ArenaMode::Mmap { dir: None };
        let msgs =
            Messages::uniform_partitioned_in(&m, &p, Precision::F64, &mode).unwrap();
        msgs.write_msg(&m, 3, &[0.1, 0.2, 0.7]);
        let snap = msgs.snapshot();
        msgs.write_msg(&m, 3, &[0.5, 0.3, 0.2]);
        msgs.restore(&snap);
        assert_eq!(msgs.snapshot(), snap);
        // Snapshots are interchangeable with heap-arena states.
        let mem = Messages::uniform(&m);
        mem.restore(&snap);
        assert_eq!(mem.snapshot(), snap);
    }

    #[cfg(unix)]
    #[test]
    fn uniform_like_mirrors_arena_mode() {
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 1);
        let mode = ArenaMode::Mmap { dir: None };
        let live = Messages::uniform_in(&m, Precision::F64, &mode).unwrap();
        let shadow = Messages::uniform_like(&m, &live);
        assert!(shadow.arena_mode().is_mmap());
        assert_eq!(shadow.snapshot(), Messages::uniform(&m).snapshot());
    }

    #[test]
    fn mmap_arena_bad_dir_is_clean_error() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let mode = ArenaMode::Mmap { dir: Some("/nonexistent-rbp-arena-dir".into()) };
        assert!(Messages::uniform_in(&m, Precision::F64, &mode).is_err());
    }
}
