//! Shared message state.
//!
//! Message vectors live in per-shard, cache-line-aligned **arenas** of
//! [`AtomicF64`] cells. The default ([`Messages::uniform`]) is one arena
//! whose cell order is exactly the flat layout from [`Mrf::msg_offset`] —
//! bit-for-bit the historical flat-array behavior. A locality-aware run
//! ([`Messages::uniform_partitioned`]) lays each
//! [`Partition`](crate::model::Partition) shard's messages out
//! contiguously in that shard's own arena, so a worker that stays on its
//! shard walks hot, contiguous cache lines instead of striding a single
//! model-sized array.
//!
//! Either way, worker threads read and write cells with relaxed atomics —
//! the same benign-race discipline as the paper's Java implementation. A
//! message read can observe a concurrent writer's partial update; BP
//! tolerates such races (they act as slightly stale inputs) and the
//! engines' claim flags prevent two threads from *writing* one message
//! concurrently.
//!
//! Snapshots ([`Messages::snapshot`] / [`Messages::restore`] and the
//! `MsgSource for [f64]` impl) always use the *flat* `msg_offset` layout
//! regardless of the arena sharding, so frozen state is interchangeable
//! across layouts.

use super::simd::{self, Kernel};
use crate::model::{Mrf, Partition, MAX_DOMAIN};
use crate::util::AtomicF64;

/// Fixed-size stack buffer for one message / one domain's worth of values.
pub type MsgBuf = [f64; MAX_DOMAIN];

/// Allocate a zeroed message buffer.
///
/// This zero-initializes all `MAX_DOMAIN` (64) entries — a 512-byte
/// memset — regardless of the live domain, so hot loops must not call it
/// per update: hold one buffer (or a
/// [`MsgScratch`](crate::bp::MsgScratch) /
/// [`NodeScratch`](crate::bp::NodeScratch)) per worker and reuse it. The
/// kernels themselves only read/write the live `|D|`-prefix.
#[inline]
pub fn msg_buf() -> MsgBuf {
    [0.0; MAX_DOMAIN]
}

/// Something messages can be read from: the live atomic state or a plain
/// snapshot (used by the synchronous engine's double buffering and by
/// marginal computation on frozen state).
pub trait MsgSource {
    /// Copy message `e` into `out[..len]`; returns `len`.
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize;

    /// Bulk variant of [`MsgSource::read_msg`] used by the SIMD kernel:
    /// implementations stream whole cache-line tiles instead of one
    /// cell-index computation per element. Always returns the same values
    /// as `read_msg` — only the access pattern differs — so the scalar
    /// kernel keeps calling `read_msg` and stays bit-for-bit the pre-SIMD
    /// path while the SIMD kernel reads through this.
    #[inline]
    fn read_msg_bulk(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        self.read_msg(mrf, e, out)
    }

    /// Zero-copy borrowed view of message `e`, when the source can hand
    /// one out (plain snapshot slices can; the live atomic state cannot).
    /// Lets the SIMD kernel's gather loops consume snapshot messages in
    /// place instead of round-tripping through `MsgScratch::tmp`.
    #[inline]
    fn borrow_msg(&self, _mrf: &Mrf, _e: u32) -> Option<&[f64]> {
        None
    }

    /// In-kernel L2 residual: `‖new − μ_e‖₂` computed in one pass over the
    /// source's cells, without materializing the current value in a
    /// caller buffer. The scalar kernel accumulates in exactly the order
    /// of [`residual_l2`](crate::bp::update::residual_l2) over a fresh
    /// read, so it is bit-for-bit the historical
    /// read-then-`residual_l2` composition; the SIMD kernel uses the
    /// lane-tiled reduction.
    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        let mut cur = msg_buf();
        let len = self.read_msg(mrf, e, &mut cur);
        debug_assert_eq!(len, new.len());
        match kernel {
            Kernel::Scalar => crate::bp::update::residual_l2(new, &cur[..len]),
            Kernel::Simd => simd::sq_diff_sum(new, &cur[..len]).sqrt(),
        }
    }
}

/// Cells per 64-byte cache line (an [`AtomicF64`] is 8 bytes).
const CELLS_PER_LINE: usize = 8;

/// One cache line of message cells. The alignment guarantee is what makes
/// per-shard arenas genuinely private at the cache level: two shards never
/// share a line, so cross-shard false sharing cannot occur.
#[repr(align(64))]
struct CacheLine([AtomicF64; CELLS_PER_LINE]);

/// Build one arena from plain values — a single non-atomic initialization
/// pass over a freshly owned allocation (the cells become shared only when
/// the arena is published to worker threads).
fn arena_from_values(vals: &[f64]) -> Box<[CacheLine]> {
    (0..vals.len().div_ceil(CELLS_PER_LINE))
        .map(|l| {
            CacheLine(std::array::from_fn(|k| {
                AtomicF64::new(vals.get(l * CELLS_PER_LINE + k).copied().unwrap_or(0.0))
            }))
        })
        .collect()
}

/// The live, concurrently-updatable message state.
pub struct Messages {
    /// One cache-line-aligned cell arena per shard.
    arenas: Vec<Box<[CacheLine]>>,
    /// Shard holding each message.
    edge_shard: Box<[u32]>,
    /// Cell offset of each message within its shard's arena.
    edge_local: Box<[u32]>,
    /// Flat-layout offsets (= `Mrf::msg_offset` plus a trailing total):
    /// the snapshot/restore layout, shared across all arena shardings.
    flat_offset: Box<[u32]>,
}

impl Messages {
    /// All messages initialized uniform (1/|D|), in one flat arena whose
    /// cell order is the `Mrf::msg_offset` layout. Initialization is a
    /// single bulk pass — no per-cell atomic stores on the freshly owned
    /// allocation.
    pub fn uniform(mrf: &Mrf) -> Self {
        let me = mrf.num_messages();
        let mut vals = vec![0.0f64; mrf.total_msg_len];
        for e in 0..me as u32 {
            let len = mrf.msg_len(e);
            let off = mrf.msg_offset[e as usize] as usize;
            vals[off..off + len].fill(1.0 / len as f64);
        }
        Messages {
            arenas: vec![arena_from_values(&vals)],
            edge_shard: vec![0u32; me].into_boxed_slice(),
            edge_local: mrf.msg_offset.clone().into_boxed_slice(),
            flat_offset: Self::flat_offsets(mrf),
        }
    }

    /// All messages initialized uniform, with each shard of `partition`
    /// (over the message universe: `partition.num_tasks()` must equal
    /// `mrf.num_messages()`) stored contiguously in its own cache-line-
    /// aligned arena. Behaviorally identical to [`Messages::uniform`]
    /// through [`MsgSource`] / [`Messages::write_msg`]; only the physical
    /// layout differs.
    pub fn uniform_partitioned(mrf: &Mrf, partition: &Partition) -> Self {
        let me = mrf.num_messages();
        assert_eq!(
            partition.num_tasks(),
            me,
            "partition must cover the message universe"
        );
        let k = partition.num_shards();
        let mut edge_shard = vec![0u32; me];
        let mut edge_local = vec![0u32; me];
        let mut arenas = Vec::with_capacity(k);
        let mut vals: Vec<f64> = Vec::new();
        for s in 0..k {
            vals.clear();
            for &e in partition.tasks_of(s) {
                edge_shard[e as usize] = s as u32;
                edge_local[e as usize] = vals.len() as u32;
                let len = mrf.msg_len(e);
                vals.resize(vals.len() + len, 1.0 / len as f64);
            }
            arenas.push(arena_from_values(&vals));
        }
        Messages {
            arenas,
            edge_shard: edge_shard.into_boxed_slice(),
            edge_local: edge_local.into_boxed_slice(),
            flat_offset: Self::flat_offsets(mrf),
        }
    }

    /// Uniform state sharing `layout`'s arena sharding — used by caches
    /// that shadow the live state (the residual lookahead) so their
    /// locality matches the state they mirror.
    pub fn uniform_like(mrf: &Mrf, layout: &Messages) -> Self {
        let me = mrf.num_messages();
        assert_eq!(layout.num_messages(), me, "layout built for a different model");
        let mut vals: Vec<Vec<f64>> = layout
            .arenas
            .iter()
            .map(|a| vec![0.0f64; a.len() * CELLS_PER_LINE])
            .collect();
        for e in 0..me as u32 {
            let s = layout.edge_shard[e as usize] as usize;
            let off = layout.edge_local[e as usize] as usize;
            let len = mrf.msg_len(e);
            vals[s][off..off + len].fill(1.0 / len as f64);
        }
        Messages {
            arenas: vals.iter().map(|v| arena_from_values(v)).collect(),
            edge_shard: layout.edge_shard.clone(),
            edge_local: layout.edge_local.clone(),
            flat_offset: layout.flat_offset.clone(),
        }
    }

    fn flat_offsets(mrf: &Mrf) -> Box<[u32]> {
        let mut flat = Vec::with_capacity(mrf.num_messages() + 1);
        flat.extend_from_slice(&mrf.msg_offset);
        flat.push(mrf.total_msg_len as u32);
        flat.into_boxed_slice()
    }

    #[inline]
    fn cell(&self, shard: usize, idx: usize) -> &AtomicF64 {
        &self.arenas[shard][idx / CELLS_PER_LINE].0[idx % CELLS_PER_LINE]
    }

    /// Number of messages tracked.
    pub fn num_messages(&self) -> usize {
        self.edge_shard.len()
    }

    /// Number of arena shards (1 for the flat [`Messages::uniform`] layout).
    pub fn num_shards(&self) -> usize {
        self.arenas.len()
    }

    /// Write message `e` from `vals[..len]`.
    #[inline]
    pub fn write_msg(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        for k in 0..len {
            self.cell(shard, off + k).store(vals[k]);
        }
    }

    /// Bulk [`Messages::write_msg`]: stores stream whole cache-line tiles
    /// (one line lookup per 8 cells instead of one index computation per
    /// cell). Identical stored values and relaxed ordering; used by the
    /// SIMD kernel's write pass.
    #[inline]
    pub fn write_msg_bulk(&self, mrf: &Mrf, e: u32, vals: &[f64]) {
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        let arena = &self.arenas[shard];
        let mut k = 0;
        while k < len && (off + k) % CELLS_PER_LINE != 0 {
            self.cell(shard, off + k).store(vals[k]);
            k += 1;
        }
        while k + CELLS_PER_LINE <= len {
            let line = &arena[(off + k) / CELLS_PER_LINE].0;
            for (c, v) in line.iter().zip(&vals[k..k + CELLS_PER_LINE]) {
                c.store(*v);
            }
            k += CELLS_PER_LINE;
        }
        while k < len {
            self.cell(shard, off + k).store(vals[k]);
            k += 1;
        }
    }

    /// Fused write + residual: store `vals` into message `e` while
    /// accumulating `‖vals − μ_e^{old}‖₂` against the value each cell held
    /// just before its store — one pass over the cells instead of the
    /// historical read-current / `residual_l2` / write triple. With
    /// [`Kernel::Scalar`] the squared differences accumulate in the exact
    /// sequential order of `residual_l2`, so the returned residual is
    /// bit-for-bit the value the unfused triple computes; [`Kernel::Simd`]
    /// uses the lane-tiled reduction. Returns the residual.
    pub fn write_msg_residual(&self, mrf: &Mrf, e: u32, vals: &[f64], kernel: Kernel) -> f64 {
        let len = mrf.msg_len(e);
        debug_assert!(vals.len() >= len);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        match kernel {
            Kernel::Scalar => {
                let mut acc = 0.0f64;
                for k in 0..len {
                    let cell = self.cell(shard, off + k);
                    let d = vals[k] - cell.load();
                    acc += d * d;
                    cell.store(vals[k]);
                }
                acc.sqrt()
            }
            Kernel::Simd => {
                // Same lane tiling + reduction grouping as
                // `simd::sq_diff_sum`, so the fused form prices exactly
                // like the unfused simd reference.
                let mut acc = [0.0f64; simd::LANES];
                let mut k = 0;
                while k + simd::LANES <= len {
                    for l in 0..simd::LANES {
                        let cell = self.cell(shard, off + k + l);
                        let d = vals[k + l] - cell.load();
                        acc[l] += d * d;
                        cell.store(vals[k + l]);
                    }
                    k += simd::LANES;
                }
                let mut tail = 0.0f64;
                while k < len {
                    let cell = self.cell(shard, off + k);
                    let d = vals[k] - cell.load();
                    tail += d * d;
                    cell.store(vals[k]);
                    k += 1;
                }
                simd::reduce(acc, tail).sqrt()
            }
        }
    }

    /// Copy the full state into a plain vector in the flat `msg_offset`
    /// layout (for snapshots/tests) — identical across arena shardings.
    pub fn snapshot(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.len()];
        for e in 0..self.num_messages() {
            let flat = self.flat_offset[e] as usize;
            let len = (self.flat_offset[e + 1] - self.flat_offset[e]) as usize;
            let shard = self.edge_shard[e] as usize;
            let off = self.edge_local[e] as usize;
            for k in 0..len {
                out[flat + k] = self.cell(shard, off + k).load();
            }
        }
        out
    }

    /// Overwrite the full state from a flat-layout snapshot.
    pub fn restore(&self, snap: &[f64]) {
        assert_eq!(snap.len(), self.len());
        for e in 0..self.num_messages() {
            let flat = self.flat_offset[e] as usize;
            let len = (self.flat_offset[e + 1] - self.flat_offset[e]) as usize;
            let shard = self.edge_shard[e] as usize;
            let off = self.edge_local[e] as usize;
            for k in 0..len {
                self.cell(shard, off + k).store(snap[flat + k]);
            }
        }
    }

    /// Number of f64 cells (logical — excludes arena padding).
    pub fn len(&self) -> usize {
        self.flat_offset.last().map_or(0, |&t| t as usize)
    }

    /// True when the state holds no cells.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl MsgSource for Messages {
    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let len = mrf.msg_len(e);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        for k in 0..len {
            out[k] = self.cell(shard, off + k).load();
        }
        len
    }

    /// Line-tiled bulk read: one arena-line lookup per 8 cells, with the
    /// 8 relaxed loads of a full line unrolled (atomic loads never
    /// auto-vectorize, so removing the per-cell index arithmetic and
    /// bounds checks is where the win is). Same values as `read_msg`.
    #[inline]
    fn read_msg_bulk(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let len = mrf.msg_len(e);
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        let arena = &self.arenas[shard];
        let mut k = 0;
        while k < len && (off + k) % CELLS_PER_LINE != 0 {
            out[k] = self.cell(shard, off + k).load();
            k += 1;
        }
        while k + CELLS_PER_LINE <= len {
            let line = &arena[(off + k) / CELLS_PER_LINE].0;
            for (o, c) in out[k..k + CELLS_PER_LINE].iter_mut().zip(line) {
                *o = c.load();
            }
            k += CELLS_PER_LINE;
        }
        while k < len {
            out[k] = self.cell(shard, off + k).load();
            k += 1;
        }
        len
    }

    /// Single-pass residual against the live cells: no `cur` buffer, one
    /// load per cell. Scalar accumulation order matches `residual_l2`
    /// exactly (bit-for-bit); SIMD uses the 4-lane grouping.
    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        let len = mrf.msg_len(e);
        debug_assert_eq!(len, new.len());
        let shard = self.edge_shard[e as usize] as usize;
        let off = self.edge_local[e as usize] as usize;
        match kernel {
            Kernel::Scalar => {
                let mut acc = 0.0f64;
                for k in 0..len {
                    let d = new[k] - self.cell(shard, off + k).load();
                    acc += d * d;
                }
                acc.sqrt()
            }
            Kernel::Simd => {
                // Same lane tiling + reduction grouping as
                // `simd::sq_diff_sum` (see `simd::reduce`).
                let mut acc = [0.0f64; simd::LANES];
                let mut k = 0;
                while k + simd::LANES <= len {
                    for l in 0..simd::LANES {
                        let d = new[k + l] - self.cell(shard, off + k + l).load();
                        acc[l] += d * d;
                    }
                    k += simd::LANES;
                }
                let mut tail = 0.0f64;
                while k < len {
                    let d = new[k] - self.cell(shard, off + k).load();
                    tail += d * d;
                    k += 1;
                }
                simd::reduce(acc, tail).sqrt()
            }
        }
    }
}

/// A frozen snapshot (flat `Vec<f64>` in the `msg_offset` layout) is also
/// a source.
impl MsgSource for [f64] {
    #[inline]
    fn read_msg(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        out[..len].copy_from_slice(&self[off..off + len]);
        len
    }

    /// Snapshots hand out zero-copy views — the SIMD gather loops consume
    /// them in place instead of copying through `MsgScratch::tmp`.
    #[inline]
    fn borrow_msg(&self, mrf: &Mrf, e: u32) -> Option<&[f64]> {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        Some(&self[off..off + len])
    }

    fn residual_l2_against(&self, mrf: &Mrf, e: u32, new: &[f64], kernel: Kernel) -> f64 {
        let off = mrf.msg_offset[e as usize] as usize;
        let len = mrf.msg_len(e);
        debug_assert_eq!(len, new.len());
        let cur = &self[off..off + len];
        match kernel {
            Kernel::Scalar => crate::bp::update::residual_l2(new, cur),
            Kernel::Simd => simd::sq_diff_sum(new, cur).sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::ModelSpec;
    use crate::model::builders;

    #[test]
    fn uniform_init() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let len = msgs.read_msg(&m, e, &mut buf);
            assert_eq!(len, 2);
            assert_eq!(&buf[..2], &[0.5, 0.5]);
        }
    }

    #[test]
    fn uniform_init_wide_domain() {
        let m = builders::build(&ModelSpec::Ldpc { n: 12, flip_prob: 0.07 }, 1);
        let msgs = Messages::uniform(&m);
        let mut buf = msg_buf();
        // find a variable→constraint edge (length 64)
        let e = (0..m.num_messages() as u32).find(|&e| m.msg_len(e) == 64).unwrap();
        let len = msgs.read_msg(&m, e, &mut buf);
        assert_eq!(len, 64);
        assert!((buf[..64].iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn write_read_roundtrip() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 1, &[0.25, 0.75]);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 1, &mut buf);
        assert_eq!(&buf[..2], &[0.25, 0.75]);
        // neighbors untouched
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.5, 0.5]);
    }

    #[test]
    fn snapshot_restore() {
        let m = builders::build(&ModelSpec::Path { n: 4 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 0, &[0.9, 0.1]);
        let snap = msgs.snapshot();
        msgs.write_msg(&m, 0, &[0.5, 0.5]);
        msgs.restore(&snap);
        let mut buf = msg_buf();
        msgs.read_msg(&m, 0, &mut buf);
        assert_eq!(&buf[..2], &[0.9, 0.1]);
    }

    #[test]
    fn slice_source_matches_layout() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let msgs = Messages::uniform(&m);
        msgs.write_msg(&m, 2, &[0.3, 0.7]);
        let snap = msgs.snapshot();
        let mut a = msg_buf();
        let mut b = msg_buf();
        for e in 0..m.num_messages() as u32 {
            msgs.read_msg(&m, e, &mut a);
            snap.as_slice().read_msg(&m, e, &mut b);
            assert_eq!(&a[..2], &b[..2]);
        }
    }

    #[test]
    fn cache_line_is_aligned() {
        assert_eq!(std::mem::align_of::<CacheLine>(), 64);
        assert_eq!(std::mem::size_of::<CacheLine>(), 64);
    }

    #[test]
    fn sharded_arenas_behave_like_flat() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        for shards in [1, 2, 7] {
            let p = Partition::contiguous(m.num_messages(), shards);
            let sharded = Messages::uniform_partitioned(&m, &p);
            assert_eq!(sharded.num_shards(), shards.min(m.num_messages()));
            let flat = Messages::uniform(&m);
            assert_eq!(sharded.snapshot(), flat.snapshot(), "shards={shards}");
            // Writes through the shared API land identically.
            sharded.write_msg(&m, 5, &[0.2, 0.8]);
            flat.write_msg(&m, 5, &[0.2, 0.8]);
            assert_eq!(sharded.snapshot(), flat.snapshot(), "shards={shards}");
            let mut a = msg_buf();
            sharded.read_msg(&m, 5, &mut a);
            assert_eq!(&a[..2], &[0.2, 0.8]);
        }
    }

    #[test]
    fn sharded_snapshot_restores_into_flat() {
        let m = builders::build(&ModelSpec::Potts { n: 3, q: 3 }, 2);
        let p = Partition::bfs_edges(&m.graph, 3);
        let sharded = Messages::uniform_partitioned(&m, &p);
        sharded.write_msg(&m, 3, &[0.1, 0.2, 0.7]);
        let flat = Messages::uniform(&m);
        flat.restore(&sharded.snapshot());
        let mut buf = msg_buf();
        flat.read_msg(&m, 3, &mut buf);
        assert_eq!(&buf[..3], &[0.1, 0.2, 0.7]);
    }

    #[test]
    fn uniform_like_mirrors_layout() {
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 1);
        let p = Partition::contiguous(m.num_messages(), 2);
        let live = Messages::uniform_partitioned(&m, &p);
        let shadow = Messages::uniform_like(&m, &live);
        assert_eq!(shadow.num_shards(), live.num_shards());
        assert_eq!(shadow.snapshot(), Messages::uniform(&m).snapshot());
    }
}
