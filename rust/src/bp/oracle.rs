//! Exact marginalization by exhaustive enumeration.
//!
//! The correctness oracle for the whole stack: on models small enough to
//! enumerate (`Π_i |D_i|` bounded), compute exact marginals directly from
//! the MRF's joint distribution
//! `Pr[X = x] ∝ Π_i ψ_i(x_i) · Π_{ij} ψ_ij(x_i, x_j)` and compare against
//! BP's beliefs. On trees, BP is exact at convergence, so the comparison is
//! tight; on loopy graphs the oracle quantifies the loopy-BP approximation
//! error in tests.

use crate::model::Mrf;

/// Exact marginals, or `None` if the state space exceeds `limit`
/// assignments.
pub fn exact_marginals(mrf: &Mrf, limit: u64) -> Option<Vec<Vec<f64>>> {
    let n = mrf.num_nodes();
    // State-space size with overflow care.
    let mut total: u64 = 1;
    for &d in mrf.domain.iter() {
        total = total.checked_mul(d as u64)?;
        if total > limit {
            return None;
        }
    }

    let mut acc: Vec<Vec<f64>> = mrf.domain.iter().map(|&d| vec![0.0; d as usize]).collect();
    let mut assign = vec![0usize; n];
    let mut z = 0.0f64;

    // Precompute undirected edge list (even directed edges).
    let m_undirected = mrf.num_messages() / 2;
    let edges: Vec<(usize, usize, usize)> = (0..m_undirected)
        .map(|k| {
            let e = 2 * k;
            (
                mrf.graph.edge_src[e] as usize,
                mrf.graph.edge_dst[e] as usize,
                e,
            )
        })
        .collect();

    loop {
        // Joint weight of this assignment.
        let mut w = 1.0f64;
        for i in 0..n {
            w *= mrf.node_factors.of(i)[assign[i]];
            if w == 0.0 {
                break;
            }
        }
        if w != 0.0 {
            for &(a, b, e) in &edges {
                w *= mrf.pool.get(mrf.edge_factor[e], assign[a], assign[b]);
                if w == 0.0 {
                    break;
                }
            }
        }
        if w != 0.0 {
            z += w;
            for i in 0..n {
                acc[i][assign[i]] += w;
            }
        }

        // Mixed-radix increment.
        let mut pos = 0;
        loop {
            if pos == n {
                // Done: normalize and return.
                if z > 0.0 {
                    for a in &mut acc {
                        for v in a.iter_mut() {
                            *v /= z;
                        }
                    }
                }
                return Some(acc);
            }
            assign[pos] += 1;
            if assign[pos] < mrf.domain[pos] as usize {
                break;
            }
            assign[pos] = 0;
            pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builders;
    use crate::configio::ModelSpec;

    #[test]
    fn two_node_chain_by_hand() {
        // Path 0-1 with root prior (0.1,0.9) and equality factor: the joint
        // has only two nonzero assignments, (0,0) w=0.1·0.25… — actually
        // with uniform non-root priors: w(0,0)=0.1·0.5, w(1,1)=0.9·0.5.
        let m = builders::build(&ModelSpec::Path { n: 2 }, 1);
        let mg = exact_marginals(&m, 1 << 20).unwrap();
        assert!((mg[0][0] - 0.1).abs() < 1e-12);
        assert!((mg[1][1] - 0.9).abs() < 1e-12);
    }

    #[test]
    fn tree_marginals_all_follow_root() {
        // Equality factors force all nodes to share the root's distribution.
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let mg = exact_marginals(&m, 1 << 20).unwrap();
        for (i, node) in mg.iter().enumerate() {
            assert!((node[0] - 0.1).abs() < 1e-12, "node {i}: {node:?}");
        }
    }

    #[test]
    fn limit_respected() {
        let m = builders::build(&ModelSpec::Tree { n: 40 }, 1);
        assert!(exact_marginals(&m, 1 << 20).is_none());
    }

    #[test]
    fn marginals_normalized_on_loopy_model() {
        let m = builders::build(&ModelSpec::Ising { n: 3 }, 5);
        let mg = exact_marginals(&m, 1 << 20).unwrap();
        for node in &mg {
            let s: f64 = node.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn ldpc_tiny_parity_enforced() {
        // Smallest instance: 6 variables, 3 constraints (may need a couple
        // of seeds for a simple graph). Exact joint must put zero mass on
        // odd-parity constraint-node states, so variable marginals reflect
        // the code structure. State space: 2^6 · 64^3 = 2^24.
        let inst = builders::ldpc::build(6, 0.07, 2);
        let mg = exact_marginals(&inst.mrf, 1 << 25).unwrap();
        for (i, node) in mg.iter().enumerate().take(inst.num_vars) {
            let s: f64 = node.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "node {i}");
        }
    }
}
