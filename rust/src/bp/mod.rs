//! Belief-propagation core: message state, the update rule, the residual
//! lookahead cache, marginal extraction, and the exact enumeration oracle.

pub mod lookahead;
pub mod marginals;
pub mod oracle;
pub mod simd;
pub mod state;
pub mod update;

pub use lookahead::Lookahead;
pub use marginals::{all_marginals, decode_bits, max_marginal_diff, node_marginal};
pub use oracle::exact_marginals;
pub use simd::Kernel;
pub use state::{msg_buf, ArenaMode, Messages, MsgBuf, MsgSource, Precision};
pub use update::{
    compute_message, compute_message_with, fused_node_refresh, incoming_product, normalize,
    residual_l2, residual_linf, MsgScratch, NodeScratch,
};
