//! Residual lookahead cache.
//!
//! Residual BP (Elidan et al. 2006) performs *lookahead*: for every message
//! it keeps the recomputed value `μ'` alongside the live value `μ`, and the
//! priority of the message is `res(μ) = ‖μ' − μ‖₂`. Updating a message
//! commits the precomputed `μ'` and refreshes the pending values of the
//! affected messages (the out-edges of the destination node).
//!
//! The cache mirrors the arena layout of the live [`Messages`] it shadows
//! (flat or sharded — see `bp::state`), so concurrent refreshes are benign
//! races exactly like message writes, and a shard-local worker keeps its
//! pending values as cache-hot as its live ones.
//!
//! The cache is bound to an update [`Kernel`] at construction
//! (`RunConfig::kernel`): with [`Kernel::Scalar`] every refresh runs the
//! historical per-element path bit-for-bit; with [`Kernel::Simd`] the
//! refreshes run the lane-tiled data path with bulk message I/O, and the
//! residual comes out of the kernel itself
//! ([`MsgSource::residual_l2_against`]) instead of a separate
//! read-current-then-`residual_l2` pass.

use super::simd::Kernel;
use super::state::{msg_buf, Messages, MsgSource};
use super::update::{compute_message_with, fused_node_refresh, MsgScratch, NodeScratch};
use crate::model::Mrf;
use crate::util::AtomicF64;

/// Pending (`μ'`) values and residuals for every message.
pub struct Lookahead {
    /// Pending message values, same layout as the live state.
    pending: Messages,
    /// `res(e) = ‖pending[e] − live[e]‖₂`, maintained on refresh/commit.
    residual: Vec<AtomicF64>,
    /// The update kernel every refresh/commit of this cache runs.
    kernel: Kernel,
}

impl Lookahead {
    /// Build the cache: compute `μ'` and the residual for every edge from
    /// the current live state, through the edge-wise kernel. The pending
    /// store adopts `live`'s arena sharding.
    pub fn init(mrf: &Mrf, live: &Messages, kernel: Kernel) -> Self {
        let la = Self::empty(mrf, live, kernel);
        let mut scratch = MsgScratch::new();
        for e in 0..mrf.num_messages() as u32 {
            la.refresh(mrf, live, e, &mut scratch);
        }
        la
    }

    /// [`Lookahead::init`] through the node-centric fused kernel: one
    /// [`Lookahead::refresh_node`] per node covers every directed edge
    /// exactly once (each edge has one source) in O(Σ deg·|D|) total work
    /// instead of O(Σ deg²·|D|). Values agree with [`Lookahead::init`] to
    /// ≤ 1e-12 (product-order rounding only).
    pub fn init_fused(mrf: &Mrf, live: &Messages, kernel: Kernel) -> Self {
        let la = Self::empty(mrf, live, kernel);
        let mut scratch = NodeScratch::new();
        let mut batch = Vec::new();
        for j in 0..mrf.num_nodes() as u32 {
            la.refresh_node(mrf, live, j, None, &mut scratch, &mut batch);
            batch.clear();
        }
        la
    }

    /// Delta-aware re-prime for a warm start: the pending store is cloned
    /// from `live` (so every un-refreshed edge has residual 0 and a
    /// spurious commit is a value-preserving no-op), then only the
    /// out-edges of `nodes` — exactly the messages whose recomputation
    /// reads a perturbed prior `ψ_i` — are re-priced through the edge-wise
    /// kernel. On a converged `live` state this produces the same cache as
    /// a full [`Lookahead::init`] up to the fixed-point tolerance, in
    /// O(Σ_{i∈nodes} deg(i)·deg·|D|) instead of O(edges) work.
    pub fn init_delta(mrf: &Mrf, live: &Messages, kernel: Kernel, nodes: &[u32]) -> Self {
        let la = Self::warm(mrf, live, kernel);
        let mut scratch = MsgScratch::new();
        for &i in nodes {
            for s in mrf.graph.slots(i as usize) {
                la.refresh(mrf, live, mrf.graph.adj_out[s], &mut scratch);
            }
        }
        la
    }

    /// [`Lookahead::init_delta`] through the node-centric fused kernel: one
    /// [`Lookahead::refresh_node`] per perturbed node re-prices its whole
    /// out-set in a single O(deg·|D|) pass.
    pub fn init_delta_fused(mrf: &Mrf, live: &Messages, kernel: Kernel, nodes: &[u32]) -> Self {
        let la = Self::warm(mrf, live, kernel);
        let mut scratch = NodeScratch::new();
        let mut batch = Vec::new();
        for &i in nodes {
            la.refresh_node(mrf, live, i, None, &mut scratch, &mut batch);
            batch.clear();
        }
        la
    }

    /// Pending store primed to equal `live` exactly (same stored bits at
    /// either precision), all residuals zero.
    fn warm(mrf: &Mrf, live: &Messages, kernel: Kernel) -> Self {
        let la = Self::empty(mrf, live, kernel);
        la.pending.restore(&live.snapshot());
        la
    }

    /// Allocate the pending store + residual table (all zero residuals).
    fn empty(mrf: &Mrf, live: &Messages, kernel: Kernel) -> Self {
        let pending = Messages::uniform_like(mrf, live);
        let mut residual = Vec::with_capacity(mrf.num_messages());
        residual.resize_with(mrf.num_messages(), AtomicF64::default);
        Lookahead { pending, residual, kernel }
    }

    /// The update kernel this cache was bound to.
    #[inline]
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Footprint of the pending store as `(logical_bytes, padded_bytes)`
    /// (see [`Messages::arena_bytes`]). The pending arenas mirror the live
    /// state's precision, so a lookahead engine's message memory is the
    /// live bytes plus exactly this.
    pub fn arena_bytes(&self) -> (usize, usize) {
        self.pending.arena_bytes()
    }

    /// Current residual (priority) of edge `e`.
    #[inline]
    pub fn residual(&self, e: u32) -> f64 {
        self.residual[e as usize].load()
    }

    /// Recompute `μ'_e` from the live state through the edge-wise kernel;
    /// store it and its residual. `scratch` is the caller's per-worker
    /// gather buffers (hot loops reuse one; see [`MsgScratch`]). Returns
    /// the new residual.
    pub fn refresh(&self, mrf: &Mrf, live: &Messages, e: u32, scratch: &mut MsgScratch) -> f64 {
        // Binary fast path: 2-wide stack buffers, no 64-wide zeroing
        // (memset was ~12% of baseline cycles; EXPERIMENTS.md §Perf).
        if mrf.msg_len(e) == 2 {
            let mut new = [0.0f64; 2];
            compute_message_with(mrf, live, e, &mut new, scratch, self.kernel);
            let res = live.residual_l2_against(mrf, e, &new, self.kernel);
            self.pending.write_msg(mrf, e, &new);
            self.residual[e as usize].store(res);
            return res;
        }
        let mut new = msg_buf();
        let len = compute_message_with(mrf, live, e, &mut new, scratch, self.kernel);
        let res = live.residual_l2_against(mrf, e, &new[..len], self.kernel);
        match self.kernel {
            Kernel::Scalar => self.pending.write_msg(mrf, e, &new[..len]),
            Kernel::Simd => self.pending.write_msg_bulk(mrf, e, &new[..len]),
        }
        self.residual[e as usize].store(res);
        res
    }

    /// Node-centric fused refresh: recompute the pending value and
    /// residual of every out-edge of `j` except `skip` (typically the
    /// reverse of a just-committed edge `(i→j)`, whose pending value
    /// excludes the changed input and therefore cannot have moved) in one
    /// O(deg·|D|) pass via [`fused_node_refresh`] — the O(deg) replacement
    /// for calling [`Lookahead::refresh`] per affected edge, which costs
    /// O(deg²) per node touch. The residual of each refreshed edge comes
    /// out of the kernel itself (no second pass over the live value).
    /// Appends one `(edge, residual)` pair per refreshed edge to `out` for
    /// the caller to requeue.
    pub fn refresh_node(
        &self,
        mrf: &Mrf,
        live: &Messages,
        j: u32,
        skip: Option<u32>,
        scratch: &mut NodeScratch,
        out: &mut Vec<(u32, f64)>,
    ) {
        let kernel = self.kernel;
        fused_node_refresh(mrf, live, j, skip, scratch, kernel, |e, vals, res| {
            match kernel {
                Kernel::Scalar => self.pending.write_msg(mrf, e, vals),
                Kernel::Simd => self.pending.write_msg_bulk(mrf, e, vals),
            }
            self.residual[e as usize].store(res);
            out.push((e, res));
        });
    }

    /// Commit `μ'_e` into the live state and zero `res(e)`. Returns the
    /// residual that was satisfied (0 if the edge was already converged —
    /// a *wasted* update in the paper's terminology).
    ///
    /// The caller is responsible for refreshing the affected out-edges of
    /// `dst(e)` afterwards (see [`Lookahead::affected_edges`]).
    pub fn commit(&self, mrf: &Mrf, live: &Messages, e: u32) -> f64 {
        let res = self.residual[e as usize].load();
        if mrf.msg_len(e) == 2 {
            let mut val = [0.0f64; 2];
            self.pending.read_msg(mrf, e, &mut val);
            live.write_msg(mrf, e, &val);
        } else {
            let mut val = msg_buf();
            match self.kernel {
                Kernel::Scalar => {
                    let len = self.pending.read_msg(mrf, e, &mut val);
                    live.write_msg(mrf, e, &val[..len]);
                }
                Kernel::Simd => {
                    let len = self.pending.read_msg_bulk(mrf, e, &mut val);
                    live.write_msg_bulk(mrf, e, &val[..len]);
                }
            }
        }
        self.residual[e as usize].store(0.0);
        res
    }

    /// The edges whose pending value changes when `e = (i→j)` is committed:
    /// every out-edge of `j` except the reverse `j→i`.
    #[inline]
    pub fn affected_edges<'a>(&self, mrf: &'a Mrf, e: u32) -> impl Iterator<Item = u32> + 'a {
        let j = mrf.graph.edge_dst[e as usize] as usize;
        let rev = mrf.graph.reverse(e);
        mrf.graph
            .slots(j)
            .map(move |s| mrf.graph.adj_out[s])
            .filter(move |&k| k != rev)
    }

    /// Max residual over all edges (sequential convergence check).
    pub fn max_residual(&self) -> f64 {
        self.residual.iter().map(|r| r.load()).fold(0.0, f64::max)
    }

    /// Read pending value of edge `e` into `out`; returns length.
    pub fn read_pending(&self, mrf: &Mrf, e: u32, out: &mut [f64]) -> usize {
        self.pending.read_msg(mrf, e, out)
    }

    /// Directly overwrite the pending value + residual of edge `e`
    /// (used by the PJRT batched path, which computes updates externally).
    pub fn store_pending(&self, mrf: &Mrf, e: u32, vals: &[f64], res: f64) {
        self.pending.write_msg(mrf, e, vals);
        self.residual[e as usize].store(res);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::builders;
    use crate::configio::ModelSpec;

    #[test]
    fn init_residuals_nonzero_only_at_root() {
        // Tree model: only the root's outgoing messages have information to
        // push (priors elsewhere are uniform and factors are equality).
        let m = builders::build(&ModelSpec::Tree { n: 15 }, 1);
        let live = Messages::uniform(&m);
        let la = Lookahead::init(&m, &live, Kernel::Scalar);
        for e in 0..m.num_messages() as u32 {
            let src = m.graph.edge_src[e as usize];
            let res = la.residual(e);
            if src == 0 {
                assert!(res > 0.1, "root out-edge {e} res={res}");
            } else {
                assert!(res < 1e-12, "edge {e} res={res}");
            }
        }
    }

    #[test]
    fn commit_zeroes_residual_and_updates_live() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let live = Messages::uniform(&m);
        let la = Lookahead::init(&m, &live, Kernel::Scalar);
        assert!(la.residual(0) > 0.0);
        let res = la.commit(&m, &live, 0);
        assert!(res > 0.0);
        assert_eq!(la.residual(0), 0.0);
        let mut buf = msg_buf();
        live.read_msg(&m, 0, &mut buf);
        assert!((buf[0] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn affected_edges_excludes_reverse() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let live = Messages::uniform(&m);
        let la = Lookahead::init(&m, &live, Kernel::Simd);
        // Edge 0 is root→1. Affected edges are 1's out-edges except 1→root.
        let e = 0u32;
        let j = m.graph.edge_dst[0] as usize;
        let affected: Vec<u32> = la.affected_edges(&m, e).collect();
        assert_eq!(affected.len(), m.graph.degree(j) - 1);
        for &k in &affected {
            assert_eq!(m.graph.edge_src[k as usize] as usize, j);
            assert_ne!(k, m.graph.reverse(e));
        }
    }

    #[test]
    fn propagation_chain() {
        // Commit root's edge, refresh affected, check the frontier advanced.
        let m = builders::build(&ModelSpec::Path { n: 4 }, 1);
        let live = Messages::uniform(&m);
        let la = Lookahead::init(&m, &live, Kernel::Scalar);
        let mut scratch = MsgScratch::new();
        let frontier: Vec<u32> = (0..m.num_messages() as u32)
            .filter(|&e| la.residual(e) > 1e-9)
            .collect();
        assert_eq!(frontier, vec![0]); // only root's out-edge
        la.commit(&m, &live, 0);
        let affected: Vec<u32> = la.affected_edges(&m, 0).collect();
        for &k in &affected {
            la.refresh(&m, &live, k, &mut scratch);
        }
        let frontier2: Vec<u32> = (0..m.num_messages() as u32)
            .filter(|&e| la.residual(e) > 1e-9)
            .collect();
        assert_eq!(frontier2, affected); // moved one hop down the path
    }

    #[test]
    fn max_residual_decreases_on_tree() {
        let m = builders::build(&ModelSpec::Tree { n: 7 }, 1);
        let live = Messages::uniform(&m);
        let la = Lookahead::init(&m, &live, Kernel::Scalar);
        let mut scratch = MsgScratch::new();
        // Run sequential residual to convergence by always committing max.
        let mut steps = 0;
        while la.max_residual() > 1e-9 {
            let e = (0..m.num_messages() as u32)
                .max_by(|&a, &b| la.residual(a).partial_cmp(&la.residual(b)).unwrap())
                .unwrap();
            la.commit(&m, &live, e);
            let affected: Vec<u32> = la.affected_edges(&m, e).collect();
            for &k in &affected {
                la.refresh(&m, &live, k, &mut scratch);
            }
            steps += 1;
            assert!(steps < 100, "should converge quickly");
        }
        // Tree with root evidence: exactly the 6 away-from-root edges fire.
        assert_eq!(steps, 6);
    }

    #[test]
    fn init_fused_matches_edgewise_init() {
        for spec in [
            ModelSpec::Tree { n: 31 },
            ModelSpec::Ising { n: 4 },
            ModelSpec::Ldpc { n: 24, flip_prob: 0.07 },
            ModelSpec::PowerLaw { n: 60, m: 3 },
        ] {
            for kernel in [Kernel::Scalar, Kernel::Simd] {
                let m = builders::build(&spec, 9);
                let live = Messages::uniform(&m);
                let a = Lookahead::init(&m, &live, kernel);
                let b = Lookahead::init_fused(&m, &live, kernel);
                let mut pa = msg_buf();
                let mut pb = msg_buf();
                for e in 0..m.num_messages() as u32 {
                    assert!(
                        (a.residual(e) - b.residual(e)).abs() <= 1e-12,
                        "{spec:?} {kernel:?} edge {e} residual"
                    );
                    let la = a.read_pending(&m, e, &mut pa);
                    let lb = b.read_pending(&m, e, &mut pb);
                    assert_eq!(la, lb);
                    for x in 0..la {
                        assert!(
                            (pa[x] - pb[x]).abs() <= 1e-12,
                            "{spec:?} {kernel:?} edge {e} x={x}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn refresh_node_matches_per_edge_refresh() {
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let live = Messages::uniform(&m);
        let a = Lookahead::init(&m, &live, Kernel::Scalar);
        let b = Lookahead::init(&m, &live, Kernel::Scalar);
        let mut scratch = MsgScratch::new();
        // Commit one edge on both, then refresh its destination's out-set
        // per-edge on `a` and fused on `b`.
        let e = 0u32;
        a.commit(&m, &live, e);
        // b shares `live`, so committing again writes the same value.
        b.commit(&m, &live, e);
        for k in a.affected_edges(&m, e) {
            a.refresh(&m, &live, k, &mut scratch);
        }
        let j = m.graph.edge_dst[e as usize];
        let mut sc = NodeScratch::new();
        let mut batch = Vec::new();
        b.refresh_node(&m, &live, j, Some(m.graph.reverse(e)), &mut sc, &mut batch);
        assert_eq!(batch.len(), m.graph.degree(j as usize) - 1);
        for &(k, r) in &batch {
            assert!((a.residual(k) - r).abs() <= 1e-12, "edge {k}");
            assert!((b.residual(k) - r).abs() <= 1e-12, "edge {k} stored");
        }
    }

    #[test]
    fn store_pending_roundtrip() {
        let m = builders::build(&ModelSpec::Path { n: 3 }, 1);
        let live = Messages::uniform(&m);
        let la = Lookahead::init(&m, &live, Kernel::Simd);
        la.store_pending(&m, 1, &[0.4, 0.6], 0.123);
        assert_eq!(la.residual(1), 0.123);
        let mut buf = msg_buf();
        la.read_pending(&m, 1, &mut buf);
        assert_eq!(&buf[..2], &[0.4, 0.6]);
    }

    #[test]
    fn init_delta_over_all_nodes_matches_fresh_init_bitwise() {
        // The delta re-prime runs the same refresh kernels as a full init,
        // so handing it every node must reproduce the fresh cache exactly
        // (same bits), for both the edge-wise and the fused constructor.
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let m = builders::build(&ModelSpec::PowerLaw { n: 60, m: 3 }, 9);
            let live = Messages::uniform(&m);
            // Make the live state non-trivial first.
            let warm = Lookahead::init(&m, &live, kernel);
            for e in 0..8 {
                warm.commit(&m, &live, e);
            }
            let all: Vec<u32> = (0..m.num_nodes() as u32).collect();
            for (fresh, cache) in [
                (Lookahead::init(&m, &live, kernel), Lookahead::init_delta(&m, &live, kernel, &all)),
                (
                    Lookahead::init_fused(&m, &live, kernel),
                    Lookahead::init_delta_fused(&m, &live, kernel, &all),
                ),
            ] {
                let mut pa = msg_buf();
                let mut pb = msg_buf();
                for e in 0..m.num_messages() as u32 {
                    assert_eq!(
                        fresh.residual(e).to_bits(),
                        cache.residual(e).to_bits(),
                        "{kernel:?} edge {e} residual"
                    );
                    let la = fresh.read_pending(&m, e, &mut pa);
                    let lb = cache.read_pending(&m, e, &mut pb);
                    assert_eq!(la, lb);
                    for x in 0..la {
                        assert_eq!(pa[x].to_bits(), pb[x].to_bits(), "{kernel:?} edge {e} x={x}");
                    }
                }
            }
        }
    }

    #[test]
    fn init_delta_refreshes_only_the_perturbed_out_set() {
        // Subset re-prime: out-edges of the named nodes carry exactly the
        // fresh-init values; every other edge keeps pending == live
        // (residual 0), so a spurious commit of it is a no-op.
        let m = builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let live = Messages::uniform(&m);
        let warm = Lookahead::init(&m, &live, Kernel::Scalar);
        for e in 0..6 {
            warm.commit(&m, &live, e);
        }
        let nodes = [2u32, 5, 11];
        let fresh = Lookahead::init(&m, &live, Kernel::Scalar);
        let cache = Lookahead::init_delta(&m, &live, Kernel::Scalar, &nodes);
        let mut pa = msg_buf();
        let mut pb = msg_buf();
        for e in 0..m.num_messages() as u32 {
            let src = m.graph.edge_src[e as usize];
            if nodes.contains(&src) {
                assert_eq!(fresh.residual(e).to_bits(), cache.residual(e).to_bits(), "edge {e}");
                let la = fresh.read_pending(&m, e, &mut pa);
                let lb = cache.read_pending(&m, e, &mut pb);
                assert_eq!(la, lb);
                for x in 0..la {
                    assert_eq!(pa[x].to_bits(), pb[x].to_bits(), "edge {e} x={x}");
                }
            } else {
                assert_eq!(cache.residual(e), 0.0, "edge {e} outside the out-set");
                let lb = cache.read_pending(&m, e, &mut pb);
                live.read_msg(&m, e, &mut pa);
                for x in 0..lb {
                    assert_eq!(pa[x].to_bits(), pb[x].to_bits(), "edge {e} pending != live");
                }
            }
        }
    }

    #[test]
    fn scalar_and_simd_caches_agree() {
        let inst = builders::ldpc::build(24, 0.07, 4);
        let m = &inst.mrf;
        let live = Messages::uniform(m);
        let a = Lookahead::init_fused(m, &live, Kernel::Scalar);
        let b = Lookahead::init_fused(m, &live, Kernel::Simd);
        let mut pa = msg_buf();
        let mut pb = msg_buf();
        for e in 0..m.num_messages() as u32 {
            assert!((a.residual(e) - b.residual(e)).abs() <= 1e-12, "edge {e}");
            let la = a.read_pending(m, e, &mut pa);
            let lb = b.read_pending(m, e, &mut pb);
            assert_eq!(la, lb);
            for x in 0..la {
                assert!((pa[x] - pb[x]).abs() <= 1e-12, "edge {e} x={x}");
            }
        }
    }
}
