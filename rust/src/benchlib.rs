//! Lightweight benchmark driver for the `cargo bench` targets.
//!
//! criterion is unavailable in the offline build, so the `cargo bench`
//! targets (`rust/benches/*.rs`, built with `harness = false`) use this
//! module: warmup, repeated measurement, robust statistics, and three
//! reporters — markdown (human), CSV (spreadsheets), and JSON (the
//! canonical machine-readable form, mirroring the `BENCH_*.json`
//! philosophy of the `telemetry` module: diffable artifacts, not
//! write-only tables). End-to-end BP convergence runs are seconds long, so
//! the driver measures a configurable number of full runs rather than
//! criterion's adaptive sampling.
//!
//! Full {engine × scheduler × threads} sweeps with convergence traces and
//! regression comparison live in `telemetry::run_bench` (the `bench` CLI
//! subcommand); this module stays the low-level component driver.

use crate::configio::Json;
use crate::util::stats::{fmt_duration, Summary};
use std::io::Write;
use std::time::Instant;

/// One measured benchmark: a label, the sample of wall-clock times, and an
/// optional scalar "metric" stream (e.g. message updates) recorded per run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label within its group.
    pub name: String,
    /// Per-sample wall-clock seconds.
    pub times_secs: Vec<f64>,
    /// Per-sample scalar metric (benchmark-defined; e.g. ops performed).
    pub metrics: Vec<f64>,
}

impl BenchResult {
    /// Robust summary of the wall-clock samples.
    pub fn time_summary(&self) -> Option<Summary> {
        Summary::of(&self.times_secs)
    }

    /// Robust summary of the metric samples.
    pub fn metric_summary(&self) -> Option<Summary> {
        Summary::of(&self.metrics)
    }

    /// Serialize samples + derived summaries as JSON.
    pub fn to_json(&self) -> Json {
        let summary = |s: Option<Summary>| s.map_or(Json::Null, |s| s.to_json());
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("times_secs", Json::Arr(self.times_secs.iter().map(|&t| Json::Num(t)).collect())),
            ("metrics", Json::Arr(self.metrics.iter().map(|&m| Json::Num(m)).collect())),
            ("time_summary", summary(self.time_summary())),
            ("metric_summary", summary(self.metric_summary())),
        ])
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup runs discarded from statistics.
    pub warmup: usize,
    /// Measured runs.
    pub samples: usize,
    /// Hard per-benchmark wall-clock budget in seconds: once exceeded, stop
    /// sampling early (at least one sample is always taken).
    pub budget_secs: f64,
    /// Print progress to stderr.
    pub verbose: bool,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // CLI override hooks: RBP_BENCH_SAMPLES / RBP_BENCH_BUDGET.
        let samples = std::env::var("RBP_BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3);
        let budget_secs = std::env::var("RBP_BENCH_BUDGET")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(60.0);
        BenchConfig { warmup: 1, samples, budget_secs, verbose: true }
    }
}

/// A group of related benchmarks rendered as one table (≈ criterion group).
pub struct BenchGroup {
    /// Group title (markdown heading / output file stem).
    pub title: String,
    /// Runner configuration shared by the group's benchmarks.
    pub config: BenchConfig,
    /// Completed measurements, in registration order.
    pub results: Vec<BenchResult>,
}

impl BenchGroup {
    /// Empty group with the default [`BenchConfig`].
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), config: BenchConfig::default(), results: Vec::new() }
    }

    /// Replace the runner configuration.
    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Run `f` repeatedly; `f` returns an optional scalar metric for the run
    /// (e.g. number of message updates).
    pub fn bench<F: FnMut() -> f64>(&mut self, name: &str, mut f: F) {
        if self.config.verbose {
            eprintln!("[bench] {} / {name}", self.title);
        }
        let started = Instant::now();
        for _ in 0..self.config.warmup {
            let _ = f();
            if started.elapsed().as_secs_f64() > self.config.budget_secs {
                break;
            }
        }
        let mut times = Vec::with_capacity(self.config.samples);
        let mut metrics = Vec::with_capacity(self.config.samples);
        for i in 0..self.config.samples {
            let t0 = Instant::now();
            let m = f();
            times.push(t0.elapsed().as_secs_f64());
            metrics.push(m);
            if i + 1 < self.config.samples
                && started.elapsed().as_secs_f64() > self.config.budget_secs
            {
                if self.config.verbose {
                    eprintln!("[bench]   budget exceeded after {} samples", i + 1);
                }
                break;
            }
        }
        self.results.push(BenchResult { name: name.to_string(), times_secs: times, metrics });
    }

    /// Render the group as a markdown table.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("### {}\n\n", self.title);
        s.push_str("| benchmark | samples | mean time | stddev | min | max | metric (mean) |\n");
        s.push_str("|---|---|---|---|---|---|---|\n");
        for r in &self.results {
            if let Some(t) = r.time_summary() {
                let metric = r
                    .metric_summary()
                    .map(|m| format!("{:.1}", m.mean))
                    .unwrap_or_else(|| "-".into());
                s.push_str(&format!(
                    "| {} | {} | {} | {} | {} | {} | {} |\n",
                    r.name,
                    t.n,
                    fmt_duration(t.mean),
                    fmt_duration(t.stddev),
                    fmt_duration(t.min),
                    fmt_duration(t.max),
                    metric
                ));
            }
        }
        s
    }

    /// Render as CSV rows: `group,name,sample_idx,time_secs,metric`.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("group,name,sample,time_secs,metric\n");
        for r in &self.results {
            for (i, (t, m)) in r.times_secs.iter().zip(&r.metrics).enumerate() {
                s.push_str(&format!("{},{},{},{},{}\n", self.title, r.name, i, t, m));
            }
        }
        s
    }

    /// Render the group as a JSON document (the canonical machine-readable
    /// reporter; keys are sorted, so outputs diff deterministically).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "config",
                Json::obj(vec![
                    ("warmup", Json::Num(self.config.warmup as f64)),
                    ("samples", Json::Num(self.config.samples as f64)),
                    ("budget_secs", Json::Num(self.config.budget_secs)),
                ]),
            ),
            ("results", Json::Arr(self.results.iter().map(BenchResult::to_json).collect())),
        ])
    }

    /// Print markdown to stdout and write CSV + JSON under
    /// `results/bench/`.
    pub fn report(&self) {
        println!("{}", self.to_markdown());
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_ok() {
            let stem = sanitize(&self.title);
            let path = dir.join(format!("{stem}.csv"));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
            }
            let path = dir.join(format!("{stem}.json"));
            if let Ok(mut f) = std::fs::File::create(&path) {
                let _ = f.write_all(self.to_json().to_string_pretty().as_bytes());
            }
        }
    }
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(samples: usize) -> BenchConfig {
        BenchConfig { warmup: 0, samples, budget_secs: 10.0, verbose: false }
    }

    #[test]
    fn bench_records_samples() {
        let mut g = BenchGroup::new("t").with_config(quiet(4));
        let mut calls = 0;
        g.bench("noop", || {
            calls += 1;
            calls as f64
        });
        assert_eq!(calls, 4);
        assert_eq!(g.results[0].times_secs.len(), 4);
        assert_eq!(g.results[0].metrics, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn budget_cuts_sampling() {
        let cfg = BenchConfig { warmup: 0, samples: 100, budget_secs: 0.05, verbose: false };
        let mut g = BenchGroup::new("t").with_config(cfg);
        g.bench("sleep", || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            0.0
        });
        assert!(g.results[0].times_secs.len() < 100);
        assert!(!g.results[0].times_secs.is_empty());
    }

    #[test]
    fn markdown_contains_rows() {
        let mut g = BenchGroup::new("grp").with_config(quiet(2));
        g.bench("a", || 1.0);
        let md = g.to_markdown();
        assert!(md.contains("### grp"));
        assert!(md.contains("| a |"));
    }

    #[test]
    fn csv_shape() {
        let mut g = BenchGroup::new("grp").with_config(quiet(2));
        g.bench("a", || 1.0);
        let csv = g.to_csv();
        assert_eq!(csv.lines().count(), 3); // header + 2 samples
        assert!(csv.starts_with("group,name,sample"));
    }

    #[test]
    fn json_reporter_roundtrips() {
        let mut g = BenchGroup::new("grp").with_config(quiet(2));
        g.bench("a", || 7.0);
        let text = g.to_json().to_string_pretty();
        let v = crate::configio::parse(&text).unwrap();
        assert_eq!(v.get("title").unwrap().as_str(), Some("grp"));
        let results = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("metrics").unwrap().as_arr().unwrap().len(), 2);
        assert!(results[0].get("time_summary").unwrap().get("mean").is_some());
    }

    #[test]
    fn sanitize_path_chars() {
        assert_eq!(sanitize("Table 1 / speedups"), "Table_1___speedups");
    }
}
