//! Atomic floating-point cells and cache-padded wrappers.
//!
//! The concurrent BP engines share the message state between worker threads
//! with *benign races*, exactly like the paper's Java implementation (plain
//! volatile arrays): a reader may observe a message vector mid-update. BP
//! tolerates this — the algorithm converges to the same fixed point — but
//! Rust requires that such shared mutation go through atomics. [`AtomicF64`]
//! provides relaxed-ordering f64 loads/stores via bit-casting to `u64`;
//! [`AtomicF32`] is the same discipline over `u32` for the reduced-precision
//! message arenas (`RunConfig::precision`).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// An `f64` cell that can be read and written concurrently.
///
/// All operations use `Relaxed` ordering: BP message updates are idempotent
/// re-normalizations and the engines do not rely on cross-cell ordering for
/// correctness (only the scheduler's claim flags synchronize).
#[derive(Debug)]
pub struct AtomicF64 {
    bits: AtomicU64,
}

impl AtomicF64 {
    #[inline]
    /// Cell holding `v`.
    pub fn new(v: f64) -> Self {
        Self { bits: AtomicU64::new(v.to_bits()) }
    }

    #[inline]
    /// Relaxed load.
    pub fn load(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    #[inline]
    /// Relaxed store.
    pub fn store(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Atomically add `v`; returns the previous value. Used by the
    /// no-lookahead engine's accumulated-change scores.
    pub fn fetch_add(&self, v: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cur_f = f64::from_bits(cur);
            match self.bits.compare_exchange_weak(
                cur,
                (cur_f + v).to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return cur_f,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Atomically set to `min(self, v)`; returns the previous value.
    pub fn fetch_min(&self, v: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cur_f = f64::from_bits(cur);
            if v >= cur_f {
                return cur_f;
            }
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return cur_f,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Atomically set to `max(self, v)`; returns the previous value.
    /// Used by convergence tracking (max residual seen this epoch).
    pub fn fetch_max(&self, v: f64) -> f64 {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let cur_f = f64::from_bits(cur);
            if v <= cur_f {
                return cur_f;
            }
            match self.bits.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return cur_f,
                Err(actual) => cur = actual,
            }
        }
    }
}

impl Default for AtomicF64 {
    fn default() -> Self {
        Self::new(0.0)
    }
}

impl Clone for AtomicF64 {
    fn clone(&self) -> Self {
        Self::new(self.load())
    }
}

/// An `f32` cell that can be read and written concurrently.
///
/// The storage half of the precision axis (`RunConfig::precision`): message
/// arenas hold these when a run stores messages in single precision, so a
/// 64-byte cache line carries 16 cells instead of 8. Same relaxed-ordering
/// benign-race discipline as [`AtomicF64`]; compute stays f64 in registers,
/// so this cell intentionally has no arithmetic RMW helpers — values are
/// rounded once on store and widened on load.
#[derive(Debug)]
pub struct AtomicF32 {
    bits: AtomicU32,
}

impl AtomicF32 {
    #[inline]
    /// Cell holding `v`.
    pub fn new(v: f32) -> Self {
        Self { bits: AtomicU32::new(v.to_bits()) }
    }

    #[inline]
    /// Relaxed load.
    pub fn load(&self) -> f32 {
        f32::from_bits(self.bits.load(Ordering::Relaxed))
    }

    #[inline]
    /// Relaxed store.
    pub fn store(&self, v: f32) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }
}

impl Default for AtomicF32 {
    fn default() -> Self {
        Self::new(0.0)
    }
}

impl Clone for AtomicF32 {
    fn clone(&self) -> Self {
        Self::new(self.load())
    }
}

/// Pad-to-cache-line wrapper to avoid false sharing on hot per-thread
/// counters. 128 bytes covers adjacent-line prefetching on x86.
#[repr(align(128))]
#[derive(Debug, Default)]
pub struct CachePadded<T>(pub T);

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn roundtrip() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(), 1.5);
        a.store(-0.25);
        assert_eq!(a.load(), -0.25);
    }

    #[test]
    fn special_values() {
        let a = AtomicF64::new(f64::NAN);
        assert!(a.load().is_nan());
        a.store(f64::INFINITY);
        assert_eq!(a.load(), f64::INFINITY);
        a.store(0.0);
        assert_eq!(a.load(), 0.0);
        a.store(-0.0);
        assert_eq!(a.load().to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn fetch_max_monotone() {
        let a = AtomicF64::new(0.0);
        assert_eq!(a.fetch_max(1.0), 0.0);
        assert_eq!(a.fetch_max(0.5), 1.0);
        assert_eq!(a.load(), 1.0);
        a.fetch_max(2.0);
        assert_eq!(a.load(), 2.0);
    }

    #[test]
    fn fetch_add_accumulates() {
        let a = AtomicF64::new(1.0);
        assert_eq!(a.fetch_add(0.5), 1.0);
        assert_eq!(a.load(), 1.5);
        a.fetch_add(-2.0);
        assert_eq!(a.load(), -0.5);
    }

    #[test]
    fn fetch_add_concurrent_sums() {
        let a = Arc::new(AtomicF64::new(0.0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for _ in 0..1000 {
                        a.fetch_add(1.0);
                    }
                });
            }
        });
        assert_eq!(a.load(), 4000.0);
    }

    #[test]
    fn fetch_min_monotone() {
        let a = AtomicF64::new(5.0);
        assert_eq!(a.fetch_min(3.0), 5.0);
        assert_eq!(a.fetch_min(4.0), 3.0);
        assert_eq!(a.load(), 3.0);
    }

    #[test]
    fn fetch_max_concurrent() {
        let a = Arc::new(AtomicF64::new(0.0));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let a = Arc::clone(&a);
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        a.fetch_max((t * 1000 + i) as f64);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(a.load(), 3999.0);
    }

    #[test]
    fn f32_roundtrip_and_special_values() {
        let a = AtomicF32::new(1.5);
        assert_eq!(a.load(), 1.5);
        a.store(-0.25);
        assert_eq!(a.load(), -0.25);
        a.store(f32::NAN);
        assert!(a.load().is_nan());
        a.store(0.0);
        assert_eq!(a.load(), 0.0);
        a.store(-0.0);
        assert_eq!(a.load().to_bits(), (-0.0f32).to_bits());
        assert_eq!(AtomicF32::default().load(), 0.0);
        assert_eq!(std::mem::size_of::<AtomicF32>(), 4);
    }

    #[test]
    fn f32_concurrent_stores_never_tear() {
        // Every observed value must be one of the stored bit patterns.
        let a = Arc::new(AtomicF32::new(1.0));
        std::thread::scope(|s| {
            for t in 0..2 {
                let a = Arc::clone(&a);
                s.spawn(move || {
                    for _ in 0..1000 {
                        a.store(if t == 0 { 1.0 } else { 2.0 });
                    }
                });
            }
            let a = Arc::clone(&a);
            s.spawn(move || {
                for _ in 0..1000 {
                    let v = a.load();
                    assert!(v == 1.0 || v == 2.0);
                }
            });
        });
    }

    #[test]
    fn cache_padded_alignment() {
        assert!(std::mem::align_of::<CachePadded<u64>>() >= 128);
        let c = CachePadded(5u64);
        assert_eq!(*c, 5);
    }
}
