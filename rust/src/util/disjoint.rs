//! Shared-slice writer for provably disjoint parallel writes.
//!
//! The deterministic parallel counting sort in [`crate::model::graph`] and
//! the parallel arena initialization in [`crate::bp`] both partition an
//! output slice by *value-dependent* indices (a node's adjacency slots, an
//! edge's shard), so the compiler cannot see that concurrent writers touch
//! disjoint elements. [`DisjointWriter`] is the one narrow escape hatch:
//! it shares a `&mut [T]` across scoped threads and exposes an `unsafe`
//! per-index write whose safety contract is exactly "no two threads write
//! the same index, and nobody reads until the threads join".

use std::cell::UnsafeCell;

/// A shared view of a mutable slice allowing concurrent writes from many
/// threads, provided the caller's partitioning guarantees every index is
/// written by at most one thread.
///
/// The borrow of the underlying slice keeps ordinary readers out for the
/// writer's lifetime; reads through the writer itself are not offered, so
/// the only aliasing to reason about is write/write disjointness.
pub struct DisjointWriter<'a, T> {
    cells: &'a [UnsafeCell<T>],
}

// SAFETY: `DisjointWriter` only allows writes, and `write`'s contract
// requires callers to keep concurrently-written indices disjoint, so
// sharing the view across threads cannot create a data race that the
// contract doesn't already forbid.
unsafe impl<T: Send + Sync> Sync for DisjointWriter<'_, T> {}

impl<'a, T> DisjointWriter<'a, T> {
    /// Wrap a mutable slice for disjoint parallel writing. The slice is
    /// exclusively borrowed for the writer's lifetime.
    pub fn new(slice: &'a mut [T]) -> Self {
        let len = slice.len();
        let ptr = slice.as_mut_ptr().cast::<UnsafeCell<T>>();
        // SAFETY: `UnsafeCell<T>` is `repr(transparent)` over `T`, and we
        // hold the unique borrow of the slice, so reinterpreting it as a
        // slice of cells of the same length is sound.
        let cells = unsafe { std::slice::from_raw_parts(ptr, len) };
        Self { cells }
    }

    /// Number of elements in the wrapped slice.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True when the wrapped slice is empty.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Write `v` into element `i`.
    ///
    /// # Safety
    ///
    /// No other thread may write index `i` concurrently, and no element
    /// may be read through any alias until all writing threads have been
    /// joined. Bounds are still checked (out-of-range panics).
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        // SAFETY: per the contract above, this thread is the only writer
        // of index `i` while the scope is live.
        unsafe { *self.cells[i].get() = v };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_parallel_writes_land() {
        let mut out = vec![0u32; 1024];
        let w = DisjointWriter::new(&mut out);
        assert_eq!(w.len(), 1024);
        assert!(!w.is_empty());
        std::thread::scope(|s| {
            for t in 0..4usize {
                let w = &w;
                s.spawn(move || {
                    for i in (t..1024).step_by(4) {
                        // SAFETY: threads write strided, disjoint indices.
                        unsafe { w.write(i, i as u32) };
                    }
                });
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u32));
    }
}
