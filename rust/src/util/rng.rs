//! Deterministic pseudo-random number generation.
//!
//! No `rand` crate is available in the offline build environment, so we ship
//! our own small PRNG stack: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256**) as the workhorse generator. Both are well-studied, fast,
//! and adequate for workload generation and randomized scheduling decisions
//! (the paper's Multiqueue only needs cheap uniform choices; it does not need
//! cryptographic randomness).
//!
//! All randomness in the library flows through explicit seeds so that
//! sequential runs are bit-reproducible and tests are deterministic.

/// SplitMix64: used to expand a single `u64` seed into a full xoshiro state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014); this is the variant recommended by the xoshiro
/// authors for state initialization.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from an arbitrary seed (any value is fine,
    /// including 0).
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the general-purpose generator used throughout the
/// library. Period 2^256 − 1; passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (the canonical initialization).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    /// Derive an independent stream for a worker thread: equivalent to
    /// re-seeding with a hash of `(seed, stream)`. Cheaper and simpler than
    /// jump polynomials, and collision-safe for the stream counts we use.
    pub fn stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0x9E3779B97F4A7C15));
        // burn a few values so that nearby (seed, stream) pairs decorrelate
        sm.next_u64();
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` using Lemire's nearly-divisionless method.
    /// Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection-free for our purposes: 128-bit multiply-shift. The bias
        // is ≤ n / 2^64, negligible for scheduling / workload generation.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform `usize` index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm for small k, full shuffle otherwise.
        if k * 4 < n {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.index(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            self.shuffle(&mut out);
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 1234567 from the public-domain C impl.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_streams_differ() {
        let mut a = Xoshiro256::stream(42, 0);
        let mut b = Xoshiro256::stream(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_close_to_half() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn uniform_respects_bounds() {
        let mut r = Xoshiro256::seed_from_u64(5);
        for _ in 0..10_000 {
            let x = r.uniform(-2.5, 2.5);
            assert!((-2.5..2.5).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from_u64(13);
        for &(n, k) in &[(100usize, 5usize), (100, 50), (10, 10), (1000, 3)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.07)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.07).abs() < 0.005, "rate={rate}");
    }
}
