//! Minimal raw-`mmap` wrapper for the out-of-core paths (zero-copy model
//! loads, file-backed message arenas).
//!
//! The offline build has no `libc` crate, so the two syscall entry points
//! we need (`mmap`/`munmap`, plus `ftruncate` for sizing arena temp
//! files) are declared by hand with their Linux/unix ABI constants. Both
//! wrappers are `#[cfg(unix)]`; on other platforms the constructors
//! return a clean error and callers fall back to the owned/heap paths.
//!
//! Two mapping flavors:
//!
//! - [`Mmap`]: a shared read-only mapping of a whole file — the zero-copy
//!   model-load path borrows typed sections straight out of it.
//! - [`MmapMut`]: a shared read-write mapping of an *unlinked* temp file —
//!   the file-backed arena path writes message cells through it. The file
//!   is unlinked immediately after creation, so the mapping is the only
//!   live reference and the kernel reclaims the blocks when the mapping
//!   drops (including on crash), with no cleanup pass needed.
//!
//! Safety argument (shared by both): a mapping is only constructed over
//! `len > 0` bytes the kernel accepted (`mmap` returning `MAP_FAILED` is
//! an error), the pointer is page-aligned by the mmap contract (4096 ⊇
//! the 64-byte alignment every caller needs), and the backing memory
//! stays valid until `Drop` runs `munmap`. Callers that reinterpret
//! bytes as `u32`/`f64` validate length-divisibility and offset
//! alignment *before* the cast; see `model::io` and `bp::state`.

use anyhow::{bail, Context, Result};
use std::fs::File;

#[cfg(unix)]
use std::os::unix::io::AsRawFd;

#[cfg(unix)]
mod sys {
    //! Hand-declared prototypes for the three syscalls used here,
    //! matching the Linux (and POSIX) C ABI on 64-bit targets.
    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const MAP_SHARED: i32 = 1;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
        pub fn ftruncate(fd: i32, len: i64) -> i32;
    }

    /// `MAP_FAILED` is `(void*)-1`.
    pub fn map_failed() -> *mut u8 {
        usize::MAX as *mut u8
    }
}

/// A shared read-only memory mapping of an entire file.
///
/// The mapped bytes live until this value drops; the model loader keeps
/// an `Arc<Mmap>` next to every borrowed section so the lifetime is
/// enforced by reference counting rather than borrows.
#[derive(Debug)]
pub struct Mmap {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is read-only and file-backed; the raw pointer is
// only dereferenced through `as_slice`, which hands out `&[u8]` — shared
// immutable access from any thread is sound.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `len` bytes of `file` read-only. Fails cleanly on empty files,
    /// on kernel refusal, and on non-unix platforms.
    #[cfg(unix)]
    pub fn map_file(file: &File, len: u64) -> Result<Mmap> {
        if len == 0 {
            bail!("cannot mmap an empty file");
        }
        let len = usize::try_from(len).context("file too large for address space")?;
        // SAFETY: fd is a valid open file descriptor for the lifetime of
        // the call; a MAP_SHARED PROT_READ mapping of a regular file has
        // no aliasing requirements on our side. The result is checked
        // against MAP_FAILED before use.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            bail!("mmap of {len} bytes failed");
        }
        Ok(Mmap { ptr, len })
    }

    /// Non-unix stub: always an error, so callers fall back to the read
    /// path.
    #[cfg(not(unix))]
    pub fn map_file(_file: &File, _len: u64) -> Result<Mmap> {
        bail!("mmap model loading is only supported on unix")
    }

    /// The mapped bytes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live mapping established in
        // `map_file` and released only in `Drop`.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Mapped length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is mapped (never constructed; kept for API
    /// completeness and clippy's `len_without_is_empty`).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        // SAFETY: ptr/len came from a successful mmap and are unmapped
        // exactly once.
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

/// A shared read-write mapping of a freshly created, immediately
/// unlinked temp file — backing storage for file-backed message arenas.
///
/// The file is sparse (`ftruncate` to size, no data written), so blocks
/// materialize only as pages are dirtied; unlinking right after `mmap`
/// means the kernel drops the blocks when the mapping (the sole
/// reference) goes away, even if the process crashes.
#[derive(Debug)]
pub struct MmapMut {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is private to this process (the backing file is
// unlinked before the constructor returns). Callers only ever access it
// through atomic cells (`AtomicF64`/`AtomicF32` lines), which carry
// their own synchronization — the same contract as the heap arenas.
unsafe impl Send for MmapMut {}
unsafe impl Sync for MmapMut {}

impl MmapMut {
    /// Create an unlinked sparse temp file of `len` bytes under `dir`
    /// and map it read-write. `tag` disambiguates concurrent arenas.
    #[cfg(unix)]
    pub fn temp(dir: &std::path::Path, tag: &str, len: usize) -> Result<MmapMut> {
        if len == 0 {
            bail!("cannot create an empty arena mapping");
        }
        // Unique name: pid + tag + a process-wide counter. The file is
        // unlinked before we return, so the name only needs to dodge
        // collisions within this call window.
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!(".rbp-arena-{}-{}-{}", std::process::id(), tag, seq);
        let path = dir.join(name);
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create_new(true)
            .open(&path)
            .with_context(|| format!("creating arena temp file in {}", dir.display()))?;
        // SAFETY: fd is valid; ftruncate extends the empty file to `len`
        // sparse bytes. Checked for failure (e.g. ENOSPC-reserving
        // filesystems, EFBIG).
        let rc = unsafe { sys::ftruncate(file.as_raw_fd(), len as i64) };
        if rc != 0 {
            std::fs::remove_file(&path).ok();
            bail!("sizing arena temp file to {len} bytes failed");
        }
        // SAFETY: as in `Mmap::map_file`, but PROT_READ|PROT_WRITE over
        // a file we exclusively own; result checked against MAP_FAILED.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        // Unlink regardless of mmap success: on success the mapping
        // keeps the inode alive; on failure we must not leak the file.
        std::fs::remove_file(&path).ok();
        if ptr == sys::map_failed() {
            bail!("mmap of {len}-byte arena file failed");
        }
        Ok(MmapMut { ptr, len })
    }

    /// Non-unix stub: always an error, so callers fall back to heap
    /// arenas.
    #[cfg(not(unix))]
    pub fn temp(_dir: &std::path::Path, _tag: &str, _len: usize) -> Result<MmapMut> {
        bail!("mmap-backed arenas are only supported on unix")
    }

    /// Base pointer of the mapping (page-aligned).
    #[inline]
    pub fn as_ptr(&self) -> *mut u8 {
        self.ptr
    }

    /// Mapped length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is mapped (never constructed; for clippy's
    /// `len_without_is_empty`).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for MmapMut {
    fn drop(&mut self) {
        #[cfg(unix)]
        // SAFETY: ptr/len came from a successful mmap and are unmapped
        // exactly once; the unlinked backing file dies with the mapping.
        unsafe {
            sys::munmap(self.ptr, self.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    #[test]
    fn map_file_round_trip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(".rbp-mmap-test-{}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&[1u8, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        f.sync_all().unwrap();
        let f = std::fs::File::open(&path).unwrap();
        let m = Mmap::map_file(&f, 8).unwrap();
        assert_eq!(m.as_slice(), &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(m.len(), 8);
        assert!(!m.is_empty());
        drop(m);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn map_empty_file_is_clean_error() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(".rbp-mmap-empty-{}", std::process::id()));
        std::fs::File::create(&path).unwrap();
        let f = std::fs::File::open(&path).unwrap();
        assert!(Mmap::map_file(&f, 0).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn temp_mapping_reads_back_writes() {
        let m = MmapMut::temp(&std::env::temp_dir(), "test", 4096).unwrap();
        assert_eq!(m.len(), 4096);
        assert!(!m.is_empty());
        // SAFETY: test-local exclusive access to a live 4096-byte mapping.
        unsafe {
            *m.as_ptr() = 0xAB;
            *m.as_ptr().add(4095) = 0xCD;
            assert_eq!(*m.as_ptr(), 0xAB);
            assert_eq!(*m.as_ptr().add(4095), 0xCD);
        }
    }

    #[test]
    fn temp_mapping_rejects_bad_dir() {
        let bad = std::path::Path::new("/nonexistent-rbp-dir");
        assert!(MmapMut::temp(bad, "test", 4096).is_err());
    }
}
