//! Small statistics helpers shared by the benchmark harness and the
//! experiment reports: robust summary statistics and simple significance
//! heuristics, in the spirit of criterion's reporting (criterion itself is
//! not available in the offline build).

use crate::configio::Json;

/// Summary statistics over a sample of observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n−1 denominator).
    pub stddev: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
    /// Interpolated median.
    pub median: f64,
    /// 5th percentile (interpolated).
    pub p05: f64,
    /// 95th percentile (interpolated).
    pub p95: f64,
}

impl Summary {
    /// Compute summary statistics; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.5),
            p05: percentile_sorted(&sorted, 0.05),
            p95: percentile_sorted(&sorted, 0.95),
        })
    }

    /// Relative standard deviation (coefficient of variation), in [0, ∞).
    pub fn rsd(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stddev / self.mean.abs()
        }
    }

    /// Serialize as a JSON object — the one summary shape shared by every
    /// reporter (`results/bench/*.json` and the `BENCH_*.json` baselines).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean", Json::Num(self.mean)),
            ("stddev", Json::Num(self.stddev)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("median", Json::Num(self.median)),
            ("p05", Json::Num(self.p05)),
            ("p95", Json::Num(self.p95)),
        ])
    }
}

/// Linear-interpolation percentile of an already-sorted sample, `q ∈ [0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Format a duration in seconds with an adaptive unit, e.g. `1.23 ms`.
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Format a count with thousands separators, e.g. `1_234_567`.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(*b as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        assert!(Summary::of(&[]).is_none());
        let s = Summary::of(&[7.0]).unwrap();
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.5), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn rsd_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]).unwrap();
        assert_eq!(s.rsd(), 0.0);
    }

    #[test]
    fn summary_json_shape() {
        let s = Summary::of(&[1.0, 2.0, 3.0]).unwrap();
        let j = s.to_json();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("median").unwrap().as_f64(), Some(2.0));
        assert!(j.get("p05").is_some() && j.get("p95").is_some());
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_duration(2.5e-9), "2.5 ns");
    }

    #[test]
    fn fmt_count_separators() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1_000");
        assert_eq!(fmt_count(1234567), "1_234_567");
    }
}
