//! Shared low-level utilities: deterministic PRNG, atomic f64 cells,
//! statistics, and timing helpers. These stand in for the `rand` /
//! `criterion`-adjacent crates that are unavailable in the offline build.

pub mod atomic;
pub mod disjoint;
pub mod mmap;
pub mod rng;
pub mod stats;

pub use atomic::{AtomicF32, AtomicF64, CachePadded};
pub use disjoint::DisjointWriter;
pub use rng::{SplitMix64, Xoshiro256};
pub use stats::{fmt_count, fmt_duration, Summary};

use std::time::Instant;

/// Thread count for cold-path parallel sweeps (model build, bulk model
/// I/O, arena init, snapshot/marginal extraction): 1 below a small work
/// threshold — where spawn overhead swamps the sweep itself — otherwise
/// the machine's parallelism capped at 8 (the cold path is memory-bound;
/// wider fan-out only adds contention). Solve-loop threading is configured
/// explicitly per run and does not use this heuristic.
pub fn cold_path_threads(work_items: usize) -> usize {
    if work_items < (1 << 14) {
        return 1;
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
}

/// The process's peak resident set size in bytes (`VmHWM` from
/// `/proc/self/status`), or 0 where procfs is unavailable (non-Linux).
/// Monotone over the process lifetime — the kernel's high-water mark —
/// so periodic samples can simply max-merge. This is the out-of-core
/// axis's ground truth: an mmap-arena run of a larger-than-RAM model
/// shows a peak RSS far below its logical message + model footprint,
/// because the kernel reclaims cold pages instead of growing the heap.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            // Format: "VmHWM:     1234 kB" — the unit is always kB.
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Simple scope timer returning elapsed seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds since [`Timer::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_is_monotone_and_plausible() {
        let a = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // A live process has touched at least a few pages.
            assert!(a > 4096, "VmHWM should be readable on Linux (got {a})");
        }
        // Force some allocation, then re-read: the high-water mark never
        // decreases.
        let v = vec![1u8; 1 << 20];
        std::hint::black_box(&v);
        let b = peak_rss_bytes();
        assert!(b >= a);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
