//! Shared low-level utilities: deterministic PRNG, atomic f64 cells,
//! statistics, and timing helpers. These stand in for the `rand` /
//! `criterion`-adjacent crates that are unavailable in the offline build.

pub mod atomic;
pub mod rng;
pub mod stats;

pub use atomic::{AtomicF32, AtomicF64, CachePadded};
pub use rng::{SplitMix64, Xoshiro256};
pub use stats::{fmt_count, fmt_duration, Summary};

use std::time::Instant;

/// Simple scope timer returning elapsed seconds.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds since [`Timer::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
