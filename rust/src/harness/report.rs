//! Result tables: collection, markdown/CSV/trace-JSON rendering, and file
//! output.

use crate::configio::Json;
use crate::telemetry::Trace;
use crate::util::fmt_duration;
use anyhow::{Context, Result};
use std::path::Path;

/// One measured cell of an experiment.
#[derive(Debug, Clone)]
pub struct Row {
    /// Model family name (`tree`, `ising`, …).
    pub model: String,
    /// Algorithm display name.
    pub algorithm: String,
    /// Worker thread count.
    pub threads: usize,
    /// Wall-clock seconds inside the engine.
    pub wall_secs: f64,
    /// Committed message updates.
    pub updates: u64,
    /// Updates with residual ≥ ε.
    pub useful_updates: u64,
    /// Pops whose priority had already dropped below ε.
    pub wasted_pops: u64,
    /// Pops discarded for a stale epoch.
    pub stale_pops: u64,
    /// Allocated (cache-line-padded) message-arena bytes of the run — a
    /// gauge; halves under f32 storage.
    pub msg_bytes_padded: u64,
    /// Whether the run converged within budget.
    pub converged: bool,
    /// RNG seed of the run.
    pub seed: u64,
}

impl Row {
    /// Serialize as a JSON object (the `run --out` report shape).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("algorithm", Json::Str(self.algorithm.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("updates", Json::Num(self.updates as f64)),
            ("useful_updates", Json::Num(self.useful_updates as f64)),
            ("wasted_pops", Json::Num(self.wasted_pops as f64)),
            ("stale_pops", Json::Num(self.stale_pops as f64)),
            ("msg_bytes_padded", Json::Num(self.msg_bytes_padded as f64)),
            ("converged", Json::Bool(self.converged)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }
}

/// An experiment's collected rows plus free-form header notes.
pub struct Report {
    /// File-name stem (`table3`, `fig2`, …).
    pub id: String,
    /// Human-readable title rendered as the markdown heading.
    pub title: String,
    /// Free-form header notes (testbed, scale, seed).
    pub notes: Vec<String>,
    /// Raw measured cells.
    pub rows: Vec<Row>,
    /// Pre-rendered markdown tables (experiment-specific pivots).
    pub tables: Vec<String>,
    /// Per-cell convergence traces (`(cell id, trace)`), emitted as
    /// `<id>.traces.json` alongside the markdown/CSV.
    pub traces: Vec<(String, Trace)>,
}

impl Report {
    /// Empty report with the given file stem and title.
    pub fn new(id: &str, title: &str) -> Self {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            notes: Vec::new(),
            rows: Vec::new(),
            tables: Vec::new(),
            traces: Vec::new(),
        }
    }

    /// Append a header note.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Append a measured row.
    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Append a pre-rendered markdown table.
    pub fn add_table(&mut self, md: String) {
        self.tables.push(md);
    }

    /// Attach a cell's convergence trace (empty traces are dropped).
    pub fn add_trace(&mut self, cell_id: impl Into<String>, trace: Trace) {
        if !trace.is_empty() {
            self.traces.push((cell_id.into(), trace));
        }
    }

    /// JSON document of all attached traces: an array of
    /// `{"cell": …, "trace": […]}` objects (an array, not an object keyed
    /// by cell id, because sweeps can measure the same cell repeatedly).
    pub fn traces_json(&self) -> Json {
        Json::Arr(
            self.traces
                .iter()
                .map(|(cell, t)| {
                    Json::obj(vec![("cell", Json::Str(cell.clone())), ("trace", t.to_json())])
                })
                .collect(),
        )
    }

    /// Raw per-row markdown (appendix of each report).
    pub fn raw_table(&self) -> String {
        let mut s = String::from(
            "| model | algorithm | p | time | updates | useful | wasted pops | converged |\n\
             |---|---|---|---|---|---|---|---|\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} |\n",
                r.model,
                r.algorithm,
                r.threads,
                fmt_duration(r.wall_secs),
                r.updates,
                r.useful_updates,
                r.wasted_pops,
                if r.converged { "yes" } else { "NO" },
            ));
        }
        s
    }

    /// Render notes + pivot tables + raw rows as one markdown document.
    pub fn to_markdown(&self) -> String {
        let mut s = format!("## {} — {}\n\n", self.id, self.title);
        for n in &self.notes {
            s.push_str(&format!("- {n}\n"));
        }
        s.push('\n');
        for t in &self.tables {
            s.push_str(t);
            s.push('\n');
        }
        s.push_str("### Raw measurements\n\n");
        s.push_str(&self.raw_table());
        s
    }

    /// Render the raw rows as CSV.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "model,algorithm,threads,wall_secs,updates,useful_updates,wasted_pops,stale_pops,msg_bytes_padded,converged,seed\n",
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{}\n",
                r.model,
                r.algorithm,
                r.threads,
                r.wall_secs,
                r.updates,
                r.useful_updates,
                r.wasted_pops,
                r.stale_pops,
                r.msg_bytes_padded,
                r.converged,
                r.seed
            ));
        }
        s
    }

    /// Write `<dir>/<id>.md`, `<dir>/<id>.csv`, and (when traces were
    /// attached) `<dir>/<id>.traces.json`; print the markdown.
    pub fn emit(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
        std::fs::write(dir.join(format!("{}.md", self.id)), self.to_markdown())?;
        std::fs::write(dir.join(format!("{}.csv", self.id)), self.to_csv())?;
        if !self.traces.is_empty() {
            std::fs::write(
                dir.join(format!("{}.traces.json", self.id)),
                self.traces_json().to_string_pretty(),
            )?;
        }
        println!("{}", self.to_markdown());
        Ok(())
    }
}

/// Ratio formatted like the paper's tables ("2.54x", "—" for DNF).
pub fn ratio_cell(ok: bool, ratio: f64) -> String {
    if ok && ratio.is_finite() {
        format!("{ratio:.3}x")
    } else {
        "—".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Row {
        Row {
            model: "ising".into(),
            algorithm: "relaxed_residual".into(),
            threads: 4,
            wall_secs: 1.25,
            updates: 1000,
            useful_updates: 900,
            wasted_pops: 100,
            stale_pops: 5,
            msg_bytes_padded: 8192,
            converged: true,
            seed: 42,
        }
    }

    #[test]
    fn markdown_and_csv_render() {
        let mut rep = Report::new("table1", "Speedups");
        rep.note("testbed: 1 core");
        rep.push(row());
        rep.add_table("| a |\n|---|\n| b |\n".into());
        let md = rep.to_markdown();
        assert!(md.contains("## table1"));
        assert!(md.contains("relaxed_residual"));
        assert!(md.contains("testbed"));
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(ratio_cell(true, 2.538), "2.538x");
        assert_eq!(ratio_cell(false, 2.5), "—");
        assert_eq!(ratio_cell(true, f64::INFINITY), "—");
    }

    #[test]
    fn emit_writes_files() {
        let mut rep = Report::new("test_emit", "t");
        rep.push(row());
        let dir = std::path::PathBuf::from("/tmp/rbp_report_test");
        rep.emit(&dir).unwrap();
        assert!(dir.join("test_emit.md").exists());
        assert!(dir.join("test_emit.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
