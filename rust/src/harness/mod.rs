//! Experiment harness: regenerates every table and figure in the paper's
//! evaluation (§5, Appendix B) plus the §4 analytical experiments.
//!
//! | id | paper artifact | function |
//! |---|---|---|
//! | `table1` / `table5` | speedups vs sequential residual (moderate sizes, max threads) | [`Harness::tables_moderate`] |
//! | `table2` / `table6` | update counts vs sequential residual | (same run) |
//! | `table3` | relaxed-vs-exact extra updates across thread counts | [`Harness::table3`] |
//! | `table4` | relaxed residual vs best non-relaxed | [`Harness::table4`] |
//! | `table7` | randomized synchronous (lowP sweep) | [`Harness::table7`] |
//! | `fig2`   | 1000² Ising wall-clock + updates at p ∈ {20,35,70} | [`Harness::fig2`] |
//! | `fig4`–`fig7` | per-model scaling curves (time & updates vs p) | [`Harness::fig_scaling`] |
//! | `lemma2` | good-case vs bad-case relaxation overhead on trees | [`Harness::lemma2`] |
//!
//! Sizes scale with `--scale` (1.0 = the paper's "small" §5.5 sizes; the
//! default is tuned so the full suite completes on this single-core
//! container). Every report lands in `results/` as markdown + CSV, plus a
//! `<id>.traces.json` with each cell's convergence trace (sampled every
//! [`TRACE_TICK_MS`] ms; see the `telemetry` module for the schema).

pub mod report;

pub use report::{ratio_cell, Report, Row};

use crate::configio::{
    AlgorithmSpec, ArenaMode, Kernel, LoadMode, ModelSpec, PartitionSpec, Precision, RunConfig,
};
use crate::model::{EvidenceDelta, Mrf};
use crate::run::run_on_model_observed;
use crate::telemetry::{Trace, TraceRecorder, DELTA_FRACTION};
use anyhow::Result;
use std::cell::RefCell;
use std::path::PathBuf;
use std::time::Duration;

/// Convergence-trace sampling interval for harness cells. Coarser than the
/// `bench` default because experiment cells run up to minutes and the
/// traces of a full suite must stay reviewable.
pub const TRACE_TICK_MS: u64 = 50;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Harness {
    /// Instance-size multiplier; 1.0 = the paper's "small" sizes
    /// (tree 10⁶, grids 300², LDPC 30 000).
    pub scale: f64,
    /// Thread counts for scaling sweeps (paper: 1..70 on 72 cores).
    pub threads: Vec<usize>,
    /// The "many threads" point used by Tables 1/2/5/6 (paper: 70).
    pub max_threads: usize,
    /// Directory reports are written to.
    pub out_dir: PathBuf,
    /// RNG seed for model construction and scheduler randomness.
    pub seed: u64,
    /// Per-cell wall-clock limit in seconds (paper: 5 minutes).
    pub time_limit: f64,
    /// Use the PJRT/AOT compute path where the engine supports it.
    pub use_pjrt: bool,
    /// Locality axis applied to every cell (the `locality` experiment
    /// additionally sweeps it per cell).
    pub partition: PartitionSpec,
    /// Update-kernel shape axis applied to every cell (the `fused`
    /// experiment additionally sweeps it per cell).
    pub fused: bool,
    /// Data-path kernel axis applied to every cell (the `simd` experiment
    /// additionally sweeps it per cell).
    pub kernel: Kernel,
    /// Storage-precision axis applied to every cell (the `precision`
    /// experiment additionally sweeps it per cell). Defaults to f64 so
    /// every historical experiment trajectory stays bit-identical.
    pub precision: Precision,
    /// Model-cache directory consulted before building (`--load-model`):
    /// a spec whose `cache_slug` file exists there is loaded from disk
    /// instead of rebuilt.
    pub load_model: Option<PathBuf>,
    /// Model-cache directory built models are saved into (`--save-model`,
    /// format v2) so later sweeps can `--load-model` them.
    pub save_model: Option<PathBuf>,
    /// How `--load-model` files are brought in (`--load-mode`): zero-copy
    /// mapped sections, copying reads, or auto (map with read fallback).
    pub load_mode: LoadMode,
    /// Message-arena backing applied to every cell (`--arena`): heap or
    /// file-backed temp mappings (the out-of-core axis).
    pub arena: ArenaMode,
    /// Run checksum + semantic validation on mapped loads
    /// (`--verify-load`).
    pub verify_load: bool,
    /// Damping factor applied to every cell (`--damping`, the message
    /// update blend `m' = m^{1−F}·m_old^F`); 0.0 keeps the historical
    /// undamped trajectories bit-identical.
    pub damping: f64,
    /// Traces recorded by [`Harness::run_cell`] since the last
    /// [`Harness::drain_traces`], keyed by cell id.
    pub trace_log: RefCell<Vec<(String, Trace)>>,
}

impl Default for Harness {
    fn default() -> Self {
        Harness {
            scale: 0.05,
            threads: vec![1, 2, 4, 8],
            max_threads: 8,
            out_dir: PathBuf::from("results"),
            seed: 42,
            time_limit: 120.0,
            use_pjrt: false,
            partition: PartitionSpec::Off,
            fused: true,
            kernel: Kernel::Simd,
            precision: Precision::F64,
            load_model: None,
            save_model: None,
            load_mode: LoadMode::Auto,
            arena: ArenaMode::Mem,
            verify_load: false,
            damping: 0.0,
            trace_log: RefCell::new(Vec::new()),
        }
    }
}

impl Harness {
    /// The four benchmark models at the configured scale.
    pub fn models(&self) -> Vec<ModelSpec> {
        vec![
            ModelSpec::Tree { n: scaled(1_000_000, self.scale).max(15) },
            ModelSpec::Ising { n: side(300, self.scale).max(4) },
            ModelSpec::Potts { n: side(300, self.scale).max(4), q: 3 },
            ModelSpec::Ldpc { n: scaled(30_000, self.scale).max(24), flip_prob: 0.07 },
        ]
    }

    /// Resolve `spec` through the optional model cache: load it from
    /// `load_model` when the cached file exists, otherwise build it (and
    /// persist into `save_model` when set). All experiment model
    /// construction funnels through here so every sweep honors
    /// `--save-model`/`--load-model`.
    pub fn model(&self, spec: &ModelSpec) -> Result<Mrf> {
        let (mrf, _prep) = crate::run::obtain_model(
            spec,
            self.seed,
            self.load_model.as_deref(),
            self.save_model.as_deref(),
            self.load_mode,
            self.verify_load,
        )?;
        Ok(mrf)
    }

    fn cfg(&self, spec: &ModelSpec, alg: AlgorithmSpec, threads: usize) -> RunConfig {
        let mut cfg = RunConfig::new(spec.clone(), alg).with_threads(threads).with_seed(self.seed);
        cfg.time_limit_secs = self.time_limit;
        cfg.use_pjrt = self.use_pjrt;
        cfg.partition = self.partition;
        cfg.fused = self.fused;
        cfg.kernel = self.kernel;
        cfg.precision = self.precision;
        cfg.arena = self.arena.clone();
        cfg.damping = self.damping;
        cfg
    }

    /// Run one cell on a shared model instance, recording its convergence
    /// trace into the harness trace log (drained into the report by
    /// [`Harness::drain_traces`]).
    pub fn run_cell(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        alg: AlgorithmSpec,
        threads: usize,
    ) -> Result<Row> {
        self.run_cell_partitioned(mrf, spec, alg, threads, self.partition)
    }

    /// [`Harness::run_cell`] with an explicit locality axis (used by the
    /// `locality` experiment's off-vs-affine sweep).
    pub fn run_cell_partitioned(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        alg: AlgorithmSpec,
        threads: usize,
        partition: PartitionSpec,
    ) -> Result<Row> {
        let mut cfg = self.cfg(spec, alg.clone(), threads);
        cfg.partition = partition;
        eprintln!(
            "[harness] {} / {} / p={} / partition={} …",
            spec.name(),
            alg.name(),
            threads,
            partition.label()
        );
        // Same id policy as the bench cells: off-axis ids keep their
        // historical form so trace keys stay joinable across revisions;
        // a harness-wide fused-off axis marks its cells like bench does.
        let mut id = if partition.is_on() {
            format!("{}/{}/p{}/{}", spec.name(), alg.name(), threads, partition.label())
        } else {
            format!("{}/{}/p{}", spec.name(), alg.name(), threads)
        };
        if !self.fused {
            id.push_str("/edgewise");
        }
        if self.kernel == Kernel::Scalar {
            id.push_str("/scalar");
        }
        if self.precision.is_f32() {
            id.push_str("/f32");
        }
        self.run_cell_with(mrf, spec, alg, cfg, id)
    }

    /// Shared cell runner: execute `cfg` on `mrf`, record the trace under
    /// `id`, and package the [`Row`] — the single body behind every
    /// `run_cell*` variant.
    fn run_cell_with(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        alg: AlgorithmSpec,
        cfg: RunConfig,
        id: String,
    ) -> Result<Row> {
        let recorder = TraceRecorder::new(Duration::from_millis(TRACE_TICK_MS));
        let rep = run_on_model_observed(&cfg, mrf.clone(), Some(&recorder))?;
        self.trace_log.borrow_mut().push((id, recorder.take()));
        let m = &rep.stats.metrics.total;
        Ok(Row {
            model: spec.name().to_string(),
            algorithm: alg.name(),
            threads: cfg.threads,
            wall_secs: rep.stats.wall_secs,
            updates: m.updates,
            useful_updates: m.useful_updates,
            wasted_pops: m.wasted_pops,
            stale_pops: m.stale_pops,
            msg_bytes_padded: m.msg_bytes_padded,
            converged: rep.stats.converged,
            seed: self.seed,
        })
    }

    /// Move every trace recorded since the last drain into `rep` (called
    /// right before each report's `emit`).
    pub fn drain_traces(&self, rep: &mut Report) {
        for (id, trace) in self.trace_log.borrow_mut().drain(..) {
            rep.add_trace(id, trace);
        }
    }

    /// The full §5.1 roster used by Tables 1/2 (main) and 5/6 (appendix).
    pub fn moderate_roster(&self) -> Vec<AlgorithmSpec> {
        vec![
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::CoarseGrained,
            AlgorithmSpec::Splash { h: 2 },
            AlgorithmSpec::Splash { h: 10 },
            AlgorithmSpec::RandomSplash { h: 2 },
            AlgorithmSpec::RandomSplash { h: 10 },
            AlgorithmSpec::Bucket,
            AlgorithmSpec::RelaxedResidual,
            AlgorithmSpec::WeightDecay,
            AlgorithmSpec::Priority,
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
            AlgorithmSpec::RelaxedSmartSplash { h: 10 },
        ]
    }

    /// Tables 1 & 2 (and the appendix Tables 5 & 6): every algorithm at
    /// `max_threads` vs the sequential residual baseline, on all four
    /// models, reporting wall-clock speedup and update ratios.
    pub fn tables_moderate(&self) -> Result<Report> {
        let mut rep = Report::new(
            "table1_2_5_6",
            "Speedups and update counts vs sequential residual (Tables 1, 2, 5, 6)",
        );
        self.standard_notes(&mut rep);
        rep.note(format!("concurrent algorithms at p = {}", self.max_threads));

        let roster = self.moderate_roster();
        let mut speedup_md = String::from("| input | baseline |");
        let mut updates_md = String::from("| input | baseline updates |");
        for a in &roster {
            speedup_md.push_str(&format!(" {} |", a.name()));
            updates_md.push_str(&format!(" {} |", a.name()));
        }
        speedup_md.push('\n');
        updates_md.push('\n');
        let sep = format!("|{}\n", "---|".repeat(roster.len() + 2));
        speedup_md.push_str(&sep);
        updates_md.push_str(&sep);

        for spec in self.models() {
            let mrf = self.model(&spec)?;
            let base = self.run_cell(&mrf, &spec, AlgorithmSpec::SequentialResidual, 1)?;
            speedup_md
                .push_str(&format!("| {} | {:.2} s |", spec.name(), base.wall_secs));
            updates_md.push_str(&format!("| {} | {} |", spec.name(), base.updates));
            rep.push(base.clone());
            for alg in &roster {
                let row = self.run_cell(&mrf, &spec, alg.clone(), self.max_threads)?;
                speedup_md.push_str(&format!(
                    " {} |",
                    ratio_cell(row.converged, base.wall_secs / row.wall_secs)
                ));
                updates_md.push_str(&format!(
                    " {} |",
                    ratio_cell(row.converged, row.updates as f64 / base.updates as f64)
                ));
                rep.push(row);
            }
            speedup_md.push('\n');
            updates_md.push('\n');
        }
        rep.add_table(format!(
            "### Speedups vs sequential residual (higher is better)\n\n{speedup_md}"
        ));
        rep.add_table(format!(
            "### Total updates relative to sequential residual (lower is better)\n\n{updates_md}"
        ));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Table 3: extra updates of relaxed residual vs the exact sequential
    /// baseline, across thread counts.
    pub fn table3(&self) -> Result<Report> {
        let mut rep = Report::new(
            "table3",
            "Additional updates of relaxed residual vs exact residual (Table 3)",
        );
        self.standard_notes(&mut rep);

        let models = self.models();
        let mut baselines = Vec::new();
        let mut mrfs = Vec::new();
        for spec in &models {
            let mrf = self.model(spec)?;
            let base = self.run_cell(&mrf, spec, AlgorithmSpec::SequentialResidual, 1)?;
            rep.push(base.clone());
            baselines.push(base);
            mrfs.push(mrf);
        }

        let mut md = String::from("| threads |");
        for spec in &models {
            md.push_str(&format!(" {} |", spec.name()));
        }
        md.push_str("\n|");
        md.push_str(&"---|".repeat(models.len() + 1));
        md.push('\n');
        md.push_str("| exact (1) |");
        for b in &baselines {
            md.push_str(&format!(" {} |", b.updates));
        }
        md.push('\n');

        for &p in &self.threads {
            md.push_str(&format!("| relaxed {p} |"));
            for (i, spec) in models.iter().enumerate() {
                let row = self.run_cell(&mrfs[i], spec, AlgorithmSpec::RelaxedResidual, p)?;
                let pct =
                    100.0 * (row.updates as f64 / baselines[i].updates as f64 - 1.0);
                md.push_str(&format!(
                    " {} |",
                    if row.converged { format!("{pct:+.2}%") } else { "—".into() }
                ));
                rep.push(row);
            }
            md.push('\n');
        }
        rep.add_table(format!("### Extra updates from relaxation\n\n{md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Table 4: relaxed residual speedup vs the best non-relaxed
    /// alternative per model and thread count.
    pub fn table4(&self) -> Result<Report> {
        let mut rep = Report::new(
            "table4",
            "Relaxed residual vs best non-relaxed alternative (Table 4)",
        );
        self.standard_notes(&mut rep);
        let non_relaxed = vec![
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::CoarseGrained,
            AlgorithmSpec::Splash { h: 2 },
            AlgorithmSpec::Splash { h: 10 },
        ];
        let models = self.models();
        let mut md = String::from("| threads |");
        for spec in &models {
            md.push_str(&format!(" {} |", spec.name()));
        }
        md.push_str("\n|");
        md.push_str(&"---|".repeat(models.len() + 1));
        md.push('\n');

        for &p in &self.threads {
            md.push_str(&format!("| {p} |"));
            for spec in &models {
                let mrf = self.model(spec)?;
                let rr = self.run_cell(&mrf, spec, AlgorithmSpec::RelaxedResidual, p)?;
                let mut best: Option<f64> = None;
                for alg in &non_relaxed {
                    let row = self.run_cell(&mrf, spec, alg.clone(), p)?;
                    if row.converged {
                        best = Some(best.map_or(row.wall_secs, |b: f64| b.min(row.wall_secs)));
                    }
                    rep.push(row);
                }
                md.push_str(&format!(
                    " {} |",
                    match (rr.converged, best) {
                        (true, Some(b)) => ratio_cell(true, b / rr.wall_secs),
                        _ => "—".into(),
                    }
                ));
                rep.push(rr);
            }
            md.push('\n');
        }
        rep.add_table(format!(
            "### Speedup of relaxed residual over best non-relaxed (>1 = relaxed wins)\n\n{md}"
        ));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Table 7: randomized synchronous with lowP ∈ {0.1, 0.4, 0.7} vs the
    /// synchronous baseline at max threads and relaxed residual at p = 1.
    pub fn table7(&self) -> Result<Report> {
        let mut rep =
            Report::new("table7", "Randomized synchronous vs baselines (Table 7)");
        self.standard_notes(&mut rep);
        let models = self.models();
        let mut md = String::from("| algorithm |");
        for spec in &models {
            md.push_str(&format!(" {} |", spec.name()));
        }
        md.push_str("\n|");
        md.push_str(&"---|".repeat(models.len() + 1));
        md.push('\n');

        let mut line = |label: &str, rows: Vec<Row>, rep: &mut Report| {
            md.push_str(&format!("| {label} |"));
            for r in rows {
                md.push_str(&format!(
                    " {} |",
                    if r.converged { format!("{:.3} s", r.wall_secs) } else { "—".into() }
                ));
                rep.push(r);
            }
            md.push('\n');
        };

        let synch: Vec<Row> = models
            .iter()
            .map(|s| {
                let mrf = self.model(s)?;
                self.run_cell(&mrf, s, AlgorithmSpec::Synchronous, self.max_threads)
            })
            .collect::<Result<_>>()?;
        line(&format!("synch {}", self.max_threads), synch, &mut rep);

        let rr1: Vec<Row> = models
            .iter()
            .map(|s| {
                let mrf = self.model(s)?;
                self.run_cell(&mrf, s, AlgorithmSpec::RelaxedResidual, 1)
            })
            .collect::<Result<_>>()?;
        line("relaxed residual 1", rr1, &mut rep);

        for low_p in [0.1, 0.4, 0.7] {
            let rows: Vec<Row> = models
                .iter()
                .map(|s| {
                    let mrf = self.model(s)?;
                    self.run_cell(
                        &mrf,
                        s,
                        AlgorithmSpec::RandomSynchronous { low_p },
                        self.max_threads,
                    )
                })
                .collect::<Result<_>>()?;
            line(
                &format!("random synch {} (lowP={low_p})", self.max_threads),
                rows,
                &mut rep,
            );
        }
        rep.add_table(format!("### Running time (s)\n\n{md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Figure 2: Ising grid, three thread counts, three algorithms,
    /// time + update series.
    pub fn fig2(&self) -> Result<Report> {
        let mut rep = Report::new("fig2", "Ising grid: Synch vs Splash(10) vs Relaxed Residual (Figure 2)");
        self.standard_notes(&mut rep);
        // Paper: 1000² and p ∈ {20, 35, 70}; scaled analogues here.
        let spec = ModelSpec::Ising { n: side(1000, self.scale).max(8) };
        let points: Vec<usize> = self.fig2_threads();
        rep.note(format!("model: ising {0}×{0}", match spec { ModelSpec::Ising { n } => n, _ => 0 }));
        let mrf = self.model(&spec)?;
        let base = self.run_cell(&mrf, &spec, AlgorithmSpec::SequentialResidual, 1)?;
        rep.push(base.clone());
        let algs = [
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::Splash { h: 10 },
            AlgorithmSpec::RelaxedResidual,
        ];
        let mut md = String::from("| p | algorithm | time (s) | updates (rel. seq residual) |\n|---|---|---|---|\n");
        for &p in &points {
            for alg in &algs {
                let row = self.run_cell(&mrf, &spec, alg.clone(), p)?;
                md.push_str(&format!(
                    "| {p} | {} | {} | {} |\n",
                    alg.name(),
                    if row.converged { format!("{:.3}", row.wall_secs) } else { "—".into() },
                    ratio_cell(row.converged, row.updates as f64 / base.updates as f64),
                ));
                rep.push(row);
            }
        }
        rep.add_table(md);
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    fn fig2_threads(&self) -> Vec<usize> {
        // Paper's {20, 35, 70} scaled onto this testbed's sweep range.
        let hi = self.max_threads;
        let mut v: Vec<usize> = vec![(hi + 1) / 4, (hi + 1) / 2, hi]
            .into_iter()
            .map(|p| p.max(1))
            .collect();
        v.dedup();
        v
    }

    /// Figures 4–7: per-model scaling study (time & updates vs threads)
    /// for the main roster. `which` ∈ {tree, ising, potts, ldpc}.
    pub fn fig_scaling(&self, which: &str) -> Result<Report> {
        let (fig_id, spec) = match which {
            "tree" => ("fig4", self.models()[0].clone()),
            "ising" => ("fig5", self.models()[1].clone()),
            "potts" => ("fig6", self.models()[2].clone()),
            "ldpc" => ("fig7", self.models()[3].clone()),
            other => anyhow::bail!("unknown figure model '{other}'"),
        };
        let mut rep = Report::new(
            fig_id,
            &format!("{which} model scaling: time and updates vs threads (Figure {})", &fig_id[3..]),
        );
        self.standard_notes(&mut rep);

        let algs: Vec<AlgorithmSpec> = vec![
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::CoarseGrained,
            AlgorithmSpec::RelaxedResidual,
            AlgorithmSpec::WeightDecay,
            AlgorithmSpec::Priority,
            AlgorithmSpec::Splash { h: 2 },
            AlgorithmSpec::RandomSplash { h: 2 },
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        ];
        let mrf = self.model(&spec)?;
        let base = self.run_cell(&mrf, &spec, AlgorithmSpec::SequentialResidual, 1)?;
        rep.push(base.clone());

        let mut time_md = String::from("| algorithm |");
        let mut upd_md = String::from("| algorithm |");
        for &p in &self.threads {
            time_md.push_str(&format!(" p={p} |"));
            upd_md.push_str(&format!(" p={p} |"));
        }
        let sep = format!("\n|{}\n", "---|".repeat(self.threads.len() + 1));
        time_md.push_str(&sep);
        upd_md.push_str(&sep);
        time_md.push_str(&format!("| seq residual | {:.3} s (p=1) |\n", base.wall_secs));
        upd_md.push_str(&format!("| seq residual | {} (p=1) |\n", base.updates));

        for alg in &algs {
            time_md.push_str(&format!("| {} |", alg.name()));
            upd_md.push_str(&format!("| {} |", alg.name()));
            for &p in &self.threads {
                let row = self.run_cell(&mrf, &spec, alg.clone(), p)?;
                time_md.push_str(&format!(
                    " {} |",
                    if row.converged { format!("{:.3}", row.wall_secs) } else { "—".into() }
                ));
                upd_md.push_str(&format!(
                    " {} |",
                    if row.converged { format!("{}", row.updates) } else { "—".into() }
                ));
                rep.push(row);
            }
            time_md.push('\n');
            upd_md.push('\n');
        }
        rep.add_table(format!("### Execution time (s) vs threads\n\n{time_md}"));
        rep.add_table(format!("### Updates vs threads\n\n{upd_md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// §4 / Lemma 2 / Claim 4: relaxation overhead on trees — good case
    /// (uniform expansion), bad cases (path, adversarial tree).
    pub fn lemma2(&self) -> Result<Report> {
        let mut rep = Report::new(
            "lemma2",
            "Relaxation overhead on trees: good vs bad instances (§4, Appendix A)",
        );
        self.standard_notes(&mut rep);
        let n = scaled(100_000, self.scale).max(1_000);
        let specs = vec![
            ModelSpec::UniformTree { n, arity: 2 },
            ModelSpec::Tree { n },
            ModelSpec::Path { n: (n / 10).max(100) },
            ModelSpec::AdversarialTree { n },
        ];
        let mut md = String::from(
            "| instance | p | algorithm | useful | total updates | waste (%) |\n|---|---|---|---|---|---|\n",
        );
        for spec in &specs {
            let mrf = self.model(spec)?;
            for &p in &self.threads {
                for alg in [AlgorithmSpec::RelaxedResidual, AlgorithmSpec::RelaxedOptimalTree] {
                    // Optimal-tree needs a tree; all these are trees.
                    let row = self.run_cell(&mrf, spec, alg.clone(), p)?;
                    let waste = 100.0 * (row.updates.saturating_sub(row.useful_updates)) as f64
                        / row.updates.max(1) as f64;
                    md.push_str(&format!(
                        "| {} | {p} | {} | {} | {} | {:.2}% |\n",
                        spec.name(),
                        alg.name(),
                        row.useful_updates,
                        row.updates,
                        waste,
                    ));
                    rep.push(row);
                }
            }
        }
        rep.add_table(format!(
            "### Useful vs wasted updates under relaxation (Lemma 2 / Claim 4)\n\n{md}"
        ));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Locality study: relaxed residual with the partition axis off vs
    /// shard-affine, on the grid and power-law workloads — the partition
    /// speedup measured, not asserted. On a single-core CI runner the
    /// wall-clock ratios hover near 1 (see EXPERIMENTS.md §Locality);
    /// update counts confirm the schedule itself stays equivalent.
    pub fn locality(&self) -> Result<Report> {
        let mut rep = Report::new(
            "locality",
            "Shard-affine scheduling + sharded arenas vs locality-blind (partition axis)",
        );
        self.standard_notes(&mut rep);
        let grid = side(300, self.scale).max(6);
        let pl = scaled(90_000, self.scale).max(200);
        let specs = vec![
            ModelSpec::Ising { n: grid },
            ModelSpec::PowerLaw { n: pl, m: 2 },
        ];
        let axes = [PartitionSpec::Off, PartitionSpec::affine()];
        let mut md = String::from(
            "| input | p | partition | time (s) | updates | speedup vs off |\n|---|---|---|---|---|---|\n",
        );
        for spec in &specs {
            let mrf = self.model(spec)?;
            for &p in &self.threads {
                // Baseline timing is only meaningful from a converged run;
                // a timed-out baseline would fabricate a speedup (see
                // EXPERIMENTS.md §Locality).
                let mut off_secs = None;
                for axis in axes {
                    let row = self.run_cell_partitioned(
                        &mrf,
                        spec,
                        AlgorithmSpec::RelaxedResidual,
                        p,
                        axis,
                    )?;
                    let speedup = match (axis, off_secs) {
                        (PartitionSpec::Off, _) => {
                            if row.converged {
                                off_secs = Some(row.wall_secs);
                                "1.00×".to_string()
                            } else {
                                "—".into()
                            }
                        }
                        (_, Some(base)) if row.converged => {
                            format!("{:.2}×", base / row.wall_secs.max(1e-9))
                        }
                        _ => "—".into(),
                    };
                    md.push_str(&format!(
                        "| {} | {p} | {} | {} | {} | {} |\n",
                        spec.name(),
                        axis.label(),
                        if row.converged { format!("{:.3}", row.wall_secs) } else { "—".into() },
                        row.updates,
                        speedup,
                    ));
                    rep.push(row);
                }
            }
        }
        rep.add_table(format!("### Locality axis: off vs shard-affine\n\n{md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// [`Harness::run_cell`] with an explicit update-kernel axis (used by
    /// the `fused` experiment's on-vs-off sweep).
    pub fn run_cell_fused(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        alg: AlgorithmSpec,
        threads: usize,
        fused: bool,
    ) -> Result<Row> {
        let mut cfg = self.cfg(spec, alg.clone(), threads);
        cfg.fused = fused;
        eprintln!(
            "[harness] {} / {} / p={} / fused={} …",
            spec.name(),
            alg.name(),
            threads,
            if fused { "on" } else { "off" }
        );
        // Fused-on ids keep the historical form (joinable across
        // revisions); edgewise cells carry the suffix, mirroring bench.
        // The partition axis (inherited from the harness) keeps its own
        // label so these ids never collide with partition-off cells.
        let mut id = if self.partition.is_on() {
            format!("{}/{}/p{}/{}", spec.name(), alg.name(), threads, self.partition.label())
        } else {
            format!("{}/{}/p{}", spec.name(), alg.name(), threads)
        };
        if !fused {
            id.push_str("/edgewise");
        }
        if self.kernel == Kernel::Scalar {
            id.push_str("/scalar");
        }
        if self.precision.is_f32() {
            id.push_str("/f32");
        }
        self.run_cell_with(mrf, spec, alg, cfg, id)
    }

    /// [`Harness::run_cell`] with an explicit data-path kernel (used by
    /// the `simd` experiment's scalar-vs-simd sweep).
    pub fn run_cell_kernel(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        alg: AlgorithmSpec,
        threads: usize,
        kernel: Kernel,
    ) -> Result<Row> {
        let mut cfg = self.cfg(spec, alg.clone(), threads);
        cfg.kernel = kernel;
        eprintln!(
            "[harness] {} / {} / p={} / kernel={} …",
            spec.name(),
            alg.name(),
            threads,
            kernel.label()
        );
        // Simd ids keep the historical form (joinable across revisions);
        // scalar cells carry the suffix, mirroring bench. The inherited
        // axes keep their own labels (partition, and `/edgewise` when the
        // harness-wide fused axis is off) so these ids never collide with
        // differently-configured cells.
        let mut id = if self.partition.is_on() {
            format!("{}/{}/p{}/{}", spec.name(), alg.name(), threads, self.partition.label())
        } else {
            format!("{}/{}/p{}", spec.name(), alg.name(), threads)
        };
        if !self.fused {
            id.push_str("/edgewise");
        }
        if kernel == Kernel::Scalar {
            id.push_str("/scalar");
        }
        if self.precision.is_f32() {
            id.push_str("/f32");
        }
        self.run_cell_with(mrf, spec, alg, cfg, id)
    }

    /// [`Harness::run_cell`] with an explicit storage precision (used by
    /// the `precision` experiment's f64-vs-f32 sweep).
    pub fn run_cell_precision(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        alg: AlgorithmSpec,
        threads: usize,
        precision: Precision,
    ) -> Result<Row> {
        let mut cfg = self.cfg(spec, alg.clone(), threads);
        cfg.precision = precision;
        eprintln!(
            "[harness] {} / {} / p={} / precision={} …",
            spec.name(),
            alg.name(),
            threads,
            precision.label()
        );
        // f64 ids keep the historical form (the harness default arm,
        // joinable across revisions); f32 cells carry the suffix. The
        // inherited axes keep their own labels so these ids never collide
        // with differently-configured cells.
        let mut id = if self.partition.is_on() {
            format!("{}/{}/p{}/{}", spec.name(), alg.name(), threads, self.partition.label())
        } else {
            format!("{}/{}/p{}", spec.name(), alg.name(), threads)
        };
        if !self.fused {
            id.push_str("/edgewise");
        }
        if self.kernel == Kernel::Scalar {
            id.push_str("/scalar");
        }
        if precision.is_f32() {
            id.push_str("/f32");
        }
        self.run_cell_with(mrf, spec, alg, cfg, id)
    }

    /// Storage-precision A/B: relaxed residual with f32 message arenas vs
    /// the bit-frozen f64 arm, on the bandwidth-bound wide-domain
    /// workloads (LDPC 64-state constraints, q = 32 Potts) where halving
    /// the bytes per message shows up as cache reach. The speedup is
    /// measured, not asserted; the bytes column records the halved arena
    /// footprint, and update counts confirm the schedules stay comparable.
    pub fn precision_ab(&self) -> Result<Report> {
        let mut rep = Report::new(
            "precision",
            "f32 message arenas vs the bit-frozen f64 arm (storage-precision axis)",
        );
        self.standard_notes(&mut rep);
        let ldpc = scaled(30_000, self.scale).max(24);
        let grid = side(120, self.scale).max(4);
        let specs = vec![
            ModelSpec::Ldpc { n: ldpc, flip_prob: 0.07 },
            ModelSpec::Potts { n: grid, q: 32 },
        ];
        let mut md = String::from(
            "| input | p | precision | arena KiB | time (s) | updates | speedup vs f64 |\n|---|---|---|---|---|---|---|\n",
        );
        for spec in &specs {
            let mrf = self.model(spec)?;
            for &p in &self.threads {
                let mut f64_secs = None;
                for precision in [Precision::F64, Precision::F32] {
                    let row = self.run_cell_precision(
                        &mrf,
                        spec,
                        AlgorithmSpec::RelaxedResidual,
                        p,
                        precision,
                    )?;
                    let speedup = match (precision, f64_secs) {
                        (Precision::F64, _) => {
                            if row.converged {
                                f64_secs = Some(row.wall_secs);
                                "1.00×".to_string()
                            } else {
                                "—".into()
                            }
                        }
                        (Precision::F32, Some(base)) if row.converged => {
                            format!("{:.2}×", base / row.wall_secs.max(1e-9))
                        }
                        _ => "—".into(),
                    };
                    md.push_str(&format!(
                        "| {} | {p} | {} | {:.1} | {} | {} | {} |\n",
                        spec.name(),
                        precision.label(),
                        row.msg_bytes_padded as f64 / 1024.0,
                        if row.converged { format!("{:.3}", row.wall_secs) } else { "—".into() },
                        row.updates,
                        speedup,
                    ));
                    rep.push(row);
                }
            }
        }
        rep.add_table(format!("### Storage-precision axis: f32 vs f64\n\n{md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Warm arm of the `delta` experiment: converge the base instance
    /// (untimed), then resume across `delta` from the resident message
    /// state via [`RunReport::resume_delta`](crate::run::RunReport),
    /// recording the resumed run's trace under the `/delta` cell id. The
    /// returned row's `wall_secs` is the time-to-reconverge; the second
    /// value is the seeded frontier size (`tasks_touched`).
    fn run_cell_warm(
        &self,
        mrf: &Mrf,
        spec: &ModelSpec,
        threads: usize,
        delta: &EvidenceDelta,
    ) -> Result<(Row, u64)> {
        let alg = AlgorithmSpec::RelaxedResidual;
        let cfg = self.cfg(spec, alg.clone(), threads);
        eprintln!("[harness] {} / {} / p={} / delta warm …", spec.name(), alg.name(), threads);
        let id = format!("{}/{}/p{}/delta", spec.name(), alg.name(), threads);
        let recorder = TraceRecorder::new(Duration::from_millis(TRACE_TICK_MS));
        let mut rep = run_on_model_observed(&cfg, mrf.clone(), None)?;
        let base_converged = rep.stats.converged;
        rep.resume_delta(delta, Some(&recorder))?;
        self.trace_log.borrow_mut().push((id, recorder.take()));
        let m = &rep.stats.metrics.total;
        let tasks_touched = m.tasks_touched;
        let row = Row {
            model: spec.name().to_string(),
            algorithm: alg.name(),
            threads: cfg.threads,
            wall_secs: rep.stats.wall_secs,
            updates: m.updates,
            useful_updates: m.useful_updates,
            wasted_pops: m.wasted_pops,
            stale_pops: m.stale_pops,
            msg_bytes_padded: m.msg_bytes_padded,
            converged: base_converged && rep.stats.converged,
            seed: self.seed,
        };
        Ok((row, tasks_touched))
    }

    /// Incremental re-convergence A/B (the delta axis): perturb
    /// [`DELTA_FRACTION`] of the priors, then re-converge relaxed residual
    /// warm (resident state + frontier seeding) vs scratch (uniform
    /// restart on the same perturbed instance), on the locality workloads
    /// (power-law hubs, LDPC). The table reports time-to-reconverge, the
    /// warm-over-scratch speedup, and the seeded frontier size — the
    /// speedup is measured here and floored in CI on the bench delta cell.
    pub fn delta_ab(&self) -> Result<Report> {
        let mut rep = Report::new(
            "delta",
            "Warm-start re-convergence on evidence deltas vs scratch re-solve (delta axis)",
        );
        self.standard_notes(&mut rep);
        rep.note(format!("perturbed prior fraction = {DELTA_FRACTION}"));
        let pl = scaled(90_000, self.scale).max(200);
        let ldpc = scaled(30_000, self.scale).max(24);
        let specs = vec![
            ModelSpec::PowerLaw { n: pl, m: 3 },
            ModelSpec::Ldpc { n: ldpc, flip_prob: 0.07 },
        ];
        let mut md = String::from(
            "| input | p | arm | time (s) | updates | tasks touched | speedup vs scratch |\n\
             |---|---|---|---|---|---|---|\n",
        );
        for spec in &specs {
            let mrf = self.model(spec)?;
            let delta = EvidenceDelta::random_perturbation(&mrf, DELTA_FRACTION, self.seed);
            let mut perturbed = mrf.clone();
            delta.apply(&mut perturbed);
            for &p in &self.threads {
                let alg = AlgorithmSpec::RelaxedResidual;
                let cfg = self.cfg(spec, alg.clone(), p);
                let scratch_id =
                    format!("{}/{}/p{}/delta_scratch", spec.name(), alg.name(), p);
                let scratch =
                    self.run_cell_with(&perturbed, spec, alg.clone(), cfg, scratch_id)?;
                md.push_str(&format!(
                    "| {} | {p} | scratch | {} | {} | — | 1.00× |\n",
                    spec.name(),
                    if scratch.converged {
                        format!("{:.3}", scratch.wall_secs)
                    } else {
                        "—".into()
                    },
                    scratch.updates,
                ));
                let (warm, tasks_touched) = self.run_cell_warm(&mrf, spec, p, &delta)?;
                let speedup = if warm.converged && scratch.converged {
                    format!("{:.2}×", scratch.wall_secs / warm.wall_secs.max(1e-9))
                } else {
                    "—".into()
                };
                md.push_str(&format!(
                    "| {} | {p} | warm | {} | {} | {tasks_touched} | {speedup} |\n",
                    spec.name(),
                    if warm.converged { format!("{:.3}", warm.wall_secs) } else { "—".into() },
                    warm.updates,
                ));
                rep.push(scratch);
                rep.push(warm);
            }
        }
        rep.add_table(format!(
            "### Delta axis: warm re-convergence vs scratch re-solve\n\n{md}"
        ));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Data-path kernel A/B: relaxed residual with the lane-tiled SIMD
    /// kernel vs the scalar reference, on the wide-domain workloads (LDPC
    /// 64-state constraints, q = 32 Potts) where the inner `|D|`-wide
    /// loops dominate. The speedup is measured, not asserted; update
    /// counts confirm the schedule itself stays equivalent.
    pub fn simd_ab(&self) -> Result<Report> {
        let mut rep = Report::new(
            "simd",
            "Lane-tiled SIMD message data path vs scalar reference (kernel axis)",
        );
        self.standard_notes(&mut rep);
        let ldpc = scaled(30_000, self.scale).max(24);
        let grid = side(120, self.scale).max(4);
        let specs = vec![
            ModelSpec::Ldpc { n: ldpc, flip_prob: 0.07 },
            ModelSpec::Potts { n: grid, q: 32 },
        ];
        let mut md = String::from(
            "| input | p | kernel | time (s) | updates | speedup vs scalar |\n|---|---|---|---|---|---|\n",
        );
        for spec in &specs {
            let mrf = self.model(spec)?;
            for &p in &self.threads {
                let mut scalar_secs = None;
                for kernel in [Kernel::Scalar, Kernel::Simd] {
                    let row = self.run_cell_kernel(
                        &mrf,
                        spec,
                        AlgorithmSpec::RelaxedResidual,
                        p,
                        kernel,
                    )?;
                    let speedup = match (kernel, scalar_secs) {
                        (Kernel::Scalar, _) => {
                            if row.converged {
                                scalar_secs = Some(row.wall_secs);
                                "1.00×".to_string()
                            } else {
                                "—".into()
                            }
                        }
                        (Kernel::Simd, Some(base)) if row.converged => {
                            format!("{:.2}×", base / row.wall_secs.max(1e-9))
                        }
                        _ => "—".into(),
                    };
                    md.push_str(&format!(
                        "| {} | {p} | {} | {} | {} | {} |\n",
                        spec.name(),
                        kernel.label(),
                        if row.converged { format!("{:.3}", row.wall_secs) } else { "—".into() },
                        row.updates,
                        speedup,
                    ));
                    rep.push(row);
                }
            }
        }
        rep.add_table(format!("### Data-path kernel axis: simd vs scalar\n\n{md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Update-kernel A/B: relaxed residual with the node-centric fused
    /// refresh on vs the edge-wise fan-out, on the high-degree workloads
    /// (power-law hubs, LDPC constraints) where the per-node-touch cost is
    /// O(deg²) without fusion. The speedup is measured, not asserted;
    /// update counts confirm the schedule itself stays equivalent.
    pub fn fused_ab(&self) -> Result<Report> {
        let mut rep = Report::new(
            "fused",
            "Node-centric fused update kernel vs edge-wise refresh (kernel axis)",
        );
        self.standard_notes(&mut rep);
        let pl = scaled(90_000, self.scale).max(200);
        let ldpc = scaled(30_000, self.scale).max(24);
        let specs = vec![
            ModelSpec::PowerLaw { n: pl, m: 3 },
            ModelSpec::Ldpc { n: ldpc, flip_prob: 0.07 },
        ];
        let mut md = String::from(
            "| input | p | kernel | time (s) | updates | speedup vs edgewise |\n|---|---|---|---|---|---|\n",
        );
        for spec in &specs {
            let mrf = self.model(spec)?;
            for &p in &self.threads {
                let mut edgewise_secs = None;
                for fused in [false, true] {
                    let row = self.run_cell_fused(
                        &mrf,
                        spec,
                        AlgorithmSpec::RelaxedResidual,
                        p,
                        fused,
                    )?;
                    let speedup = match (fused, edgewise_secs) {
                        (false, _) => {
                            if row.converged {
                                edgewise_secs = Some(row.wall_secs);
                                "1.00×".to_string()
                            } else {
                                "—".into()
                            }
                        }
                        (true, Some(base)) if row.converged => {
                            format!("{:.2}×", base / row.wall_secs.max(1e-9))
                        }
                        _ => "—".into(),
                    };
                    md.push_str(&format!(
                        "| {} | {p} | {} | {} | {} | {} |\n",
                        spec.name(),
                        if fused { "fused" } else { "edgewise" },
                        if row.converged { format!("{:.3}", row.wall_secs) } else { "—".into() },
                        row.updates,
                        speedup,
                    ));
                    rep.push(row);
                }
            }
        }
        rep.add_table(format!("### Update-kernel axis: fused vs edgewise\n\n{md}"));
        self.drain_traces(&mut rep);
        rep.emit(&self.out_dir)?;
        Ok(rep)
    }

    /// Run everything.
    pub fn all(&self) -> Result<()> {
        self.tables_moderate()?;
        self.table3()?;
        self.table4()?;
        self.table7()?;
        self.fig2()?;
        for which in ["tree", "ising", "potts", "ldpc"] {
            self.fig_scaling(which)?;
        }
        self.lemma2()?;
        self.locality()?;
        self.fused_ab()?;
        self.simd_ab()?;
        self.precision_ab()?;
        self.delta_ab()?;
        Ok(())
    }

    fn standard_notes(&self, rep: &mut Report) {
        rep.note(format!(
            "scale = {} (1.0 = the paper's 'small' sizes: tree 10⁶, grids 300², LDPC 30k)",
            self.scale
        ));
        rep.note(format!("thread sweep = {:?}, max = {}", self.threads, self.max_threads));
        rep.note(
            "testbed: single-core container — wall-clock speedups are NOT comparable to \
             the paper's 72-core Xeon; update counts and relative algorithm behavior are. \
             See EXPERIMENTS.md.",
        );
        rep.note(format!("seed = {}, per-cell time limit = {} s", self.seed, self.time_limit));
    }
}

/// Scale a node count linearly.
fn scaled(n: usize, scale: f64) -> usize {
    ((n as f64) * scale).round() as usize
}

/// Scale a grid side so the *area* scales linearly.
fn side(n: usize, scale: f64) -> usize {
    ((n as f64) * scale.sqrt()).round() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Harness {
        Harness {
            scale: 0.0004, // tree 400, grid 6², ldpc 24
            threads: vec![1, 2],
            max_threads: 2,
            out_dir: PathBuf::from("/tmp/rbp_harness_test"),
            seed: 7,
            time_limit: 60.0,
            ..Harness::default()
        }
    }

    #[test]
    fn scaling_helpers() {
        assert_eq!(scaled(1000, 0.1), 100);
        assert_eq!(side(300, 1.0), 300);
        assert_eq!(side(300, 0.25), 150);
    }

    #[test]
    fn models_respect_scale() {
        let h = tiny();
        let m = h.models();
        assert_eq!(m.len(), 4);
        if let ModelSpec::Tree { n } = m[0] {
            assert_eq!(n, 400);
        } else {
            panic!();
        }
    }

    #[test]
    fn fig2_threads_monotone() {
        let mut h = tiny();
        h.max_threads = 8;
        let t = h.fig2_threads();
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*t.last().unwrap(), 8);
    }

    #[test]
    fn run_cell_tiny_tree() {
        let h = tiny();
        let spec = ModelSpec::Tree { n: 63 };
        let mrf = crate::model::builders::build(&spec, h.seed);
        let row = h
            .run_cell(&mrf, &spec, AlgorithmSpec::RelaxedResidual, 2)
            .unwrap();
        assert!(row.converged);
        assert!(row.updates >= 62);
    }

    #[test]
    fn delta_ab_tiny_end_to_end() {
        let h = Harness { out_dir: PathBuf::from("/tmp/rbp_harness_delta_test"), ..tiny() };
        let rep = h.delta_ab().unwrap();
        // Two models × two thread counts × {scratch, warm}.
        assert_eq!(rep.rows.len(), 8);
        let md = rep.to_markdown();
        assert!(md.contains("| warm |") && md.contains("| scratch |"));
        std::fs::remove_dir_all("/tmp/rbp_harness_delta_test").ok();
    }

    #[test]
    fn table3_tiny_end_to_end() {
        let h = tiny();
        let rep = h.table3().unwrap();
        assert!(rep.rows.len() >= 4 + 2 * 4);
        assert!(rep.to_markdown().contains("relaxed 2"));
        std::fs::remove_dir_all("/tmp/rbp_harness_test").ok();
    }
}
