//! High-level entry point: build the model, run the configured engine,
//! and package the results.

use crate::bp::{all_marginals, Messages};
use crate::configio::{Json, RunConfig};
use crate::engines::{build_engine, Engine, EngineStats};
use crate::exec::RunObserver;
use crate::model::{builders, EvidenceDelta, Mrf};
use anyhow::Result;

/// Everything a caller needs after one run.
pub struct RunReport {
    /// Engine outcome (convergence, timings, counters).
    pub stats: EngineStats,
    /// The model the run executed on.
    pub mrf: Mrf,
    /// Final message state (for marginal extraction).
    pub msgs: Messages,
    /// The configuration that produced this run.
    pub config: RunConfig,
}

impl RunReport {
    /// Node marginals from the final message state.
    pub fn marginals(&self) -> Vec<Vec<f64>> {
        all_marginals(&self.mrf, &self.msgs)
    }

    /// Re-converge in place after an evidence delta: apply `delta` to the
    /// resident model, then resume the configured engine from the current
    /// message state (no `uniform_like` reset). `stats` is replaced by the
    /// warm run's outcome — its `tasks_touched` counter records the seeded
    /// frontier size and its `wall_secs` is the time-to-reconverge.
    pub fn resume_delta(
        &mut self,
        delta: &EvidenceDelta,
        observer: Option<&dyn RunObserver>,
    ) -> Result<()> {
        delta.apply(&mut self.mrf);
        let engine = build_engine(&self.config.algorithm);
        self.stats = engine.resume(&self.mrf, &self.msgs, &self.config, delta, observer)?;
        Ok(())
    }

    /// JSON summary (without the full marginal dump).
    pub fn to_json(&self) -> Json {
        let m = &self.stats.metrics.total;
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("converged", Json::Bool(self.stats.converged)),
            ("wall_secs", Json::Num(self.stats.wall_secs)),
            ("updates", Json::Num(m.updates as f64)),
            ("useful_updates", Json::Num(m.useful_updates as f64)),
            ("wasted_pops", Json::Num(m.wasted_pops as f64)),
            ("stale_pops", Json::Num(m.stale_pops as f64)),
            ("claim_failures", Json::Num(m.claim_failures as f64)),
            ("rounds", Json::Num(m.rounds as f64)),
            ("splashes", Json::Num(m.splashes as f64)),
            ("refreshes", Json::Num(m.refreshes as f64)),
            ("insert_batches", Json::Num(m.insert_batches as f64)),
            ("tasks_touched", Json::Num(m.tasks_touched as f64)),
            ("msg_bytes_logical", Json::Num(m.msg_bytes_logical as f64)),
            ("msg_bytes_padded", Json::Num(m.msg_bytes_padded as f64)),
            (
                "updates_per_sec",
                Json::Num(if self.stats.wall_secs > 0.0 {
                    m.updates as f64 / self.stats.wall_secs
                } else {
                    0.0
                }),
            ),
            ("final_max_priority", Json::Num(self.stats.final_max_priority)),
            (
                "load_imbalance",
                Json::Num(self.stats.metrics.load_imbalance()),
            ),
        ])
    }
}

/// Build the model from `cfg`, run the configured engine on fresh uniform
/// messages, and return the report.
pub fn run_config(cfg: &RunConfig) -> Result<RunReport> {
    let mrf = builders::build(&cfg.model, cfg.seed);
    run_on_model(cfg, mrf)
}

/// Run on a pre-built model (lets sweeps reuse one instance across
/// algorithms and thread counts, as the paper's tables require).
pub fn run_on_model(cfg: &RunConfig, mrf: Mrf) -> Result<RunReport> {
    run_on_model_observed(cfg, mrf, None)
}

/// Like [`run_on_model`], attaching an optional [`RunObserver`] (e.g. a
/// `telemetry::TraceRecorder`) that samples the live run — the entry point
/// the `bench` sweeps and the harness trace emission go through.
///
/// With the locality axis on (`cfg.partition`), the message state is laid
/// out in per-shard arenas matching the run's message partition, so the
/// shard-affine scheduler's locality actually translates into cache
/// locality.
pub fn run_on_model_observed(
    cfg: &RunConfig,
    mrf: Mrf,
    observer: Option<&dyn RunObserver>,
) -> Result<RunReport> {
    let msgs = build_messages(cfg, &mrf);
    let engine = build_engine(&cfg.algorithm);
    let stats = engine.run_observed(&mrf, &msgs, cfg, observer)?;
    Ok(RunReport { stats, mrf, msgs, config: cfg.clone() })
}

/// Uniform message state laid out for the run described by `cfg`:
/// per-shard arenas matching the run's message partition when the
/// locality axis is on, the flat arena otherwise, stored at
/// `cfg.precision`. The single resolution point shared by production runs
/// and the parity/property test suites — keep them on this helper so the
/// arena layout and storage precision can never drift from the config.
pub fn build_messages(cfg: &RunConfig, mrf: &Mrf) -> Messages {
    match crate::model::partition::for_messages(mrf, cfg) {
        Some(p) => Messages::uniform_partitioned_with(mrf, &p, cfg.precision),
        None => Messages::uniform_with(mrf, cfg.precision),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::{AlgorithmSpec, ModelSpec};

    #[test]
    fn run_config_end_to_end() {
        let cfg = RunConfig::new(ModelSpec::Tree { n: 31 }, AlgorithmSpec::RelaxedResidual)
            .with_threads(2);
        let report = run_config(&cfg).unwrap();
        assert!(report.stats.converged);
        let marg = report.marginals();
        assert_eq!(marg.len(), 31);
        let j = report.to_json();
        assert_eq!(j.get("converged").unwrap().as_bool(), Some(true));
        assert!(j.get("updates").unwrap().as_f64().unwrap() >= 30.0);
    }

    #[test]
    fn reuse_model_across_algorithms() {
        let mrf = crate::model::builders::build(&ModelSpec::Ising { n: 5 }, 3);
        for alg in [AlgorithmSpec::SequentialResidual, AlgorithmSpec::Synchronous] {
            let cfg = RunConfig::new(ModelSpec::Ising { n: 5 }, alg).with_seed(3);
            let r = run_on_model(&cfg, mrf.clone()).unwrap();
            assert!(r.stats.converged);
        }
    }
}
