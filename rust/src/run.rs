//! High-level entry point: build the model, run the configured engine,
//! and package the results.

use crate::bp::{all_marginals, Messages};
use crate::configio::{Json, LoadMode, RunConfig};
use crate::engines::{build_engine, Engine, EngineStats};
use crate::exec::RunObserver;
use crate::model::{builders, EvidenceDelta, Mrf};
use crate::util::Timer;
use anyhow::Result;

/// Cold-path cost of one run — everything that happens before the solve
/// loop starts. A run either builds its model in process (`build_secs`)
/// or loads it from disk (`load_secs` + `model_bytes`); the other leg is
/// zero, as are all legs on pre-built models handed straight to
/// [`run_on_model`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrepStats {
    /// Seconds spent building the model in process.
    pub build_secs: f64,
    /// Seconds spent loading the model from disk.
    pub load_secs: f64,
    /// Seconds spent initializing the message state.
    pub init_secs: f64,
    /// Serialized model size on disk (bytes); zero for in-process builds.
    pub model_bytes: u64,
    /// The load path that actually produced the model: [`LoadMode::Map`]
    /// when sections are borrowed from a file mapping, [`LoadMode::Read`]
    /// otherwise (copying disk loads *and* in-process builds — both leave
    /// the model heap-owned).
    pub load_mode: LoadMode,
}

impl Default for PrepStats {
    fn default() -> Self {
        PrepStats {
            build_secs: 0.0,
            load_secs: 0.0,
            init_secs: 0.0,
            model_bytes: 0,
            load_mode: LoadMode::Read,
        }
    }
}

/// Everything a caller needs after one run.
pub struct RunReport {
    /// Engine outcome (convergence, timings, counters).
    pub stats: EngineStats,
    /// The model the run executed on.
    pub mrf: Mrf,
    /// Final message state (for marginal extraction).
    pub msgs: Messages,
    /// The configuration that produced this run.
    pub config: RunConfig,
    /// Cold-path timings (model build/load, message init).
    pub prep: PrepStats,
}

impl RunReport {
    /// Node marginals from the final message state.
    pub fn marginals(&self) -> Vec<Vec<f64>> {
        all_marginals(&self.mrf, &self.msgs)
    }

    /// Re-converge in place after an evidence delta: apply `delta` to the
    /// resident model, then resume the configured engine from the current
    /// message state (no `uniform_like` reset). `stats` is replaced by the
    /// warm run's outcome — its `tasks_touched` counter records the seeded
    /// frontier size and its `wall_secs` is the time-to-reconverge.
    pub fn resume_delta(
        &mut self,
        delta: &EvidenceDelta,
        observer: Option<&dyn RunObserver>,
    ) -> Result<()> {
        delta.apply(&mut self.mrf);
        let engine = build_engine(&self.config.algorithm);
        self.stats = engine.resume(&self.mrf, &self.msgs, &self.config, delta, observer)?;
        Ok(())
    }

    /// JSON summary (without the full marginal dump).
    pub fn to_json(&self) -> Json {
        let m = &self.stats.metrics.total;
        Json::obj(vec![
            ("config", self.config.to_json()),
            ("converged", Json::Bool(self.stats.converged)),
            ("wall_secs", Json::Num(self.stats.wall_secs)),
            ("updates", Json::Num(m.updates as f64)),
            ("useful_updates", Json::Num(m.useful_updates as f64)),
            ("wasted_pops", Json::Num(m.wasted_pops as f64)),
            ("stale_pops", Json::Num(m.stale_pops as f64)),
            ("claim_failures", Json::Num(m.claim_failures as f64)),
            ("rounds", Json::Num(m.rounds as f64)),
            ("splashes", Json::Num(m.splashes as f64)),
            ("refreshes", Json::Num(m.refreshes as f64)),
            ("insert_batches", Json::Num(m.insert_batches as f64)),
            ("tasks_touched", Json::Num(m.tasks_touched as f64)),
            ("msg_bytes_logical", Json::Num(m.msg_bytes_logical as f64)),
            ("msg_bytes_padded", Json::Num(m.msg_bytes_padded as f64)),
            ("build_secs", Json::Num(self.prep.build_secs)),
            ("load_secs", Json::Num(self.prep.load_secs)),
            ("init_secs", Json::Num(self.prep.init_secs)),
            ("model_bytes", Json::Num(self.prep.model_bytes as f64)),
            ("load_mode", Json::Str(self.prep.load_mode.label().into())),
            ("arena", Json::Str(self.config.arena.label().into())),
            ("peak_rss_bytes", Json::Num(m.peak_rss_bytes as f64)),
            ("boundary_msgs_sent", Json::Num(m.boundary_msgs_sent as f64)),
            ("boundary_msgs_recv", Json::Num(m.boundary_msgs_recv as f64)),
            ("boundary_bytes", Json::Num(m.boundary_bytes as f64)),
            ("exchange_batches", Json::Num(m.exchange_batches as f64)),
            ("net_wait_secs", Json::Num(m.net_wait_us as f64 / 1e6)),
            (
                "updates_per_sec",
                Json::Num(if self.stats.wall_secs > 0.0 {
                    m.updates as f64 / self.stats.wall_secs
                } else {
                    0.0
                }),
            ),
            ("final_max_priority", Json::Num(self.stats.final_max_priority)),
            (
                "load_imbalance",
                Json::Num(self.stats.metrics.load_imbalance()),
            ),
        ])
    }
}

/// Resolve a model through the optional on-disk cache ("generate once,
/// sweep many"): when `load_dir` holds this spec's
/// [`cache_slug`](crate::configio::ModelSpec::cache_slug) file, load it
/// under `mode` (zero-copy mapped for v2 files under `Map`/`Auto`, the
/// copying v1/v2 read path otherwise; `verify` gates checksum + semantic
/// validation on the map path); otherwise build from the spec and, when
/// `save_dir` is set, persist it as format v2 for the next sweep. The
/// returned [`PrepStats`] carries whichever cold-path legs were
/// exercised, plus the load path that actually produced the model.
pub fn obtain_model(
    spec: &crate::configio::ModelSpec,
    seed: u64,
    load_dir: Option<&std::path::Path>,
    save_dir: Option<&std::path::Path>,
    mode: LoadMode,
    verify: bool,
) -> Result<(Mrf, PrepStats)> {
    use crate::model::io as model_io;
    use crate::util::cold_path_threads;
    let mut prep = PrepStats::default();
    let slug = spec.cache_slug(seed);
    if let Some(dir) = load_dir {
        let path = dir.join(&slug);
        if path.exists() {
            let path = path.to_string_lossy().into_owned();
            let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            let t = Timer::start();
            let (mrf, resolved) = model_io::load_with_mode(
                &path,
                cold_path_threads((bytes / 64) as usize),
                mode,
                verify,
            )?;
            prep.load_secs = t.elapsed_secs();
            prep.model_bytes = bytes;
            prep.load_mode = resolved;
            return Ok((mrf, prep));
        }
    }
    let t = Timer::start();
    let mrf = builders::build(spec, seed);
    prep.build_secs = t.elapsed_secs();
    if let Some(dir) = save_dir {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(&slug).to_string_lossy().into_owned();
        prep.model_bytes = model_io::save(&mrf, &path)?;
    }
    Ok((mrf, prep))
}

/// Build the model from `cfg`, run the configured engine on fresh uniform
/// messages, and return the report (with `build_secs` recorded).
pub fn run_config(cfg: &RunConfig) -> Result<RunReport> {
    let t = Timer::start();
    let mrf = builders::build(&cfg.model, cfg.seed);
    let prep = PrepStats { build_secs: t.elapsed_secs(), ..Default::default() };
    run_on_model_prepped(cfg, mrf, None, prep)
}

/// Run on a pre-built model (lets sweeps reuse one instance across
/// algorithms and thread counts, as the paper's tables require).
pub fn run_on_model(cfg: &RunConfig, mrf: Mrf) -> Result<RunReport> {
    run_on_model_observed(cfg, mrf, None)
}

/// Like [`run_on_model`], attaching an optional [`RunObserver`] (e.g. a
/// `telemetry::TraceRecorder`) that samples the live run — the entry point
/// the `bench` sweeps and the harness trace emission go through.
///
/// With the locality axis on (`cfg.partition`), the message state is laid
/// out in per-shard arenas matching the run's message partition, so the
/// shard-affine scheduler's locality actually translates into cache
/// locality.
pub fn run_on_model_observed(
    cfg: &RunConfig,
    mrf: Mrf,
    observer: Option<&dyn RunObserver>,
) -> Result<RunReport> {
    run_on_model_prepped(cfg, mrf, observer, PrepStats::default())
}

/// Like [`run_on_model_observed`], threading through cold-path stats the
/// caller already accrued (model build or disk-load time). Message-init
/// time is measured here, and the run's counters are stamped with the
/// model's on-disk size so it lands in BENCH cells.
pub fn run_on_model_prepped(
    cfg: &RunConfig,
    mrf: Mrf,
    observer: Option<&dyn RunObserver>,
    mut prep: PrepStats,
) -> Result<RunReport> {
    let t = Timer::start();
    let msgs = build_messages(cfg, &mrf)?;
    prep.init_secs = t.elapsed_secs();
    let engine = build_engine(&cfg.algorithm);
    let mut stats = engine.run_observed(&mrf, &msgs, cfg, observer)?;
    stats.metrics.total.model_bytes = stats.metrics.total.model_bytes.max(prep.model_bytes);
    // Engines that never enter the worker pool (sequential, synchronous)
    // still report the process-wide peak-RSS gauge.
    stats.metrics.total.peak_rss_bytes =
        stats.metrics.total.peak_rss_bytes.max(crate::util::peak_rss_bytes());
    Ok(RunReport { stats, mrf, msgs, config: cfg.clone(), prep })
}

/// Uniform message state laid out for the run described by `cfg`:
/// per-shard arenas matching the run's message partition when the
/// locality axis is on, the flat arena otherwise, stored at
/// `cfg.precision` in `cfg.arena`-backed allocations. The single
/// resolution point shared by production runs and the parity/property
/// test suites — keep them on this helper so the arena layout, storage
/// precision, backing mode, and damping factor can never drift from the
/// config. Only the file-backed arena arm can fail (temp-file creation).
pub fn build_messages(cfg: &RunConfig, mrf: &Mrf) -> Result<Messages> {
    let mut msgs = match crate::model::partition::for_messages(mrf, cfg) {
        Some(p) => Messages::uniform_partitioned_in(mrf, &p, cfg.precision, &cfg.arena)?,
        None => Messages::uniform_in(mrf, cfg.precision, &cfg.arena)?,
    };
    msgs.set_damping(cfg.damping);
    Ok(msgs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configio::{AlgorithmSpec, ModelSpec};

    #[test]
    fn run_config_end_to_end() {
        let cfg = RunConfig::new(ModelSpec::Tree { n: 31 }, AlgorithmSpec::RelaxedResidual)
            .with_threads(2);
        let report = run_config(&cfg).unwrap();
        assert!(report.stats.converged);
        let marg = report.marginals();
        assert_eq!(marg.len(), 31);
        let j = report.to_json();
        assert_eq!(j.get("converged").unwrap().as_bool(), Some(true));
        assert!(j.get("updates").unwrap().as_f64().unwrap() >= 30.0);
    }

    #[test]
    fn reuse_model_across_algorithms() {
        let mrf = crate::model::builders::build(&ModelSpec::Ising { n: 5 }, 3);
        for alg in [AlgorithmSpec::SequentialResidual, AlgorithmSpec::Synchronous] {
            let cfg = RunConfig::new(ModelSpec::Ising { n: 5 }, alg).with_seed(3);
            let r = run_on_model(&cfg, mrf.clone()).unwrap();
            assert!(r.stats.converged);
        }
    }
}
